"""Pallas kernel: blocked Euclidean distance matrix D[b, l] = ||x_b - lm_l||.

This is the shared primitive of both the LSMDS stress loop and the OSE
objective: distances between a tile of points and a tile of landmarks are
formed through the MXU-friendly decomposition

    d^2(b, l) = ||x_b||^2 + ||lm_l||^2 - 2 <x_b, lm_l>

so that the inner product runs as a (block_b x Kp) @ (Kp x block_l) matmul on
the systolic array, instead of materialising a [B, L, K] difference tensor in
VMEM (which is what a naive port of the R `dist()` formulation would do).

Grid: (B/bb, L/bl); each program owns one output tile. Both point tiles are
staged into VMEM by BlockSpec; K is padded to a sublane multiple (zeros do
not change distances).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_util import LANE_MIN, ceil_to, pad_axis, pick_block


def _kernel(x_ref, lm_ref, o_ref):
    x = x_ref[...]  # [bb, Kp]
    lm = lm_ref[...]  # [bl, Kp]
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)  # [bb, 1]
    l2 = jnp.sum(lm * lm, axis=-1, keepdims=True).T  # [1, bl]
    cross = jax.lax.dot_general(
        x,
        lm,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [bb, bl]
    sq = jnp.maximum(x2 + l2 - 2.0 * cross, 0.0)
    o_ref[...] = jnp.sqrt(sq).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_l"))
def pairwise_dist(
    x: jnp.ndarray, lm: jnp.ndarray, *, block_b: int = 128, block_l: int = 128
) -> jnp.ndarray:
    """Distance matrix between x [B, K] and lm [L, K]; returns [B, L] f32."""
    b, k = x.shape
    l, k2 = lm.shape
    if k != k2:
        raise ValueError(f"coordinate dims differ: {k} vs {k2}")
    kp = ceil_to(k, LANE_MIN)
    bb = pick_block(b, block_b)
    bl = pick_block(l, block_l)
    bp = ceil_to(b, bb)
    lp = ceil_to(l, bl)

    xp = pad_axis(pad_axis(x.astype(jnp.float32), 1, kp), 0, bp)
    lmp = pad_axis(pad_axis(lm.astype(jnp.float32), 1, kp), 0, lp)

    out = pl.pallas_call(
        _kernel,
        grid=(bp // bb, lp // bl),
        in_specs=[
            pl.BlockSpec((bb, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bl, kp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bl), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, lp), jnp.float32),
        interpret=True,
    )(xp, lmp)
    return out[:b, :l]
