"""Pallas kernel: gradient (and per-row residual) of the LSMDS raw stress.

This is the O(N^2 K) hot spot of the landmark-embedding stage (paper Eq. 1).
For a configuration X [N, K] and dissimilarities Delta [N, N]:

    grad_i   = 2 * sum_j (d_ij - delta_ij) * (x_i - x_j) / d_ij
    sres_i   = sum_j (d_ij - delta_ij)^2          (sum = 2 * sigma_raw)

Schedule: grid (N/bi, N/bj). The j axis is the reduction axis — each (i, j)
program adds its column-block contribution into the grad/sres tiles owned by
row-block i (classic revisited-output accumulation; the j==0 program zeroes
the accumulators). Row/column tiles of X are staged in VMEM; the [bi, bj]
Delta tile streams through. The pairwise distances inside a tile use the same
MXU decomposition as `pairwise.py`; the (x_i - x_j) contraction is again a
matmul: sum_j coef_ij * (x_i - x_j) = x_i * rowsum(coef) - coef @ X_j.

The diagonal and any padding columns are masked via global iota indices
(n_real is baked statically at lowering time).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_util import LANE_MIN, ceil_to, pad_axis, pick_block

_EPS = 1e-12


def _kernel(n_real, bi, bj, xi_ref, xj_ref, delta_ref, grad_ref, sres_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)
        sres_ref[...] = jnp.zeros_like(sres_ref)

    xi = xi_ref[...]  # [bi, Kp]
    xj = xj_ref[...]  # [bj, Kp]
    delta = delta_ref[...]  # [bi, bj]

    x2 = jnp.sum(xi * xi, axis=-1, keepdims=True)
    y2 = jnp.sum(xj * xj, axis=-1, keepdims=True).T
    cross = jax.lax.dot_general(
        xi, xj, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d = jnp.sqrt(jnp.maximum(x2 + y2 - 2.0 * cross, 0.0))  # [bi, bj]

    rows = i * bi + jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 0)
    cols = j * bj + jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 1)
    valid = (rows != cols) & (cols < n_real) & (rows < n_real)

    resid = jnp.where(valid, d - delta, 0.0)
    coef = jnp.where(valid, resid / jnp.maximum(d, _EPS), 0.0)

    row = jnp.sum(coef, axis=1, keepdims=True)  # [bi, 1]
    contrib = 2.0 * (
        xi * row
        - jax.lax.dot_general(
            coef, xj, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    )
    grad_ref[...] += contrib
    sres_ref[...] += jnp.sum(resid * resid, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block",))
def stress_grad(x: jnp.ndarray, delta: jnp.ndarray, *, block: int = 256):
    """Returns (grad [N, K], row_sres [N]) for configuration x, target delta."""
    n, k = x.shape
    if delta.shape != (n, n):
        raise ValueError(f"delta shape {delta.shape} != ({n}, {n})")
    kp = ceil_to(k, LANE_MIN)
    b = pick_block(n, block)
    np_ = ceil_to(n, b)

    xp = pad_axis(pad_axis(x.astype(jnp.float32), 1, kp), 0, np_)
    dp = pad_axis(pad_axis(delta.astype(jnp.float32), 1, np_), 0, np_)

    kern = functools.partial(_kernel, n, b, b)
    grad, sres = pl.pallas_call(
        kern,
        grid=(np_ // b, np_ // b),
        in_specs=[
            pl.BlockSpec((b, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((b, kp), lambda i, j: (j, 0)),
            pl.BlockSpec((b, b), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((b, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((b, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, kp), jnp.float32),
            jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        ],
        interpret=True,
    )(xp, xp, dp)
    return grad[:n, :k], sres[:n, 0]
