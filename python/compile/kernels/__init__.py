"""L1 Pallas kernels for the paper's compute hot-spots.

- pairwise.pairwise_dist: blocked Euclidean distance matrix (shared primitive)
- stress.stress_grad:     LSMDS raw-stress gradient (Eq. 1 hot spot)
- ose.ose_grad:           batched out-of-sample objective gradient (Eq. 2)
- mlp.mlp_fwd:            fused 3-hidden-layer MLP forward (NN-OSE hot path)
- ref:                    pure-jnp oracles for all of the above

All kernels run with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); block shapes are chosen to be TPU-legal so the same code
lowers to Mosaic unchanged on real hardware.
"""

from . import ref  # noqa: F401
from .mlp import mlp_fwd  # noqa: F401
from .ose import ose_grad  # noqa: F401
from .pairwise import pairwise_dist  # noqa: F401
from .stress import stress_grad  # noqa: F401
