"""Pallas kernel: batched gradient of the out-of-sample objective (Eq. 2).

For B independent new points y_b (the only movable coordinates), L fixed
landmark embeddings lm, and measured dissimilarities delta[b, i]:

    sigma_hat(y_b) = sum_i (||lm_i - y_b|| - delta_bi)^2
    grad_b         = 2 * sum_i (d_bi - delta_bi) * (y_b - lm_i) / d_bi

Schedule: grid (B/bb, L/bl) with the landmark axis as the revisited-output
reduction axis (same pattern as `stress.py`). Each program computes one
[bb, bl] distance tile via the MXU decomposition and folds its contribution
into the [bb, Kp] gradient accumulator. Padding landmarks are masked by a
statically baked l_real.

This kernel is what makes the "optimisation method" batched: the paper's R
implementation moves one point at a time through `optim`; here a whole batch
of independent Eq.-2 problems shares each landmark tile fetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_util import LANE_MIN, ceil_to, pad_axis, pick_block

_EPS = 1e-12


def _kernel(l_real, bl, y_ref, lm_ref, delta_ref, grad_ref, sres_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)
        sres_ref[...] = jnp.zeros_like(sres_ref)

    y = y_ref[...]  # [bb, Kp]
    lm = lm_ref[...]  # [bl, Kp]
    delta = delta_ref[...]  # [bb, bl]

    y2 = jnp.sum(y * y, axis=-1, keepdims=True)
    l2 = jnp.sum(lm * lm, axis=-1, keepdims=True).T
    cross = jax.lax.dot_general(
        y, lm, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d = jnp.sqrt(jnp.maximum(y2 + l2 - 2.0 * cross, 0.0))  # [bb, bl]

    cols = j * bl + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    valid = cols < l_real

    resid = jnp.where(valid, d - delta, 0.0)
    coef = jnp.where(valid, resid / jnp.maximum(d, _EPS), 0.0)

    row = jnp.sum(coef, axis=1, keepdims=True)
    grad_ref[...] += 2.0 * (
        y * row
        - jax.lax.dot_general(
            coef, lm, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    )
    sres_ref[...] += jnp.sum(resid * resid, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_b", "block_l"))
def ose_grad(
    y: jnp.ndarray,
    lm: jnp.ndarray,
    delta: jnp.ndarray,
    *,
    block_b: int = 128,
    block_l: int = 512,
):
    """Returns (grad [B, K], sres [B]) of Eq. 2 for each batched point."""
    b, k = y.shape
    l, k2 = lm.shape
    if k != k2:
        raise ValueError(f"coordinate dims differ: {k} vs {k2}")
    if delta.shape != (b, l):
        raise ValueError(f"delta shape {delta.shape} != ({b}, {l})")
    kp = ceil_to(k, LANE_MIN)
    bb = pick_block(b, block_b)
    bl = pick_block(l, block_l)
    bp = ceil_to(b, bb)
    lp = ceil_to(l, bl)

    yp = pad_axis(pad_axis(y.astype(jnp.float32), 1, kp), 0, bp)
    lmp = pad_axis(pad_axis(lm.astype(jnp.float32), 1, kp), 0, lp)
    dp = pad_axis(pad_axis(delta.astype(jnp.float32), 1, lp), 0, bp)

    kern = functools.partial(_kernel, l, bl)
    grad, sres = pl.pallas_call(
        kern,
        grid=(bp // bb, lp // bl),
        in_specs=[
            pl.BlockSpec((bb, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bl, kp), lambda i, j: (j, 0)),
            pl.BlockSpec((bb, bl), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, kp), jnp.float32),
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        ],
        interpret=True,
    )(yp, lmp, dp)
    return grad[:b, :k], sres[:b, 0]
