"""Shared helpers for the Pallas kernels: padding and tiling arithmetic.

TPU tiling note (DESIGN.md §Hardware-Adaptation): the embedding dimension of
this paper is tiny (K = 7), far below the 128-lane VPU width, so every kernel
pads K up to `LANE_MIN` sublanes and keeps the *point* dimension as the tiled
axis. Interpret mode does not enforce tile alignment, but we keep the layout
TPU-legal so the same BlockSpecs lower to Mosaic unchanged.
"""

from __future__ import annotations

import jax.numpy as jnp

# Minimal padding multiple for the trailing (lane) axis. Real TPU fp32 tiles
# are (8, 128); we pad the coordinate axis to 8 which keeps VMEM cost ~zero
# for K=7 while remaining a legal sublane multiple.
LANE_MIN = 8


def ceil_to(value: int, multiple: int) -> int:
    """Smallest multiple of `multiple` that is >= value (and >= multiple)."""
    if value <= 0:
        return multiple
    return ((value + multiple - 1) // multiple) * multiple


def pad_axis(a: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    """Zero-pad `a` along `axis` up to length `target` (no-op if equal)."""
    cur = a.shape[axis]
    if cur == target:
        return a
    if cur > target:
        raise ValueError(f"cannot pad axis {axis} from {cur} down to {target}")
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, target - cur)
    return jnp.pad(a, widths)


def pick_block(n: int, preferred: int) -> int:
    """Block size for a padded axis: the preferred tile, shrunk for tiny n.

    Keeps the grid non-trivial for test-sized inputs while using full tiles
    for production shapes.
    """
    if n >= preferred:
        return preferred
    return max(LANE_MIN, ceil_to(n, LANE_MIN))
