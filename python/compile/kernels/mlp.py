"""Pallas kernel: fused 3-hidden-layer ReLU MLP forward (the NN-OSE hot path).

The paper's neural OSE maps a distance vector delta in R^L to coordinates in
R^K through an MLP with three hidden layers (Sec. 4.2). At serving time this
is the entire per-query compute, so instead of four library matmuls with
three intermediate HBM round-trips we fuse the whole chain into one kernel:

    grid over batch tiles; ALL weight matrices are pinned in VMEM
    (index_map is constant in the grid index, so Mosaic hoists the copies
    out of the loop). At the paper's largest setting (L = 2100, H = 256/128/64,
    K = 7 -> padded 8) the resident weights are
        2100*256 + 256*128 + 128*64 + 64*8 floats ~= 2.3 MB fp32,
    comfortably inside a TensorCore's ~16 MB VMEM, leaving room for the
    [bb, L] activation tile.

Intermediate activations live in registers/VMEM scratch for the lifetime of
a batch tile — nothing but the input tile and the [bb, K] result touches HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_util import LANE_MIN, ceil_to, pad_axis, pick_block


def _matmul(a, b):
    return jax.lax.dot_general(
        a, b, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _kernel(d_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
            w4_ref, b4_ref, o_ref):
    h = jnp.maximum(_matmul(d_ref[...], w1_ref[...]) + b1_ref[...], 0.0)
    h = jnp.maximum(_matmul(h, w2_ref[...]) + b2_ref[...], 0.0)
    h = jnp.maximum(_matmul(h, w3_ref[...]) + b3_ref[...], 0.0)
    o_ref[...] = _matmul(h, w4_ref[...]) + b4_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b",))
def mlp_fwd(d: jnp.ndarray, params, *, block_b: int = 256) -> jnp.ndarray:
    """Fused forward: d [B, L] -> [B, K].

    params = (w1 [L,H1], b1 [H1], w2 [H1,H2], b2 [H2], w3 [H2,H3], b3 [H3],
              w4 [H3,K], b4 [K]).
    """
    w1, b1, w2, b2, w3, b3, w4, b4 = params
    b, l = d.shape
    if w1.shape[0] != l:
        raise ValueError(f"w1 rows {w1.shape[0]} != input width {l}")
    h1, h2, h3 = w1.shape[1], w2.shape[1], w3.shape[1]
    k = w4.shape[1]

    lp = ceil_to(l, LANE_MIN)
    kp = ceil_to(k, LANE_MIN)
    bb = pick_block(b, block_b)
    bp = ceil_to(b, bb)

    f32 = jnp.float32
    dp = pad_axis(pad_axis(d.astype(f32), 1, lp), 0, bp)
    w1p = pad_axis(w1.astype(f32), 0, lp)
    w4p = pad_axis(w4.astype(f32), 1, kp)
    b4p = pad_axis(b4.astype(f32).reshape(1, -1), 1, kp)

    def full(shape):
        # Weight blocks: the whole array every grid step (constant index_map).
        return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    out = pl.pallas_call(
        _kernel,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, lp), lambda i: (i, 0)),
            full((lp, h1)),
            full((1, h1)),
            full((h1, h2)),
            full((1, h2)),
            full((h2, h3)),
            full((1, h3)),
            full((h3, kp)),
            full((1, kp)),
        ],
        out_specs=pl.BlockSpec((bb, kp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, kp), f32),
        interpret=True,
    )(
        dp,
        w1p,
        b1.astype(f32).reshape(1, -1),
        w2.astype(f32),
        b2.astype(f32).reshape(1, -1),
        w3.astype(f32),
        b3.astype(f32).reshape(1, -1),
        w4p,
        b4p,
    )
    return out[:b, :k]
