"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth the pytest suite checks the kernels against, and
they double as the forward implementations used inside differentiated L2
graphs (Pallas interpret-mode kernels are not differentiable without a custom
VJP, so `mlp_train_step` traces the reference forward; the fused kernel is
the *inference* hot path).

All functions are shape-polymorphic and operate on float32 unless stated.
"""

from __future__ import annotations

import jax.numpy as jnp

# Numerical floor used wherever we divide by a pairwise distance. The stress
# gradient has a removable singularity at d == 0 (the subgradient 0 is valid);
# clamping the denominator reproduces the convention of SMACOF/R `smacof`.
EPS = 1e-12


def pairwise_dist(x: jnp.ndarray, lm: jnp.ndarray) -> jnp.ndarray:
    """Euclidean distance matrix D[b, l] = ||x_b - lm_l||_2.

    x:  [B, K] query/batch coordinates
    lm: [L, K] landmark coordinates
    """
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)  # [B, 1]
    l2 = jnp.sum(lm * lm, axis=-1, keepdims=True).T  # [1, L]
    cross = x @ lm.T  # [B, L]
    sq = jnp.maximum(x2 + l2 - 2.0 * cross, 0.0)
    return jnp.sqrt(sq)


def stress_and_grad(x: jnp.ndarray, delta: jnp.ndarray):
    """Raw stress and its gradient for a full configuration (LSMDS, Eq. 1).

    sigma_raw(X) = sum_{i<j} (d_ij - delta_ij)^2
    grad_i       = 2 * sum_j (d_ij - delta_ij) * (x_i - x_j) / d_ij

    x:     [N, K] configuration
    delta: [N, N] dissimilarities (symmetric, zero diagonal)
    Returns (grad [N, K], row_sres [N]) where sum(row_sres) == 2 * sigma_raw
    (each unordered pair counted twice).
    """
    d = pairwise_dist(x, x)  # [N, N]
    n = x.shape[0]
    eye = jnp.eye(n, dtype=bool)
    resid = jnp.where(eye, 0.0, d - delta)  # [N, N]
    coef = resid / jnp.maximum(d, EPS)  # [N, N]
    coef = jnp.where(eye, 0.0, coef)
    # grad_i = 2 * ( x_i * sum_j coef_ij - sum_j coef_ij x_j )
    row = jnp.sum(coef, axis=1, keepdims=True)  # [N, 1]
    grad = 2.0 * (x * row - coef @ x)  # [N, K]
    row_sres = jnp.sum(resid * resid, axis=1)  # [N]
    return grad, row_sres


def ose_objective_and_grad(y: jnp.ndarray, lm: jnp.ndarray, delta: jnp.ndarray):
    """Objective/gradient of the per-point OSE problem (paper Eq. 2), batched.

    sigma_hat(y_b) = sum_i (||lm_i - y_b|| - delta_bi)^2
    grad_b         = 2 * sum_i (d_bi - delta_bi) * (y_b - lm_i) / d_bi

    y:     [B, K] candidate embeddings (the only movable points)
    lm:    [L, K] fixed landmark embeddings
    delta: [B, L] dissimilarities from each new object to each landmark
    Returns (grad [B, K], sres [B]).
    """
    d = pairwise_dist(y, lm)  # [B, L]
    resid = d - delta
    coef = resid / jnp.maximum(d, EPS)  # [B, L]
    row = jnp.sum(coef, axis=1, keepdims=True)  # [B, 1]
    grad = 2.0 * (y * row - coef @ lm)  # [B, K]
    sres = jnp.sum(resid * resid, axis=1)  # [B]
    return grad, sres


def mlp_fwd(d: jnp.ndarray, params) -> jnp.ndarray:
    """3-hidden-layer ReLU MLP f_theta: R^L -> R^K (paper Sec. 4.2).

    d:      [B, L] distances-to-landmarks input
    params: tuple (w1, b1, w2, b2, w3, b3, w4, b4) with
            w1 [L,H1], w2 [H1,H2], w3 [H2,H3], w4 [H3,K]
    """
    w1, b1, w2, b2, w3, b3, w4, b4 = params
    h = jnp.maximum(d @ w1 + b1, 0.0)
    h = jnp.maximum(h @ w2 + b2, 0.0)
    h = jnp.maximum(h @ w3 + b3, 0.0)
    return h @ w4 + b4


def mae_loss(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 3: mean over the batch of the Euclidean residual norm."""
    sq = jnp.sum((pred - target) ** 2, axis=-1)
    return jnp.mean(jnp.sqrt(sq + EPS))
