"""L2: the paper's compute graphs in JAX, built on the L1 Pallas kernels.

Four graph families are lowered by `aot.py` and executed from Rust:

- `lsmds_steps`    — T gradient-descent steps on the raw stress (Eq. 1) of a
                     full configuration. With lr = 1/(2N) on a centred
                     configuration a step *is* the unweighted SMACOF/Guttman
                     transform (see note below), so one artifact family covers
                     both the paper's GD-LSMDS and the De Leeuw baseline.
- `ose_opt`        — the paper's optimisation OSE (Eq. 2): T GD steps on a
                     batch of independent single-point problems, landmarks
                     fixed. lr = 1/(2L) likewise recovers the majorization
                     update, which descends monotonically without tuning.
- `mlp_fwd_infer`  — the NN-OSE serving path, the fused Pallas MLP kernel.
- `mlp_train_step` — one Adam minibatch step on the Eq.-3 loss (mean
                     Euclidean residual norm). Traces the *reference* forward
                     (interpret-mode Pallas has no VJP); XLA fuses it fine and
                     the fused kernel remains the inference hot path.

GD <-> SMACOF equivalence used above: for raw stress with unit weights the
Guttman transform of a centred configuration equals X - grad/(2N); for the
single-movable-point objective (Eq. 2) it equals y - grad/(2L). We verify
both identities in the pytest suite rather than trusting the algebra.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import mlp_fwd, ose_grad, stress_grad
from .kernels import ref

# ---------------------------------------------------------------------------
# LSMDS: landmark/reference embedding (paper Sec. 2.1)
# ---------------------------------------------------------------------------


def lsmds_steps(x, delta, lr, *, steps: int, block: int = 256):
    """Run `steps` GD iterations on sigma_raw(X); returns (X', sigma_raw).

    x:     [N, K] current configuration
    delta: [N, N] dissimilarity targets
    lr:    scalar step size. 1/(2N) == SMACOF; the Rust driver owns policy.

    The returned sigma_raw is the stress of the configuration *before* the
    last update (the value the final gradient was computed at), which is what
    a convergence check wants.
    """

    def body(_, carry):
        xc, _ = carry
        grad, sres = stress_grad(xc, delta, block=block)
        sigma = 0.5 * jnp.sum(sres)
        return xc - lr * grad, sigma

    x0 = x.astype(jnp.float32)
    xf, sigma = jax.lax.fori_loop(0, steps, body, (x0, jnp.float32(0.0)))
    return xf, sigma


def normalized_stress(x, delta):
    """sigma = sqrt(sigma_raw / sum_{i<j} delta_ij^2) (paper Sec. 2.1)."""
    d = ref.pairwise_dist(x, x)
    n = x.shape[0]
    mask = ~jnp.eye(n, dtype=bool)
    num = jnp.sum(jnp.where(mask, (d - delta) ** 2, 0.0)) / 2.0
    den = jnp.sum(jnp.where(mask, delta * delta, 0.0)) / 2.0
    return jnp.sqrt(num / jnp.maximum(den, 1e-30))


# ---------------------------------------------------------------------------
# Optimisation OSE (paper Sec. 4.1, Eq. 2)
# ---------------------------------------------------------------------------


def ose_opt(xl, d, y0, lr, *, steps: int, block_b: int = 128, block_l: int = 512):
    """T GD steps on a batch of Eq.-2 problems; returns (Y*, sres[B]).

    xl: [L, K] fixed landmark embedding
    d:  [B, L] dissimilarities new-object -> landmarks
    y0: [B, K] initial guesses (paper uses zeros)
    lr: scalar; 1/(2L) == per-point majorization (monotone)
    Returned sres is Eq. 2 evaluated at the *final* iterate.
    """

    def body(_, y):
        grad, _ = ose_grad(y, xl, d, block_b=block_b, block_l=block_l)
        return y - lr * grad

    yf = jax.lax.fori_loop(0, steps, body, y0.astype(jnp.float32))
    _, sres = ose_grad(yf, xl, d, block_b=block_b, block_l=block_l)
    return yf, sres


# ---------------------------------------------------------------------------
# Neural-network OSE (paper Sec. 4.2)
# ---------------------------------------------------------------------------

N_PARAMS = 8  # w1 b1 w2 b2 w3 b3 w4 b4


def mlp_fwd_infer(d, *params, block_b: int = 256):
    """Serving path: fused Pallas forward. d [B, L] -> coords [B, K]."""
    return mlp_fwd(d, tuple(params), block_b=block_b)


def _loss(params, d, x):
    pred = ref.mlp_fwd(d, params)
    return ref.mae_loss(pred, x)


def mlp_train_step(*args):
    """One Adam step on the Eq.-3 loss.

    args = (w1,b1,...,b4, m1,...,m8, v1,...,v8, t, d, x, lr)
           |---- 8 ----|  |-- 8 --|  |-- 8 --|
    t:  scalar f32 step count *before* this update (0 on the first call)
    d:  [B, L] inputs; x: [B, K] labels; lr: scalar
    Returns (new_params..., new_m..., new_v..., t+1, loss) — 26 outputs.

    Adam with the standard bias correction (Kingma & Ba; paper Sec. 4.2 uses
    Keras defaults, which we mirror: beta1=0.9, beta2=0.999, eps=1e-7).
    """
    params = tuple(args[0:8])
    m = tuple(args[8:16])
    v = tuple(args[16:24])
    t, d, x, lr = args[24], args[25], args[26], args[27]

    beta1, beta2, eps = 0.9, 0.999, 1e-7
    loss, grads = jax.value_and_grad(_loss)(params, d, x)
    t1 = t + 1.0
    bc1 = 1.0 - beta1**t1
    bc2 = 1.0 - beta2**t1

    new_p, new_m, new_v = [], [], []
    for p, mi, vi, g in zip(params, m, v, grads):
        mi = beta1 * mi + (1.0 - beta1) * g
        vi = beta2 * vi + (1.0 - beta2) * (g * g)
        step = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
        new_p.append(p - step)
        new_m.append(mi)
        new_v.append(vi)

    return (*new_p, *new_m, *new_v, t1, loss)


def mlp_loss(*args):
    """Eq.-3 loss only (validation): args = (w1..b4, d, x) -> scalar."""
    params = tuple(args[0:8])
    d, x = args[8], args[9]
    return _loss(params, d, x)
