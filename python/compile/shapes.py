"""Shape-variant registry: every AOT artifact this repo ships.

Each variant pins the static dimensions of one graph (PJRT executables are
shape-monomorphic). The registry is grouped into *scales*:

- smoke: tiny shapes, always built; used by Rust integration tests.
- small: the quick-CI experiment protocol (N=1200 reference points,
         m=200 out-of-sample, L swept over 8 values).
- paper: the paper's protocol (N=5000/m=500, L in [100, 2100], K=7).

`make artifacts` builds all three (lowering is cheap — a few seconds);
`python -m compile.aot --scales smoke,small` trims if needed.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

K_DIM = 7  # paper Sec. 5.3: K = 7 recommended by [4] for name strings

# Hidden sizes (paper: "estimates of the intrinsic dimension of the previous
# layers"; we follow the conventional pyramid used with Keras defaults).
HIDDEN = (256, 128, 64)
HIDDEN_SMOKE = (32, 16, 8)

# Landmark sweeps driving Figures 1-4.
L_SWEEP_SMALL = [50, 100, 200, 300, 400, 600, 800, 1000]
L_SWEEP_PAPER = [100, 300, 500, 700, 900, 1100, 1300, 1500, 1800, 2100]

N_REF_SMALL = 1200
N_REF_PAPER = 5000

OSE_BATCHES = [1, 64, 256]  # single-query latency path + batched serving
TRAIN_BATCH = 256
OSE_STEPS = 60  # inner GD iterations per ose_opt call
LSMDS_STEPS = 10  # GD iterations per lsmds_steps call (Rust loops + checks)


@dataclass(frozen=True)
class Variant:
    graph: str  # lsmds_steps | ose_opt | mlp_fwd | mlp_train_step | mlp_loss
    dims: Dict[str, int]  # static dims, e.g. {"N":.., "K":.., "T":..}
    scale: str

    @property
    def key(self) -> str:
        parts = [f"{k}{v}" for k, v in sorted(self.dims.items())]
        return f"{self.graph}__" + "_".join(parts)

    @property
    def filename(self) -> str:
        return f"{self.key}.hlo.txt"


def _nn_dims(l: int, hidden: Tuple[int, int, int], b: int) -> Dict[str, int]:
    h1, h2, h3 = hidden
    return {"L": l, "K": K_DIM, "B": b, "H1": h1, "H2": h2, "H3": h3}


def _scale_variants(scale: str, l_sweep: List[int], n_ref: int,
                    hidden: Tuple[int, int, int]) -> List[Variant]:
    out: List[Variant] = []
    # Reference/full LSMDS embedding (creates the initial configuration).
    out.append(Variant("lsmds_steps",
                       {"N": n_ref, "K": K_DIM, "T": LSMDS_STEPS}, scale))
    # Landmark-only LSMDS for the two-stage scaling pipeline.
    for l in {l_sweep[1], l_sweep[3], l_sweep[-1]}:
        out.append(Variant("lsmds_steps",
                           {"N": l, "K": K_DIM, "T": LSMDS_STEPS}, scale))
    for l in l_sweep:
        for b in OSE_BATCHES:
            out.append(Variant(
                "ose_opt",
                {"L": l, "K": K_DIM, "B": b, "T": OSE_STEPS}, scale))
            out.append(Variant("mlp_fwd", _nn_dims(l, hidden, b), scale))
        out.append(Variant("mlp_train_step",
                           _nn_dims(l, hidden, TRAIN_BATCH), scale))
        out.append(Variant("mlp_loss",
                           _nn_dims(l, hidden, TRAIN_BATCH), scale))
    return out


def variants_for_scales(scales: List[str]) -> List[Variant]:
    out: List[Variant] = []
    if "smoke" in scales:
        out += [
            Variant("lsmds_steps", {"N": 64, "K": K_DIM, "T": 5}, "smoke"),
            Variant("ose_opt", {"L": 32, "K": K_DIM, "B": 8, "T": 5}, "smoke"),
            Variant("mlp_fwd", _nn_dims(32, HIDDEN_SMOKE, 8), "smoke"),
            Variant("mlp_train_step", _nn_dims(32, HIDDEN_SMOKE, 16), "smoke"),
            Variant("mlp_loss", _nn_dims(32, HIDDEN_SMOKE, 16), "smoke"),
        ]
    if "small" in scales:
        out += _scale_variants("small", L_SWEEP_SMALL, N_REF_SMALL, HIDDEN)
    if "paper" in scales:
        out += _scale_variants("paper", L_SWEEP_PAPER, N_REF_PAPER, HIDDEN)
    # de-dup (the same dims can appear in several scales)
    seen, uniq = set(), []
    for v in out:
        if v.key not in seen:
            seen.add(v.key)
            uniq.append(v)
    return uniq


DEFAULT_SCALES = ["smoke", "small", "paper"]
ALL_SCALES = ["smoke", "small", "paper"]
