"""AOT lowering: every shape variant in `shapes.py` -> HLO text + manifest.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the runtime's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run as:  cd python && python -m compile.aot --out ../artifacts [--scales all]
The Rust runtime discovers artifacts exclusively through manifest.json.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, shapes

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), F32)


def _nn_param_specs(dims):
    l, k = dims["L"], dims["K"]
    h1, h2, h3 = dims["H1"], dims["H2"], dims["H3"]
    return [
        ("w1", _spec(l, h1)), ("b1", _spec(h1)),
        ("w2", _spec(h1, h2)), ("b2", _spec(h2)),
        ("w3", _spec(h2, h3)), ("b3", _spec(h3)),
        ("w4", _spec(h3, k)), ("b4", _spec(k)),
    ]


def build_fn_and_args(variant: shapes.Variant):
    """Returns (callable, [(arg_name, ShapeDtypeStruct), ...])."""
    d = variant.dims
    g = variant.graph
    if g == "lsmds_steps":
        n, k, t = d["N"], d["K"], d["T"]
        fn = functools.partial(model.lsmds_steps, steps=t)
        args = [("x", _spec(n, k)), ("delta", _spec(n, n)), ("lr", _spec())]
    elif g == "ose_opt":
        l, k, b, t = d["L"], d["K"], d["B"], d["T"]
        fn = functools.partial(model.ose_opt, steps=t)
        args = [("xl", _spec(l, k)), ("d", _spec(b, l)),
                ("y0", _spec(b, k)), ("lr", _spec())]
    elif g == "mlp_fwd":
        fn = model.mlp_fwd_infer
        args = [("d", _spec(d["B"], d["L"]))] + _nn_param_specs(d)
    elif g == "mlp_train_step":
        fn = model.mlp_train_step
        p = _nn_param_specs(d)
        args = (p
                + [(f"m_{name}", spec) for name, spec in p]
                + [(f"v_{name}", spec) for name, spec in p]
                + [("t", _spec()),
                   ("d", _spec(d["B"], d["L"])),
                   ("x", _spec(d["B"], d["K"])),
                   ("lr", _spec())])
    elif g == "mlp_loss":
        fn = model.mlp_loss
        args = _nn_param_specs(d) + [("d", _spec(d["B"], d["L"])),
                                     ("x", _spec(d["B"], d["K"]))]
    else:
        raise ValueError(f"unknown graph {g}")
    return fn, args


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True: the Rust
    side unwraps the single tuple result)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant: shapes.Variant, out_dir: str) -> dict:
    fn, named_args = build_fn_and_args(variant)
    arg_specs = [s for _, s in named_args]
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, variant.filename)
    with open(path, "w") as f:
        f.write(text)

    out_shapes = jax.eval_shape(fn, *arg_specs)
    flat, _ = jax.tree_util.tree_flatten(out_shapes)
    return {
        "name": variant.key,
        "graph": variant.graph,
        "scale": variant.scale,
        "file": variant.filename,
        "dims": variant.dims,
        "args": [
            {"name": n, "shape": list(s.shape), "dtype": "f32"}
            for n, s in named_args
        ],
        "outputs": [
            {"shape": list(s.shape), "dtype": "f32"} for s in flat
        ],
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--scales", default=",".join(shapes.DEFAULT_SCALES),
                    help="comma list of smoke,small,paper or 'all'")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the artifact file already exists")
    args = ap.parse_args()

    scales = (shapes.ALL_SCALES if args.scales == "all"
              else [s.strip() for s in args.scales.split(",") if s.strip()])
    variants = shapes.variants_for_scales(scales)
    os.makedirs(args.out, exist_ok=True)

    manifest_path = os.path.join(args.out, "manifest.json")
    existing: dict = {}
    if os.path.exists(manifest_path) and not args.force:
        with open(manifest_path) as f:
            for entry in json.load(f).get("artifacts", []):
                existing[entry["name"]] = entry

    entries = []
    t_start = time.time()
    for i, v in enumerate(variants):
        path = os.path.join(args.out, v.filename)
        if not args.force and v.key in existing and os.path.exists(path):
            entries.append(existing[v.key])
            continue
        t0 = time.time()
        entries.append(lower_variant(v, args.out))
        print(f"[{i + 1}/{len(variants)}] {v.key}  "
              f"({time.time() - t0:.1f}s)", flush=True)

    # keep entries from other scales that are already on disk
    for name, entry in existing.items():
        if name not in {e["name"] for e in entries} and os.path.exists(
                os.path.join(args.out, entry["file"])):
            entries.append(entry)

    manifest = {
        "version": 1,
        "generator": "compile/aot.py",
        "k_dim": shapes.K_DIM,
        "hidden": list(shapes.HIDDEN),
        "artifacts": sorted(entries, key=lambda e: e["name"]),
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest in "
          f"{time.time() - t_start:.1f}s -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
