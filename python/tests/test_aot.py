"""AOT pipeline: variant registry sanity, lowering round-trip, manifest."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model, shapes

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_variant_keys_unique_and_stable():
    vs = shapes.variants_for_scales(shapes.ALL_SCALES)
    keys = [v.key for v in vs]
    assert len(keys) == len(set(keys))
    # key format round-trips the dims deterministically
    v = shapes.Variant("ose_opt", {"L": 100, "K": 7, "B": 64, "T": 60}, "x")
    assert v.key == "ose_opt__B64_K7_L100_T60"
    assert v.filename.endswith(".hlo.txt")


def test_every_scale_has_all_graph_families():
    for scale in ["small", "paper"]:
        vs = [v for v in shapes.variants_for_scales([scale])]
        graphs = {v.graph for v in vs}
        assert graphs == {"lsmds_steps", "ose_opt", "mlp_fwd",
                          "mlp_train_step", "mlp_loss"}


def test_build_fn_and_args_signature_consistency():
    for v in shapes.variants_for_scales(["smoke"]):
        fn, named = aot.build_fn_and_args(v)
        out = jax.eval_shape(fn, *[s for _, s in named])
        flat, _ = jax.tree_util.tree_flatten(out)
        assert len(flat) >= 1
        if v.graph == "mlp_train_step":
            assert len(named) == 28
            assert len(flat) == 26
        if v.graph == "ose_opt":
            b, k = v.dims["B"], v.dims["K"]
            assert tuple(flat[0].shape) == (b, k)


def test_lower_smoke_variant_produces_parseable_hlo(tmp_path):
    v = shapes.Variant("ose_opt", {"L": 16, "K": 7, "B": 4, "T": 2}, "test")
    entry = aot.lower_variant(v, str(tmp_path))
    text = (tmp_path / v.filename).read_text()
    assert "HloModule" in text
    assert "ENTRY" in text
    # ids in the text must be 32-bit safe for xla_extension 0.5.1
    assert entry["args"][0]["name"] == "xl"
    assert entry["outputs"][0]["shape"] == [4, 7]


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_consistent_with_disk():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    assert man["k_dim"] == shapes.K_DIM
    names = set()
    for e in man["artifacts"]:
        assert e["name"] not in names
        names.add(e["name"])
        assert os.path.exists(os.path.join(ART_DIR, e["file"])), e["file"]
        assert e["graph"] in {"lsmds_steps", "ose_opt", "mlp_fwd",
                              "mlp_train_step", "mlp_loss"}
        for a in e["args"]:
            assert a["dtype"] == "f32"
            assert all(isinstance(x, int) and x >= 0 for x in a["shape"])


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_covers_smoke_scale():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    names = {e["name"] for e in man["artifacts"]}
    for v in shapes.variants_for_scales(["smoke"]):
        assert v.key in names, f"missing smoke artifact {v.key}"


def test_train_step_graph_executes_like_eager():
    """Lowered-and-compiled train step == eager python call (same numerics)."""
    v = shapes.variants_for_scales(["smoke"])
    train = [x for x in v if x.graph == "mlp_train_step"][0]
    fn, named = aot.build_fn_and_args(train)
    rng = np.random.default_rng(0)
    args = [np.asarray(rng.normal(size=s.shape), np.float32) * 0.1
            for _, s in named]
    eager = fn(*args)
    jitted = jax.jit(fn)(*args)
    for a, b in zip(jax.tree_util.tree_leaves(eager),
                    jax.tree_util.tree_leaves(jitted)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
