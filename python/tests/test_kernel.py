"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

No `hypothesis` in this image, so coverage comes from dense
`pytest.mark.parametrize` sweeps over shapes (aligned, ragged, degenerate),
block sizes (dividing and non-dividing), seeds, and data regimes
(coincident points, zero dissimilarities, large magnitudes).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import mlp_fwd, ose_grad, pairwise_dist, ref, stress_grad

RTOL = 1e-5
ATOL = 1e-4


def rnd(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


def sym_delta(rng, n, scale=1.0):
    d = np.abs(rng.normal(size=(n, n))).astype(np.float32) * scale
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    return d


# ---------------------------------------------------------------------------
# pairwise_dist
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,l,k", [
    (1, 1, 1), (2, 3, 7), (8, 8, 8), (16, 16, 7), (37, 53, 7),
    (64, 128, 7), (100, 100, 3), (128, 64, 16), (5, 200, 2), (200, 5, 2),
])
@pytest.mark.parametrize("seed", [0, 1])
def test_pairwise_matches_ref(b, l, k, seed):
    rng = np.random.default_rng(seed)
    x, lm = rnd(rng, b, k), rnd(rng, l, k)
    got = np.asarray(pairwise_dist(x, lm, block_b=16, block_l=16))
    want = np.asarray(ref.pairwise_dist(jnp.asarray(x), jnp.asarray(lm)))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("block_b,block_l", [(8, 8), (16, 32), (128, 128), (256, 512)])
def test_pairwise_block_size_invariance(block_b, block_l):
    rng = np.random.default_rng(2)
    x, lm = rnd(rng, 45, 7), rnd(rng, 91, 7)
    base = np.asarray(pairwise_dist(x, lm, block_b=8, block_l=8))
    got = np.asarray(pairwise_dist(x, lm, block_b=block_b, block_l=block_l))
    np.testing.assert_allclose(got, base, rtol=RTOL, atol=ATOL)


def test_pairwise_self_distance_zero_diagonal():
    rng = np.random.default_rng(3)
    x = rnd(rng, 33, 7)
    d = np.asarray(pairwise_dist(x, x, block_b=16, block_l=16))
    # MXU decomposition ||x||^2+||y||^2-2<x,y> cancels catastrophically at
    # x == y: the diagonal is sqrt(f32 cancellation noise) ~ 1e-3, not 0.
    np.testing.assert_allclose(np.diag(d), np.zeros(33), atol=5e-3)
    np.testing.assert_allclose(d, d.T, rtol=RTOL, atol=ATOL)


def test_pairwise_coincident_points():
    x = np.zeros((10, 7), dtype=np.float32)
    lm = np.zeros((12, 7), dtype=np.float32)
    d = np.asarray(pairwise_dist(x, lm, block_b=8, block_l=8))
    np.testing.assert_allclose(d, np.zeros((10, 12)), atol=1e-6)


def test_pairwise_large_magnitude():
    rng = np.random.default_rng(4)
    x, lm = rnd(rng, 20, 5) * 1e3, rnd(rng, 30, 5) * 1e3
    got = np.asarray(pairwise_dist(x, lm, block_b=8, block_l=8))
    want = np.asarray(ref.pairwise_dist(jnp.asarray(x), jnp.asarray(lm)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-1)


def test_pairwise_known_values():
    x = np.array([[0.0, 0.0], [3.0, 4.0]], dtype=np.float32)
    lm = np.array([[0.0, 0.0], [6.0, 8.0]], dtype=np.float32)
    d = np.asarray(pairwise_dist(x, lm, block_b=8, block_l=8))
    np.testing.assert_allclose(d, [[0.0, 10.0], [5.0, 5.0]], atol=1e-5)


def test_pairwise_rejects_dim_mismatch():
    with pytest.raises(ValueError):
        pairwise_dist(np.zeros((4, 3), np.float32), np.zeros((4, 2), np.float32))


# ---------------------------------------------------------------------------
# stress_grad
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k", [
    (2, 1), (8, 7), (16, 7), (37, 7), (64, 3), (100, 7), (130, 2),
])
@pytest.mark.parametrize("seed", [0, 5])
def test_stress_grad_matches_ref(n, k, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, n, k)
    delta = sym_delta(rng, n)
    g, s = stress_grad(x, delta, block=16)
    gr, sr = ref.stress_and_grad(jnp.asarray(x), jnp.asarray(delta))
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("block", [8, 32, 64, 256])
def test_stress_grad_block_invariance(block):
    rng = np.random.default_rng(6)
    x = rnd(rng, 70, 7)
    delta = sym_delta(rng, 70)
    g8, s8 = stress_grad(x, delta, block=8)
    g, s = stress_grad(x, delta, block=block)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g8), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s8), rtol=1e-4, atol=1e-3)


def test_stress_grad_matches_autodiff():
    """grad from the kernel == jax.grad of the (masked) stress definition."""
    rng = np.random.default_rng(7)
    n, k = 24, 5
    x = rnd(rng, n, k)
    delta = sym_delta(rng, n)

    import jax

    def sigma_raw(xc):
        # NaN-safe distances: mask *inside* the sqrt, otherwise autodiff of
        # sqrt(0) on the diagonal poisons the whole gradient.
        diff = xc[:, None, :] - xc[None, :, :]
        sq = jnp.sum(diff * diff, axis=-1)
        mask = ~jnp.eye(n, dtype=bool)
        d = jnp.sqrt(jnp.where(mask, sq, 1.0))
        return 0.5 * jnp.sum(jnp.where(mask, (d - delta) ** 2, 0.0))

    want = np.asarray(jax.grad(sigma_raw)(jnp.asarray(x)))
    got, _ = stress_grad(x, delta, block=8)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-3)


def test_stress_grad_zero_at_perfect_embedding():
    """If delta are exactly the Euclidean distances, stress = 0 and grad = 0."""
    rng = np.random.default_rng(8)
    x = rnd(rng, 30, 7)
    delta = np.asarray(ref.pairwise_dist(jnp.asarray(x), jnp.asarray(x)))
    g, s = stress_grad(x, delta, block=16)
    assert float(jnp.sum(s)) < 1e-6
    np.testing.assert_allclose(np.asarray(g), np.zeros_like(x), atol=1e-4)


def test_stress_grad_sres_is_twice_sigma():
    rng = np.random.default_rng(9)
    n = 26
    x = rnd(rng, n, 7)
    delta = sym_delta(rng, n)
    _, s = stress_grad(x, delta, block=8)
    d = np.asarray(ref.pairwise_dist(jnp.asarray(x), jnp.asarray(x)))
    mask = ~np.eye(n, dtype=bool)
    sigma_raw = 0.5 * np.sum(((d - delta) ** 2)[mask])
    np.testing.assert_allclose(0.5 * float(np.sum(np.asarray(s))), sigma_raw,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# ose_grad
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,l,k", [
    (1, 1, 1), (1, 100, 7), (8, 32, 7), (37, 53, 7), (64, 500, 7), (256, 50, 3),
])
@pytest.mark.parametrize("seed", [0, 3])
def test_ose_grad_matches_ref(b, l, k, seed):
    rng = np.random.default_rng(seed)
    y, lm = rnd(rng, b, k), rnd(rng, l, k)
    delta = np.abs(rnd(rng, b, l))
    g, s = ose_grad(y, lm, delta, block_b=16, block_l=32)
    gr, sr = ref.ose_objective_and_grad(
        jnp.asarray(y), jnp.asarray(lm), jnp.asarray(delta))
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-4, atol=1e-3)


def test_ose_grad_matches_autodiff():
    rng = np.random.default_rng(11)
    b, l, k = 9, 41, 7
    y, lm = rnd(rng, b, k), rnd(rng, l, k)
    delta = np.abs(rnd(rng, b, l))

    import jax

    def obj(yc):
        d = ref.pairwise_dist(yc, jnp.asarray(lm))
        return jnp.sum((d - delta) ** 2)

    want = np.asarray(jax.grad(obj)(jnp.asarray(y)))
    got, _ = ose_grad(y, lm, delta, block_b=8, block_l=8)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-3)


def test_ose_grad_zero_at_exact_solution():
    rng = np.random.default_rng(12)
    lm = rnd(rng, 40, 7)
    y = rnd(rng, 6, 7)
    delta = np.asarray(ref.pairwise_dist(jnp.asarray(y), jnp.asarray(lm)))
    g, s = ose_grad(y, lm, delta, block_b=8, block_l=16)
    assert float(np.max(np.asarray(s))) < 1e-6
    np.testing.assert_allclose(np.asarray(g), np.zeros_like(y), atol=1e-4)


def test_ose_grad_batch_independence():
    """Each row's gradient must not depend on other rows in the batch."""
    rng = np.random.default_rng(13)
    lm = rnd(rng, 30, 7)
    y = rnd(rng, 12, 7)
    delta = np.abs(rnd(rng, 12, 30))
    g_full, s_full = ose_grad(y, lm, delta, block_b=8, block_l=8)
    g_row, s_row = ose_grad(y[3:4], lm, delta[3:4], block_b=8, block_l=8)
    np.testing.assert_allclose(np.asarray(g_full)[3:4], np.asarray(g_row),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_full)[3:4], np.asarray(s_row),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# mlp_fwd
# ---------------------------------------------------------------------------


def make_params(rng, l, h1, h2, h3, k, scale=0.1):
    shapes = [(l, h1), (h1,), (h1, h2), (h2,), (h2, h3), (h3,), (h3, k), (k,)]
    return tuple(rnd(rng, *s) * scale for s in shapes)


@pytest.mark.parametrize("b,l,hidden,k", [
    (1, 10, (8, 8, 8), 2), (8, 32, (32, 16, 8), 7), (37, 100, (64, 32, 16), 7),
    (256, 300, (256, 128, 64), 7), (5, 2100, (256, 128, 64), 7),
])
def test_mlp_fwd_matches_ref(b, l, hidden, k):
    rng = np.random.default_rng(b + l)
    params = make_params(rng, l, *hidden, k)
    d = np.abs(rnd(rng, b, l))
    got = np.asarray(mlp_fwd(d, params, block_b=16))
    want = np.asarray(ref.mlp_fwd(jnp.asarray(d), tuple(map(jnp.asarray, params))))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_b", [8, 64, 256])
def test_mlp_fwd_block_invariance(block_b):
    rng = np.random.default_rng(21)
    params = make_params(rng, 50, 32, 16, 8, 7)
    d = np.abs(rnd(rng, 100, 50))
    base = np.asarray(mlp_fwd(d, params, block_b=8))
    got = np.asarray(mlp_fwd(d, params, block_b=block_b))
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_mlp_fwd_relu_clamps():
    """All-negative first-layer output => result is exactly the later biases."""
    rng = np.random.default_rng(22)
    l, h1, h2, h3, k = 12, 8, 8, 8, 3
    params = list(make_params(rng, l, h1, h2, h3, k))
    params[0] = -np.abs(params[0])  # w1 <= 0
    params[1] = -np.ones(h1, dtype=np.float32)  # b1 < 0
    d = np.abs(rnd(rng, 6, l))
    got = np.asarray(mlp_fwd(d, tuple(params), block_b=8))
    # h1 = 0 -> h2 = relu(b2), deterministic chain
    h = np.maximum(params[3], 0.0)
    h = np.maximum(h @ params[4] + params[5], 0.0)
    want = np.broadcast_to(h @ params[6] + params[7], (6, k))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_mlp_fwd_rejects_bad_input_width():
    rng = np.random.default_rng(23)
    params = make_params(rng, 50, 32, 16, 8, 7)
    with pytest.raises(ValueError):
        mlp_fwd(np.zeros((4, 49), np.float32), params)
