"""L2 graph semantics: LSMDS descent, SMACOF identity, OSE, Adam training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rnd(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


def config_delta(rng, n, k, noise=0.0):
    """A realisable dissimilarity matrix: distances of a random config."""
    x = rnd(rng, n, k)
    d = np.asarray(ref.pairwise_dist(jnp.asarray(x), jnp.asarray(x)))
    if noise:
        e = np.abs(rng.normal(size=d.shape)).astype(np.float32) * noise
        e = (e + e.T) / 2
        np.fill_diagonal(e, 0.0)
        d = d + e
    return x, d


def raw_stress(x, delta):
    d = np.asarray(ref.pairwise_dist(jnp.asarray(x), jnp.asarray(x)))
    mask = ~np.eye(x.shape[0], dtype=bool)
    return 0.5 * float(np.sum(((d - delta) ** 2)[mask]))


# ---------------------------------------------------------------------------
# lsmds_steps
# ---------------------------------------------------------------------------


def test_lsmds_smacof_lr_descends_monotonically():
    """lr = 1/(2N) is the Guttman transform: stress must never increase."""
    rng = np.random.default_rng(0)
    n, k = 40, 3
    _, delta = config_delta(rng, n, k, noise=0.3)
    x = rnd(rng, n, k)
    x -= x.mean(axis=0)  # centred: GD(1/2N) == SMACOF
    lr = 1.0 / (2 * n)
    prev = raw_stress(x, delta)
    for _ in range(10):
        x1, _ = model.lsmds_steps(jnp.asarray(x), jnp.asarray(delta),
                                  jnp.float32(lr), steps=5, block=16)
        x = np.asarray(x1)
        cur = raw_stress(x, delta)
        assert cur <= prev + 1e-3, f"stress increased {prev} -> {cur}"
        prev = cur


def test_lsmds_gd_step_equals_guttman_transform():
    """Explicit check of the GD(1/2N) == SMACOF identity used everywhere."""
    rng = np.random.default_rng(1)
    n, k = 18, 4
    _, delta = config_delta(rng, n, k, noise=0.2)
    x = rnd(rng, n, k)
    x -= x.mean(axis=0)

    x1, _ = model.lsmds_steps(jnp.asarray(x), jnp.asarray(delta),
                              jnp.float32(1.0 / (2 * n)), steps=1, block=8)

    # Guttman transform: x_i' = (1/n) [ x_i * sum_j (delta/d)_ij
    #                                   - sum_{j != i} (delta/d)_ij x_j ]
    d = np.array(ref.pairwise_dist(jnp.asarray(x), jnp.asarray(x)))
    np.fill_diagonal(d, 1.0)
    ratio = delta / np.maximum(d, 1e-12)
    np.fill_diagonal(ratio, 0.0)
    guttman = (x * ratio.sum(axis=1, keepdims=True) - ratio @ x) / n
    np.testing.assert_allclose(np.asarray(x1), guttman, rtol=1e-4, atol=1e-4)


def test_lsmds_recovers_exact_configuration():
    """With realisable delta, stress should approach ~0."""
    rng = np.random.default_rng(2)
    n, k = 30, 2
    _, delta = config_delta(rng, n, k)
    x = rnd(rng, n, k) * 0.5
    x -= x.mean(axis=0)
    lr = 1.0 / (2 * n)
    xj = jnp.asarray(x)
    for _ in range(40):
        xj, _ = model.lsmds_steps(xj, jnp.asarray(delta), jnp.float32(lr),
                                  steps=10, block=16)
    den = 0.5 * float(np.sum(delta**2))
    sigma = np.sqrt(raw_stress(np.asarray(xj), delta) / den)
    assert sigma < 0.05, f"normalized stress {sigma}"


def test_lsmds_reported_sigma_matches_definition():
    rng = np.random.default_rng(3)
    n, k = 20, 3
    _, delta = config_delta(rng, n, k, noise=0.5)
    x = rnd(rng, n, k)
    # steps=1: reported sigma is the stress at the pre-update configuration
    _, sigma = model.lsmds_steps(jnp.asarray(x), jnp.asarray(delta),
                                 jnp.float32(0.0), steps=1, block=8)
    np.testing.assert_allclose(float(sigma), raw_stress(x, delta), rtol=1e-4)


# ---------------------------------------------------------------------------
# ose_opt
# ---------------------------------------------------------------------------


def test_ose_opt_majorization_descends():
    rng = np.random.default_rng(4)
    l, k, b = 60, 7, 16
    lm = rnd(rng, l, k)
    y_true = rnd(rng, b, k)
    delta = np.asarray(ref.pairwise_dist(jnp.asarray(y_true), jnp.asarray(lm)))
    y0 = jnp.zeros((b, k), jnp.float32)  # paper's initial guess
    lr = jnp.float32(1.0 / (2 * l))

    def sres_of(y):
        _, s = ref.ose_objective_and_grad(y, jnp.asarray(lm), jnp.asarray(delta))
        return np.asarray(s)

    y1, s1 = model.ose_opt(jnp.asarray(lm), jnp.asarray(delta), y0, lr,
                           steps=5, block_b=8, block_l=16)
    y2, s2 = model.ose_opt(jnp.asarray(lm), jnp.asarray(delta), y1, lr,
                           steps=25, block_b=8, block_l=16)
    assert np.all(np.asarray(s2) <= np.asarray(s1) + 1e-4)
    # with exact (realisable) delta the objective should get near zero
    assert float(np.median(np.asarray(s2))) < 0.3 * float(np.median(sres_of(y0)))


def test_ose_opt_reported_sres_is_final_objective():
    rng = np.random.default_rng(5)
    l, k, b = 25, 3, 4
    lm, y0 = rnd(rng, l, k), rnd(rng, b, k)
    delta = np.abs(rnd(rng, b, l))
    yf, sres = model.ose_opt(jnp.asarray(lm), jnp.asarray(delta),
                             jnp.asarray(y0), jnp.float32(1.0 / (2 * l)),
                             steps=7, block_b=8, block_l=8)
    _, want = ref.ose_objective_and_grad(yf, jnp.asarray(lm), jnp.asarray(delta))
    np.testing.assert_allclose(np.asarray(sres), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ose_opt_zero_steps_returns_y0():
    rng = np.random.default_rng(6)
    lm, y0 = rnd(rng, 10, 2), rnd(rng, 3, 2)
    delta = np.abs(rnd(rng, 3, 10))
    yf, _ = model.ose_opt(jnp.asarray(lm), jnp.asarray(delta),
                          jnp.asarray(y0), jnp.float32(0.05),
                          steps=0, block_b=8, block_l=8)
    np.testing.assert_allclose(np.asarray(yf), y0, atol=1e-7)


def test_ose_opt_in_sample_point_recovers_position():
    """OSE of a point that *is* a landmark should land on that landmark."""
    rng = np.random.default_rng(7)
    l, k = 80, 7
    lm = rnd(rng, l, k)
    target = lm[5:6]
    delta = np.asarray(ref.pairwise_dist(jnp.asarray(target), jnp.asarray(lm)))
    y0 = jnp.zeros((1, k), jnp.float32)
    yf, sres = model.ose_opt(jnp.asarray(lm), jnp.asarray(delta), y0,
                             jnp.float32(1.0 / (2 * l)), steps=400,
                             block_b=8, block_l=16)
    assert float(sres[0]) < 1e-2
    np.testing.assert_allclose(np.asarray(yf), target, atol=0.05)


# ---------------------------------------------------------------------------
# mlp_train_step / mlp_loss
# ---------------------------------------------------------------------------


def make_state(rng, l, h, k):
    h1, h2, h3 = h
    shapes = [(l, h1), (h1,), (h1, h2), (h2,), (h2, h3), (h3,), (h3, k), (k,)]
    params = tuple(rnd(rng, *s) * 0.1 for s in shapes)
    zeros = tuple(np.zeros(s, np.float32) for s in shapes)
    return params, zeros, zeros


def test_train_step_decreases_loss():
    rng = np.random.default_rng(8)
    l, h, k, b = 30, (32, 16, 8), 7, 64
    params, m, v = make_state(rng, l, h, k)
    d = np.abs(rnd(rng, b, l))
    # learnable target: a linear map of the inputs (random labels would cap
    # how far the loss can fall and make the test meaningless)
    x = (d @ rnd(rng, l, k) * 0.3).astype(np.float32)

    state = [jnp.asarray(a) for a in (*params, *m, *v)]
    t = jnp.float32(0.0)
    first_loss = None
    for _ in range(120):
        out = model.mlp_train_step(*state, t, jnp.asarray(d), jnp.asarray(x),
                                   jnp.float32(1e-2))
        state, t, loss = list(out[:24]), out[24], out[25]
        if first_loss is None:
            first_loss = float(loss)
    assert float(loss) < 0.5 * first_loss, (first_loss, float(loss))


def test_train_step_adam_matches_numpy_reference():
    """One full Adam update cross-checked against a hand-written numpy Adam."""
    rng = np.random.default_rng(9)
    l, h, k, b = 12, (8, 8, 8), 3, 10
    params, m, v = make_state(rng, l, h, k)
    d = np.abs(rnd(rng, b, l))
    x = rnd(rng, b, k)

    grads = jax.grad(
        lambda p: ref.mae_loss(ref.mlp_fwd(jnp.asarray(d), p), jnp.asarray(x))
    )(tuple(map(jnp.asarray, params)))

    out = model.mlp_train_step(*map(jnp.asarray, (*params, *m, *v)),
                               jnp.float32(0.0), jnp.asarray(d),
                               jnp.asarray(x), jnp.float32(1e-3))
    got_params = [np.asarray(a) for a in out[:8]]

    beta1, beta2, eps, lr, t1 = 0.9, 0.999, 1e-7, 1e-3, 1.0
    for p, g, gp in zip(params, grads, got_params):
        g = np.asarray(g)
        mi = (1 - beta1) * g
        vi = (1 - beta2) * g * g
        step = lr * (mi / (1 - beta1**t1)) / (np.sqrt(vi / (1 - beta2**t1)) + eps)
        np.testing.assert_allclose(gp, p - step, rtol=1e-4, atol=1e-6)


def test_train_step_t_increments_and_loss_matches_mlp_loss():
    rng = np.random.default_rng(10)
    l, h, k, b = 16, (8, 8, 8), 2, 6
    params, m, v = make_state(rng, l, h, k)
    d = np.abs(rnd(rng, b, l))
    x = rnd(rng, b, k)
    out = model.mlp_train_step(*map(jnp.asarray, (*params, *m, *v)),
                               jnp.float32(4.0), jnp.asarray(d),
                               jnp.asarray(x), jnp.float32(1e-3))
    assert float(out[24]) == 5.0
    want = model.mlp_loss(*map(jnp.asarray, params), jnp.asarray(d),
                          jnp.asarray(x))
    np.testing.assert_allclose(float(out[25]), float(want), rtol=1e-6)


def test_mae_loss_is_mean_euclidean_norm():
    pred = jnp.asarray(np.array([[3.0, 4.0], [0.0, 0.0]], np.float32))
    target = jnp.zeros((2, 2), jnp.float32)
    np.testing.assert_allclose(float(ref.mae_loss(pred, target)), 2.5, rtol=1e-4)


def test_normalized_stress_zero_for_perfect_config():
    rng = np.random.default_rng(11)
    x = rnd(rng, 15, 3)
    delta = ref.pairwise_dist(jnp.asarray(x), jnp.asarray(x))
    s = model.normalized_stress(jnp.asarray(x), delta)
    assert float(s) < 1e-4
