"""Shape-registry contract tests — pure Python, no JAX required.

These run in every environment (the JAX-dependent suites are skipped via
conftest.py when the stack is missing), so the optional CI job always has
something real to check: the variant registry the Rust runtime's manifest
contract is built on.
"""

from compile import shapes


def test_smoke_scale_covers_every_graph_family():
    graphs = {v.graph for v in shapes.variants_for_scales(["smoke"])}
    assert graphs == {
        "lsmds_steps",
        "ose_opt",
        "mlp_fwd",
        "mlp_train_step",
        "mlp_loss",
    }


def test_variant_keys_are_unique_across_all_scales():
    vs = shapes.variants_for_scales(shapes.ALL_SCALES)
    keys = [v.key for v in vs]
    assert len(keys) == len(set(keys))


def test_sweeps_match_the_paper_protocol():
    assert shapes.K_DIM == 7
    assert shapes.L_SWEEP_PAPER[0] == 100
    assert shapes.L_SWEEP_PAPER[-1] == 2100
    assert len(shapes.L_SWEEP_SMALL) == 8
