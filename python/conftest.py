"""Pytest configuration for the L1/L2 (JAX/Pallas) test suite.

Two jobs:

- Put ``python/`` on ``sys.path`` (pytest inserts this conftest's
  directory automatically in rootdir mode), so ``from compile import ...``
  resolves without packaging.
- Skip the JAX-dependent modules cleanly when JAX is unavailable: CI
  images without the JAX/Pallas stack must not fail collection with
  ImportError. ``test_shapes.py`` is pure Python and always runs, so the
  suite never collects zero tests (pytest exit code 5).
"""

import importlib.util

HAVE_JAX = importlib.util.find_spec("jax") is not None

collect_ignore = (
    []
    if HAVE_JAX
    else [
        "tests/test_kernel.py",
        "tests/test_model.py",
        "tests/test_aot.py",
    ]
)
