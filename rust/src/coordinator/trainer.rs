//! NN-OSE training coordinator: drives the `mlp_train_step` artifact (or
//! the pure-Rust mirror) over minibatches with shuffling, epochs and
//! early stopping. Training data is the paper's recipe (Sec. 4.2): inputs
//! are distances-to-landmarks of the N configured points, labels are their
//! LSMDS coordinates.

use anyhow::{Context, Result};

use crate::mds::Matrix;
use crate::nn::{self, MlpParams, MlpShape};
use crate::runtime::{OwnedArg, RuntimeHandle};
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub lr: f32,
    pub epochs: usize,
    /// Stop when the epoch loss improves less than this (relative) for
    /// `patience` consecutive epochs.
    pub rel_tol: f64,
    pub patience: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { lr: 1e-3, epochs: 200, rel_tol: 1e-4, patience: 5, seed: 42 }
    }
}

/// Dim constraints identifying the artifact matching an MLP shape.
pub fn train_constraints(shape: &MlpShape) -> Vec<(&'static str, usize)> {
    vec![
        ("L", shape.input),
        ("H1", shape.hidden[0]),
        ("H2", shape.hidden[1]),
        ("H3", shape.hidden[2]),
        ("K", shape.output),
    ]
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub epochs_run: usize,
    pub final_loss: f64,
    pub loss_history: Vec<f64>,
    pub wall_s: f64,
}

/// Train via the PJRT `mlp_train_step` artifact. `inputs` is N x L
/// (distances to landmarks), `labels` is N x K (LSMDS coordinates).
pub fn train_pjrt(
    handle: &RuntimeHandle,
    shape: &MlpShape,
    inputs: &Matrix,
    labels: &Matrix,
    cfg: &TrainConfig,
) -> Result<(MlpParams, TrainReport)> {
    let l = shape.input;
    let spec = handle
        .manifest()
        .find("mlp_train_step", &train_constraints(shape))
        .with_context(|| format!("no mlp_train_step artifact for L={l}"))?
        .clone();
    let b = spec.dim("B").context("train artifact missing B")?;
    anyhow::ensure!(inputs.rows == labels.rows, "inputs/labels row mismatch");
    anyhow::ensure!(inputs.cols == l, "inputs width != L");

    let mut rng = Rng::new(cfg.seed);
    let params = MlpParams::init(shape, &mut rng);
    let mut flat: Vec<Vec<f32>> = params.flatten();
    let zeros: Vec<Vec<f32>> = flat.iter().map(|p| vec![0.0; p.len()]).collect();
    let mut m = zeros.clone();
    let mut v = zeros;
    let mut t = 0.0f32;

    // argument shapes for the 8 param slots (w matrices need 2-D literals)
    let arg_shapes: Vec<Vec<usize>> =
        spec.args.iter().map(|a| a.shape.clone()).collect();
    let to_arg = |data: Vec<f32>, shape: &[usize]| -> OwnedArg {
        if shape.len() == 2 {
            OwnedArg::Mat(Matrix::from_vec(shape[0], shape[1], data))
        } else {
            OwnedArg::Vec1(data)
        }
    };

    let n = inputs.rows;
    let mut order: Vec<usize> = (0..n).collect();
    let t_start = std::time::Instant::now();
    let mut history = Vec::new();
    let mut best = f64::INFINITY;
    let mut stale = 0usize;
    let mut epochs_run = 0usize;

    for _epoch in 0..cfg.epochs {
        epochs_run += 1;
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0f64;
        let mut batches = 0usize;
        let mut start = 0;
        while start < n {
            // assemble a batch of exactly `b` rows (wrap around at the end
            // of the epoch, standard drop-nothing minibatching)
            let mut d = Matrix::zeros(b, l);
            let mut x = Matrix::zeros(b, labels.cols);
            for r in 0..b {
                let src = order[(start + r) % n];
                d.row_mut(r).copy_from_slice(inputs.row(src));
                x.row_mut(r).copy_from_slice(labels.row(src));
            }
            start += b;

            let mut args: Vec<OwnedArg> = Vec::with_capacity(28);
            for (i, p) in flat.iter().enumerate() {
                args.push(to_arg(p.clone(), &arg_shapes[i]));
            }
            for (i, p) in m.iter().enumerate() {
                args.push(to_arg(p.clone(), &arg_shapes[8 + i]));
            }
            for (i, p) in v.iter().enumerate() {
                args.push(to_arg(p.clone(), &arg_shapes[16 + i]));
            }
            args.push(OwnedArg::Scalar(t));
            args.push(OwnedArg::Mat(d));
            args.push(OwnedArg::Mat(x));
            args.push(OwnedArg::Scalar(cfg.lr));

            let out = handle.execute(&spec.name, args)?;
            // outputs: 8 params, 8 m, 8 v, t, loss
            for (i, o) in out.iter().take(8).enumerate() {
                flat[i] = o.data.clone();
            }
            for (i, o) in out.iter().skip(8).take(8).enumerate() {
                m[i] = o.data.clone();
            }
            for (i, o) in out.iter().skip(16).take(8).enumerate() {
                v[i] = o.data.clone();
            }
            t = out[24].scalar();
            epoch_loss += out[25].scalar() as f64;
            batches += 1;
        }
        let loss = epoch_loss / batches.max(1) as f64;
        history.push(loss);
        if loss < best * (1.0 - cfg.rel_tol) {
            best = loss;
            stale = 0;
        } else {
            stale += 1;
            if stale >= cfg.patience {
                break;
            }
        }
    }

    let trained = MlpParams::from_flat(shape, &flat);
    let report = TrainReport {
        epochs_run,
        final_loss: *history.last().unwrap_or(&f64::NAN),
        loss_history: history,
        wall_s: t_start.elapsed().as_secs_f64(),
    };
    Ok((trained, report))
}

/// Pure-Rust fallback trainer (same protocol, same Adam constants).
pub fn train_rust(
    shape: &MlpShape,
    inputs: &Matrix,
    labels: &Matrix,
    batch: usize,
    cfg: &TrainConfig,
) -> (MlpParams, TrainReport) {
    let mut rng = Rng::new(cfg.seed);
    let mut params = MlpParams::init(shape, &mut rng);
    let mut opt = nn::Adam::new(shape, cfg.lr);
    let n = inputs.rows;
    let b = batch.min(n).max(1);
    let mut order: Vec<usize> = (0..n).collect();
    let t_start = std::time::Instant::now();
    let mut history = Vec::new();
    let mut best = f64::INFINITY;
    let mut stale = 0usize;
    let mut epochs_run = 0usize;

    for _epoch in 0..cfg.epochs {
        epochs_run += 1;
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0;
        let mut batches = 0usize;
        let mut start = 0;
        while start < n {
            let mut d = Matrix::zeros(b, shape.input);
            let mut x = Matrix::zeros(b, shape.output);
            for r in 0..b {
                let src = order[(start + r) % n];
                d.row_mut(r).copy_from_slice(inputs.row(src));
                x.row_mut(r).copy_from_slice(labels.row(src));
            }
            start += b;
            let (loss, grads) = nn::backward(&params, &d, &x);
            opt.step(&mut params, &grads);
            epoch_loss += loss;
            batches += 1;
        }
        let loss = epoch_loss / batches.max(1) as f64;
        history.push(loss);
        if loss < best * (1.0 - cfg.rel_tol) {
            best = loss;
            stale = 0;
        } else {
            stale += 1;
            if stale >= cfg.patience {
                break;
            }
        }
    }
    let report = TrainReport {
        epochs_run,
        final_loss: *history.last().unwrap_or(&f64::NAN),
        loss_history: history,
        wall_s: t_start.elapsed().as_secs_f64(),
    };
    (params, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_trainer_fits_linear_map() {
        let mut rng = Rng::new(1);
        let n = 200;
        let shape = MlpShape { input: 12, hidden: [16, 16, 8], output: 3 };
        let inputs = Matrix::from_vec(
            n,
            12,
            (0..n * 12).map(|_| rng.next_f32() * 2.0).collect(),
        );
        let a = Matrix::random_normal(&mut rng, 12, 3, 0.4);
        let mut labels = Matrix::zeros(n, 3);
        for r in 0..n {
            for c in 0..3 {
                let mut acc = 0.0f32;
                for i in 0..12 {
                    acc += inputs.at(r, i) * a.at(i, c);
                }
                labels.set(r, c, acc);
            }
        }
        let (params, report) = train_rust(
            &shape,
            &inputs,
            &labels,
            32,
            &TrainConfig { epochs: 120, lr: 3e-3, ..Default::default() },
        );
        assert!(
            report.final_loss < 0.35 * report.loss_history[0],
            "{} -> {}",
            report.loss_history[0],
            report.final_loss
        );
        // prediction shape sanity
        let y = nn::forward(&params, &inputs);
        assert_eq!((y.rows, y.cols), (n, 3));
    }

    #[test]
    fn early_stopping_triggers() {
        let mut rng = Rng::new(2);
        let shape = MlpShape { input: 4, hidden: [4, 4, 4], output: 1 };
        let inputs = Matrix::random_normal(&mut rng, 16, 4, 1.0);
        let labels = Matrix::zeros(16, 1);
        let (_, report) = train_rust(
            &shape,
            &inputs,
            &labels,
            16,
            &TrainConfig {
                epochs: 500,
                lr: 1e-2,
                rel_tol: 1e-3,
                patience: 3,
                ..Default::default()
            },
        );
        assert!(report.epochs_run < 500, "never early-stopped");
    }
}
