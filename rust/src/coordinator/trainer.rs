//! NN-OSE training coordinator: drives [`ComputeBackend::mlp_train_step`]
//! over minibatches with shuffling, epochs and early stopping. Training
//! data is the paper's recipe (Sec. 4.2): inputs are
//! distances-to-landmarks of the N configured points, labels are their
//! LSMDS coordinates.
//!
//! [`train_backend`] is the production path (native backend by default,
//! PJRT artifacts when built with `--features pjrt` and available);
//! [`train_rust`] is the structured-state oracle the backend path is
//! cross-checked against in `tests/backend_parity.rs`.

use anyhow::Result;

use crate::mds::Matrix;
use crate::nn::{self, MlpParams, MlpShape};
use crate::runtime::{AdamState, Backend, ComputeBackend};
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
/// NN-OSE training settings (Adam + early stopping).
pub struct TrainConfig {
    /// Adam learning rate.
    pub lr: f32,
    /// Maximum training epochs.
    pub epochs: usize,
    /// Stop when the epoch loss improves less than this (relative) for
    /// `patience` consecutive epochs.
    pub rel_tol: f64,
    /// Early stopping: epochs without improvement before giving up.
    pub patience: usize,
    /// Seed for init and shuffling.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { lr: 1e-3, epochs: 200, rel_tol: 1e-4, patience: 5, seed: 42 }
    }
}

#[derive(Clone, Debug)]
/// What one training run did.
pub struct TrainReport {
    /// Epochs actually executed (early stopping may cut the budget).
    pub epochs_run: usize,
    /// Final training loss.
    pub final_loss: f64,
    /// Per-epoch loss trajectory.
    pub loss_history: Vec<f64>,
    /// Wall-clock seconds spent training.
    pub wall_s: f64,
}

/// Train through a compute backend. `inputs` is N x L (distances to
/// landmarks), `labels` is N x K (LSMDS coordinates). `batch` is the
/// minibatch size used unless the backend pins one (PJRT train artifacts
/// are batch-monomorphic). Batches are assembled at exactly the chosen
/// size, wrapping around at the end of each epoch (drop-nothing
/// minibatching), so every backend sees identical batch shapes.
pub fn train_backend(
    backend: &Backend,
    shape: &MlpShape,
    inputs: &Matrix,
    labels: &Matrix,
    batch: usize,
    cfg: &TrainConfig,
) -> Result<(MlpParams, TrainReport)> {
    anyhow::ensure!(inputs.rows == labels.rows, "inputs/labels row mismatch");
    anyhow::ensure!(inputs.cols == shape.input, "inputs width != L");
    anyhow::ensure!(labels.cols == shape.output, "labels width != K");
    anyhow::ensure!(inputs.rows > 0, "empty training set");

    let n = inputs.rows;
    // A backend-pinned batch (PJRT train artifacts are batch-monomorphic)
    // is honoured even when n < B — the wraparound assembly below fills
    // the batch, so the artifact still executes. Otherwise the caller's
    // batch is clamped to the dataset.
    let b = match backend.mlp_train_batch(shape) {
        Some(pinned) => pinned.max(1),
        None => batch.min(n).max(1),
    };

    let mut rng = Rng::new(cfg.seed);
    let mut state = AdamState::new(&MlpParams::init(shape, &mut rng));
    let mut order: Vec<usize> = (0..n).collect();
    let t_start = std::time::Instant::now();
    let mut history = Vec::new();
    let mut best = f64::INFINITY;
    let mut stale = 0usize;
    let mut epochs_run = 0usize;

    for _epoch in 0..cfg.epochs {
        epochs_run += 1;
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0f64;
        let mut batches = 0usize;
        let mut start = 0;
        while start < n {
            let mut d = Matrix::zeros(b, shape.input);
            let mut x = Matrix::zeros(b, shape.output);
            for r in 0..b {
                let src = order[(start + r) % n];
                d.row_mut(r).copy_from_slice(inputs.row(src));
                x.row_mut(r).copy_from_slice(labels.row(src));
            }
            start += b;
            epoch_loss += backend.mlp_train_step(&mut state, &d, &x, cfg.lr)? as f64;
            batches += 1;
        }
        let loss = epoch_loss / batches.max(1) as f64;
        history.push(loss);
        if loss < best * (1.0 - cfg.rel_tol) {
            best = loss;
            stale = 0;
        } else {
            stale += 1;
            if stale >= cfg.patience {
                break;
            }
        }
    }

    let report = TrainReport {
        epochs_run,
        final_loss: *history.last().unwrap_or(&f64::NAN),
        loss_history: history,
        wall_s: t_start.elapsed().as_secs_f64(),
    };
    Ok((state.to_params(), report))
}

/// Pure-Rust oracle trainer over structured [`nn::Adam`] state (same
/// protocol, same Adam constants, same batch assembly as
/// [`train_backend`]).
pub fn train_rust(
    shape: &MlpShape,
    inputs: &Matrix,
    labels: &Matrix,
    batch: usize,
    cfg: &TrainConfig,
) -> (MlpParams, TrainReport) {
    let mut rng = Rng::new(cfg.seed);
    let mut params = MlpParams::init(shape, &mut rng);
    let mut opt = nn::Adam::new(shape, cfg.lr);
    let n = inputs.rows;
    let b = batch.min(n).max(1);
    let mut order: Vec<usize> = (0..n).collect();
    let t_start = std::time::Instant::now();
    let mut history = Vec::new();
    let mut best = f64::INFINITY;
    let mut stale = 0usize;
    let mut epochs_run = 0usize;

    for _epoch in 0..cfg.epochs {
        epochs_run += 1;
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0;
        let mut batches = 0usize;
        let mut start = 0;
        while start < n {
            let mut d = Matrix::zeros(b, shape.input);
            let mut x = Matrix::zeros(b, shape.output);
            for r in 0..b {
                let src = order[(start + r) % n];
                d.row_mut(r).copy_from_slice(inputs.row(src));
                x.row_mut(r).copy_from_slice(labels.row(src));
            }
            start += b;
            let (loss, grads) = nn::backward(&params, &d, &x);
            opt.step(&mut params, &grads);
            epoch_loss += loss;
            batches += 1;
        }
        let loss = epoch_loss / batches.max(1) as f64;
        history.push(loss);
        if loss < best * (1.0 - cfg.rel_tol) {
            best = loss;
            stale = 0;
        } else {
            stale += 1;
            if stale >= cfg.patience {
                break;
            }
        }
    }
    let report = TrainReport {
        epochs_run,
        final_loss: *history.last().unwrap_or(&f64::NAN),
        loss_history: history,
        wall_s: t_start.elapsed().as_secs_f64(),
    };
    (params, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_trainer_fits_linear_map() {
        let mut rng = Rng::new(1);
        let n = 200;
        let shape = MlpShape { input: 12, hidden: [16, 16, 8], output: 3 };
        let inputs = Matrix::from_vec(
            n,
            12,
            (0..n * 12).map(|_| rng.next_f32() * 2.0).collect(),
        );
        let a = Matrix::random_normal(&mut rng, 12, 3, 0.4);
        let mut labels = Matrix::zeros(n, 3);
        for r in 0..n {
            for c in 0..3 {
                let mut acc = 0.0f32;
                for i in 0..12 {
                    acc += inputs.at(r, i) * a.at(i, c);
                }
                labels.set(r, c, acc);
            }
        }
        let (params, report) = train_rust(
            &shape,
            &inputs,
            &labels,
            32,
            &TrainConfig { epochs: 120, lr: 3e-3, ..Default::default() },
        );
        assert!(
            report.final_loss < 0.35 * report.loss_history[0],
            "{} -> {}",
            report.loss_history[0],
            report.final_loss
        );
        // prediction shape sanity
        let y = nn::forward(&params, &inputs);
        assert_eq!((y.rows, y.cols), (n, 3));
    }

    #[test]
    fn early_stopping_triggers() {
        let mut rng = Rng::new(2);
        let shape = MlpShape { input: 4, hidden: [4, 4, 4], output: 1 };
        let inputs = Matrix::random_normal(&mut rng, 16, 4, 1.0);
        let labels = Matrix::zeros(16, 1);
        let (_, report) = train_rust(
            &shape,
            &inputs,
            &labels,
            16,
            &TrainConfig {
                epochs: 500,
                lr: 1e-2,
                rel_tol: 1e-3,
                patience: 3,
                ..Default::default()
            },
        );
        assert!(report.epochs_run < 500, "never early-stopped");
    }

    #[test]
    fn backend_trainer_runs_on_native() {
        let mut rng = Rng::new(3);
        let shape = MlpShape { input: 6, hidden: [8, 8, 8], output: 2 };
        let inputs = Matrix::random_normal(&mut rng, 40, 6, 1.0);
        let labels = Matrix::random_normal(&mut rng, 40, 2, 1.0);
        let backend = Backend::native();
        let (params, report) = train_backend(
            &backend,
            &shape,
            &inputs,
            &labels,
            16,
            &TrainConfig { epochs: 10, patience: 100, ..Default::default() },
        )
        .unwrap();
        assert_eq!(report.epochs_run, 10);
        assert_eq!(report.loss_history.len(), 10);
        assert!(report.final_loss.is_finite());
        let y = nn::forward(&params, &inputs);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }
}
