//! Streaming OSE service: the "high performance" serving half of the paper
//! (fast DR on streaming datasets), rebuilt as a fault-isolated replicated
//! executor pool:
//!
//! ```text
//!  clients --query--> [frontend pool: dissimilarities to landmarks]
//!          --delta row--> [bounded dispatch queue]
//!          --batch--> [executor replica 0..R-1, each owns an OseMethod]
//!          --coords--> per-request reply channels (+ drift monitor feed)
//! ```
//!
//! Dynamic batching: an executor dispatches a batch when it reaches
//! `max_batch` or when its oldest member has waited `max_delay`, whichever
//! first. The bounded queue applies backpressure to the frontend.
//!
//! Fault isolation: each executor wraps `embed` in `catch_unwind`. A
//! poisoned batch fails *that batch* — its callers get error replies, the
//! replica is rebuilt from the [`OseMethodFactory`] (mid-batch state may be
//! corrupt), and every other replica keeps serving. The old single-batcher
//! design died on the first panic and silently hung all future queries.
//!
//! The server is generic over the object domain `T: ?Sized` (strings,
//! numeric vectors, anything with a [`Dissimilarity`]), so vector
//! workloads serve through the same path as the paper's string workloads.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::mds::Matrix;
use crate::ose::{OseMethod, OseMethodFactory};
use crate::strdist::Dissimilarity;
use crate::util::threadpool::WorkerPool;

use super::metrics::Metrics;
use super::stream::{DriftConfig, DriftMonitor};

#[derive(Clone, Debug)]
/// Dynamic-batching shape of the serving loop: when a batch dispatches,
/// how deep the queue may grow, and how many frontend/executor workers
/// run.
pub struct BatcherConfig {
    /// Dispatch as soon as this many requests are pending.
    pub max_batch: usize,
    /// ... or when the oldest pending request has waited this long.
    pub max_delay: Duration,
    /// Bounded queue capacity between frontend and executors (backpressure).
    pub queue_cap: usize,
    /// Frontend worker threads (distance computation).
    pub frontend_threads: usize,
    /// OSE executor replicas pulling batches from the shared queue. Each
    /// replica owns an independent method instance built by the factory.
    pub replicas: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            queue_cap: 4096,
            frontend_threads: 4,
            replicas: 1,
        }
    }
}

/// Attach a [`DriftMonitor`] to the serving loop: every served query feeds
/// its normalised Eq.-2 score (mapped coordinates vs the landmark
/// configuration), and the resulting status / re-embed signal surfaces in
/// [`Metrics::snapshot`].
pub struct DriftHook {
    /// L x K landmark configuration the monitor scores against.
    pub landmark_config: Matrix,
    /// Monitor window/calibration settings.
    pub cfg: DriftConfig,
}

struct DriftState {
    landmark_config: Matrix,
    monitor: Mutex<DriftMonitor>,
}

/// A completed query.
#[derive(Clone, Debug)]
pub struct QueryResult {
    /// Embedded coordinates of the query (length K).
    pub coords: Vec<f32>,
    /// End-to-end latency as measured by the server.
    pub latency: Duration,
}

struct WorkItem {
    delta: Vec<f32>,
    started: Instant,
    reply: Sender<Result<QueryResult, String>>,
}

/// The OSE serving coordinator, generic over the object domain.
///
/// Shutdown semantics: the executor replicas exit when every sender into
/// the dispatch queue is gone — i.e. when the server's own handle AND all
/// caller-held clones have been dropped. `shutdown()`/`Drop` releases the
/// server's handle and joins; callers must drop their clones first (or the
/// join blocks until they do).
pub struct Server<T: ?Sized + Send + Sync + 'static> {
    handle: Option<ServerHandle<T>>,
    executors: Vec<JoinHandle<()>>,
    // keep the pool alive; dropped (and joined) after the executors
    _frontend: Arc<WorkerPool>,
}

/// Cheap-to-clone client handle: submits queries into the batching
/// queue and exposes the shared [`Metrics`].
pub struct ServerHandle<T: ?Sized + Send + Sync + 'static> {
    landmarks: Arc<Vec<Box<T>>>,
    metric: Arc<dyn Dissimilarity<T> + Send + Sync>,
    pool: Arc<WorkerPool>,
    tx: SyncSender<WorkItem>,
    /// Shared serving counters (live; see [`Metrics::snapshot`]).
    pub metrics: Arc<Metrics>,
}

// manual impl: derive(Clone) would demand T: Clone, which Box-shared
// unsized objects neither need nor can provide
impl<T: ?Sized + Send + Sync + 'static> Clone for ServerHandle<T> {
    fn clone(&self) -> Self {
        Self {
            landmarks: Arc::clone(&self.landmarks),
            metric: Arc::clone(&self.metric),
            pool: Arc::clone(&self.pool),
            tx: self.tx.clone(),
            metrics: Arc::clone(&self.metrics),
        }
    }
}

impl Server<str> {
    /// Convenience constructor for the common string workload.
    pub fn start_strings(
        landmarks: Vec<String>,
        metric: Arc<dyn Dissimilarity<str> + Send + Sync>,
        factory: Arc<dyn OseMethodFactory>,
        cfg: BatcherConfig,
        drift: Option<DriftHook>,
    ) -> Server<str> {
        Self::start(
            landmarks.into_iter().map(String::into_boxed_str).collect(),
            metric,
            factory,
            cfg,
            drift,
        )
    }
}

impl<T: ?Sized + Send + Sync + 'static> Server<T> {
    /// Start the service with `cfg.replicas` executor replicas, each owning
    /// a method instance built by `factory` (methods may hold a
    /// [`crate::runtime::Backend`], which is Send).
    pub fn start(
        landmarks: Vec<Box<T>>,
        metric: Arc<dyn Dissimilarity<T> + Send + Sync>,
        factory: Arc<dyn OseMethodFactory>,
        cfg: BatcherConfig,
        drift: Option<DriftHook>,
    ) -> Server<T> {
        let probe = factory.build();
        assert_eq!(
            landmarks.len(),
            probe.landmarks(),
            "landmark count must match the OSE method"
        );
        if let Some(h) = &drift {
            assert_eq!(
                (h.landmark_config.rows, h.landmark_config.cols),
                (probe.landmarks(), probe.dim()),
                "drift hook landmark configuration must be L x K"
            );
        }
        let metrics = Arc::new(Metrics::new());
        let replicas = cfg.replicas.max(1);
        metrics.set_replicas(replicas);
        let (tx, rx) = std::sync::mpsc::sync_channel::<WorkItem>(cfg.queue_cap);
        let rx = Arc::new(Mutex::new(rx));
        let pool = Arc::new(WorkerPool::new(cfg.frontend_threads));
        let drift = drift.map(|h| {
            Arc::new(DriftState {
                landmark_config: h.landmark_config,
                monitor: Mutex::new(DriftMonitor::new(h.cfg)),
            })
        });

        let mut first = Some(probe);
        let executors = (0..replicas)
            .map(|i| {
                let method =
                    first.take().unwrap_or_else(|| factory.build());
                let rx = Arc::clone(&rx);
                let factory = Arc::clone(&factory);
                let metrics = Arc::clone(&metrics);
                let drift = drift.clone();
                let ecfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("ose-exec-{i}"))
                    .spawn(move || {
                        executor_loop(
                            &rx,
                            method,
                            factory.as_ref(),
                            &ecfg,
                            &metrics,
                            drift.as_deref(),
                        )
                    })
                    .expect("spawning executor replica")
            })
            .collect();

        let handle = ServerHandle {
            landmarks: Arc::new(landmarks),
            metric,
            pool: Arc::clone(&pool),
            tx,
            metrics,
        };
        Server { handle: Some(handle), executors, _frontend: pool }
    }

    /// A new client handle onto the running server.
    pub fn handle(&self) -> ServerHandle<T> {
        self.handle.clone().expect("server already shut down")
    }

    /// Graceful shutdown: waits for in-flight work to drain. All caller
    /// handles must be dropped first, or this blocks until they are.
    pub fn shutdown(mut self) {
        self.join_inner();
    }

    fn join_inner(&mut self) {
        // Release our sender; the executors exit once all handles are gone.
        self.handle.take();
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

impl<T: ?Sized + Send + Sync + 'static> Drop for Server<T> {
    fn drop(&mut self) {
        self.join_inner();
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// One executor replica: form a batch from the shared queue, embed it, and
/// reply — with `catch_unwind` fencing so a poisoned batch cannot take the
/// replica (let alone the service) down.
fn executor_loop(
    rx: &Mutex<Receiver<WorkItem>>,
    mut method: Box<dyn OseMethod>,
    factory: &dyn OseMethodFactory,
    cfg: &BatcherConfig,
    metrics: &Metrics,
    drift: Option<&DriftState>,
) {
    let l = method.landmarks();
    let k = method.dim();
    loop {
        // Form the next batch while holding the queue lock: the lock both
        // shares the single consumer end across replicas and guarantees
        // each item lands in exactly one batch. Holding it through the
        // straggler wait is deliberate — arrivals during the wait belong in
        // THIS batch; a peer replica grabbing them would only shrink it.
        let items = {
            let queue = match rx.lock() {
                Ok(g) => g,
                // a poisoned queue lock means a peer panicked INSIDE batch
                // formation (not embed) — unrecoverable by design
                Err(_) => return,
            };
            // block for the first item of the next batch
            let first = match queue.recv() {
                Ok(item) => item,
                Err(_) => return, // all senders gone
            };
            let mut items = vec![first];
            // greedily drain the backlog first: under load the queue
            // already holds a full batch and waiting would only add latency
            while items.len() < cfg.max_batch {
                match queue.try_recv() {
                    Ok(item) => items.push(item),
                    Err(_) => break,
                }
            }
            // under light load, wait up to max_delay (from NOW — not from
            // the request's submit time, which may already be in the past
            // after a queue wait) for stragglers to share the execution
            if items.len() < cfg.max_batch {
                let deadline = Instant::now() + cfg.max_delay;
                while items.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match queue.recv_timeout(deadline - now) {
                        Ok(item) => items.push(item),
                        Err(_) => break, // timeout or disconnected
                    }
                }
            }
            items
        }; // lock released: embedding runs concurrently across replicas

        // defensive depth check — query_delta validates at submission, so
        // a mismatch here means a bug, but it must not poison the batch
        let (items, bad): (Vec<_>, Vec<_>) =
            items.into_iter().partition(|it| it.delta.len() == l);
        for item in bad {
            metrics.record_failed();
            let _ = item.reply.send(Err(format!(
                "delta row has {} entries, expected {l}",
                item.delta.len()
            )));
        }
        if items.is_empty() {
            continue;
        }

        let t0 = Instant::now();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            let mut deltas = Matrix::zeros(items.len(), l);
            for (r, item) in items.iter().enumerate() {
                deltas.row_mut(r).copy_from_slice(&item.delta);
            }
            method.embed(&deltas)
        }));
        match outcome {
            // a mis-shaped result would panic row() below, OUTSIDE the
            // unwind fence — demote it to a clean batch failure instead
            Ok(Ok(coords)) if coords.rows != items.len() || coords.cols != k => {
                let msg = format!(
                    "embed returned {}x{}, expected {}x{k}",
                    coords.rows,
                    coords.cols,
                    items.len()
                );
                log::error!("{msg}");
                for item in items {
                    metrics.record_failed();
                    let _ = item.reply.send(Err(msg.clone()));
                }
            }
            Ok(Ok(coords)) => {
                metrics.record_batch(items.len(), t0.elapsed());
                // reply FIRST: drift scoring is observability, and must not
                // sit on the callers' latency path
                for (r, item) in items.iter().enumerate() {
                    let latency = item.started.elapsed();
                    metrics.record_completed(latency);
                    let _ = item.reply.send(Ok(QueryResult {
                        coords: coords.row(r).to_vec(),
                        latency,
                    }));
                }
                if let Some(ds) = drift {
                    feed_drift(ds, &items, &coords, metrics);
                }
            }
            Ok(Err(e)) => {
                // clean error from the method: the batch fails, the replica
                // state is intact — no restart needed
                let msg = format!("embed failed: {e:#}");
                log::error!("{msg}");
                for item in items {
                    metrics.record_failed();
                    let _ = item.reply.send(Err(msg.clone()));
                }
            }
            Err(payload) => {
                // panic: fail THIS batch only, then rebuild the replica
                // from the factory — mid-batch state may be corrupt
                let msg = format!(
                    "embed panicked: {} (batch failed, replica restarted)",
                    panic_message(payload.as_ref())
                );
                log::error!("{msg}");
                metrics.record_panic();
                for item in items {
                    metrics.record_failed();
                    let _ = item.reply.send(Err(msg.clone()));
                }
                method = factory.build();
                metrics.record_replica_restart();
            }
        }
    }
}

/// Score every row of a served batch against the landmark configuration
/// and feed the drift monitor (scores computed outside the monitor lock).
/// Non-finite scores (NaN deltas or diverged coordinates) are dropped:
/// they carry no drift signal, and a NaN would panic the monitor's median
/// sort OUTSIDE the executor's unwind fence.
fn feed_drift(ds: &DriftState, items: &[WorkItem], coords: &Matrix, metrics: &Metrics) {
    let scores: Vec<f64> = items
        .iter()
        .enumerate()
        .map(|(r, item)| {
            DriftMonitor::score(&ds.landmark_config, &item.delta, coords.row(r))
        })
        .filter(|s| s.is_finite())
        .collect();
    if scores.is_empty() {
        return;
    }
    let mut mon = match ds.monitor.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    for s in scores {
        let status = mon.push(s);
        metrics.record_drift(status);
    }
}

impl<T: ?Sized + Send + Sync + 'static> ServerHandle<T> {
    /// Async query: returns a receiver that yields the result. Accepts any
    /// owned form of the object (`String`/`&str` for `T = str`,
    /// `Vec<f32>`/`&[f32]` for `T = [f32]`, ...).
    pub fn query<O: Into<Box<T>>>(&self, obj: O) -> Receiver<Result<QueryResult, String>> {
        let obj: Box<T> = obj.into();
        let (reply, rx) = channel();
        let started = Instant::now();
        self.metrics.record_request();
        let landmarks = Arc::clone(&self.landmarks);
        let metric = Arc::clone(&self.metric);
        let tx = self.tx.clone();
        let metrics = Arc::clone(&self.metrics);
        self.pool.submit(move || {
            let t0 = Instant::now();
            let delta: Vec<f32> = landmarks
                .iter()
                .map(|lm| metric.dist(&obj, lm) as f32)
                .collect();
            metrics.record_dist(t0.elapsed());
            let item = WorkItem { delta, started, reply };
            // backpressure: block if the queue is full
            if let Err(e) = tx.send(item) {
                let WorkItem { reply, .. } = e.0;
                metrics.record_failed();
                let _ = reply.send(Err("server shutting down".into()));
            }
        });
        rx
    }

    /// Query with a precomputed distance row (bypasses the frontend).
    /// Rejects wrong-length rows at submission — a mis-sized row used to
    /// panic `copy_from_slice` inside the batcher and kill the service.
    pub fn query_delta(
        &self,
        delta: Vec<f32>,
    ) -> Result<Receiver<Result<QueryResult, String>>, String> {
        if delta.len() != self.landmarks.len() {
            return Err(format!(
                "delta row has {} entries, expected {} (one per landmark)",
                delta.len(),
                self.landmarks.len()
            ));
        }
        let (reply, rx) = channel();
        self.metrics.record_request();
        let item = WorkItem { delta, started: Instant::now(), reply };
        match self.tx.try_send(item) {
            Ok(()) => {}
            Err(TrySendError::Full(item)) => {
                // blocking fallback under overload; the executors can still
                // vanish mid-wait, so the disconnect path mirrors below
                if let Err(e) = self.tx.send(item) {
                    let WorkItem { reply, .. } = e.0;
                    self.metrics.record_failed();
                    let _ = reply.send(Err("server shutting down".into()));
                }
            }
            Err(TrySendError::Disconnected(item)) => {
                self.metrics.record_failed();
                let _ = item.reply.send(Err("server shutting down".into()));
            }
        }
        Ok(rx)
    }

    /// Blocking query.
    pub fn query_sync<O: Into<Box<T>>>(&self, obj: O) -> Result<QueryResult, String> {
        self.query(obj)
            .recv()
            .map_err(|_| "server dropped the request".to_string())?
    }

    /// The landmark objects this server measures queries against.
    pub fn landmark_objects(&self) -> &[Box<T>] {
        &self.landmarks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{MlpParams, MlpShape};
    use crate::ose::{factory_fn, RustNn};
    use crate::util::prng::Rng;

    fn tiny_factory() -> Arc<dyn OseMethodFactory> {
        let mut rng = Rng::new(1);
        let params = MlpParams::init(
            &MlpShape { input: 16, hidden: [8, 8, 8], output: 3 },
            &mut rng,
        );
        factory_fn(move || Box::new(RustNn { params: params.clone() }))
    }

    fn tiny_server(max_batch: usize, delay_ms: u64, replicas: usize) -> Server<str> {
        let landmarks: Vec<String> =
            (0..16).map(|i| format!("landmark{i:02}")).collect();
        Server::start_strings(
            landmarks,
            Arc::new(crate::strdist::Levenshtein),
            tiny_factory(),
            BatcherConfig {
                max_batch,
                max_delay: Duration::from_millis(delay_ms),
                queue_cap: 128,
                frontend_threads: 2,
                replicas,
            },
            None,
        )
    }

    #[test]
    fn serves_queries_end_to_end() {
        let server = tiny_server(8, 2, 1);
        let h = server.handle();
        let mut rxs = Vec::new();
        for i in 0..40 {
            rxs.push(h.query(format!("query name {i}")));
        }
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.coords.len(), 3);
            assert!(r.coords.iter().all(|c| c.is_finite()));
        }
        let snap = h.metrics.snapshot();
        assert_eq!(snap.completed, 40);
        assert_eq!(snap.failed, 0);
        assert!(snap.batches <= 40);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn replicated_pool_serves_everything_exactly_once() {
        let server = tiny_server(8, 1, 4);
        let h = server.handle();
        let rxs: Vec<_> = (0..200)
            .map(|i| h.query(format!("replicated query {i}")))
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.coords.len(), 3);
            assert!(rx.try_recv().is_err(), "duplicate reply");
        }
        let snap = h.metrics.snapshot();
        assert_eq!(snap.completed, 200);
        assert_eq!(snap.failed, 0);
        assert_eq!(snap.replicas, 4);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn single_query_dispatches_without_waiting_for_full_batch() {
        // de-flaked: instead of a CI-hostile wall-clock bound, assert the
        // dispatch behaviour — a lone request must go out as a batch of 1
        // (the max_delay deadline), not wait for max_batch peers
        let server = tiny_server(64, 5, 1);
        let h = server.handle();
        let rx = h.query("solo query");
        let r = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("lone query must be dispatched by the deadline")
            .unwrap();
        assert_eq!(r.coords.len(), 3);
        let snap = h.metrics.snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.batches, 1, "must dispatch exactly one batch");
        assert!(
            (snap.mean_batch_size - 1.0).abs() < 1e-9,
            "lone query dispatched as batch of {}",
            snap.mean_batch_size
        );
        drop(h);
        server.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let server = tiny_server(32, 20, 1);
        let h = server.handle();
        let rxs: Vec<_> = (0..64)
            .map(|_| h.query_delta(vec![1.0; 16]).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let snap = h.metrics.snapshot();
        assert!(
            snap.mean_batch_size > 1.5,
            "no batching: mean={}",
            snap.mean_batch_size
        );
        drop(h);
        server.shutdown();
    }

    #[test]
    fn query_delta_rejects_wrong_length_at_submission() {
        let server = tiny_server(8, 2, 2);
        let h = server.handle();
        // too short and too long both fail fast instead of panicking the
        // executor via copy_from_slice
        assert!(h.query_delta(vec![1.0; 3]).is_err());
        assert!(h.query_delta(vec![1.0; 17]).is_err());
        assert!(h.query_delta(vec![]).is_err());
        // the service is still healthy afterwards
        let ok = h.query_delta(vec![1.0; 16]).unwrap();
        assert!(ok.recv().unwrap().is_ok());
        let snap = h.metrics.snapshot();
        assert_eq!(snap.completed, 1);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn results_are_request_specific() {
        // two very different queries must not get each other's coordinates
        let server = tiny_server(2, 50, 1);
        let h = server.handle();
        let rx_a = h.query("aaaaaaaaaaaaaaaa");
        let rx_b = h.query("zz");
        let a = rx_a.recv().unwrap().unwrap();
        let b = rx_b.recv().unwrap().unwrap();
        // deterministic MLP: same input -> same output; check self-consistency
        let a2 = h.query_sync("aaaaaaaaaaaaaaaa").unwrap();
        assert_eq!(a.coords, a2.coords);
        assert_ne!(a.coords, b.coords);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn drift_monitor_feeds_from_served_queries() {
        let mut rng = Rng::new(5);
        let landmarks: Vec<String> =
            (0..16).map(|i| format!("landmark{i:02}")).collect();
        let server = Server::start_strings(
            landmarks,
            Arc::new(crate::strdist::Levenshtein),
            tiny_factory(),
            BatcherConfig { replicas: 2, ..Default::default() },
            Some(DriftHook {
                landmark_config: Matrix::random_normal(&mut rng, 16, 3, 1.0),
                cfg: DriftConfig { window: 8, calibration: 8, degrade_factor: 1e9 },
            }),
        );
        let h = server.handle();
        let rxs: Vec<_> = (0..40)
            .map(|i| h.query(format!("drift query {i}")))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(h.metrics.snapshot().completed, 40);
        // calibration (8) + half-window fill done after 40 queries; an
        // astronomical degrade factor keeps a stationary stream Healthy.
        // Scores land just AFTER the replies, so poll with a bounded wait.
        let t0 = Instant::now();
        loop {
            let snap = h.metrics.snapshot();
            if snap.drift_status == Some(crate::coordinator::DriftStatus::Healthy) {
                assert_eq!(snap.drift_signals, 0);
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "drift monitor never reported Healthy: {:?}",
                snap.drift_status
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        drop(h);
        server.shutdown();
    }
}
