//! Streaming OSE service: the "high performance" serving half of the paper
//! (fast DR on streaming datasets). vLLM-router-shaped:
//!
//! ```text
//!  clients --query--> [frontend pool: Levenshtein distances to landmarks]
//!          --delta row--> [bounded queue] --> [batcher thread]
//!          --batch (padded to artifact shape)--> [OSE method / PJRT]
//!          --coords--> per-request reply channels
//! ```
//!
//! Dynamic batching: a batch is dispatched when it reaches `max_batch` or
//! when its oldest member has waited `max_delay`, whichever first. The
//! bounded queue applies backpressure to the frontend.

use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::mds::Matrix;
use crate::ose::OseMethod;
use crate::strdist::Dissimilarity;
use crate::util::threadpool::WorkerPool;

use super::metrics::Metrics;

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Dispatch as soon as this many requests are pending.
    pub max_batch: usize,
    /// ... or when the oldest pending request has waited this long.
    pub max_delay: Duration,
    /// Bounded queue capacity between frontend and batcher (backpressure).
    pub queue_cap: usize,
    /// Frontend worker threads (distance computation).
    pub frontend_threads: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            queue_cap: 4096,
            frontend_threads: 4,
        }
    }
}

/// A completed query.
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub coords: Vec<f32>,
    pub latency: Duration,
}

struct WorkItem {
    delta: Vec<f32>,
    started: Instant,
    reply: Sender<Result<QueryResult, String>>,
}

/// The OSE serving coordinator for string objects.
///
/// Shutdown semantics: the batcher thread exits when every sender into its
/// queue is gone — i.e. when the server's own handle AND all caller-held
/// clones have been dropped. `shutdown()`/`Drop` releases the server's
/// handle and joins; callers must drop their clones first (or the join
/// blocks until they do).
pub struct Server {
    handle: Option<ServerHandle>,
    batcher: Option<JoinHandle<()>>,
    // keep the pool alive; dropped (and joined) before the batcher
    _frontend: Arc<WorkerPool>,
}

#[derive(Clone)]
pub struct ServerHandle {
    landmarks: Arc<Vec<String>>,
    metric: Arc<dyn Dissimilarity<str> + Send + Sync>,
    pool: Arc<WorkerPool>,
    tx: SyncSender<WorkItem>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    /// Start the service. `method` runs on the batcher thread (it may hold
    /// a [`crate::runtime::Backend`], which is Send).
    pub fn start(
        landmarks: Vec<String>,
        metric: Arc<dyn Dissimilarity<str> + Send + Sync>,
        mut method: Box<dyn OseMethod>,
        cfg: BatcherConfig,
    ) -> Server {
        assert_eq!(
            landmarks.len(),
            method.landmarks(),
            "landmark count must match the OSE method"
        );
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = std::sync::mpsc::sync_channel::<WorkItem>(cfg.queue_cap);
        let pool = Arc::new(WorkerPool::new(cfg.frontend_threads));
        let m2 = Arc::clone(&metrics);
        let bcfg = cfg.clone();
        let batcher = std::thread::Builder::new()
            .name("ose-batcher".into())
            .spawn(move || batcher_loop(rx, &mut *method, &bcfg, &m2))
            .expect("spawning batcher");

        let handle = ServerHandle {
            landmarks: Arc::new(landmarks),
            metric,
            pool: Arc::clone(&pool),
            tx,
            metrics,
        };
        Server { handle: Some(handle), batcher: Some(batcher), _frontend: pool }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone().expect("server already shut down")
    }

    /// Graceful shutdown: waits for in-flight work to drain. All caller
    /// handles must be dropped first, or this blocks until they are.
    pub fn shutdown(mut self) {
        self.join_inner();
    }

    fn join_inner(&mut self) {
        // Release our sender; the batcher exits once all handles are gone.
        self.handle.take();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.join_inner();
    }
}

fn batcher_loop(
    rx: Receiver<WorkItem>,
    method: &mut dyn OseMethod,
    cfg: &BatcherConfig,
    metrics: &Metrics,
) {
    let l = method.landmarks();
    let k = method.dim();
    loop {
        // block for the first item of the next batch
        let first = match rx.recv() {
            Ok(item) => item,
            Err(_) => return, // all senders gone
        };
        let mut items = vec![first];
        // greedily drain the backlog first: under load the queue already
        // holds a full batch and waiting would only add latency
        while items.len() < cfg.max_batch {
            match rx.try_recv() {
                Ok(item) => items.push(item),
                Err(_) => break,
            }
        }
        // under light load, wait up to max_delay (from NOW — not from the
        // request's submit time, which may already be in the past after a
        // queue wait) for stragglers to share the execution
        if items.len() < cfg.max_batch {
            let deadline = Instant::now() + cfg.max_delay;
            while items.len() < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(item) => items.push(item),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        }

        // assemble the batch
        let mut deltas = Matrix::zeros(items.len(), l);
        for (r, item) in items.iter().enumerate() {
            deltas.row_mut(r).copy_from_slice(&item.delta);
        }
        let t0 = Instant::now();
        match method.embed(&deltas) {
            Ok(coords) => {
                metrics.record_batch(items.len(), t0.elapsed());
                debug_assert_eq!(coords.cols, k);
                for (r, item) in items.into_iter().enumerate() {
                    let latency = item.started.elapsed();
                    metrics.record_completed(latency);
                    let _ = item.reply.send(Ok(QueryResult {
                        coords: coords.row(r).to_vec(),
                        latency,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("embed failed: {e:#}");
                log::error!("{msg}");
                for item in items {
                    metrics.record_failed();
                    let _ = item.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

impl ServerHandle {
    /// Async query: returns a receiver that yields the result.
    pub fn query(&self, name: String) -> Receiver<Result<QueryResult, String>> {
        let (reply, rx) = channel();
        let started = Instant::now();
        self.metrics.record_request();
        let landmarks = Arc::clone(&self.landmarks);
        let metric = Arc::clone(&self.metric);
        let tx = self.tx.clone();
        let metrics = Arc::clone(&self.metrics);
        self.pool.submit(move || {
            let t0 = Instant::now();
            let delta: Vec<f32> = landmarks
                .iter()
                .map(|lm| metric.dist(&name, lm) as f32)
                .collect();
            metrics.record_dist(t0.elapsed());
            let item = WorkItem { delta, started, reply };
            // backpressure: block if the queue is full
            if let Err(e) = tx.send(item) {
                let WorkItem { reply, .. } = e.0;
                metrics.record_failed();
                let _ = reply.send(Err("server shutting down".into()));
            }
        });
        rx
    }

    /// Query with a precomputed distance row (bypasses the frontend).
    pub fn query_delta(
        &self,
        delta: Vec<f32>,
    ) -> Receiver<Result<QueryResult, String>> {
        let (reply, rx) = channel();
        self.metrics.record_request();
        let item = WorkItem { delta, started: Instant::now(), reply };
        match self.tx.try_send(item) {
            Ok(()) => {}
            Err(TrySendError::Full(item)) => {
                // blocking fallback under overload
                let _ = self.tx.send(item);
            }
            Err(TrySendError::Disconnected(item)) => {
                self.metrics.record_failed();
                let _ = item.reply.send(Err("server shutting down".into()));
            }
        }
        rx
    }

    /// Blocking query.
    pub fn query_sync(&self, name: &str) -> Result<QueryResult, String> {
        self.query(name.to_string())
            .recv()
            .map_err(|_| "server dropped the request".to_string())?
    }

    pub fn landmark_names(&self) -> &[String] {
        &self.landmarks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{MlpParams, MlpShape};
    use crate::ose::RustNn;
    use crate::util::prng::Rng;

    fn tiny_server(max_batch: usize, delay_ms: u64) -> Server {
        let mut rng = Rng::new(1);
        let landmarks: Vec<String> =
            (0..16).map(|i| format!("landmark{i:02}")).collect();
        let params = MlpParams::init(
            &MlpShape { input: 16, hidden: [8, 8, 8], output: 3 },
            &mut rng,
        );
        Server::start(
            landmarks,
            Arc::new(crate::strdist::Levenshtein),
            Box::new(RustNn { params }),
            BatcherConfig {
                max_batch,
                max_delay: Duration::from_millis(delay_ms),
                queue_cap: 128,
                frontend_threads: 2,
            },
        )
    }

    #[test]
    fn serves_queries_end_to_end() {
        let server = tiny_server(8, 2);
        let h = server.handle();
        let mut rxs = Vec::new();
        for i in 0..40 {
            rxs.push(h.query(format!("query name {i}")));
        }
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.coords.len(), 3);
            assert!(r.coords.iter().all(|c| c.is_finite()));
        }
        let snap = h.metrics.snapshot();
        assert_eq!(snap.completed, 40);
        assert_eq!(snap.failed, 0);
        assert!(snap.batches <= 40);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn single_query_latency_bounded_by_max_delay() {
        let server = tiny_server(64, 5);
        let h = server.handle();
        let r = h.query_sync("solo query").unwrap();
        // a lone request must be dispatched by the deadline, not wait for
        // a full batch
        assert!(
            r.latency < Duration::from_millis(200),
            "latency {:?}",
            r.latency
        );
        drop(h);
        server.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let server = tiny_server(32, 20);
        let h = server.handle();
        let rxs: Vec<_> = (0..64)
            .map(|_| h.query_delta(vec![1.0; 16]))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let snap = h.metrics.snapshot();
        assert!(
            snap.mean_batch_size > 1.5,
            "no batching: mean={}",
            snap.mean_batch_size
        );
        drop(h);
        server.shutdown();
    }

    #[test]
    fn results_are_request_specific() {
        // two very different queries must not get each other's coordinates
        let server = tiny_server(2, 50);
        let h = server.handle();
        let rx_a = h.query("aaaaaaaaaaaaaaaa".to_string());
        let rx_b = h.query("zz".to_string());
        let a = rx_a.recv().unwrap().unwrap();
        let b = rx_b.recv().unwrap().unwrap();
        // deterministic MLP: same input -> same output; check self-consistency
        let a2 = h.query_sync("aaaaaaaaaaaaaaaa").unwrap();
        assert_eq!(a.coords, a2.coords);
        assert_ne!(a.coords, b.coords);
        drop(h);
        server.shutdown();
    }
}
