//! Streaming OSE service: the "high performance" serving half of the paper
//! (fast DR on streaming datasets), rebuilt as a fault-isolated replicated
//! executor pool:
//!
//! ```text
//!  clients --submit--> [frontend pool: dissimilarities to landmarks]
//!          --delta row--> [bounded dispatch queue]
//!          --batch--> [executor replica 0..R-1, each owns an OseMethod]
//!          --coords--> per-request reply sinks (+ drift monitor feed)
//! ```
//!
//! Dynamic batching: an executor dispatches a batch when it reaches
//! `max_batch` or when its oldest member has waited `max_delay`, whichever
//! first. The bounded queue applies backpressure to the frontend.
//!
//! Fault isolation: each executor wraps `embed` in `catch_unwind`. A
//! poisoned batch fails *that batch* — its callers get
//! [`ServeError::ReplicaPanic`] replies, the replica is rebuilt from the
//! [`OseMethodFactory`] (mid-batch state may be corrupt), and every other
//! replica keeps serving.
//!
//! The serving API (PR 6 redesign):
//! - construction goes through [`ServerBuilder`], validated at
//!   [`ServerBuilder::build`];
//! - every query enters through [`ServerHandle::submit`] with a typed
//!   [`Request`] and comes back through a [`Ticket`] (or a caller-supplied
//!   [`ReplySink`] via [`ServerHandle::submit_sink`], the zero-thread path
//!   the network front door uses);
//! - every failure is a typed [`ServeError`] with a stable wire code.
//!
//! The server is generic over the object domain `T: ?Sized` (strings,
//! numeric vectors, anything with a [`Dissimilarity`]), so vector
//! workloads serve through the same path as the paper's string workloads.
//!
//! Per-query solve cost is set by the replica method the factory builds:
//! dense [`super::methods::BackendOpt`] majorizes against all L landmarks,
//! while a `query_k`-restricted factory
//! ([`super::methods::BackendOpt::replica_factory_sparse`]) first walks
//! the landmark small-world graph ([`crate::mds::graph`]) to the query's
//! k nearest landmarks and solves the k-row sub-problem —
//! O(k log L + k·steps) instead of O(L·steps) per query. The full
//! front-door-to-kernel anatomy of one query, including this choice, is
//! documented in docs/QUERY_PATH.md.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::mds::Matrix;
use crate::ose::{OseMethod, OseMethodFactory};
use crate::runtime::Backend;
use crate::strdist::Dissimilarity;
use crate::util::threadpool::WorkerPool;

use super::error::{panic_message, ServeError};
use super::metrics::Metrics;
use super::shard::ShardConfig;
use super::stream::{DriftConfig, DriftMonitor};

#[derive(Clone, Debug)]
/// Dynamic-batching shape of the serving loop: when a batch dispatches,
/// how deep the queue may grow, and how many frontend/executor workers
/// run.
pub struct BatcherConfig {
    /// Dispatch as soon as this many requests are pending.
    pub max_batch: usize,
    /// ... or when the oldest pending request has waited this long.
    pub max_delay: Duration,
    /// Bounded queue capacity between frontend and executors (backpressure).
    pub queue_cap: usize,
    /// Frontend worker threads (distance computation).
    pub frontend_threads: usize,
    /// OSE executor replicas pulling batches from the shared queue. Each
    /// replica owns an independent method instance built by the factory.
    pub replicas: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            queue_cap: 4096,
            frontend_threads: 4,
            replicas: 1,
        }
    }
}

/// Attach a [`DriftMonitor`] to the serving loop: every served query feeds
/// its normalised Eq.-2 score (mapped coordinates vs the landmark
/// configuration), and the resulting status / re-embed signal surfaces in
/// [`Metrics::snapshot`].
pub struct DriftHook {
    /// L x K landmark configuration the monitor scores against.
    pub landmark_config: Matrix,
    /// Monitor window/calibration settings.
    pub cfg: DriftConfig,
}

pub(crate) struct DriftState {
    pub(crate) landmark_config: Matrix,
    pub(crate) monitor: Mutex<DriftMonitor>,
}

impl DriftState {
    pub(crate) fn from_hook(h: DriftHook) -> Self {
        Self {
            landmark_config: h.landmark_config,
            monitor: Mutex::new(DriftMonitor::new(h.cfg)),
        }
    }
}

/// A completed query.
#[derive(Clone, Debug)]
pub struct QueryResult {
    /// Embedded coordinates of the query (length K).
    pub coords: Vec<f32>,
    /// End-to-end latency as measured by the server.
    pub latency: Duration,
    /// True when the result was reduced from a partial shard quorum
    /// (some shard's contribution is missing). Always false on the
    /// unsharded path.
    pub degraded: bool,
}

/// A query, either as a raw object (the frontend computes its landmark
/// distances) or as a precomputed delta row (bypasses the frontend).
pub enum Request<T: ?Sized> {
    /// An object in the server's domain; distances are computed by the
    /// frontend pool with the server's [`Dissimilarity`].
    Object(Box<T>),
    /// A precomputed row of distances to the landmarks (length L).
    Delta(Vec<f32>),
}

impl<T: ?Sized> Request<T> {
    /// Wrap any owned form of an object (`String`/`&str` for `T = str`,
    /// `Vec<f32>`/`&[f32]` for `T = [f32]`, ...).
    pub fn object<O: Into<Box<T>>>(obj: O) -> Request<T> {
        Request::Object(obj.into())
    }

    /// Wrap a precomputed delta row (one distance per landmark).
    pub fn delta(row: Vec<f32>) -> Request<T> {
        Request::Delta(row)
    }
}

/// Completion callback for one request: invoked exactly once, from
/// whichever server thread finishes (or fails) the request. The
/// thread-free alternative to [`Ticket`] — the network front door hands
/// one of these to [`ServerHandle::submit_sink`] so no thread ever parks
/// waiting for a result.
pub type ReplySink = Box<dyn FnOnce(Result<QueryResult, ServeError>) + Send>;

/// A pending query submitted through [`ServerHandle::submit`]: a one-shot
/// handle the result arrives on.
pub struct Ticket {
    rx: Receiver<Result<QueryResult, ServeError>>,
}

impl Ticket {
    pub(crate) fn new(rx: Receiver<Result<QueryResult, ServeError>>) -> Self {
        Self { rx }
    }

    /// Block until the result arrives. A server torn down mid-flight
    /// yields [`ServeError::Shutdown`].
    pub fn recv(&self) -> Result<QueryResult, ServeError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServeError::Shutdown),
        }
    }

    /// Block up to `timeout` for the result; [`ServeError::Timeout`] when
    /// it expires.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<QueryResult, ServeError> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err(ServeError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(ServeError::Shutdown),
        }
    }

    /// Non-blocking poll: `None` while the query is still in flight.
    pub fn try_recv(&self) -> Option<Result<QueryResult, ServeError>> {
        self.rx.try_recv().ok()
    }

    /// Consume the ticket and block for the result (the one-expression
    /// form of a synchronous query).
    pub fn recv_sync(self) -> Result<QueryResult, ServeError> {
        self.recv()
    }

    /// Unwrap into the raw channel receiver (for select-style callers and
    /// the deprecated shims).
    pub fn into_receiver(self) -> Receiver<Result<QueryResult, ServeError>> {
        self.rx
    }
}

pub(crate) struct WorkItem {
    pub(crate) delta: Vec<f32>,
    pub(crate) started: Instant,
    pub(crate) reply: ReplySink,
}

/// Callback the refresh controller installs on the query path: every raw
/// [`Request::Object`] submission is offered to it (before the frontend
/// computes distances), which is how recent queries end up in the ingest
/// buffer a refresh appends to the corpus.
pub(crate) type IngestTap<T> = Arc<dyn Fn(&T) + Send + Sync>;

/// One serving generation: the landmark objects queries are measured
/// against, the dispatch queue feeding that generation's executor
/// replicas, and the generation tag. A hot refresh builds a successor
/// and swaps it in under the core's engine lock; dropping the old `tx`
/// here is exactly what lets the retired executors drain and exit.
struct Engine<T: ?Sized> {
    landmarks: Arc<Vec<Box<T>>>,
    tx: SyncSender<WorkItem>,
    generation: u64,
}

/// State shared by every handle clone (and the [`Server`] itself): the
/// current [`Engine`], the executor threads of the live generation, and
/// everything needed to spawn a successor generation at swap time.
struct ServerCore<T: ?Sized + Send + Sync + 'static> {
    engine: RwLock<Engine<T>>,
    metric: Arc<dyn Dissimilarity<T> + Send + Sync>,
    pool: Arc<WorkerPool>,
    metrics: Arc<Metrics>,
    batcher: BatcherConfig,
    /// Drift-monitor settings carried across generations (each swap arms
    /// a FRESH monitor that recalibrates on post-swap traffic).
    drift_cfg: Option<DriftConfig>,
    /// Executor join handles of the live generation; a swap replaces the
    /// set and joins the retired one (measuring the drain).
    executors: Mutex<Vec<JoinHandle<()>>>,
    ingest: RwLock<Option<IngestTap<T>>>,
}

impl<T: ?Sized + Send + Sync + 'static> ServerCore<T> {
    /// Read the live engine. Lock poisoning is tolerated (the engine is
    /// only ever written by swap/shutdown, and a panicked writer leaves
    /// it in a consistent state): the serving path must not panic.
    fn engine_read(&self) -> std::sync::RwLockReadGuard<'_, Engine<T>> {
        match self.engine.read() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn engine_write(&self) -> std::sync::RwLockWriteGuard<'_, Engine<T>> {
        match self.engine.write() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }
}

/// The OSE serving coordinator, generic over the object domain.
///
/// Shutdown semantics: `shutdown()`/`Drop` disconnects the dispatch
/// queue (late submits get [`ServeError::Shutdown`]) and joins the
/// executors once queued and in-flight work has drained. Caller-held
/// handle clones stay valid pointers but every submission through them
/// fails with `Shutdown` afterwards.
pub struct Server<T: ?Sized + Send + Sync + 'static> {
    handle: Option<ServerHandle<T>>,
    core: Arc<ServerCore<T>>,
    // keep the pool alive; dropped (and joined) after the executors
    _frontend: Arc<WorkerPool>,
}

/// Cheap-to-clone client handle: submits queries into the batching
/// queue of the current serving generation and exposes the shared
/// [`Metrics`]. A hot refresh (`coordinator::refresh`) swaps the
/// generation underneath all clones atomically.
pub struct ServerHandle<T: ?Sized + Send + Sync + 'static> {
    core: Arc<ServerCore<T>>,
    /// Shared serving counters (live; see [`Metrics::snapshot`]).
    pub metrics: Arc<Metrics>,
}

// manual impl: derive(Clone) would demand T: Clone, which Box-shared
// unsized objects neither need nor can provide
impl<T: ?Sized + Send + Sync + 'static> Clone for ServerHandle<T> {
    fn clone(&self) -> Self {
        Self {
            core: Arc::clone(&self.core),
            metrics: Arc::clone(&self.metrics),
        }
    }
}

/// Validated construction of a [`Server`] (and, via
/// [`ServerBuilder::build_sharded`], of a
/// [`ShardedServer`](super::shard::ShardedServer)): collects the batcher
/// shape, replica count, drift hook, shard plan and limits, then checks
/// the whole configuration once at `build()`.
///
/// ```ignore
/// let server = Server::builder(landmarks, metric, factory)
///     .batcher(cfg.batcher())
///     .replicas(4)
///     .build()?;
/// ```
pub struct ServerBuilder<T: ?Sized + Send + Sync + 'static> {
    pub(crate) landmarks: Vec<Box<T>>,
    pub(crate) metric: Arc<dyn Dissimilarity<T> + Send + Sync>,
    pub(crate) factory: Arc<dyn OseMethodFactory>,
    pub(crate) batcher: BatcherConfig,
    pub(crate) drift: Option<DriftHook>,
    pub(crate) landmark_config: Option<Matrix>,
    pub(crate) shard_cfg: ShardConfig,
    pub(crate) backend: Backend,
}

impl ServerBuilder<str> {
    /// Builder for the common string workload.
    pub fn strings(
        landmarks: Vec<String>,
        metric: Arc<dyn Dissimilarity<str> + Send + Sync>,
        factory: Arc<dyn OseMethodFactory>,
    ) -> ServerBuilder<str> {
        Server::builder(
            landmarks.into_iter().map(String::into_boxed_str).collect(),
            metric,
            factory,
        )
    }
}

impl<T: ?Sized + Send + Sync + 'static> ServerBuilder<T> {
    /// Set the dynamic-batching shape (queue depth, batch size, delays,
    /// worker counts). [`crate::coordinator::RunConfig::batcher`] produces
    /// one from the shared CLI/config-file path.
    pub fn batcher(mut self, cfg: BatcherConfig) -> Self {
        self.batcher = cfg;
        self
    }

    /// Set the executor replica count (shorthand for mutating the batcher
    /// config).
    pub fn replicas(mut self, replicas: usize) -> Self {
        self.batcher.replicas = replicas;
        self
    }

    /// Attach a drift monitor fed by every served query.
    pub fn drift(mut self, hook: DriftHook) -> Self {
        self.drift = Some(hook);
        self
    }

    /// Provide the L x K landmark configuration. Required for
    /// [`Self::build_sharded`] (each shard re-solves against its slice of
    /// it); ignored by the unsharded [`Self::build`].
    pub fn landmark_config(mut self, config: Matrix) -> Self {
        self.landmark_config = Some(config);
        self
    }

    /// Set the shard plan used by [`Self::build_sharded`].
    pub fn shards(mut self, cfg: ShardConfig) -> Self {
        self.shard_cfg = cfg;
        self
    }

    /// Compute backend the per-shard optimisation methods run on
    /// (sharded path only; the unsharded path uses the factory as given).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Validate the configuration and start the unsharded replicated
    /// server.
    pub fn build(self) -> Result<Server<T>, ServeError> {
        let probe = self.factory.build();
        if self.landmarks.len() != probe.landmarks() {
            return Err(ServeError::BadInput {
                reason: format!(
                    "{} landmarks but the OSE method expects {}",
                    self.landmarks.len(),
                    probe.landmarks()
                ),
            });
        }
        if let Some(h) = &self.drift {
            let want = (probe.landmarks(), probe.dim());
            let got = (h.landmark_config.rows, h.landmark_config.cols);
            if got != want {
                return Err(ServeError::BadInput {
                    reason: format!(
                        "drift hook landmark configuration is {}x{}, expected {}x{}",
                        got.0, got.1, want.0, want.1
                    ),
                });
            }
        }
        let cfg = self.batcher;
        let metrics = Arc::new(Metrics::new());
        metrics.set_replicas(cfg.replicas.max(1));
        let pool = Arc::new(WorkerPool::new(cfg.frontend_threads));
        let drift_cfg = self.drift.as_ref().map(|h| h.cfg.clone());
        let drift = self.drift.map(|h| Arc::new(DriftState::from_hook(h)));

        let (tx, executors) =
            spawn_generation(Arc::clone(&self.factory), Some(probe), &cfg, &metrics, drift, 0)?;

        let core = Arc::new(ServerCore {
            engine: RwLock::new(Engine {
                landmarks: Arc::new(self.landmarks),
                tx,
                generation: 0,
            }),
            metric: self.metric,
            pool: Arc::clone(&pool),
            metrics: Arc::clone(&metrics),
            batcher: cfg,
            drift_cfg,
            executors: Mutex::new(executors),
            ingest: RwLock::new(None),
        });
        let handle = ServerHandle { core: Arc::clone(&core), metrics };
        Ok(Server { handle: Some(handle), core, _frontend: pool })
    }
}

/// Spawn one generation's executor replica pool: a fresh bounded
/// dispatch queue plus `cfg.replicas` threads running [`executor_loop`].
/// The first replica reuses `first` (the builder's validation probe, or
/// the refresh controller's); the rest are built from the factory. The
/// replicas exit once every clone of the returned sender is gone —
/// which is exactly how a generation swap retires them.
fn spawn_generation(
    factory: Arc<dyn OseMethodFactory>,
    mut first: Option<Box<dyn OseMethod>>,
    cfg: &BatcherConfig,
    metrics: &Arc<Metrics>,
    drift: Option<Arc<DriftState>>,
    generation: u64,
) -> Result<(SyncSender<WorkItem>, Vec<JoinHandle<()>>), ServeError> {
    let replicas = cfg.replicas.max(1);
    let (tx, rx) = std::sync::mpsc::sync_channel::<WorkItem>(cfg.queue_cap.max(1));
    let rx = Arc::new(Mutex::new(rx));
    let mut executors = Vec::with_capacity(replicas);
    for i in 0..replicas {
        let method = first.take().unwrap_or_else(|| factory.build());
        let rx = Arc::clone(&rx);
        let factory = Arc::clone(&factory);
        let metrics = Arc::clone(metrics);
        let drift = drift.clone();
        let ecfg = cfg.clone();
        let handle = std::thread::Builder::new()
            .name(format!("ose-exec-g{generation}-{i}"))
            .spawn(move || {
                executor_loop(
                    &rx,
                    method,
                    factory.as_ref(),
                    &ecfg,
                    &metrics,
                    drift.as_deref(),
                )
            })
            .map_err(|e| ServeError::Internal {
                reason: format!("spawning executor replica {i}: {e}"),
            })?;
        executors.push(handle);
    }
    Ok((tx, executors))
}

impl Server<str> {
    /// Deprecated positional constructor for the string workload.
    #[deprecated(since = "0.6.0", note = "use ServerBuilder::strings(...).build()")]
    pub fn start_strings(
        landmarks: Vec<String>,
        metric: Arc<dyn Dissimilarity<str> + Send + Sync>,
        factory: Arc<dyn OseMethodFactory>,
        cfg: BatcherConfig,
        drift: Option<DriftHook>,
    ) -> Server<str> {
        let mut b = ServerBuilder::strings(landmarks, metric, factory).batcher(cfg);
        if let Some(h) = drift {
            b = b.drift(h);
        }
        // LINT-ALLOW(panic): deprecated infallible-signature shim; build() is the fix.
        b.build().expect("invalid server configuration")
    }
}

impl<T: ?Sized + Send + Sync + 'static> Server<T> {
    /// Builder-style construction (see [`ServerBuilder`]). The method
    /// instances come from `factory` (methods may hold a
    /// [`crate::runtime::Backend`], which is Send).
    pub fn builder(
        landmarks: Vec<Box<T>>,
        metric: Arc<dyn Dissimilarity<T> + Send + Sync>,
        factory: Arc<dyn OseMethodFactory>,
    ) -> ServerBuilder<T> {
        ServerBuilder {
            landmarks,
            metric,
            factory,
            batcher: BatcherConfig::default(),
            drift: None,
            landmark_config: None,
            shard_cfg: ShardConfig::default(),
            backend: Backend::native(),
        }
    }

    /// Deprecated positional constructor.
    #[deprecated(since = "0.6.0", note = "use Server::builder(...).build()")]
    pub fn start(
        landmarks: Vec<Box<T>>,
        metric: Arc<dyn Dissimilarity<T> + Send + Sync>,
        factory: Arc<dyn OseMethodFactory>,
        cfg: BatcherConfig,
        drift: Option<DriftHook>,
    ) -> Server<T> {
        let mut b = Self::builder(landmarks, metric, factory).batcher(cfg);
        if let Some(h) = drift {
            b = b.drift(h);
        }
        // LINT-ALLOW(panic): deprecated infallible-signature shim; build() is the fix.
        b.build().expect("invalid server configuration")
    }

    /// A new client handle onto the running server.
    ///
    /// # Panics
    /// After [`Server::shutdown`] has consumed the handle.
    pub fn handle(&self) -> ServerHandle<T> {
        // LINT-ALLOW(panic): documented contract; use after shutdown is a caller bug.
        self.handle.clone().expect("server already shut down")
    }

    /// Graceful shutdown: disconnects the dispatch queue (late submits
    /// get [`ServeError::Shutdown`]) and waits for queued and in-flight
    /// work to drain.
    pub fn shutdown(mut self) {
        self.join_inner();
    }

    fn join_inner(&mut self) {
        self.handle.take();
        // Swap the live sender for one whose receiver is already gone:
        // the executors drain the queue and exit, and any submission
        // racing the shutdown fails cleanly with Shutdown instead of
        // blocking on a queue nobody serves.
        let (dead_tx, _) = std::sync::mpsc::sync_channel::<WorkItem>(1);
        self.core.engine_write().tx = dead_tx;
        let handles: Vec<JoinHandle<()>> = {
            let mut ex = match self.core.executors.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            ex.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

impl<T: ?Sized + Send + Sync + 'static> Drop for Server<T> {
    fn drop(&mut self) {
        self.join_inner();
    }
}

/// One executor replica: form a batch from the shared queue, embed it, and
/// reply — with `catch_unwind` fencing so a poisoned batch cannot take the
/// replica (let alone the service) down. Shared with the per-shard pools
/// in [`super::shard`].
pub(crate) fn executor_loop(
    rx: &Mutex<Receiver<WorkItem>>,
    mut method: Box<dyn OseMethod>,
    factory: &dyn OseMethodFactory,
    cfg: &BatcherConfig,
    metrics: &Metrics,
    drift: Option<&DriftState>,
) {
    let l = method.landmarks();
    let k = method.dim();
    loop {
        // Form the next batch while holding the queue lock: the lock both
        // shares the single consumer end across replicas and guarantees
        // each item lands in exactly one batch. Holding it through the
        // straggler wait is deliberate — arrivals during the wait belong in
        // THIS batch; a peer replica grabbing them would only shrink it.
        let items = {
            let queue = match rx.lock() {
                Ok(g) => g,
                // a poisoned queue lock means a peer panicked INSIDE batch
                // formation (not embed) — unrecoverable by design
                Err(_) => return,
            };
            // block for the first item of the next batch
            let first = match queue.recv() {
                Ok(item) => item,
                Err(_) => return, // all senders gone
            };
            let mut items = vec![first];
            // greedily drain the backlog first: under load the queue
            // already holds a full batch and waiting would only add latency
            while items.len() < cfg.max_batch {
                match queue.try_recv() {
                    Ok(item) => items.push(item),
                    Err(_) => break,
                }
            }
            // under light load, wait up to max_delay (from NOW — not from
            // the request's submit time, which may already be in the past
            // after a queue wait) for stragglers to share the execution
            if items.len() < cfg.max_batch {
                let deadline = Instant::now() + cfg.max_delay;
                while items.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match queue.recv_timeout(deadline - now) {
                        Ok(item) => items.push(item),
                        Err(_) => break, // timeout or disconnected
                    }
                }
            }
            items
        }; // lock released: embedding runs concurrently across replicas

        // defensive depth check — submit validates at submission, so a
        // mismatch here means a bug, but it must not poison the batch
        let (items, bad): (Vec<_>, Vec<_>) =
            items.into_iter().partition(|it| it.delta.len() == l);
        for item in bad {
            metrics.record_failed();
            let reason = format!(
                "delta row has {} entries, expected {l}",
                item.delta.len()
            );
            (item.reply)(Err(ServeError::BadInput { reason }));
        }
        if items.is_empty() {
            continue;
        }

        let t0 = Instant::now();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            let mut deltas = Matrix::zeros(items.len(), l);
            for (r, item) in items.iter().enumerate() {
                deltas.row_mut(r).copy_from_slice(&item.delta);
            }
            method.embed(&deltas)
        }));
        match outcome {
            // a mis-shaped result would panic row() below, OUTSIDE the
            // unwind fence — demote it to a clean batch failure instead
            Ok(Ok(coords)) if coords.rows != items.len() || coords.cols != k => {
                let reason = format!(
                    "embed returned {}x{}, expected {}x{k}",
                    coords.rows,
                    coords.cols,
                    items.len()
                );
                log::error!("{reason}");
                for item in items {
                    metrics.record_failed();
                    (item.reply)(Err(ServeError::Internal { reason: reason.clone() }));
                }
            }
            Ok(Ok(coords)) => {
                metrics.record_batch(items.len(), t0.elapsed());
                // reply FIRST: drift scoring is observability, and must not
                // sit on the callers' latency path
                let mut served_deltas = Vec::new();
                for (r, item) in items.into_iter().enumerate() {
                    let latency = item.started.elapsed();
                    metrics.record_completed(latency);
                    (item.reply)(Ok(QueryResult {
                        coords: coords.row(r).to_vec(),
                        latency,
                        degraded: false,
                    }));
                    if drift.is_some() {
                        served_deltas.push(item.delta);
                    }
                }
                if let Some(ds) = drift {
                    feed_drift(ds, &served_deltas, &coords, metrics);
                }
            }
            Ok(Err(e)) => {
                // clean error from the method: the batch fails, the replica
                // state is intact — no restart needed
                let reason = format!("embed failed: {e:#}");
                log::error!("{reason}");
                for item in items {
                    metrics.record_failed();
                    (item.reply)(Err(ServeError::Internal { reason: reason.clone() }));
                }
            }
            Err(payload) => {
                // panic: fail THIS batch only, then rebuild the replica
                // from the factory — mid-batch state may be corrupt
                let reason = format!(
                    "{} (batch failed, replica restarted)",
                    panic_message(payload.as_ref())
                );
                log::error!("embed panicked: {reason}");
                metrics.record_panic();
                for item in items {
                    metrics.record_failed();
                    (item.reply)(Err(ServeError::ReplicaPanic {
                        reason: reason.clone(),
                    }));
                }
                method = factory.build();
                metrics.record_replica_restart();
            }
        }
    }
}

/// Score every row of a served batch against the landmark configuration
/// and feed the drift monitor (scores computed outside the monitor lock).
/// Non-finite scores (NaN deltas or diverged coordinates) are dropped:
/// they carry no drift signal, and a NaN would panic the monitor's median
/// sort OUTSIDE the executor's unwind fence.
pub(crate) fn feed_drift(
    ds: &DriftState,
    deltas: &[Vec<f32>],
    coords: &Matrix,
    metrics: &Metrics,
) {
    let scores: Vec<f64> = deltas
        .iter()
        .enumerate()
        .map(|(r, delta)| {
            DriftMonitor::score(&ds.landmark_config, delta, coords.row(r))
        })
        .filter(|s| s.is_finite())
        .collect();
    if scores.is_empty() {
        return;
    }
    let mut mon = match ds.monitor.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    for s in scores {
        let status = mon.push(s);
        metrics.record_drift(status);
    }
}

impl<T: ?Sized + Send + Sync + 'static> ServerHandle<T> {
    /// Submit a query; the result arrives on the returned [`Ticket`].
    /// This is THE query surface — object and delta requests, async and
    /// blocking consumption, all flow through here.
    pub fn submit(&self, req: Request<T>) -> Ticket {
        let (reply, rx) = channel();
        self.submit_sink(
            req,
            Box::new(move |r| {
                let _ = reply.send(r);
            }),
        );
        Ticket::new(rx)
    }

    /// Submit a query with a completion callback instead of a ticket: the
    /// sink is invoked exactly once from a server thread. Invalid
    /// requests invoke it immediately (still exactly once), so callers
    /// have a single error surface.
    pub fn submit_sink(&self, req: Request<T>, sink: ReplySink) {
        self.metrics.record_request();
        // Pin the current generation for this request: the landmark set
        // and the queue sender are read together under the engine lock,
        // so a concurrent swap can never mix one generation's distances
        // with the other's executors.
        let (landmarks, tx) = {
            let engine = self.core.engine_read();
            (Arc::clone(&engine.landmarks), engine.tx.clone())
        };
        match req {
            Request::Delta(delta) => {
                if delta.len() != landmarks.len() {
                    self.metrics.record_failed();
                    let reason = format!(
                        "delta row has {} entries, expected {} (one per landmark)",
                        delta.len(),
                        landmarks.len()
                    );
                    sink(Err(ServeError::BadInput { reason }));
                    return;
                }
                let item = WorkItem { delta, started: Instant::now(), reply: sink };
                match tx.try_send(item) {
                    Ok(()) => {}
                    Err(TrySendError::Full(item)) => {
                        // blocking fallback under overload; the executors
                        // can still vanish mid-wait, so the disconnect path
                        // mirrors below
                        if let Err(e) = tx.send(item) {
                            let WorkItem { reply, .. } = e.0;
                            self.metrics.record_failed();
                            reply(Err(ServeError::Shutdown));
                        }
                    }
                    Err(TrySendError::Disconnected(item)) => {
                        self.metrics.record_failed();
                        (item.reply)(Err(ServeError::Shutdown));
                    }
                }
            }
            Request::Object(obj) => {
                // Offer the raw object to the refresh controller's
                // ingest tap (cheap clone into a bounded buffer) before
                // it moves into the frontend closure.
                {
                    let tap = match self.core.ingest.read() {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                    if let Some(t) = tap.as_ref() {
                        t(&obj);
                    }
                }
                let metric = Arc::clone(&self.core.metric);
                let metrics = Arc::clone(&self.metrics);
                let started = Instant::now();
                self.core.pool.submit(move || {
                    let t0 = Instant::now();
                    let delta: Vec<f32> = landmarks
                        .iter()
                        .map(|lm| metric.dist(&obj, lm) as f32)
                        .collect();
                    metrics.record_dist(t0.elapsed());
                    let item = WorkItem { delta, started, reply: sink };
                    // backpressure: block if the queue is full
                    if let Err(e) = tx.send(item) {
                        let WorkItem { reply, .. } = e.0;
                        metrics.record_failed();
                        reply(Err(ServeError::Shutdown));
                    }
                });
            }
        }
    }

    /// Deprecated async object query.
    #[deprecated(since = "0.6.0", note = "use submit(Request::object(..))")]
    pub fn query<O: Into<Box<T>>>(
        &self,
        obj: O,
    ) -> Receiver<Result<QueryResult, ServeError>> {
        self.submit(Request::object(obj)).into_receiver()
    }

    /// Deprecated delta-row query. Rejects wrong-length rows
    /// synchronously, like the pre-PR-6 API did.
    #[deprecated(since = "0.6.0", note = "use submit(Request::delta(..))")]
    pub fn query_delta(
        &self,
        delta: Vec<f32>,
    ) -> Result<Receiver<Result<QueryResult, ServeError>>, ServeError> {
        let expect = self.landmark_objects().len();
        if delta.len() != expect {
            return Err(ServeError::BadInput {
                reason: format!(
                    "delta row has {} entries, expected {expect} (one per landmark)",
                    delta.len(),
                ),
            });
        }
        Ok(self.submit(Request::Delta(delta)).into_receiver())
    }

    /// Deprecated blocking object query.
    #[deprecated(since = "0.6.0", note = "use submit(Request::object(..)).recv()")]
    pub fn query_sync<O: Into<Box<T>>>(&self, obj: O) -> Result<QueryResult, ServeError> {
        self.submit(Request::object(obj)).recv()
    }

    /// The landmark objects of the CURRENT serving generation. The
    /// returned `Arc` is a stable snapshot: a concurrent refresh swap
    /// never mutates it, it installs a successor set.
    pub fn landmark_objects(&self) -> Arc<Vec<Box<T>>> {
        Arc::clone(&self.core.engine_read().landmarks)
    }

    /// Generation tag of the engine currently serving: 0 at build, +1
    /// per successful [`swap_generation`](Self::swap_generation).
    pub fn generation(&self) -> u64 {
        self.core.engine_read().generation
    }

    /// The dissimilarity metric the frontend measures queries with
    /// (shared with the refresh controller, which evaluates the same
    /// metric at the storage layer when re-solving the base).
    pub(crate) fn metric(&self) -> Arc<dyn Dissimilarity<T> + Send + Sync> {
        Arc::clone(&self.core.metric)
    }

    /// Install (or clear) the refresh controller's ingest tap on the
    /// object-query path.
    pub(crate) fn set_ingest_tap(&self, tap: Option<IngestTap<T>>) {
        let mut slot = match self.core.ingest.write() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        *slot = tap;
    }

    /// Atomically replace the serving generation: spawn a fresh executor
    /// pool from `factory`, install `landmarks` plus the new dispatch
    /// queue under the engine write lock, then join the retired
    /// executors. The retired pool drains its queued work before
    /// exiting, so every in-flight query completes on the generation it
    /// was submitted against — never a mixed one — and no submission
    /// window exists in which requests fail. When the server was built
    /// with a drift hook and `landmark_config` is provided, the new
    /// generation gets a FRESH monitor (same [`DriftConfig`]) that
    /// recalibrates on post-swap traffic.
    ///
    /// Returns the new generation tag and the measured drain time of the
    /// retired executors. The refresh controller is the only caller and
    /// serialises swaps.
    pub(crate) fn swap_generation(
        &self,
        landmarks: Vec<Box<T>>,
        factory: Arc<dyn OseMethodFactory>,
        landmark_config: Option<Matrix>,
    ) -> Result<(u64, Duration), ServeError> {
        let probe = factory.build();
        if landmarks.len() != probe.landmarks() {
            return Err(ServeError::BadInput {
                reason: format!(
                    "swap offers {} landmarks but the OSE method expects {}",
                    landmarks.len(),
                    probe.landmarks()
                ),
            });
        }
        let drift = match (&self.core.drift_cfg, landmark_config) {
            (Some(cfg), Some(config)) => {
                if (config.rows, config.cols) != (probe.landmarks(), probe.dim()) {
                    return Err(ServeError::BadInput {
                        reason: format!(
                            "swap landmark configuration is {}x{}, expected {}x{}",
                            config.rows,
                            config.cols,
                            probe.landmarks(),
                            probe.dim()
                        ),
                    });
                }
                Some(Arc::new(DriftState::from_hook(DriftHook {
                    landmark_config: config,
                    cfg: cfg.clone(),
                })))
            }
            _ => None,
        };
        let generation = self.core.engine_read().generation + 1;
        let (tx, new_execs) = spawn_generation(
            factory,
            Some(probe),
            &self.core.batcher,
            &self.core.metrics,
            drift,
            generation,
        )?;
        {
            let mut engine = self.core.engine_write();
            engine.landmarks = Arc::new(landmarks);
            engine.tx = tx;
            engine.generation = generation;
            // the old tx drops here: the retired executors drain and exit
        }
        let old = {
            let mut ex = match self.core.executors.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            std::mem::replace(&mut *ex, new_execs)
        };
        let t0 = Instant::now();
        for h in old {
            let _ = h.join();
        }
        let drain = t0.elapsed();
        self.metrics.set_generation(generation);
        self.metrics.record_swap_drain(drain);
        Ok((generation, drain))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{MlpParams, MlpShape};
    use crate::ose::{factory_fn, RustNn};
    use crate::util::prng::Rng;

    fn tiny_factory() -> Arc<dyn OseMethodFactory> {
        let mut rng = Rng::new(1);
        let params = MlpParams::init(
            &MlpShape { input: 16, hidden: [8, 8, 8], output: 3 },
            &mut rng,
        );
        factory_fn(move || Box::new(RustNn { params: params.clone() }))
    }

    fn tiny_server(max_batch: usize, delay_ms: u64, replicas: usize) -> Server<str> {
        let landmarks: Vec<String> =
            (0..16).map(|i| format!("landmark{i:02}")).collect();
        ServerBuilder::strings(
            landmarks,
            Arc::new(crate::strdist::Levenshtein),
            tiny_factory(),
        )
        .batcher(BatcherConfig {
            max_batch,
            max_delay: Duration::from_millis(delay_ms),
            queue_cap: 128,
            frontend_threads: 2,
            replicas,
        })
        .build()
        .unwrap()
    }

    #[test]
    fn serves_queries_end_to_end() {
        let server = tiny_server(8, 2, 1);
        let h = server.handle();
        let mut tickets = Vec::new();
        for i in 0..40 {
            tickets.push(h.submit(Request::object(format!("query name {i}"))));
        }
        for t in tickets {
            let r = t.recv().unwrap();
            assert_eq!(r.coords.len(), 3);
            assert!(r.coords.iter().all(|c| c.is_finite()));
            assert!(!r.degraded, "unsharded path never degrades");
        }
        let snap = h.metrics.snapshot();
        assert_eq!(snap.completed, 40);
        assert_eq!(snap.failed, 0);
        assert!(snap.batches <= 40);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn replicated_pool_serves_everything_exactly_once() {
        let server = tiny_server(8, 1, 4);
        let h = server.handle();
        let tickets: Vec<_> = (0..200)
            .map(|i| h.submit(Request::object(format!("replicated query {i}"))))
            .collect();
        for t in tickets {
            let r = t.recv().unwrap();
            assert_eq!(r.coords.len(), 3);
            assert!(t.try_recv().is_none(), "duplicate reply");
        }
        let snap = h.metrics.snapshot();
        assert_eq!(snap.completed, 200);
        assert_eq!(snap.failed, 0);
        assert_eq!(snap.replicas, 4);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn single_query_dispatches_without_waiting_for_full_batch() {
        // de-flaked: instead of a CI-hostile wall-clock bound, assert the
        // dispatch behaviour — a lone request must go out as a batch of 1
        // (the max_delay deadline), not wait for max_batch peers
        let server = tiny_server(64, 5, 1);
        let h = server.handle();
        let t = h.submit(Request::object("solo query"));
        let r = t
            .recv_timeout(Duration::from_secs(30))
            .expect("lone query must be dispatched by the deadline");
        assert_eq!(r.coords.len(), 3);
        let snap = h.metrics.snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.batches, 1, "must dispatch exactly one batch");
        assert!(
            (snap.mean_batch_size - 1.0).abs() < 1e-9,
            "lone query dispatched as batch of {}",
            snap.mean_batch_size
        );
        drop(h);
        server.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let server = tiny_server(32, 20, 1);
        let h = server.handle();
        let tickets: Vec<_> = (0..64)
            .map(|_| h.submit(Request::delta(vec![1.0; 16])))
            .collect();
        for t in tickets {
            t.recv().unwrap();
        }
        let snap = h.metrics.snapshot();
        assert!(
            snap.mean_batch_size > 1.5,
            "no batching: mean={}",
            snap.mean_batch_size
        );
        drop(h);
        server.shutdown();
    }

    #[test]
    fn submit_rejects_wrong_length_delta() {
        let server = tiny_server(8, 2, 2);
        let h = server.handle();
        // too short and too long both fail fast with a typed BadInput
        // instead of panicking the executor via copy_from_slice
        for bad in [vec![1.0; 3], vec![1.0; 17], vec![]] {
            let r = h.submit(Request::delta(bad)).recv();
            assert!(matches!(r, Err(ServeError::BadInput { .. })), "{r:?}");
        }
        // the service is still healthy afterwards
        let ok = h.submit(Request::delta(vec![1.0; 16])).recv();
        assert!(ok.is_ok());
        let snap = h.metrics.snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 3);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn submit_sink_delivers_without_a_waiting_thread() {
        let server = tiny_server(8, 1, 1);
        let h = server.handle();
        let (tx, rx) = channel();
        h.submit_sink(
            Request::object("sink query"),
            Box::new(move |r| {
                tx.send(r).unwrap();
            }),
        );
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(r.coords.len(), 3);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn results_are_request_specific() {
        // two very different queries must not get each other's coordinates
        let server = tiny_server(2, 50, 1);
        let h = server.handle();
        let t_a = h.submit(Request::object("aaaaaaaaaaaaaaaa"));
        let t_b = h.submit(Request::object("zz"));
        let a = t_a.recv().unwrap();
        let b = t_b.recv().unwrap();
        // deterministic MLP: same input -> same output; check self-consistency
        let a2 = h.submit(Request::object("aaaaaaaaaaaaaaaa")).recv_sync().unwrap();
        assert_eq!(a.coords, a2.coords);
        assert_ne!(a.coords, b.coords);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn builder_rejects_bad_configurations() {
        let landmarks: Vec<String> =
            (0..10).map(|i| format!("short{i}")).collect(); // != 16
        let r = ServerBuilder::strings(
            landmarks,
            Arc::new(crate::strdist::Levenshtein),
            tiny_factory(),
        )
        .build();
        assert!(matches!(r, Err(ServeError::BadInput { .. })), "{r:?}");

        let landmarks: Vec<String> =
            (0..16).map(|i| format!("landmark{i:02}")).collect();
        let r = ServerBuilder::strings(
            landmarks,
            Arc::new(crate::strdist::Levenshtein),
            tiny_factory(),
        )
        .drift(DriftHook {
            landmark_config: Matrix::zeros(4, 4), // wrong shape (want 16x3)
            cfg: DriftConfig::default(),
        })
        .build();
        assert!(matches!(r, Err(ServeError::BadInput { .. })), "{r:?}");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_serve() {
        // the pre-PR-6 call shapes must keep compiling and answering
        // through the transition
        let server = tiny_server(8, 2, 1);
        let h = server.handle();
        let rx = h.query("legacy query");
        assert!(rx.recv().unwrap().is_ok());
        assert!(h.query_delta(vec![1.0; 3]).is_err());
        let rx = h.query_delta(vec![1.0; 16]).unwrap();
        assert!(rx.recv().unwrap().is_ok());
        assert!(h.query_sync("legacy sync").is_ok());
        drop(h);
        server.shutdown();
    }

    #[test]
    fn generation_swap_keeps_serving_and_drains_cleanly() {
        let server = tiny_server(8, 2, 2);
        let h = server.handle();
        let r = h.submit(Request::object("pre-swap query")).recv().unwrap();
        assert_eq!(r.coords.len(), 3);
        assert_eq!(h.generation(), 0);

        let swapped: Vec<Box<str>> = (0..16)
            .map(|i| format!("swapped{i:02}").into_boxed_str())
            .collect();
        let (gen, drain) = h
            .swap_generation(swapped, tiny_factory(), None)
            .expect("healthy swap");
        assert_eq!(gen, 1);
        assert_eq!(h.generation(), 1);

        let r = h.submit(Request::object("post-swap query")).recv().unwrap();
        assert_eq!(r.coords.len(), 3);
        assert!(!r.degraded, "a healthy swap must never degrade results");
        assert_eq!(&*h.landmark_objects()[0], "swapped00");

        let snap = h.metrics.snapshot();
        assert_eq!(snap.failed, 0, "no request may fail across a swap");
        assert_eq!(snap.generation, 1);
        assert_eq!(snap.swap_drain_ms, drain.as_millis() as u64);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn generation_swap_rejects_mismatched_landmarks() {
        let server = tiny_server(8, 2, 1);
        let h = server.handle();
        let wrong: Vec<Box<str>> =
            (0..10).map(|i| format!("short{i}").into_boxed_str()).collect();
        let r = h.swap_generation(wrong, tiny_factory(), None);
        assert!(matches!(r, Err(ServeError::BadInput { .. })), "{r:?}");
        assert_eq!(h.generation(), 0, "failed swap leaves the old generation");
        let ok = h.submit(Request::object("still serving")).recv();
        assert!(ok.is_ok(), "old generation must keep serving after a failed swap");
        drop(h);
        server.shutdown();
    }

    #[test]
    fn drift_monitor_feeds_from_served_queries() {
        let mut rng = Rng::new(5);
        let landmarks: Vec<String> =
            (0..16).map(|i| format!("landmark{i:02}")).collect();
        let server = ServerBuilder::strings(
            landmarks,
            Arc::new(crate::strdist::Levenshtein),
            tiny_factory(),
        )
        .batcher(BatcherConfig { replicas: 2, ..Default::default() })
        .drift(DriftHook {
            landmark_config: Matrix::random_normal(&mut rng, 16, 3, 1.0),
            cfg: DriftConfig { window: 8, calibration: 8, degrade_factor: 1e9 },
        })
        .build()
        .unwrap();
        let h = server.handle();
        let tickets: Vec<_> = (0..40)
            .map(|i| h.submit(Request::object(format!("drift query {i}"))))
            .collect();
        for t in tickets {
            t.recv().unwrap();
        }
        assert_eq!(h.metrics.snapshot().completed, 40);
        // calibration (8) + half-window fill done after 40 queries; an
        // astronomical degrade factor keeps a stationary stream Healthy.
        // Scores land just AFTER the replies, so poll with a bounded wait.
        let t0 = Instant::now();
        loop {
            let snap = h.metrics.snapshot();
            if snap.drift_status == Some(crate::coordinator::DriftStatus::Healthy) {
                assert_eq!(snap.drift_signals, 0);
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "drift monitor never reported Healthy: {:?}",
                snap.drift_status
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        drop(h);
        server.shutdown();
    }
}
