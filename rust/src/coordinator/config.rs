//! Run configuration: a typed view over a JSON config file with CLI
//! overrides — the launcher-facing "config system" for experiments and the
//! serving binary.
//!
//! Precedence: defaults < JSON file (`--config path`) < CLI flags.

use std::path::Path;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::embedder::{BaseSolver, OseBackend, PipelineConfig};
use crate::coordinator::net::NetConfig;
use crate::coordinator::server::BatcherConfig;
use crate::coordinator::shard::ShardConfig;
use crate::coordinator::trainer::TrainConfig;
use crate::mds::graph::GraphConfig;
use crate::mds::{LandmarkMethod, LsmdsConfig};
use crate::runtime::simd::KernelTier;
use crate::util::cli::Args;
use crate::util::json::Json;

#[derive(Clone, Debug)]
/// Launcher-facing run settings: every knob the CLI and JSON config
/// expose, with precedence defaults < JSON < flags.
pub struct RunConfig {
    /// Embedding dimension K.
    pub dim: usize,
    /// Landmark count L (the base-MDS sample).
    pub landmarks: usize,
    /// How the landmark sample is chosen.
    pub landmark_method: LandmarkMethod,
    /// Which OSE technique maps non-landmark points.
    pub backend: OseBackend,
    /// String-metric name (see [`crate::strdist::string_metric_by_name`]).
    pub metric: String,
    /// Base PRNG seed for the run.
    pub seed: u64,
    /// Iteration budget of the landmark LSMDS solve.
    pub lsmds_iters: usize,
    /// NN backend: Adam learning rate.
    pub train_lr: f32,
    /// NN backend: training epochs.
    pub train_epochs: usize,
    /// NN backend: hidden-layer sizes.
    pub hidden: [usize; 3],
    /// Serving: dispatch once this many requests are pending.
    pub max_batch: usize,
    /// Serving: ... or once the oldest request waited this long (ms).
    pub max_delay_ms: u64,
    /// OSE executor replicas in the serving pool (>= 1).
    pub replicas: usize,
    /// Drift-monitor sliding window in queries; 0 disables the monitor.
    pub drift_window: usize,
    /// Prefer the PJRT artifact backend when compiled in and loadable.
    pub use_pjrt: bool,
    /// `Some(rows)`: run the pipeline's OSE stage through the bounded-
    /// memory streaming path in chunks of this many rows (0 disables,
    /// i.e. monolithic). See [`PipelineConfig::stream_chunk`].
    pub stream_chunk: Option<usize>,
    /// Base-MDS solver for the landmark sample: "monolithic" (one full
    /// O(L^2) LSMDS) or "divide" (partitioned parallel blocks stitched
    /// with Procrustes; see [`BaseSolver`]).
    pub base_solver: String,
    /// Divide-and-conquer only: number of blocks B (>= 1).
    pub base_blocks: usize,
    /// Divide-and-conquer only: shared anchor count (0 = auto, sqrt(L)
    /// clamped to [2(dim+1), 512]).
    pub base_anchors: usize,
    /// Out-of-core mode: path of a corpus file written by
    /// `lmds-ose corpus` (or [`crate::data::source::CorpusWriter`]).
    /// When set, the embed pipeline runs
    /// [`crate::coordinator::embedder::embed_corpus`] against the
    /// on-disk object table instead of generating an in-memory dataset.
    pub corpus: Option<String>,
    /// Out-of-core mode: block-cache byte budget in MiB for the pread
    /// storage backend (ignored under mmap, where the OS page cache
    /// governs residency). 0 keeps the cache at its one-block floor.
    pub corpus_cache_mb: usize,
    /// Optimisation-OSE budget: `Some(steps)` runs a fixed number of
    /// majorization steps per embedding with early stopping disabled
    /// (bit-reproducible across stream chunk sizes); `None`/0 keeps the
    /// adaptive default. See [`PipelineConfig::ose_steps`].
    pub ose_steps: Option<usize>,
    /// Serving shards (>= 1; 1 = the classic unsharded server). Sharded
    /// serving partitions the landmarks and quorum-reduces per-shard
    /// partial embeddings — see [`ShardConfig`].
    pub shards: usize,
    /// Network front door: `Some("host:port")` serves the binary wire
    /// protocol over TCP there (port 0 picks an ephemeral port); `None`
    /// keeps serving in-process only.
    pub listen: Option<String>,
    /// Front door: connection limit (see [`NetConfig::max_connections`]).
    pub max_connections: usize,
    /// Front door: bounded in-flight queue before load shedding (see
    /// [`NetConfig::max_in_flight`]).
    pub max_in_flight: usize,
    /// Compute kernel tier: "auto" (the `LMDS_KERNEL_TIER` environment
    /// variable if set, else CPU feature detection), "simd" (force the
    /// vector kernels; falls back loudly when unsupported) or "scalar"
    /// (the portable reference kernels). All tiers are bit-identical —
    /// see [`crate::runtime::simd`].
    pub kernel_tier: String,
    /// Sparse OSE queries: majorize each embedding against only its k
    /// nearest landmarks, found through the landmark small-world graph
    /// (docs/QUERY_PATH.md). 0 = dense (every landmark, the classic
    /// path, bit-identical to pre-graph behaviour).
    pub query_k: usize,
    /// Landmark graph: neighbours per node per layer (HNSW `M`). Higher
    /// is denser/slower to build, higher recall.
    pub graph_m: usize,
    /// Landmark graph: query-time beam width (HNSW `ef`). Raised to
    /// `query_k` automatically when smaller.
    pub graph_ef: usize,
    /// Enable the drift-triggered hot-refresh controller
    /// ([`crate::coordinator::refresh`]): on a drift signal, recent
    /// queries are ingested into the corpus, the landmark base is
    /// re-solved in a shadow generation and the serving model is
    /// hot-swapped. Requires the opt backend, an unsharded server and a
    /// drift monitor (`drift_window > 0`).
    pub refresh: bool,
    /// Minimum spacing between two drift-triggered refreshes (ms).
    pub refresh_cooldown_ms: usize,
    /// Capacity of the refresh controller's recent-query ingest buffer
    /// (oldest entries evicted first).
    pub ingest_buffer: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            dim: 7,
            landmarks: 300,
            landmark_method: LandmarkMethod::Fps,
            backend: OseBackend::Nn,
            metric: "levenshtein".into(),
            seed: 1234,
            lsmds_iters: 300,
            train_lr: 1e-3,
            train_epochs: 150,
            hidden: [256, 128, 64],
            max_batch: 64,
            max_delay_ms: 2,
            replicas: 1,
            drift_window: 256,
            use_pjrt: true,
            stream_chunk: None,
            base_solver: "monolithic".into(),
            base_blocks: 8,
            base_anchors: 0,
            corpus: None,
            corpus_cache_mb: 64,
            ose_steps: None,
            shards: 1,
            listen: None,
            max_connections: 256,
            max_in_flight: 1024,
            kernel_tier: "auto".into(),
            query_k: 0,
            graph_m: 12,
            graph_ef: 48,
            refresh: false,
            refresh_cooldown_ms: 5000,
            ingest_buffer: 4096,
        }
    }
}

impl RunConfig {
    /// Load from a JSON file (all keys optional).
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        let json = Json::parse(&text).context("parsing config JSON")?;
        let mut cfg = RunConfig::default();
        cfg.apply_json(&json)?;
        Ok(cfg)
    }

    /// Overlay settings from a parsed JSON document (unknown keys are
    /// ignored; bad values are errors).
    pub fn apply_json(&mut self, json: &Json) -> Result<()> {
        let usize_of = |j: &Json, key: &str| -> Result<Option<usize>> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => Ok(Some(
                    v.as_usize().with_context(|| format!("config: bad {key}"))?,
                )),
            }
        };
        if let Some(v) = usize_of(json, "dim")? {
            self.dim = v;
        }
        if let Some(v) = usize_of(json, "landmarks")? {
            self.landmarks = v;
        }
        if let Some(v) = json.get("landmark_method").and_then(Json::as_str) {
            self.landmark_method = LandmarkMethod::from_name(v)
                .with_context(|| format!("config: unknown landmark_method {v}"))?;
        }
        if let Some(v) = json.get("backend").and_then(Json::as_str) {
            self.backend = OseBackend::from_name(v)
                .with_context(|| format!("config: unknown backend {v}"))?;
        }
        if let Some(v) = json.get("metric").and_then(Json::as_str) {
            anyhow::ensure!(
                crate::strdist::string_metric_by_name(v).is_some(),
                "config: unknown metric {v}"
            );
            self.metric = v.to_string();
        }
        if let Some(v) = json.get("seed").and_then(Json::as_f64) {
            self.seed = v as u64;
        }
        if let Some(v) = usize_of(json, "lsmds_iters")? {
            self.lsmds_iters = v;
        }
        if let Some(v) = json.get("train_lr").and_then(Json::as_f64) {
            self.train_lr = v as f32;
        }
        if let Some(v) = usize_of(json, "train_epochs")? {
            self.train_epochs = v;
        }
        if let Some(h) = json.get("hidden").and_then(Json::as_arr) {
            anyhow::ensure!(h.len() == 3, "config: hidden must have 3 entries");
            for (i, v) in h.iter().enumerate() {
                self.hidden[i] = v.as_usize().context("config: bad hidden entry")?;
            }
        }
        if let Some(v) = usize_of(json, "max_batch")? {
            self.max_batch = v;
        }
        if let Some(v) = json.get("max_delay_ms").and_then(Json::as_f64) {
            self.max_delay_ms = v as u64;
        }
        if let Some(v) = usize_of(json, "replicas")? {
            anyhow::ensure!(v >= 1, "config: replicas must be >= 1");
            self.replicas = v;
        }
        if let Some(v) = usize_of(json, "drift_window")? {
            self.drift_window = v;
        }
        if let Some(v) = json.get("use_pjrt").and_then(Json::as_bool) {
            self.use_pjrt = v;
        }
        if let Some(v) = usize_of(json, "stream_chunk")? {
            self.stream_chunk = if v == 0 { None } else { Some(v) };
        }
        if let Some(v) = json.get("base_solver").and_then(Json::as_str) {
            anyhow::ensure!(
                BaseSolver::from_name(v, 1, 0).is_some(),
                "config: unknown base_solver {v} (monolithic|divide)"
            );
            self.base_solver = v.to_string();
        }
        if let Some(v) = usize_of(json, "base_blocks")? {
            anyhow::ensure!(v >= 1, "config: base_blocks must be >= 1");
            self.base_blocks = v;
        }
        if let Some(v) = usize_of(json, "base_anchors")? {
            self.base_anchors = v;
        }
        if let Some(v) = json.get("corpus").and_then(Json::as_str) {
            self.corpus = if v.is_empty() { None } else { Some(v.to_string()) };
        }
        if let Some(v) = usize_of(json, "corpus_cache_mb")? {
            self.corpus_cache_mb = v;
        }
        if let Some(v) = usize_of(json, "ose_steps")? {
            self.ose_steps = if v == 0 { None } else { Some(v) };
        }
        if let Some(v) = usize_of(json, "shards")? {
            anyhow::ensure!(v >= 1, "config: shards must be >= 1");
            self.shards = v;
        }
        if let Some(v) = json.get("listen").and_then(Json::as_str) {
            self.listen = if v.is_empty() { None } else { Some(v.to_string()) };
        }
        if let Some(v) = usize_of(json, "max_connections")? {
            anyhow::ensure!(v >= 1, "config: max_connections must be >= 1");
            self.max_connections = v;
        }
        if let Some(v) = usize_of(json, "max_in_flight")? {
            anyhow::ensure!(v >= 1, "config: max_in_flight must be >= 1");
            self.max_in_flight = v;
        }
        if let Some(v) = json.get("kernel_tier").and_then(Json::as_str) {
            v.parse::<KernelTier>()
                .map_err(|e| anyhow::anyhow!("config: {e}"))?;
            self.kernel_tier = v.to_string();
        }
        if let Some(v) = usize_of(json, "query_k")? {
            self.query_k = v;
        }
        if let Some(v) = usize_of(json, "graph_m")? {
            anyhow::ensure!(v >= 2, "config: graph_m must be >= 2");
            self.graph_m = v;
        }
        if let Some(v) = usize_of(json, "graph_ef")? {
            anyhow::ensure!(v >= 1, "config: graph_ef must be >= 1");
            self.graph_ef = v;
        }
        if let Some(v) = json.get("refresh").and_then(Json::as_bool) {
            self.refresh = v;
        }
        if let Some(v) = usize_of(json, "refresh_cooldown")? {
            self.refresh_cooldown_ms = v;
        }
        if let Some(v) = usize_of(json, "ingest_buffer")? {
            anyhow::ensure!(v >= 1, "config: ingest_buffer must be >= 1");
            self.ingest_buffer = v;
        }
        Ok(())
    }

    /// Apply CLI overrides (only flags that were explicitly given).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if args.get("dim").is_some() {
            self.dim = args.usize("dim")?;
        }
        if args.get("landmarks").is_some() {
            self.landmarks = args.usize("landmarks")?;
        }
        if let Some(v) = args.get("landmark-method") {
            self.landmark_method = LandmarkMethod::from_name(v)
                .with_context(|| format!("unknown landmark method {v}"))?;
        }
        if let Some(v) = args.get("backend") {
            self.backend = OseBackend::from_name(v)
                .with_context(|| format!("unknown backend {v}"))?;
        }
        if let Some(v) = args.get("metric") {
            anyhow::ensure!(
                crate::strdist::string_metric_by_name(v).is_some(),
                "unknown metric {v}"
            );
            self.metric = v.to_string();
        }
        if args.get("seed").is_some() {
            self.seed = args.u64("seed")?;
        }
        if args.get("replicas").is_some() {
            let v = args.usize("replicas")?;
            anyhow::ensure!(v >= 1, "--replicas must be >= 1");
            self.replicas = v;
        }
        if args.get("drift-window").is_some() {
            self.drift_window = args.usize("drift-window")?;
        }
        if args.flag("no-pjrt") {
            self.use_pjrt = false;
        }
        if args.get("stream-chunk").is_some() {
            let v = args.usize("stream-chunk")?;
            self.stream_chunk = if v == 0 { None } else { Some(v) };
        }
        if let Some(v) = args.get("base-solver") {
            anyhow::ensure!(
                BaseSolver::from_name(v, 1, 0).is_some(),
                "unknown base solver {v} (monolithic|divide)"
            );
            self.base_solver = v.to_string();
        }
        if args.get("base-blocks").is_some() {
            let v = args.usize("base-blocks")?;
            anyhow::ensure!(v >= 1, "--base-blocks must be >= 1");
            self.base_blocks = v;
        }
        if args.get("base-anchors").is_some() {
            self.base_anchors = args.usize("base-anchors")?;
        }
        if let Some(v) = args.get("corpus") {
            self.corpus = if v.is_empty() { None } else { Some(v.to_string()) };
        }
        if args.get("corpus-cache-mb").is_some() {
            self.corpus_cache_mb = args.usize("corpus-cache-mb")?;
        }
        if args.get("ose-steps").is_some() {
            let v = args.usize("ose-steps")?;
            self.ose_steps = if v == 0 { None } else { Some(v) };
        }
        if args.get("shards").is_some() {
            let v = args.usize("shards")?;
            anyhow::ensure!(v >= 1, "--shards must be >= 1");
            self.shards = v;
        }
        if let Some(v) = args.get("listen") {
            self.listen = if v.is_empty() { None } else { Some(v.to_string()) };
        }
        if args.get("max-connections").is_some() {
            let v = args.usize("max-connections")?;
            anyhow::ensure!(v >= 1, "--max-connections must be >= 1");
            self.max_connections = v;
        }
        if args.get("max-in-flight").is_some() {
            let v = args.usize("max-in-flight")?;
            anyhow::ensure!(v >= 1, "--max-in-flight must be >= 1");
            self.max_in_flight = v;
        }
        if let Some(v) = args.get("kernel-tier") {
            v.parse::<KernelTier>().map_err(anyhow::Error::msg)?;
            self.kernel_tier = v.to_string();
        }
        if args.get("query-k").is_some() {
            self.query_k = args.usize("query-k")?;
        }
        if args.get("graph-m").is_some() {
            let v = args.usize("graph-m")?;
            anyhow::ensure!(v >= 2, "--graph-m must be >= 2");
            self.graph_m = v;
        }
        if args.get("graph-ef").is_some() {
            let v = args.usize("graph-ef")?;
            anyhow::ensure!(v >= 1, "--graph-ef must be >= 1");
            self.graph_ef = v;
        }
        if args.flag("refresh") {
            self.refresh = true;
        }
        if args.get("refresh-cooldown").is_some() {
            self.refresh_cooldown_ms = args.usize("refresh-cooldown")?;
        }
        if args.get("ingest-buffer").is_some() {
            let v = args.usize("ingest-buffer")?;
            anyhow::ensure!(v >= 1, "--ingest-buffer must be >= 1");
            self.ingest_buffer = v;
        }
        Ok(())
    }

    /// Block-cache byte budget for the out-of-core table's pread backend.
    pub fn corpus_cache_bytes(&self) -> usize {
        self.corpus_cache_mb << 20
    }

    /// The typed base-solver selection. Parse paths validate the name up
    /// front; a caller that sets the field directly with an unknown name
    /// falls back to monolithic, loudly.
    pub fn base(&self) -> BaseSolver {
        BaseSolver::from_name(&self.base_solver, self.base_blocks, self.base_anchors)
            .unwrap_or_else(|| {
                log::warn!(
                    "unknown base_solver {:?}; using the monolithic solver",
                    self.base_solver
                );
                BaseSolver::Monolithic
            })
    }

    /// The typed kernel-tier selection. Parse paths validate the name up
    /// front; a caller that sets the field directly with an unknown name
    /// falls back to auto, loudly.
    pub fn tier(&self) -> KernelTier {
        self.kernel_tier.parse().unwrap_or_else(|_| {
            log::warn!(
                "unknown kernel_tier {:?}; using auto detection",
                self.kernel_tier
            );
            KernelTier::Auto
        })
    }

    /// Derive the landmark-graph construction/search parameters from this
    /// run config. The graph seed is a dedicated stream off the run seed,
    /// so the same run config always builds the same graph.
    pub fn graph(&self) -> GraphConfig {
        let defaults = GraphConfig::default();
        GraphConfig {
            m: self.graph_m.max(2),
            ef_construction: defaults.ef_construction.max(self.graph_ef),
            ef_search: self.graph_ef.max(1),
            seed: self.seed ^ 0x6E57_1A97,
        }
    }

    /// Derive the embedding-pipeline configuration from this run config.
    pub fn pipeline(&self) -> PipelineConfig {
        PipelineConfig {
            dim: self.dim,
            landmarks: self.landmarks,
            landmark_method: self.landmark_method,
            backend: self.backend,
            lsmds: LsmdsConfig {
                dim: self.dim,
                max_iters: self.lsmds_iters,
                seed: self.seed,
                ..Default::default()
            },
            train: TrainConfig {
                lr: self.train_lr,
                epochs: self.train_epochs,
                seed: self.seed ^ 0x7121, // independent training stream
                ..Default::default()
            },
            hidden: self.hidden,
            nn_bootstrap: true,
            stream_chunk: self.stream_chunk,
            base_solver: self.base(),
            ose_steps: self.ose_steps,
            seed: self.seed,
            query_k: self.query_k,
            graph: self.graph(),
        }
    }

    /// Derive the serving batcher configuration from this run config.
    pub fn batcher(&self) -> BatcherConfig {
        BatcherConfig {
            max_batch: self.max_batch,
            max_delay: Duration::from_millis(self.max_delay_ms),
            replicas: self.replicas,
            ..Default::default()
        }
    }

    /// Derive the sharded-serving configuration from this run config
    /// (meaningful when `shards > 1`; shards reuse the run seed, the
    /// divide-solve anchor count and the optimisation-OSE step budget).
    pub fn shard(&self) -> ShardConfig {
        ShardConfig {
            shards: self.shards,
            anchors: self.base_anchors,
            replicas_per_shard: self.replicas,
            seed: self.seed,
            opt_steps: self.ose_steps.unwrap_or(0),
            query_k: self.query_k,
            graph: self.graph(),
            ..Default::default()
        }
    }

    /// Network front-door settings; `None` when `listen` is unset.
    pub fn net(&self) -> Option<NetConfig> {
        self.listen.as_ref().map(|addr| NetConfig {
            addr: addr.clone(),
            max_connections: self.max_connections,
            max_in_flight: self.max_in_flight,
        })
    }

    /// Drift monitor settings; `None` when `drift_window` is 0 (disabled).
    pub fn drift(&self) -> Option<crate::coordinator::stream::DriftConfig> {
        (self.drift_window > 0).then(|| crate::coordinator::stream::DriftConfig {
            window: self.drift_window,
            calibration: self.drift_window,
            ..Default::default()
        })
    }

    /// Refresh-controller settings; `None` when `refresh` is off or the
    /// drift monitor is disabled (no signal to subscribe to).
    pub fn refresh_cfg(&self) -> Option<crate::coordinator::refresh::RefreshConfig> {
        (self.refresh && self.drift_window > 0).then(|| {
            crate::coordinator::refresh::RefreshConfig {
                cooldown: Duration::from_millis(self.refresh_cooldown_ms as u64),
                ingest_buffer: self.ingest_buffer,
                ..Default::default()
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::OptSpec;

    #[test]
    fn defaults_then_json_then_cli() {
        let mut cfg = RunConfig::default();
        let json = Json::parse(
            r#"{"dim": 5, "landmarks": 100, "backend": "opt",
                "hidden": [32, 16, 8], "max_delay_ms": 7}"#,
        )
        .unwrap();
        cfg.apply_json(&json).unwrap();
        assert_eq!(cfg.dim, 5);
        assert_eq!(cfg.backend, OseBackend::Opt);
        assert_eq!(cfg.hidden, [32, 16, 8]);
        assert_eq!(cfg.max_delay_ms, 7);

        let specs = vec![
            OptSpec { name: "dim", help: "", takes_value: true, default: None },
            OptSpec { name: "backend", help: "", takes_value: true, default: None },
            OptSpec { name: "no-pjrt", help: "", takes_value: false, default: None },
        ];
        let argv: Vec<String> =
            ["--dim", "3", "--backend", "nn", "--no-pjrt"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &specs).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.dim, 3);
        assert_eq!(cfg.backend, OseBackend::Nn);
        assert!(!cfg.use_pjrt);
        // untouched values survive
        assert_eq!(cfg.landmarks, 100);
    }

    #[test]
    fn stream_chunk_round_trips_with_zero_disabling() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.stream_chunk, None);
        cfg.apply_json(&Json::parse(r#"{"stream_chunk": 512}"#).unwrap()).unwrap();
        assert_eq!(cfg.stream_chunk, Some(512));
        assert_eq!(cfg.pipeline().stream_chunk, Some(512));

        let specs = vec![OptSpec {
            name: "stream-chunk",
            help: "",
            takes_value: true,
            default: None,
        }];
        let argv: Vec<String> =
            ["--stream-chunk", "0"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &specs).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.stream_chunk, None, "0 disables streaming");
    }

    #[test]
    fn rejects_bad_values() {
        let mut cfg = RunConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"backend": "bogus"}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"metric": "bogus"}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"hidden": [1, 2]}"#).unwrap()).is_err());
    }

    #[test]
    fn derived_configs_consistent() {
        let cfg = RunConfig::default();
        let p = cfg.pipeline();
        assert_eq!(p.dim, cfg.dim);
        assert_eq!(p.landmarks, cfg.landmarks);
        let b = cfg.batcher();
        assert_eq!(b.max_batch, cfg.max_batch);
        assert_eq!(b.replicas, cfg.replicas);
    }

    #[test]
    fn base_solver_round_trips_and_validates() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.base(), BaseSolver::Monolithic);
        assert_eq!(cfg.pipeline().base_solver, BaseSolver::Monolithic);

        cfg.apply_json(
            &Json::parse(
                r#"{"base_solver": "divide", "base_blocks": 6, "base_anchors": 48}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.base(), BaseSolver::DivideConquer { blocks: 6, anchors: 48 });
        assert_eq!(
            cfg.pipeline().base_solver,
            BaseSolver::DivideConquer { blocks: 6, anchors: 48 }
        );

        let specs = vec![
            OptSpec { name: "base-solver", help: "", takes_value: true, default: None },
            OptSpec { name: "base-blocks", help: "", takes_value: true, default: None },
            OptSpec { name: "base-anchors", help: "", takes_value: true, default: None },
        ];
        let argv: Vec<String> = ["--base-solver", "monolithic", "--base-blocks", "4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&argv, &specs).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.base(), BaseSolver::Monolithic);
        assert_eq!(cfg.base_blocks, 4, "divide shape survives solver flips");

        // bad values rejected
        assert!(cfg
            .apply_json(&Json::parse(r#"{"base_solver": "bogus"}"#).unwrap())
            .is_err());
        assert!(cfg
            .apply_json(&Json::parse(r#"{"base_blocks": 0}"#).unwrap())
            .is_err());
    }

    #[test]
    fn kernel_tier_round_trips_and_validates() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.kernel_tier, "auto");
        assert_eq!(cfg.tier(), KernelTier::Auto);

        cfg.apply_json(&Json::parse(r#"{"kernel_tier": "scalar"}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.tier(), KernelTier::Scalar);

        let specs = vec![OptSpec {
            name: "kernel-tier",
            help: "",
            takes_value: true,
            default: None,
        }];
        let argv: Vec<String> =
            ["--kernel-tier", "simd"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &specs).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.tier(), KernelTier::Simd);

        // bad values rejected by both parse paths; a directly-set bad
        // field falls back to auto
        assert!(cfg
            .apply_json(&Json::parse(r#"{"kernel_tier": "avx512"}"#).unwrap())
            .is_err());
        let argv: Vec<String> =
            ["--kernel-tier", "fast"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &specs).unwrap();
        assert!(cfg.apply_args(&args).is_err());
        cfg.kernel_tier = "bogus".into();
        assert_eq!(cfg.tier(), KernelTier::Auto);
    }

    #[test]
    fn corpus_keys_round_trip() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.corpus, None);
        assert_eq!(cfg.corpus_cache_mb, 64);
        cfg.apply_json(
            &Json::parse(r#"{"corpus": "data/names.tbl", "corpus_cache_mb": 16}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.corpus.as_deref(), Some("data/names.tbl"));
        assert_eq!(cfg.corpus_cache_bytes(), 16 << 20);

        let specs = vec![
            OptSpec { name: "corpus", help: "", takes_value: true, default: None },
            OptSpec {
                name: "corpus-cache-mb",
                help: "",
                takes_value: true,
                default: None,
            },
        ];
        let argv: Vec<String> = ["--corpus", "other.tbl", "--corpus-cache-mb", "8"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&argv, &specs).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.corpus.as_deref(), Some("other.tbl"));
        assert_eq!(cfg.corpus_cache_mb, 8);
        // empty string disables out-of-core mode
        cfg.apply_json(&Json::parse(r#"{"corpus": ""}"#).unwrap()).unwrap();
        assert_eq!(cfg.corpus, None);
    }

    #[test]
    fn ose_steps_round_trips_with_zero_disabling() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.ose_steps, None);
        cfg.apply_json(&Json::parse(r#"{"ose_steps": 24}"#).unwrap()).unwrap();
        assert_eq!(cfg.ose_steps, Some(24));
        assert_eq!(cfg.pipeline().ose_steps, Some(24));

        let specs = vec![OptSpec {
            name: "ose-steps",
            help: "",
            takes_value: true,
            default: None,
        }];
        let argv: Vec<String> =
            ["--ose-steps", "0"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &specs).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.ose_steps, None, "0 restores the adaptive default");
    }

    #[test]
    fn serving_shard_and_listen_keys_round_trip() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.shards, 1);
        assert_eq!(cfg.listen, None);
        assert!(cfg.net().is_none());
        cfg.apply_json(
            &Json::parse(
                r#"{"shards": 4, "listen": "127.0.0.1:4077",
                    "max_connections": 32, "max_in_flight": 64,
                    "replicas": 2, "ose_steps": 40}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.shards, 4);
        let sc = cfg.shard();
        assert_eq!(sc.shards, 4);
        assert_eq!(sc.replicas_per_shard, 2);
        assert_eq!(sc.opt_steps, 40);
        assert_eq!(sc.seed, cfg.seed);
        let nc = cfg.net().expect("listen set");
        assert_eq!(nc.addr, "127.0.0.1:4077");
        assert_eq!(nc.max_connections, 32);
        assert_eq!(nc.max_in_flight, 64);

        let specs = vec![
            OptSpec { name: "shards", help: "", takes_value: true, default: None },
            OptSpec { name: "listen", help: "", takes_value: true, default: None },
            OptSpec {
                name: "max-connections",
                help: "",
                takes_value: true,
                default: None,
            },
            OptSpec {
                name: "max-in-flight",
                help: "",
                takes_value: true,
                default: None,
            },
        ];
        let argv: Vec<String> =
            ["--shards", "2", "--listen", "", "--max-in-flight", "16"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let args = Args::parse(&argv, &specs).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.shards, 2);
        assert!(cfg.net().is_none(), "empty --listen disables the front door");
        assert_eq!(cfg.max_in_flight, 16);
        // bad values rejected
        assert!(cfg.apply_json(&Json::parse(r#"{"shards": 0}"#).unwrap()).is_err());
        assert!(cfg
            .apply_json(&Json::parse(r#"{"max_connections": 0}"#).unwrap())
            .is_err());
    }

    #[test]
    fn query_k_and_graph_keys_round_trip() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.query_k, 0, "dense by default");
        assert_eq!(cfg.graph_m, 12);
        assert_eq!(cfg.graph_ef, 48);
        cfg.apply_json(
            &Json::parse(r#"{"query_k": 32, "graph_m": 16, "graph_ef": 96}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.query_k, 32);
        assert_eq!(cfg.pipeline().query_k, 32);
        assert_eq!(cfg.shard().query_k, 32);
        let g = cfg.graph();
        assert_eq!(g.m, 16);
        assert_eq!(g.ef_search, 96);
        assert!(g.ef_construction >= 96, "build beam at least the query beam");
        assert_eq!(cfg.pipeline().graph, g);
        assert_eq!(cfg.shard().graph, g);
        // the graph seed is a dedicated stream off the run seed
        let other = RunConfig { seed: cfg.seed ^ 1, ..RunConfig::default() };
        assert_ne!(cfg.graph().seed, other.graph().seed);

        let specs = vec![
            OptSpec { name: "query-k", help: "", takes_value: true, default: None },
            OptSpec { name: "graph-m", help: "", takes_value: true, default: None },
            OptSpec { name: "graph-ef", help: "", takes_value: true, default: None },
        ];
        let argv: Vec<String> = ["--query-k", "0", "--graph-m", "8"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&argv, &specs).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.query_k, 0, "0 restores the dense path");
        assert_eq!(cfg.graph_m, 8);
        // bad values rejected
        assert!(cfg.apply_json(&Json::parse(r#"{"graph_m": 1}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"graph_ef": 0}"#).unwrap()).is_err());
    }

    #[test]
    fn refresh_keys_round_trip() {
        let mut cfg = RunConfig::default();
        assert!(!cfg.refresh, "refresh is opt-in");
        assert!(cfg.refresh_cfg().is_none());
        cfg.apply_json(
            &Json::parse(
                r#"{"refresh": true, "refresh_cooldown": 750, "ingest_buffer": 128}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert!(cfg.refresh);
        let rc = cfg.refresh_cfg().expect("refresh + drift enabled");
        assert_eq!(rc.cooldown, Duration::from_millis(750));
        assert_eq!(rc.ingest_buffer, 128);

        // refresh without a drift monitor has no signal to act on
        cfg.drift_window = 0;
        assert!(cfg.refresh_cfg().is_none());
        cfg.drift_window = 256;

        let specs = vec![
            OptSpec { name: "refresh", help: "", takes_value: false, default: None },
            OptSpec {
                name: "refresh-cooldown",
                help: "",
                takes_value: true,
                default: None,
            },
            OptSpec {
                name: "ingest-buffer",
                help: "",
                takes_value: true,
                default: None,
            },
        ];
        let argv: Vec<String> =
            ["--refresh", "--refresh-cooldown", "250", "--ingest-buffer", "64"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let args = Args::parse(&argv, &specs).unwrap();
        let mut cli = RunConfig::default();
        cli.apply_args(&args).unwrap();
        assert!(cli.refresh);
        assert_eq!(cli.refresh_cooldown_ms, 250);
        assert_eq!(cli.ingest_buffer, 64);
        // bad values rejected
        assert!(cli
            .apply_json(&Json::parse(r#"{"ingest_buffer": 0}"#).unwrap())
            .is_err());
    }

    #[test]
    fn replicas_and_drift_window_round_trip() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.replicas, 1);
        cfg.apply_json(
            &Json::parse(r#"{"replicas": 4, "drift_window": 128}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.replicas, 4);
        assert_eq!(cfg.batcher().replicas, 4);
        assert_eq!(cfg.drift().unwrap().window, 128);

        let specs = vec![
            OptSpec { name: "replicas", help: "", takes_value: true, default: None },
            OptSpec { name: "drift-window", help: "", takes_value: true, default: None },
        ];
        let argv: Vec<String> = ["--replicas", "2", "--drift-window", "0"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&argv, &specs).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.replicas, 2);
        assert!(cfg.drift().is_none(), "0 disables the drift monitor");
        // replicas = 0 rejected
        assert!(cfg
            .apply_json(&Json::parse(r#"{"replicas": 0}"#).unwrap())
            .is_err());
    }
}
