//! Network front door: the length-prefixed binary protocol
//! ([`super::proto`]) served over TCP by a small from-scratch
//! nonblocking event loop — no async runtime, no poll crate, just
//! poll(2) on std's nonblocking sockets (matching the crate's no-new-deps
//! style; the raw syscall binding follows `data::source::table`'s mmap
//! module).
//!
//! ```text
//!  accept loop ──> per-connection state machine
//!    Deframer ──frames──> load-shed gate ──ReplySink──> QueryService
//!    completions <──wake pipe── executor/router threads
//!    write buffers ──flush──> clients (Result/Error frames)
//! ```
//!
//! One thread runs the whole loop. Queries hand a completion callback
//! ([`ReplySink`]) to the serving layer, so no thread ever parks waiting
//! for a result: executors push `(connection, request id, result)` onto a
//! completion queue and write one byte into a wake pipe, and the loop
//! encodes reply frames on its next turn.
//!
//! Overload behaviour, in order:
//! - per-connection parse errors answer with a
//!   [`Protocol`](super::error::ServeError::Protocol) frame and close
//!   after flushing;
//! - more than `max_in_flight` outstanding queries answer
//!   [`Overloaded`](super::error::ServeError::Overloaded) immediately
//!   (load shedding — the reply is cheap, the embed is not);
//! - at `max_connections` the listener is simply not polled, so further
//!   clients queue in the kernel backlog (connection limiting).
//!
//! Platform: the event loop needs poll(2)/pipe(2) and is compiled on
//! Linux (the CI and serving platform). Elsewhere [`NetServer::start`]
//! returns [`Internal`](super::error::ServeError::Internal) so callers
//! can degrade to in-process serving.

use std::sync::Arc;

use super::metrics::Metrics;
use super::server::{ReplySink, Request, ServerHandle};
use super::shard::ShardedHandle;

/// Front-door shape: where to listen and how much concurrent work to
/// admit.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Bind address, e.g. `127.0.0.1:4077` (port 0 picks an ephemeral
    /// port; read it back from [`NetServer::local_addr`]).
    pub addr: String,
    /// Connection limit: beyond this, new clients wait in the kernel
    /// backlog until a slot frees.
    pub max_connections: usize,
    /// Bounded in-flight queue: queries beyond this many outstanding
    /// embeds are answered
    /// [`Overloaded`](super::error::ServeError::Overloaded). Keep at or below
    /// the batcher's `queue_cap` so dispatch never blocks the loop.
    pub max_in_flight: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            max_connections: 256,
            max_in_flight: 1024,
        }
    }
}

/// The serving surface the front door needs: submit with a completion
/// callback, expose metrics. Implemented by both the unsharded
/// [`ServerHandle<str>`] and the sharded [`ShardedHandle<str>`], so the
/// wire protocol is identical in front of either.
pub trait QueryService: Send + Sync {
    /// Submit a text-object query; `sink` fires exactly once.
    fn submit_text(&self, text: String, sink: ReplySink);

    /// Submit a precomputed delta-row query; `sink` fires exactly once.
    fn submit_delta(&self, delta: Vec<f32>, sink: ReplySink);

    /// The serving metrics the front door records shed/connection/proto
    /// counters into.
    fn metrics(&self) -> Arc<Metrics>;
}

impl QueryService for ServerHandle<str> {
    fn submit_text(&self, text: String, sink: ReplySink) {
        self.submit_sink(Request::object(text), sink);
    }

    fn submit_delta(&self, delta: Vec<f32>, sink: ReplySink) {
        self.submit_sink(Request::Delta(delta), sink);
    }

    fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }
}

impl QueryService for ShardedHandle<str> {
    fn submit_text(&self, text: String, sink: ReplySink) {
        self.submit_sink(Request::object(text), sink);
    }

    fn submit_delta(&self, delta: Vec<f32>, sink: ReplySink) {
        self.submit_sink(Request::Delta(delta), sink);
    }

    fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }
}

#[cfg(target_os = "linux")]
pub use linux::NetServer;

#[cfg(target_os = "linux")]
mod linux {
    //! The poll(2) event loop (no libc crate in the image; the symbols
    //! come from the C runtime std already links).

    use std::collections::HashMap;
    use std::fs::File;
    use std::io::{Read, Write};
    use std::net::{SocketAddr, TcpListener, TcpStream};
    use std::os::raw::{c_int, c_ulong};
    use std::os::unix::io::{AsRawFd, FromRawFd};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::thread::JoinHandle;

    use super::super::error::ServeError;
    use super::super::proto::{Deframer, Frame};
    use super::super::server::{QueryResult, ReplySink};
    use super::{NetConfig, QueryService};

    mod sys {
        use std::os::raw::{c_int, c_ulong};

        pub const POLLIN: i16 = 0x001;
        pub const POLLOUT: i16 = 0x004;
        pub const POLLERR: i16 = 0x008;
        pub const POLLHUP: i16 = 0x010;
        pub const F_SETFL: c_int = 4;
        pub const O_NONBLOCK: c_int = 0o4000;

        #[repr(C)]
        #[derive(Clone, Copy)]
        pub struct PollFd {
            pub fd: c_int,
            pub events: i16,
            pub revents: i16,
        }

        extern "C" {
            pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
            pub fn pipe(fds: *mut c_int) -> c_int;
            pub fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
        }
    }

    /// A completed query on its way back to a connection.
    type Completion = (u64, u64, Result<QueryResult, ServeError>);

    struct Conn {
        stream: TcpStream,
        deframer: Deframer,
        out: Vec<u8>,
        out_pos: usize,
        /// Flush the write buffer, then close (set on protocol errors).
        closing: bool,
    }

    impl Conn {
        fn has_output(&self) -> bool {
            self.out_pos < self.out.len()
        }
    }

    /// The running front door. Dropping (or [`Self::shutdown`]) stops the
    /// event loop and closes every connection.
    pub struct NetServer {
        addr: SocketAddr,
        stop: Arc<AtomicBool>,
        wake_tx: Arc<File>,
        thread: Option<JoinHandle<()>>,
    }

    impl NetServer {
        /// Bind `cfg.addr` and start the event loop over `service`.
        pub fn start(
            service: Arc<dyn QueryService>,
            cfg: NetConfig,
        ) -> Result<NetServer, ServeError> {
            let listener = TcpListener::bind(&cfg.addr).map_err(|e| {
                ServeError::Internal { reason: format!("bind {}: {e}", cfg.addr) }
            })?;
            let addr = listener.local_addr().map_err(|e| ServeError::Internal {
                reason: format!("local_addr: {e}"),
            })?;
            listener.set_nonblocking(true).map_err(|e| ServeError::Internal {
                reason: format!("nonblocking listener: {e}"),
            })?;

            let mut fds = [0 as c_int; 2];
            // SAFETY: pipe writes two fds into the array on success.
            let rc = unsafe { sys::pipe(fds.as_mut_ptr()) };
            if rc != 0 {
                return Err(ServeError::Internal {
                    reason: format!("pipe: {}", std::io::Error::last_os_error()),
                });
            }
            // SAFETY: fds[0] is the freshly created read end, owned by
            // nothing else; File takes ownership and closes it on drop.
            let wake_rx = unsafe { File::from_raw_fd(fds[0]) };
            // SAFETY: likewise fds[1], the write end — each fd is wrapped
            // exactly once, so no double-close can occur.
            let wake_tx = unsafe { File::from_raw_fd(fds[1]) };
            // Nonblocking on both ends: the loop drains the read end dry,
            // and a full pipe must never park an executor mid-reply.
            // SAFETY: plain fcntl on fds this function owns.
            unsafe {
                sys::fcntl(fds[0], sys::F_SETFL, sys::O_NONBLOCK);
                sys::fcntl(fds[1], sys::F_SETFL, sys::O_NONBLOCK);
            }

            let stop = Arc::new(AtomicBool::new(false));
            let wake_tx = Arc::new(wake_tx);
            let completions: Arc<Mutex<Vec<Completion>>> =
                Arc::new(Mutex::new(Vec::new()));
            let loop_state = EventLoop {
                listener,
                wake_rx,
                wake_tx: Arc::clone(&wake_tx),
                completions,
                service,
                cfg,
                stop: Arc::clone(&stop),
            };
            let thread = std::thread::Builder::new()
                .name("ose-net".to_string())
                .spawn(move || loop_state.run())
                .map_err(|e| ServeError::Internal {
                    reason: format!("spawning event loop: {e}"),
                })?;
            Ok(NetServer { addr, stop, wake_tx, thread: Some(thread) })
        }

        /// The bound address (resolves port 0 to the ephemeral port).
        pub fn local_addr(&self) -> SocketAddr {
            self.addr
        }

        /// Stop the event loop and close every connection. In-flight
        /// embeds complete inside the serving layer; their replies are
        /// dropped.
        pub fn shutdown(mut self) {
            self.stop_inner();
        }

        fn stop_inner(&mut self) {
            self.stop.store(true, Ordering::SeqCst);
            let _ = (&*self.wake_tx).write(&[1u8]);
            if let Some(t) = self.thread.take() {
                let _ = t.join();
            }
        }
    }

    impl Drop for NetServer {
        fn drop(&mut self) {
            self.stop_inner();
        }
    }

    struct EventLoop {
        listener: TcpListener,
        wake_rx: File,
        wake_tx: Arc<File>,
        completions: Arc<Mutex<Vec<Completion>>>,
        service: Arc<dyn QueryService>,
        cfg: NetConfig,
        stop: Arc<AtomicBool>,
    }

    impl EventLoop {
        fn run(mut self) {
            let metrics = self.service.metrics();
            let mut conns: HashMap<u64, Conn> = HashMap::new();
            let mut next_token: u64 = 1;
            let mut in_flight: usize = 0;

            loop {
                if self.stop.load(Ordering::SeqCst) {
                    return;
                }
                // 1. Poll: wake pipe, listener (only below the connection
                //    limit), every connection (write interest only when
                //    output is pending).
                let accepting = conns.len() < self.cfg.max_connections;
                let base = 1 + usize::from(accepting);
                let mut fds: Vec<sys::PollFd> = Vec::with_capacity(base + conns.len());
                let mut tokens: Vec<u64> = Vec::with_capacity(conns.len());
                fds.push(sys::PollFd {
                    fd: self.wake_rx.as_raw_fd(),
                    events: sys::POLLIN,
                    revents: 0,
                });
                if accepting {
                    fds.push(sys::PollFd {
                        fd: self.listener.as_raw_fd(),
                        events: sys::POLLIN,
                        revents: 0,
                    });
                }
                for (&t, c) in &conns {
                    let mut events = sys::POLLIN;
                    if c.has_output() {
                        events |= sys::POLLOUT;
                    }
                    fds.push(sys::PollFd {
                        fd: c.stream.as_raw_fd(),
                        events,
                        revents: 0,
                    });
                    tokens.push(t);
                }
                // 500 ms safety timeout: a lost wake byte can only delay
                // completions by one tick, never hang them.
                // SAFETY: fds points at a live array of fds.len() entries.
                let rc = unsafe {
                    sys::poll(fds.as_mut_ptr(), fds.len() as c_ulong, 500)
                };
                if rc < 0 {
                    let e = std::io::Error::last_os_error();
                    if e.kind() == std::io::ErrorKind::Interrupted {
                        continue;
                    }
                    log::error!("poll failed, front door exiting: {e}");
                    return;
                }

                // 2. Drain the wake pipe dry (level-triggered poll would
                //    otherwise spin on the leftover bytes).
                if fds[0].revents != 0 {
                    let mut sink = [0u8; 64];
                    while matches!((&self.wake_rx).read(&mut sink), Ok(n) if n > 0) {}
                }

                // 3. Drain completions into the write buffers.
                let done: Vec<Completion> = {
                    let mut g = match self.completions.lock() {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                    std::mem::take(&mut *g)
                };
                for (token, id, result) in done {
                    in_flight = in_flight.saturating_sub(1);
                    // connection may have died while the query ran; the
                    // reply is simply dropped
                    if let Some(conn) = conns.get_mut(&token) {
                        let frame = match result {
                            Ok(qr) => Frame::Result {
                                id,
                                degraded: qr.degraded,
                                latency_us: qr
                                    .latency
                                    .as_micros()
                                    .min(u32::MAX as u128)
                                    as u32,
                                coords: qr.coords,
                            },
                            Err(e) => Frame::from_error(id, &e),
                        };
                        frame.encode(&mut conn.out);
                    }
                }

                // 4. Accept new connections.
                if accepting && fds[1].revents != 0 {
                    loop {
                        match self.listener.accept() {
                            Ok((stream, _)) => {
                                if conns.len() >= self.cfg.max_connections
                                    || stream.set_nonblocking(true).is_err()
                                {
                                    continue; // dropped: limit hit mid-burst
                                }
                                metrics.record_conn_open();
                                conns.insert(
                                    next_token,
                                    Conn {
                                        stream,
                                        deframer: Deframer::new(),
                                        out: Vec::new(),
                                        out_pos: 0,
                                        closing: false,
                                    },
                                );
                                next_token += 1;
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                break
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                            Err(e) => {
                                log::warn!("accept failed: {e}");
                                break;
                            }
                        }
                    }
                }

                // 5. Per-connection reads (frame handling) and writes.
                let mut dead: Vec<u64> = Vec::new();
                for (i, &token) in tokens.iter().enumerate() {
                    let revents = fds[base + i].revents;
                    // tokens was snapshotted from conns above; a missing
                    // entry would be a bookkeeping bug, but dropping the
                    // poll turn is strictly safer than panicking the
                    // event loop.
                    let Some(conn) = conns.get_mut(&token) else {
                        continue;
                    };
                    let mut alive = true;
                    if revents & (sys::POLLIN | sys::POLLERR | sys::POLLHUP) != 0 {
                        alive = self.handle_readable(token, conn, &mut in_flight);
                    }
                    // flush whenever output is pending — POLLOUT interest
                    // was only registered when there already was some, and
                    // frames enqueued THIS turn should not wait a tick
                    if alive && conn.has_output() {
                        alive = flush(conn);
                    } else if alive && conn.closing {
                        alive = false;
                    }
                    if !alive {
                        dead.push(token);
                    }
                }
                for token in dead {
                    conns.remove(&token);
                    metrics.record_conn_close();
                }
            }
        }

        /// Read everything available, decode frames, dispatch queries.
        /// Returns false when the connection should be dropped now.
        fn handle_readable(
            &mut self,
            token: u64,
            conn: &mut Conn,
            in_flight: &mut usize,
        ) -> bool {
            let metrics = self.service.metrics();
            let mut buf = [0u8; 16384];
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => return false, // peer closed
                    Ok(n) => conn.deframer.extend(&buf[..n]),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(_) => return false,
                }
            }
            while !conn.closing {
                match conn.deframer.next() {
                    Ok(Some(frame)) => {
                        self.handle_frame(token, conn, frame, in_flight)
                    }
                    Ok(None) => break,
                    Err(e) => {
                        // poisoned stream: typed error reply, then close
                        metrics.record_proto_error();
                        Frame::from_error(0, &e).encode(&mut conn.out);
                        conn.closing = true;
                    }
                }
            }
            true
        }

        fn handle_frame(
            &mut self,
            token: u64,
            conn: &mut Conn,
            frame: Frame,
            in_flight: &mut usize,
        ) {
            let metrics = self.service.metrics();
            match frame {
                Frame::Ping { id } => {
                    Frame::Pong { id }.encode(&mut conn.out);
                }
                Frame::QueryText { id, text } => {
                    if *in_flight >= self.cfg.max_in_flight {
                        metrics.record_shed();
                        Frame::from_error(id, &ServeError::Overloaded)
                            .encode(&mut conn.out);
                    } else {
                        *in_flight += 1;
                        let sink = self.make_sink(token, id);
                        self.service.submit_text(text, sink);
                    }
                }
                Frame::QueryDelta { id, delta } => {
                    if *in_flight >= self.cfg.max_in_flight {
                        metrics.record_shed();
                        Frame::from_error(id, &ServeError::Overloaded)
                            .encode(&mut conn.out);
                    } else {
                        *in_flight += 1;
                        let sink = self.make_sink(token, id);
                        self.service.submit_delta(delta, sink);
                    }
                }
                Frame::Result { id, .. } | Frame::Error { id, .. } | Frame::Pong { id } => {
                    // server-to-client frames arriving AT the server are a
                    // protocol violation
                    metrics.record_proto_error();
                    let e = ServeError::Protocol {
                        reason: "client sent a server-side frame".into(),
                    };
                    Frame::from_error(id, &e).encode(&mut conn.out);
                    conn.closing = true;
                }
            }
        }

        /// Completion callback for one request: enqueue the result and
        /// nudge the event loop through the wake pipe.
        fn make_sink(&self, token: u64, id: u64) -> ReplySink {
            let completions = Arc::clone(&self.completions);
            let wake = Arc::clone(&self.wake_tx);
            Box::new(move |result| {
                match completions.lock() {
                    Ok(mut g) => g.push((token, id, result)),
                    Err(poisoned) => poisoned.into_inner().push((token, id, result)),
                }
                // a full pipe (or torn-down loop) is fine: the byte is
                // only a nudge, the 500 ms poll timeout is the backstop
                let _ = (&*wake).write(&[1u8]);
            })
        }
    }

    /// Flush as much pending output as the socket accepts. Returns false
    /// when the connection should be dropped (write error, or flush
    /// finished on a closing connection).
    fn flush(conn: &mut Conn) -> bool {
        while conn.out_pos < conn.out.len() {
            match conn.stream.write(&conn.out[conn.out_pos..]) {
                Ok(0) => return false,
                Ok(n) => conn.out_pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return false,
            }
        }
        conn.out.clear();
        conn.out_pos = 0;
        !conn.closing
    }
}

#[cfg(not(target_os = "linux"))]
pub use fallback::NetServer;

#[cfg(not(target_os = "linux"))]
mod fallback {
    //! Non-Linux stub: same API, `start` always fails cleanly so callers
    //! degrade to in-process serving.

    use std::sync::Arc;

    use super::super::error::ServeError;
    use super::{NetConfig, QueryService};

    /// Placeholder front door for platforms without the poll(2) loop.
    pub struct NetServer {
        never: std::convert::Infallible,
    }

    impl NetServer {
        /// Always fails on this platform.
        pub fn start(
            _service: Arc<dyn QueryService>,
            _cfg: NetConfig,
        ) -> Result<NetServer, ServeError> {
            Err(ServeError::Internal {
                reason: "network front door requires Linux (poll(2) event loop)"
                    .into(),
            })
        }

        /// Unreachable: no instance can exist.
        pub fn local_addr(&self) -> std::net::SocketAddr {
            match self.never {}
        }

        /// Unreachable: no instance can exist.
        pub fn shutdown(self) {
            match self.never {}
        }
    }
}
