//! Typed error taxonomy for the serving path.
//!
//! Every error a caller can observe from the serving stack — handle,
//! shard router, network front door — is a [`ServeError`]. Each variant
//! carries a *stable wire code* so the binary protocol
//! ([`super::proto`]) can ship errors across the network and reconstruct
//! an equivalent value on the client side; the codes are part of the wire
//! contract and must never be renumbered.
//!
//! The enum is `#[non_exhaustive]`: future PRs may add variants (and
//! codes) without breaking downstream matches, which is why
//! [`ServeError::from_wire`] maps unknown codes onto
//! [`ServeError::Internal`] instead of failing.

/// A serving-path failure, with a stable wire code per variant.
#[non_exhaustive]
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The request itself is malformed (wrong delta length, bad payload).
    BadInput {
        /// What was wrong with the request.
        reason: String,
    },
    /// The server shed this request to protect itself (in-flight cap or
    /// queue limits reached). Retry later.
    Overloaded,
    /// The server is shutting down; the request was not served.
    Shutdown,
    /// An executor replica panicked while embedding the batch holding
    /// this request. The replica was restarted; retry is safe.
    ReplicaPanic {
        /// The downcast panic payload.
        reason: String,
    },
    /// A shard failed (or timed out) and the quorum reduce could not
    /// cover for it.
    ShardUnavailable {
        /// Index of the first shard that failed.
        shard: usize,
        /// Why the shard's partial result never arrived.
        reason: String,
    },
    /// The caller-side wait for a result expired.
    Timeout,
    /// A wire-protocol violation (bad frame type, oversized frame,
    /// truncated payload).
    Protocol {
        /// What the peer sent that could not be decoded.
        reason: String,
    },
    /// Anything else: internal invariant failures, unknown wire codes
    /// from a newer peer.
    Internal {
        /// Diagnostic detail.
        reason: String,
    },
}

/// Stable wire code for [`ServeError::BadInput`].
pub const CODE_BAD_INPUT: u16 = 1;
/// Stable wire code for [`ServeError::Overloaded`].
pub const CODE_OVERLOADED: u16 = 2;
/// Stable wire code for [`ServeError::Shutdown`].
pub const CODE_SHUTDOWN: u16 = 3;
/// Stable wire code for [`ServeError::ReplicaPanic`].
pub const CODE_REPLICA_PANIC: u16 = 4;
/// Stable wire code for [`ServeError::ShardUnavailable`].
pub const CODE_SHARD_UNAVAILABLE: u16 = 5;
/// Stable wire code for [`ServeError::Timeout`].
pub const CODE_TIMEOUT: u16 = 6;
/// Stable wire code for [`ServeError::Protocol`].
pub const CODE_PROTOCOL: u16 = 7;
/// Stable wire code for [`ServeError::Internal`].
pub const CODE_INTERNAL: u16 = 8;

impl ServeError {
    /// The variant's stable wire code (see the `CODE_*` constants).
    pub fn wire_code(&self) -> u16 {
        match self {
            ServeError::BadInput { .. } => CODE_BAD_INPUT,
            ServeError::Overloaded => CODE_OVERLOADED,
            ServeError::Shutdown => CODE_SHUTDOWN,
            ServeError::ReplicaPanic { .. } => CODE_REPLICA_PANIC,
            ServeError::ShardUnavailable { .. } => CODE_SHARD_UNAVAILABLE,
            ServeError::Timeout => CODE_TIMEOUT,
            ServeError::Protocol { .. } => CODE_PROTOCOL,
            ServeError::Internal { .. } => CODE_INTERNAL,
        }
    }

    /// Encode as `(code, detail, message)` for an error wire frame. The
    /// `detail` word carries variant-specific numeric payload (today: the
    /// shard index for [`ServeError::ShardUnavailable`], 0 otherwise).
    pub fn to_wire(&self) -> (u16, u64, String) {
        let detail = match self {
            ServeError::ShardUnavailable { shard, .. } => *shard as u64,
            _ => 0,
        };
        let msg = match self {
            ServeError::BadInput { reason }
            | ServeError::ReplicaPanic { reason }
            | ServeError::ShardUnavailable { reason, .. }
            | ServeError::Protocol { reason }
            | ServeError::Internal { reason } => reason.clone(),
            ServeError::Overloaded | ServeError::Shutdown | ServeError::Timeout => {
                String::new()
            }
        };
        (self.wire_code(), detail, msg)
    }

    /// Reconstruct from a wire triple. Exactly inverts [`Self::to_wire`]
    /// for every known code; unknown codes (a newer peer) collapse into
    /// [`ServeError::Internal`] with the code preserved in the reason.
    pub fn from_wire(code: u16, detail: u64, msg: String) -> ServeError {
        match code {
            CODE_BAD_INPUT => ServeError::BadInput { reason: msg },
            CODE_OVERLOADED => ServeError::Overloaded,
            CODE_SHUTDOWN => ServeError::Shutdown,
            CODE_REPLICA_PANIC => ServeError::ReplicaPanic { reason: msg },
            CODE_SHARD_UNAVAILABLE => ServeError::ShardUnavailable {
                shard: detail as usize,
                reason: msg,
            },
            CODE_TIMEOUT => ServeError::Timeout,
            CODE_PROTOCOL => ServeError::Protocol { reason: msg },
            CODE_INTERNAL => ServeError::Internal { reason: msg },
            other => ServeError::Internal {
                reason: format!("unknown wire error code {other}: {msg}"),
            },
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::BadInput { reason } => write!(f, "bad input: {reason}"),
            ServeError::Overloaded => write!(f, "server overloaded (load shed)"),
            ServeError::Shutdown => write!(f, "server shutting down"),
            ServeError::ReplicaPanic { reason } => {
                write!(f, "replica panicked: {reason}")
            }
            ServeError::ShardUnavailable { shard, reason } => {
                write!(f, "shard {shard} unavailable: {reason}")
            }
            ServeError::Timeout => write!(f, "timed out waiting for a result"),
            ServeError::Protocol { reason } => write!(f, "protocol error: {reason}"),
            ServeError::Internal { reason } => write!(f, "internal error: {reason}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Downcast a panic payload into a human-readable message — the plumbing
/// that routes `catch_unwind` payloads into
/// [`ServeError::ReplicaPanic`].
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{prop_assert, property};

    fn all_variants(reason: &str, shard: usize) -> Vec<ServeError> {
        vec![
            ServeError::BadInput { reason: reason.into() },
            ServeError::Overloaded,
            ServeError::Shutdown,
            ServeError::ReplicaPanic { reason: reason.into() },
            ServeError::ShardUnavailable { shard, reason: reason.into() },
            ServeError::Timeout,
            ServeError::Protocol { reason: reason.into() },
            ServeError::Internal { reason: reason.into() },
        ]
    }

    #[test]
    fn wire_codes_are_stable_and_distinct() {
        let codes: Vec<u16> = all_variants("x", 3)
            .iter()
            .map(ServeError::wire_code)
            .collect();
        assert_eq!(codes, vec![1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn wire_round_trip_every_variant() {
        property("serve error wire round-trip", 200, |g| {
            let reason = g.unicode_string(0, 40);
            let shard = g.usize_in(0, 1000);
            for e in all_variants(&reason, shard) {
                let (code, detail, msg) = e.to_wire();
                let back = ServeError::from_wire(code, detail, msg);
                if back != e {
                    return Err(format!("{e:?} -> {back:?}"));
                }
            }
            prop_assert(true, "ok")
        });
    }

    #[test]
    fn unknown_code_becomes_internal() {
        let e = ServeError::from_wire(999, 7, "from the future".into());
        match e {
            ServeError::Internal { reason } => {
                assert!(reason.contains("999"));
                assert!(reason.contains("from the future"));
            }
            other => panic!("expected Internal, got {other:?}"),
        }
    }

    #[test]
    fn display_mentions_the_payload() {
        let e = ServeError::ShardUnavailable { shard: 2, reason: "timeout".into() };
        let s = e.to_string();
        assert!(s.contains("shard 2") && s.contains("timeout"), "{s}");
        assert!(ServeError::Overloaded.to_string().contains("overloaded"));
    }

    #[test]
    fn panic_message_downcasts() {
        let boxed: Box<dyn std::any::Any + Send> = Box::new("static str");
        assert_eq!(panic_message(boxed.as_ref()), "static str");
        let boxed: Box<dyn std::any::Any + Send> = Box::new(String::from("owned"));
        assert_eq!(panic_message(boxed.as_ref()), "owned");
        let boxed: Box<dyn std::any::Any + Send> = Box::new(42u32);
        assert_eq!(panic_message(boxed.as_ref()), "non-string panic payload");
    }
}
