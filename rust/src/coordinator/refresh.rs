//! Drift-triggered hot re-embedding: the control loop that closes the
//! paper's streaming story.
//!
//! The drift monitor ([`super::stream`]) answers *when* the landmark
//! configuration has gone stale; this module answers *what to do about
//! it* — without taking the service down:
//!
//! ```text
//!  DriftMonitor signal ──> ingest buffered queries into the corpus
//!                          (CorpusWriter::append; crash-safe)
//!                     ──> shadow solve: re-select landmarks, warm-start
//!                          the base solve from the old configuration
//!                     ──> Procrustes-align the new base to the old
//!                          frame (overlapping landmarks as the fit set)
//!                     ──> rebuild the OSE factory (+ landmark graph)
//!                     ──> ServerHandle::swap_generation (atomic;
//!                          in-flight queries drain on the old engine)
//! ```
//!
//! Everything up to the swap happens in a *shadow generation* on the
//! controller's own thread: the serving path never blocks on the solve,
//! and a refresh that dies mid-solve (crash, chaos kill) leaves the old
//! generation serving and the corpus valid — the append is finished (or
//! cleanly empty) before the solve starts. See docs/ARCHITECTURE.md
//! ("Refresh loop") for the consistency guarantees.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::source::{
    CorpusWriter, ObjectTable, TableDelta, DEFAULT_CACHE_BUDGET,
};
use crate::mds::divide::fps_anchors;
use crate::mds::landmarks::random_landmarks;
use crate::mds::{graph_landmarks, LandmarkMethod, Matrix, Procrustes, SubsetDelta};
use crate::runtime::Backend;
use crate::strdist::Dissimilarity;
use crate::util::prng::Rng;

use super::embedder::{opt_factory, solve_base_source_warm, OseBackend, PipelineConfig};
use super::server::ServerHandle;

/// Refresh-controller knobs (see the `refresh`, `refresh_cooldown` and
/// `ingest_buffer` config keys).
#[derive(Clone, Debug)]
pub struct RefreshConfig {
    /// Minimum spacing between two drift-triggered refreshes. A signal
    /// arriving inside the cooldown is deferred, not dropped: the poll
    /// loop re-checks it once the cooldown expires.
    pub cooldown: Duration,
    /// Capacity of the recent-query ingest buffer (oldest entries are
    /// evicted first). These are the queries a refresh appends to the
    /// corpus, so the re-solve sees the drifted distribution.
    pub ingest_buffer: usize,
    /// How often the poll loop samples the drift signal.
    pub poll: Duration,
}

impl Default for RefreshConfig {
    fn default() -> Self {
        Self {
            cooldown: Duration::from_millis(5000),
            ingest_buffer: 4096,
            poll: Duration::from_millis(200),
        }
    }
}

/// Outcome of one completed refresh.
#[derive(Clone, Debug)]
pub struct RefreshReport {
    /// Generation tag now serving (old + 1).
    pub generation: u64,
    /// Buffered queries appended to the corpus by this refresh.
    pub ingested: usize,
    /// Normalised stress of the re-solved landmark base (exact for the
    /// monolithic solver, sampled for divide-and-conquer).
    pub landmark_stress: f64,
    /// RMSD of the Procrustes fit aligning the new base to the old
    /// frame over the overlapping landmarks. NaN when fewer than
    /// `dim + 1` landmarks survived and the alignment was skipped.
    pub align_rmsd: f64,
    /// How long the retired generation took to drain its in-flight work.
    pub swap_drain: Duration,
}

/// Mutable controller state: the landmark set/configuration of the
/// generation currently serving, the drift signals already consumed,
/// and the last completed report.
struct RefreshState {
    landmark_idx: Vec<usize>,
    landmark_config: Matrix,
    consumed_signals: u64,
    last: Option<RefreshReport>,
}

struct RefreshShared {
    handle: ServerHandle<str>,
    corpus: PathBuf,
    pipeline: PipelineConfig,
    backend: Backend,
    cfg: RefreshConfig,
    buffer: Mutex<VecDeque<String>>,
    state: Mutex<RefreshState>,
    /// Test hook: fail the next refresh after the corpus append but
    /// before the shadow solve (the crash point the chaos suite probes).
    chaos_kill: AtomicBool,
    stop: AtomicBool,
}

/// Lock a mutex tolerating poisoning: the ingest tap runs on the
/// serving path (must not panic) and controller state stays consistent
/// under panicking writers (every update is a whole-value replace).
fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Background refresh controller for a string-domain [`super::Server`]:
/// subscribes to the drift signal, ingests recent queries into the
/// out-of-core corpus, re-solves the landmark base in a shadow
/// generation (warm-started from the serving configuration), aligns it
/// to the old frame and hot-swaps the serving model. Built by
/// [`RefreshController::start`]; stopped by [`RefreshController::stop`]
/// or drop.
pub struct RefreshController {
    shared: Arc<RefreshShared>,
    poller: Option<JoinHandle<()>>,
}

impl RefreshController {
    /// Install the ingest tap on `handle` and spawn the poll loop.
    ///
    /// `corpus` is the text corpus the server was embedded from (the
    /// refresh appends ingested queries to it); `landmark_idx` /
    /// `landmark_config` describe the currently-serving generation
    /// (row `r` of the config is corpus row `landmark_idx[r]`).
    ///
    /// Only the optimisation OSE backend is refreshable — the NN
    /// backend would need a full retrain, which is a re-embed, not a
    /// hot refresh — and `pipeline.backend` is validated here.
    pub fn start(
        handle: ServerHandle<str>,
        corpus: PathBuf,
        pipeline: PipelineConfig,
        backend: Backend,
        landmark_idx: Vec<usize>,
        landmark_config: Matrix,
        cfg: RefreshConfig,
    ) -> Result<RefreshController> {
        anyhow::ensure!(
            pipeline.backend == OseBackend::Opt,
            "hot refresh supports the opt OSE backend only (nn needs a retrain)"
        );
        anyhow::ensure!(
            landmark_idx.len() == landmark_config.rows
                && landmark_config.cols == pipeline.dim,
            "landmark config is {}x{}, expected {}x{}",
            landmark_config.rows,
            landmark_config.cols,
            landmark_idx.len(),
            pipeline.dim
        );
        // fail fast on an unreadable corpus instead of at the first drift
        let table = ObjectTable::open(&corpus, DEFAULT_CACHE_BUDGET)?;
        anyhow::ensure!(
            landmark_idx.iter().all(|&i| i < table.len()),
            "landmark index out of corpus bounds ({} records)",
            table.len()
        );
        drop(table);

        let consumed = handle.metrics.snapshot().drift_signals;
        let shared = Arc::new(RefreshShared {
            handle,
            corpus,
            pipeline,
            backend,
            cfg,
            buffer: Mutex::new(VecDeque::new()),
            state: Mutex::new(RefreshState {
                landmark_idx,
                landmark_config,
                consumed_signals: consumed,
                last: None,
            }),
            chaos_kill: AtomicBool::new(false),
            stop: AtomicBool::new(false),
        });

        // The tap holds a Weak so a dropped controller can never keep
        // the shared state alive through the server.
        let tap = Arc::downgrade(&shared);
        shared.handle.set_ingest_tap(Some(Arc::new(move |q: &str| {
            if let Some(s) = tap.upgrade() {
                let mut buf = relock(&s.buffer);
                if buf.len() >= s.cfg.ingest_buffer.max(1) {
                    buf.pop_front();
                }
                buf.push_back(q.to_string());
            }
        })));

        let s = Arc::clone(&shared);
        let poller = std::thread::Builder::new()
            .name("ose-refresh".into())
            .spawn(move || poll_loop(&s))
            .map_err(|e| anyhow::anyhow!("spawning refresh poller: {e}"))?;
        Ok(RefreshController { shared, poller: Some(poller) })
    }

    /// Run one refresh cycle synchronously, regardless of the drift
    /// signal (the poll loop calls this on signal; tests and benches
    /// call it directly). Updates the `refreshes` / `refresh_failures`
    /// counters.
    pub fn run_once(&self) -> Result<RefreshReport> {
        let r = run_refresh(&self.shared);
        match &r {
            Ok(_) => self.shared.handle.metrics.record_refresh(),
            Err(_) => self.shared.handle.metrics.record_refresh_failure(),
        }
        r
    }

    /// The last completed refresh, if any.
    pub fn last_report(&self) -> Option<RefreshReport> {
        relock(&self.shared.state).last.clone()
    }

    /// Landmark configuration of the generation currently serving
    /// (aligned to the original frame).
    pub fn landmark_config(&self) -> Matrix {
        relock(&self.shared.state).landmark_config.clone()
    }

    /// Corpus row indices of the landmarks currently serving.
    pub fn landmark_idx(&self) -> Vec<usize> {
        relock(&self.shared.state).landmark_idx.clone()
    }

    /// Test hook: when set, the next refresh dies after the corpus
    /// append but before the shadow solve — the crash point the chaos
    /// suite uses to prove a killed refresh leaves the old generation
    /// serving and the corpus readable.
    pub fn set_chaos_kill(&self, on: bool) {
        self.shared.chaos_kill.store(on, Ordering::Release);
    }

    /// Stop the poll loop, uninstall the ingest tap and join the
    /// controller thread. Idempotent with drop.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        self.shared.handle.set_ingest_tap(None);
        if let Some(h) = self.poller.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RefreshController {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Poll the drift signal and fire refreshes, one at a time, honouring
/// the cooldown. Signals that arrive during a cooldown or a running
/// refresh are not lost: the counter comparison re-fires once allowed.
fn poll_loop(s: &Arc<RefreshShared>) {
    let mut last_fire: Option<Instant> = None;
    while !s.stop.load(Ordering::Acquire) {
        std::thread::sleep(s.cfg.poll);
        if s.stop.load(Ordering::Acquire) {
            return;
        }
        let signals = s.handle.metrics.snapshot().drift_signals;
        let consumed = relock(&s.state).consumed_signals;
        if signals <= consumed {
            continue;
        }
        if let Some(t) = last_fire {
            if t.elapsed() < s.cfg.cooldown {
                continue;
            }
        }
        last_fire = Some(Instant::now());
        match run_refresh(s) {
            Ok(r) => {
                s.handle.metrics.record_refresh();
                log::info!(
                    "refresh: generation {} live (ingested {}, stress {:.4}, \
                     align rmsd {:.4}, drain {:?})",
                    r.generation,
                    r.ingested,
                    r.landmark_stress,
                    r.align_rmsd,
                    r.swap_drain
                );
            }
            Err(e) => {
                s.handle.metrics.record_refresh_failure();
                // the old generation keeps serving; consume the signal so
                // a permanently-failing refresh cannot hot-loop faster
                // than the cooldown
                relock(&s.state).consumed_signals =
                    s.handle.metrics.snapshot().drift_signals;
                log::error!("refresh failed (old generation keeps serving): {e:#}");
            }
        }
    }
}

/// One refresh cycle: ingest, shadow solve, align, swap. Every step
/// before [`ServerHandle::swap_generation`] runs on the controller
/// thread against shadow state — a failure anywhere leaves the serving
/// generation untouched.
fn run_refresh(s: &Arc<RefreshShared>) -> Result<RefreshReport> {
    // 1. Drain the ingest buffer and append it to the corpus. The append
    //    is finished (header patched) before anything else happens, so a
    //    later failure cannot leave a torn corpus.
    let drained: Vec<String> = relock(&s.buffer).drain(..).collect();
    if !drained.is_empty() {
        let mut w = CorpusWriter::append(&s.corpus)?;
        for q in &drained {
            w.push_text(q)?;
        }
        w.finish()?;
    }

    // 2. Chaos checkpoint: the corpus is valid, the swap has not begun.
    if s.chaos_kill.load(Ordering::Acquire) {
        anyhow::bail!("chaos: refresh killed mid-solve (corpus append completed)");
    }

    // 3. Reopen the corpus and re-select landmarks over the grown record
    //    set, mirroring embed_corpus exactly (same selectors, same seeds).
    let p = &s.pipeline;
    let table = ObjectTable::open(&s.corpus, DEFAULT_CACHE_BUDGET)?;
    let metric_arc = s.handle.metric();
    let metric: &dyn Dissimilarity<str> = metric_arc.as_ref();
    let source = TableDelta::text(&table, metric)?;
    let n = table.len();
    anyhow::ensure!(
        p.landmarks <= n,
        "more landmarks ({}) than corpus records ({n})",
        p.landmarks
    );
    let new_idx = match p.landmark_method {
        LandmarkMethod::Random => {
            random_landmarks(&mut Rng::new(p.seed), n, p.landmarks)
        }
        LandmarkMethod::Fps => fps_anchors(&source, p.landmarks, p.seed),
        LandmarkMethod::MaxMinPool => {
            graph_landmarks(&source, p.landmarks, &p.graph, p.seed)
        }
    };

    // 4. Warm init: landmarks that survive the re-selection carry their
    //    serving coordinates; fresh landmarks start from the seeded
    //    random stream. The overlap doubles as the Procrustes fit set.
    let (old_idx, old_config) = {
        let st = relock(&s.state);
        (st.landmark_idx.clone(), st.landmark_config.clone())
    };
    let mut lcfg = p.lsmds.clone();
    lcfg.dim = p.dim;
    lcfg.seed = p.seed ^ 0x5eed;
    let old_pos: HashMap<usize, usize> =
        old_idx.iter().enumerate().map(|(r, &i)| (i, r)).collect();
    let mut rng = Rng::new(lcfg.seed);
    let mut init =
        Matrix::random_normal(&mut rng, new_idx.len(), p.dim, lcfg.init_sigma);
    let mut overlap_new: Vec<usize> = Vec::new();
    let mut overlap_old: Vec<usize> = Vec::new();
    for (r, &i) in new_idx.iter().enumerate() {
        if let Some(&or) = old_pos.get(&i) {
            init.row_mut(r).copy_from_slice(old_config.row(or));
            overlap_new.push(r);
            overlap_old.push(or);
        }
    }

    // 5. Shadow solve, warm-started.
    let sub = SubsetDelta::new(&source, &new_idx);
    let (config, landmark_stress) =
        solve_base_source_warm(&sub, &lcfg, p.base_solver, &s.backend, &init)?;

    // 6. Align the new base to the OLD frame over the overlap, so the
    //    coordinate space clients observe stays continuous across the
    //    swap. Under dim + 1 overlapping landmarks the fit is
    //    under-determined; serve the unaligned base instead.
    let (aligned, align_rmsd) = if overlap_new.len() >= p.dim + 1 {
        let src = config.select_rows(&overlap_new);
        let dst = old_config.select_rows(&overlap_old);
        let fit = Procrustes::fit(&src, &dst);
        (fit.apply(&config), fit.rmsd)
    } else {
        log::warn!(
            "refresh: only {} overlapping landmarks (< {}), serving unaligned",
            overlap_new.len(),
            p.dim + 1
        );
        (config, f64::NAN)
    };

    // 7. Rebuild the OSE factory around the new base (the query_k
    //    landmark graph is rebuilt inside) and swap the generation. The
    //    swap is the single commit point: everything above is shadow.
    let factory = opt_factory(p, &s.backend, aligned.clone());
    let objs: Vec<Box<str>> = table
        .text_rows(&new_idx)
        .into_iter()
        .map(String::into_boxed_str)
        .collect();
    let (generation, swap_drain) =
        s.handle
            .swap_generation(objs, factory, Some(aligned.clone()))?;

    // 8. Publish the new state and consume the signals that triggered us
    //    (later signals re-fire after the cooldown).
    let report = RefreshReport {
        generation,
        ingested: drained.len(),
        landmark_stress,
        align_rmsd,
        swap_drain,
    };
    let mut st = relock(&s.state);
    st.landmark_idx = new_idx;
    st.landmark_config = aligned;
    st.consumed_signals = s.handle.metrics.snapshot().drift_signals;
    st.last = Some(report.clone());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::embedder::embed_corpus;
    use crate::coordinator::server::{BatcherConfig, Request, ServerBuilder};
    use crate::data::{Geco, GecoConfig};
    use crate::mds::LsmdsConfig;
    use crate::strdist::Levenshtein;

    fn corpus_with_names(seed: u64, n: usize) -> (PathBuf, Vec<String>) {
        let mut geco = Geco::new(GecoConfig { seed, ..Default::default() });
        let names = geco.generate_unique(n);
        let mut path = std::env::temp_dir();
        path.push(format!("lmds_refresh_{seed}_{n}_{}", std::process::id()));
        let mut w = CorpusWriter::create_text(&path).unwrap();
        for name in &names {
            w.push_text(name).unwrap();
        }
        w.finish().unwrap();
        (path, names)
    }

    fn tiny_pipeline() -> PipelineConfig {
        PipelineConfig {
            dim: 2,
            landmarks: 20,
            landmark_method: LandmarkMethod::Random,
            backend: OseBackend::Opt,
            lsmds: LsmdsConfig { dim: 2, max_iters: 60, ..Default::default() },
            ose_steps: Some(8),
            ..Default::default()
        }
    }

    #[test]
    fn manual_refresh_swaps_generation_and_updates_state() {
        let (path, _) = corpus_with_names(31, 60);
        let pcfg = tiny_pipeline();
        let backend = Backend::native();
        let table = ObjectTable::open(&path, DEFAULT_CACHE_BUDGET).unwrap();
        let source = TableDelta::text(&table, &Levenshtein).unwrap();
        let r = embed_corpus(&source, &pcfg, &backend).unwrap();
        drop(table);

        let landmark_objs: Vec<String> = {
            let t = ObjectTable::open(&path, DEFAULT_CACHE_BUDGET).unwrap();
            t.text_rows(&r.landmark_idx)
        };
        let server = ServerBuilder::strings(
            landmark_objs,
            Arc::new(Levenshtein),
            Arc::clone(&r.factory),
        )
        .batcher(BatcherConfig { replicas: 1, ..Default::default() })
        .build()
        .unwrap();
        let h = server.handle();
        let ctl = RefreshController::start(
            h.clone(),
            path.clone(),
            pcfg,
            backend,
            r.landmark_idx.clone(),
            r.landmark_config.clone(),
            RefreshConfig {
                poll: Duration::from_secs(3600), // manual control only
                ..Default::default()
            },
        )
        .unwrap();

        // route some traffic so the ingest buffer has content
        for i in 0..10 {
            h.submit(Request::object(format!("fresh query {i}")))
                .recv()
                .unwrap();
        }
        let report = ctl.run_once().unwrap();
        assert_eq!(report.generation, 1);
        assert_eq!(h.generation(), 1);
        assert!(report.ingested > 0, "buffered queries must be ingested");
        assert!(report.landmark_stress.is_finite());
        assert!(
            report.align_rmsd.is_finite(),
            "full overlap must produce a real alignment"
        );
        let snap = h.metrics.snapshot();
        assert_eq!(snap.refreshes, 1);
        assert_eq!(snap.generation, 1);

        // the corpus grew by exactly the ingested queries and stays valid
        let t = ObjectTable::open(&path, DEFAULT_CACHE_BUDGET).unwrap();
        assert_eq!(t.len(), 60 + report.ingested);

        // post-swap serving still works
        let q = h.submit(Request::object("post refresh query")).recv().unwrap();
        assert!(q.coords.iter().all(|c| c.is_finite()));

        ctl.stop();
        drop(h);
        server.shutdown();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn refresh_rejects_nn_backend() {
        let (path, _) = corpus_with_names(32, 30);
        let pcfg = tiny_pipeline();
        let backend = Backend::native();
        let table = ObjectTable::open(&path, DEFAULT_CACHE_BUDGET).unwrap();
        let source = TableDelta::text(&table, &Levenshtein).unwrap();
        let r = embed_corpus(&source, &pcfg, &backend).unwrap();
        let landmark_objs = table.text_rows(&r.landmark_idx);
        drop(table);
        let server = ServerBuilder::strings(
            landmark_objs,
            Arc::new(Levenshtein),
            Arc::clone(&r.factory),
        )
        .build()
        .unwrap();
        let res = RefreshController::start(
            server.handle(),
            path.clone(),
            PipelineConfig { backend: OseBackend::Nn, ..tiny_pipeline() },
            backend,
            r.landmark_idx.clone(),
            r.landmark_config.clone(),
            RefreshConfig::default(),
        );
        assert!(res.is_err(), "nn backend must be rejected");
        server.shutdown();
        std::fs::remove_file(&path).ok();
    }
}
