//! Sharded serving: partition the landmark set across S shards, each
//! owning one block of the divide solve ([`partition_blocks`] — shared
//! FPS anchors plus a contiguous chunk, exactly the plan
//! `mds::divide` stitches with), and route every query across them:
//!
//! ```text
//!  clients --submit--> [frontend pool: full delta row]
//!      --sub-rows--> [shard 0: replicas over block-0 landmarks]
//!                    [shard 1: replicas over block-1 landmarks]  ...
//!      --partials--> [quorum reduce: landmark-weighted mean]
//!      --coords (degraded flag when a shard missed)--> reply sink
//! ```
//!
//! Each shard runs its own replicated executor pool (the same
//! `executor_loop` as the unsharded server) over a [`BackendOpt`] method
//! anchored to the shard's slice of the landmark configuration. Because
//! every block of the divide solve already lives in the global stitched
//! frame, the per-shard partial solutions are estimates of the same
//! coordinates and reduce by a weighted mean — no per-query Procrustes.
//!
//! Graceful degradation: the router waits `shard_timeout` for the shard
//! partials. If at least `quorum` arrive the query succeeds — flagged
//! [`QueryResult::degraded`] when any shard missed — otherwise it fails
//! with [`ServeError::ShardUnavailable`]. A dead shard (see
//! [`ShardedHandle::stop_shard`]) therefore costs accuracy, not
//! availability.
//!
//! Scope: sharding is for the *optimisation* OSE, whose objective
//! decomposes over landmarks. The NN OSE needs the full L-length delta
//! row as MLP input and cannot decompose, so a sharded build always uses
//! [`BackendOpt`] over the landmark configuration (the builder's factory
//! is only used by the unsharded path).
//!
//! With [`ShardConfig::query_k`] set, each shard additionally restricts
//! every solve to the query's `query_k` nearest landmarks within its own
//! slice, located through a shard-local small-world graph built once at
//! startup ([`crate::mds::graph`]; walk-through in docs/QUERY_PATH.md).
//! Per-query shard work then drops from O(L/S) to O(k log(L/S)).

use std::sync::mpsc::{channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::mds::divide::{partition_blocks, DivideConfig, PointsDelta};
use crate::mds::graph::GraphConfig;
use crate::strdist::Dissimilarity;
use crate::util::threadpool::WorkerPool;

use super::error::ServeError;
use super::methods::BackendOpt;
use super::metrics::{Metrics, Snapshot};
use super::server::{
    executor_loop, feed_drift, DriftState, QueryResult, ReplySink, Request,
    ServerBuilder, Ticket, WorkItem,
};

/// Shard plan: how many shards, how they share anchors, and how the
/// router behaves when shards are slow or dead.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Number of shards S (0 and 1 both mean a single shard).
    pub shards: usize,
    /// Shared anchor count per shard; 0 picks
    /// [`crate::mds::divide::auto_anchors`].
    pub anchors: usize,
    /// Executor replicas per shard.
    pub replicas_per_shard: usize,
    /// Minimum shard partials for a successful reduce; 0 = majority
    /// (S/2 + 1).
    pub quorum: usize,
    /// How long the router waits for shard partials before treating the
    /// stragglers as failed.
    pub shard_timeout: Duration,
    /// Partition seed (anchor FPS); deterministic plans per seed.
    pub seed: u64,
    /// Majorization budget per shard solve; 0 = the serving default
    /// (200 steps with early stopping).
    pub opt_steps: usize,
    /// Per-replica sparse-query restriction: each shard executor
    /// majorizes against only the `query_k` nearest landmarks of its
    /// slice, found through a shard-local small-world graph
    /// ([`crate::mds::graph`], docs/QUERY_PATH.md). 0 = dense;
    /// `query_k >=` slice length also falls back to dense per shard.
    pub query_k: usize,
    /// Landmark-graph parameters for the shard-local graphs (only read
    /// when `query_k > 0`).
    pub graph: GraphConfig,
}

impl Default for ShardConfig {
    fn default() -> Self {
        Self {
            shards: 1,
            anchors: 0,
            replicas_per_shard: 1,
            quorum: 0,
            shard_timeout: Duration::from_secs(5),
            seed: 42,
            opt_steps: 0,
            query_k: 0,
            graph: GraphConfig::default(),
        }
    }
}

struct ShardSlot {
    /// Global landmark indices this shard owns (anchors first).
    idx: Vec<usize>,
    /// Reduce weight: the landmark count backing this shard's estimate.
    weight: f64,
    /// Dispatch queue sender; `None` once the shard is stopped.
    tx: Mutex<Option<SyncSender<WorkItem>>>,
    /// Per-shard serving counters (separate from the router's, so shard
    /// fan-out does not inflate the global request/batch counts).
    metrics: Arc<Metrics>,
}

impl ShardSlot {
    fn take_tx(&self) -> Option<SyncSender<WorkItem>> {
        match self.tx.lock() {
            Ok(mut g) => g.take(),
            Err(poisoned) => poisoned.into_inner().take(),
        }
    }
}

/// The sharded OSE serving coordinator.
///
/// Shutdown joins the per-shard executor pools after withdrawing every
/// dispatch queue; caller handles must be dropped first or queries
/// submitted during teardown simply fail with
/// [`ServeError::ShardUnavailable`].
pub struct ShardedServer<T: ?Sized + Send + Sync + 'static> {
    handle: Option<ShardedHandle<T>>,
    slots: Arc<Vec<ShardSlot>>,
    executors: Vec<JoinHandle<()>>,
    _frontend: Arc<WorkerPool>,
}

/// Cheap-to-clone client handle onto a [`ShardedServer`]: same submit
/// surface as the unsharded [`super::ServerHandle`].
pub struct ShardedHandle<T: ?Sized + Send + Sync + 'static> {
    landmarks: Arc<Vec<Box<T>>>,
    metric: Arc<dyn Dissimilarity<T> + Send + Sync>,
    pool: Arc<WorkerPool>,
    slots: Arc<Vec<ShardSlot>>,
    drift: Option<Arc<DriftState>>,
    dim: usize,
    quorum: usize,
    timeout: Duration,
    /// Router-level serving counters (live; see [`Metrics::snapshot`]).
    pub metrics: Arc<Metrics>,
}

impl<T: ?Sized + Send + Sync + 'static> Clone for ShardedHandle<T> {
    fn clone(&self) -> Self {
        Self {
            landmarks: Arc::clone(&self.landmarks),
            metric: Arc::clone(&self.metric),
            pool: Arc::clone(&self.pool),
            slots: Arc::clone(&self.slots),
            drift: self.drift.clone(),
            dim: self.dim,
            quorum: self.quorum,
            timeout: self.timeout,
            metrics: Arc::clone(&self.metrics),
        }
    }
}

impl<T: ?Sized + Send + Sync + 'static> ServerBuilder<T> {
    /// Validate the configuration and start the sharded server. Requires
    /// [`Self::landmark_config`]; the per-shard solvers are
    /// [`BackendOpt`] methods over its block slices, running on the
    /// builder's backend.
    pub fn build_sharded(self) -> Result<ShardedServer<T>, ServeError> {
        let config = match self.landmark_config {
            Some(c) => c,
            None => {
                return Err(ServeError::BadInput {
                    reason: "build_sharded requires landmark_config (L x K)".into(),
                })
            }
        };
        let l = self.landmarks.len();
        if config.rows != l || config.cols == 0 {
            return Err(ServeError::BadInput {
                reason: format!(
                    "landmark_config is {}x{}, expected {l} rows and K >= 1",
                    config.rows, config.cols
                ),
            });
        }
        if l == 0 {
            return Err(ServeError::BadInput {
                reason: "cannot shard an empty landmark set".into(),
            });
        }
        let k = config.cols;
        if let Some(h) = &self.drift {
            if (h.landmark_config.rows, h.landmark_config.cols) != (l, k) {
                return Err(ServeError::BadInput {
                    reason: format!(
                        "drift hook landmark configuration is {}x{}, expected {l}x{k}",
                        h.landmark_config.rows, h.landmark_config.cols
                    ),
                });
            }
        }

        let scfg = self.shard_cfg;
        let shards = scfg.shards.max(1);
        let part = partition_blocks(
            &PointsDelta { points: &config },
            k,
            &DivideConfig { blocks: shards, anchors: scfg.anchors },
            scfg.seed,
        );
        let s_eff = part.blocks();
        let quorum = match scfg.quorum {
            0 => s_eff / 2 + 1,
            q => q.min(s_eff),
        };
        let replicas = scfg.replicas_per_shard.max(1);
        let bcfg = self.batcher;

        let metrics = Arc::new(Metrics::new());
        metrics.set_shards(s_eff);
        metrics.set_replicas(s_eff * replicas);

        let mut slots = Vec::with_capacity(s_eff);
        let mut executors = Vec::with_capacity(s_eff * replicas);
        for (s, idx) in part.block_idx.iter().enumerate() {
            let sub = config.select_rows(idx);
            let factory = if scfg.query_k > 0 {
                // sparse queries: each replica restricts the majorization
                // to the query's query_k nearest landmarks within this
                // shard's slice, located through a shard-local graph
                BackendOpt::replica_factory_sparse(
                    self.backend.clone(),
                    sub,
                    scfg.opt_steps,
                    scfg.query_k,
                    &scfg.graph,
                )
            } else {
                match scfg.opt_steps {
                    0 => BackendOpt::replica_factory(self.backend.clone(), sub),
                    steps => BackendOpt::replica_factory_budget(
                        self.backend.clone(),
                        sub,
                        steps,
                    ),
                }
            };
            let (tx, rx) =
                std::sync::mpsc::sync_channel::<WorkItem>(bcfg.queue_cap.max(1));
            let rx = Arc::new(Mutex::new(rx));
            let shard_metrics = Arc::new(Metrics::new());
            shard_metrics.set_replicas(replicas);
            for r in 0..replicas {
                let method = factory.build();
                let rx = Arc::clone(&rx);
                let factory = Arc::clone(&factory);
                let shard_metrics = Arc::clone(&shard_metrics);
                let ecfg = bcfg.clone();
                let t = std::thread::Builder::new()
                    .name(format!("ose-shard-{s}-{r}"))
                    .spawn(move || {
                        executor_loop(
                            &rx,
                            method,
                            factory.as_ref(),
                            &ecfg,
                            &shard_metrics,
                            None,
                        )
                    })
                    .map_err(|e| ServeError::Internal {
                        reason: format!("spawning shard {s} executor {r}: {e}"),
                    })?;
                executors.push(t);
            }
            slots.push(ShardSlot {
                idx: idx.clone(),
                weight: idx.len() as f64,
                tx: Mutex::new(Some(tx)),
                metrics: shard_metrics,
            });
        }

        let slots = Arc::new(slots);
        let pool = Arc::new(WorkerPool::new(bcfg.frontend_threads));
        let handle = ShardedHandle {
            landmarks: Arc::new(self.landmarks),
            metric: self.metric,
            pool: Arc::clone(&pool),
            slots: Arc::clone(&slots),
            drift: self.drift.map(|h| Arc::new(DriftState::from_hook(h))),
            dim: k,
            quorum,
            timeout: scfg.shard_timeout,
            metrics,
        };
        Ok(ShardedServer {
            handle: Some(handle),
            slots,
            executors,
            _frontend: pool,
        })
    }
}

impl<T: ?Sized + Send + Sync + 'static> ShardedServer<T> {
    /// A new client handle onto the running sharded server.
    ///
    /// # Panics
    /// After [`ShardedServer::shutdown`] has consumed the handle.
    pub fn handle(&self) -> ShardedHandle<T> {
        // LINT-ALLOW(panic): documented contract; use after shutdown is a caller bug.
        self.handle.clone().expect("server already shut down")
    }

    /// Graceful shutdown: withdraws every shard queue, then joins the
    /// executor pools. In-flight queries drain; late submissions fail
    /// with [`ServeError::ShardUnavailable`].
    pub fn shutdown(mut self) {
        self.join_inner();
    }

    fn join_inner(&mut self) {
        self.handle.take();
        for slot in self.slots.iter() {
            slot.take_tx();
        }
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

impl<T: ?Sized + Send + Sync + 'static> Drop for ShardedServer<T> {
    fn drop(&mut self) {
        self.join_inner();
    }
}

impl<T: ?Sized + Send + Sync + 'static> ShardedHandle<T> {
    /// Submit a query; the result arrives on the returned [`Ticket`].
    pub fn submit(&self, req: Request<T>) -> Ticket {
        let (reply, rx) = channel();
        self.submit_sink(
            req,
            Box::new(move |r| {
                let _ = reply.send(r);
            }),
        );
        Ticket::new(rx)
    }

    /// Submit a query with a completion callback (see
    /// [`super::ServerHandle::submit_sink`]): invoked exactly once from a
    /// router thread after the quorum reduce settles.
    pub fn submit_sink(&self, req: Request<T>, sink: ReplySink) {
        self.metrics.record_request();
        let started = Instant::now();
        match req {
            Request::Delta(delta) => {
                if delta.len() != self.landmarks.len() {
                    self.metrics.record_failed();
                    let reason = format!(
                        "delta row has {} entries, expected {} (one per landmark)",
                        delta.len(),
                        self.landmarks.len()
                    );
                    sink(Err(ServeError::BadInput { reason }));
                    return;
                }
                let router = self.router_state();
                self.pool.submit(move || {
                    route_and_reduce(&router, delta, started, sink);
                });
            }
            Request::Object(obj) => {
                let landmarks = Arc::clone(&self.landmarks);
                let metric = Arc::clone(&self.metric);
                let metrics = Arc::clone(&self.metrics);
                let router = self.router_state();
                self.pool.submit(move || {
                    let t0 = Instant::now();
                    let delta: Vec<f32> = landmarks
                        .iter()
                        .map(|lm| metric.dist(&obj, lm) as f32)
                        .collect();
                    metrics.record_dist(t0.elapsed());
                    route_and_reduce(&router, delta, started, sink);
                });
            }
        }
    }

    /// Stop one shard's dispatch queue (the chaos/maintenance hook): its
    /// executors drain and exit, and subsequent queries reduce without it
    /// — degraded while the quorum holds. Returns false when the shard
    /// index is out of range or already stopped.
    pub fn stop_shard(&self, shard: usize) -> bool {
        match self.slots.get(shard) {
            Some(slot) => slot.take_tx().is_some(),
            None => false,
        }
    }

    /// Number of shards in the plan.
    pub fn shards(&self) -> usize {
        self.slots.len()
    }

    /// The landmark indices shard `s` owns (anchors first).
    pub fn shard_landmarks(&self, s: usize) -> Option<&[usize]> {
        self.slots.get(s).map(|slot| slot.idx.as_slice())
    }

    /// Per-shard metric snapshots (executor-pool view: batches, latency,
    /// panics — the router's own counters live on [`Self::metrics`]).
    pub fn shard_snapshots(&self) -> Vec<Snapshot> {
        self.slots.iter().map(|s| s.metrics.snapshot()).collect()
    }

    /// The landmark objects this server measures queries against.
    pub fn landmark_objects(&self) -> &[Box<T>] {
        &self.landmarks
    }

    fn router_state(&self) -> RouterState {
        RouterState {
            slots: Arc::clone(&self.slots),
            metrics: Arc::clone(&self.metrics),
            drift: self.drift.clone(),
            dim: self.dim,
            quorum: self.quorum,
            timeout: self.timeout,
        }
    }
}

/// Everything the fan-out/reduce path needs, detached from `T` so the
/// router closure stays object-free.
struct RouterState {
    slots: Arc<Vec<ShardSlot>>,
    metrics: Arc<Metrics>,
    drift: Option<Arc<DriftState>>,
    dim: usize,
    quorum: usize,
    timeout: Duration,
}

/// Fan a full delta row out to every live shard, collect partials until
/// the deadline, and reduce. Runs on a frontend pool thread; the reply
/// sink fires exactly once.
fn route_and_reduce(rs: &RouterState, delta: Vec<f32>, started: Instant, sink: ReplySink) {
    let s_count = rs.slots.len();
    let mut pending: Vec<(usize, Receiver<Result<QueryResult, ServeError>>)> =
        Vec::with_capacity(s_count);
    let mut failures: Vec<(usize, ServeError)> = Vec::new();
    for (s, slot) in rs.slots.iter().enumerate() {
        let sub: Vec<f32> = slot.idx.iter().map(|&i| delta[i]).collect();
        let (rtx, rrx) = channel();
        let item = WorkItem {
            delta: sub,
            started,
            reply: Box::new(move |r| {
                let _ = rtx.send(r);
            }),
        };
        // Dispatch must never block the router: a full or withdrawn queue
        // counts as a shard failure for THIS query and the quorum decides.
        let outcome = {
            let guard = match slot.tx.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            match guard.as_ref() {
                Some(tx) => tx.try_send(item).map_err(|e| match e {
                    TrySendError::Full(_) => ServeError::Overloaded,
                    TrySendError::Disconnected(_) => ServeError::Shutdown,
                }),
                None => Err(ServeError::Shutdown),
            }
        };
        match outcome {
            Ok(()) => {
                slot.metrics.record_request();
                pending.push((s, rrx));
            }
            Err(e) => failures.push((s, e)),
        }
    }

    let deadline = Instant::now() + rs.timeout;
    let mut partials: Vec<(usize, Vec<f32>)> = Vec::with_capacity(pending.len());
    for (s, rrx) in pending {
        let remain = deadline.saturating_duration_since(Instant::now());
        match rrx.recv_timeout(remain) {
            Ok(Ok(qr)) => partials.push((s, qr.coords)),
            Ok(Err(e)) => failures.push((s, e)),
            Err(_) => failures.push((s, ServeError::Timeout)),
        }
    }
    for _ in &failures {
        rs.metrics.record_shard_failure();
    }

    if partials.len() >= rs.quorum && !partials.is_empty() {
        // landmark-count-weighted mean: a shard's estimate is as
        // constrained as the number of distances behind it
        let mut acc = vec![0.0f64; rs.dim];
        let mut wsum = 0.0f64;
        for (s, coords) in &partials {
            let w = rs.slots[*s].weight;
            for (c, v) in coords.iter().enumerate() {
                acc[c] += w * *v as f64;
            }
            wsum += w;
        }
        let coords: Vec<f32> = acc.iter().map(|a| (a / wsum) as f32).collect();
        let degraded = partials.len() < s_count;
        let latency = started.elapsed();
        rs.metrics.record_completed(latency);
        if degraded {
            rs.metrics.record_degraded();
        }
        let drift_coords = rs.drift.as_ref().map(|_| coords.clone());
        sink(Ok(QueryResult { coords, latency, degraded }));
        // drift scoring AFTER the reply (observability off the hot path),
        // against the full landmark configuration
        if let (Some(ds), Some(coords)) = (rs.drift.as_deref(), drift_coords) {
            let row = crate::mds::Matrix::from_vec(1, rs.dim, coords);
            feed_drift(ds, std::slice::from_ref(&delta), &row, &rs.metrics);
        }
    } else {
        rs.metrics.record_failed();
        let (shard, cause) = match failures.first() {
            Some((s, e)) => (*s, e.to_string()),
            None => (0, "no shards configured".to_string()),
        };
        sink(Err(ServeError::ShardUnavailable { shard, reason: cause }));
    }
}
