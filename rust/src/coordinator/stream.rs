//! Streaming drift monitor — operational support for the paper's "fast DR
//! on streaming datasets" scenario.
//!
//! An OSE configuration is only as good as its landmarks: if the incoming
//! query distribution drifts away from the data the landmarks were chosen
//! from (new name ethnicities, new sensor region, ...), per-query
//! objectives rise and the embedding silently degrades. This module keeps
//! a sliding window over a cheap per-query quality proxy (the Eq.-2
//! objective of the mapped point against the landmarks, normalised) and
//! raises a re-embedding signal when the recent window deviates from the
//! calibration baseline — the operational answer to "when do we need to
//! recompute the landmark configuration?", which the paper leaves open.

use std::collections::VecDeque;

use crate::mds::Matrix;
use crate::ose::optimise::objective_and_grad;

/// Decision emitted by the monitor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftStatus {
    /// Not enough samples yet to judge.
    Warmup,
    /// Recent quality consistent with the calibration window.
    Healthy,
    /// Recent quality degraded beyond the threshold: re-embed landmarks.
    Drifted,
}

impl DriftStatus {
    /// Stable lowercase name for logs, metric reports and JSON.
    pub fn as_str(&self) -> &'static str {
        match self {
            DriftStatus::Warmup => "warmup",
            DriftStatus::Healthy => "healthy",
            DriftStatus::Drifted => "drifted",
        }
    }
}

#[derive(Clone, Debug)]
/// Drift-monitor settings: window/calibration lengths and the
/// degradation factor that flips the status.
pub struct DriftConfig {
    /// Sliding-window length (queries).
    pub window: usize,
    /// Calibration sample count (the first `calibration` queries define
    /// the baseline).
    pub calibration: usize,
    /// Signal when the window median exceeds baseline median by this
    /// factor.
    pub degrade_factor: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        Self { window: 256, calibration: 256, degrade_factor: 1.5 }
    }
}

/// Sliding-window drift monitor over normalised per-query OSE objectives.
pub struct DriftMonitor {
    cfg: DriftConfig,
    calibration: Vec<f64>,
    baseline_median: Option<f64>,
    window: VecDeque<f64>,
}

impl DriftMonitor {
    /// Monitor with empty calibration and window state.
    pub fn new(cfg: DriftConfig) -> Self {
        Self {
            calibration: Vec::with_capacity(cfg.calibration),
            baseline_median: None,
            window: VecDeque::with_capacity(cfg.window),
            cfg,
        }
    }

    /// Quality proxy for one served query: Eq.-2 objective of the mapped
    /// point, normalised by the sum of its landmark dissimilarities (the
    /// same normalisation as the paper's PErr plots).
    pub fn score(landmarks: &Matrix, deltas: &[f32], mapped: &[f32]) -> f64 {
        let (obj, _) = objective_and_grad(landmarks, deltas, mapped);
        let denom: f64 = deltas.iter().map(|d| *d as f64).sum();
        if denom > 0.0 {
            obj / denom
        } else {
            obj
        }
    }

    /// Feed one query's score; returns the current status.
    ///
    /// Non-finite scores are dropped without touching any monitor state:
    /// a NaN admitted into the calibration set would poison the baseline
    /// median permanently (every later comparison against it is false,
    /// so the monitor could never signal again), and a NaN in the window
    /// would panic the median sort. Either way the caller just sees the
    /// status unchanged.
    pub fn push(&mut self, score: f64) -> DriftStatus {
        if !score.is_finite() {
            return self.status();
        }
        if self.baseline_median.is_none() {
            self.calibration.push(score);
            if self.calibration.len() >= self.cfg.calibration {
                self.baseline_median =
                    Some(crate::util::stats::median(&self.calibration));
            }
            return DriftStatus::Warmup;
        }
        if self.window.len() == self.cfg.window {
            self.window.pop_front();
        }
        self.window.push_back(score);
        self.status()
    }

    /// Current status without feeding a sample: `Warmup` until the
    /// baseline is armed and the window half-full, then the window-median
    /// vs baseline comparison.
    pub fn status(&self) -> DriftStatus {
        let Some(base) = self.baseline_median else {
            return DriftStatus::Warmup;
        };
        if self.window.len() < self.cfg.window / 2 {
            return DriftStatus::Warmup;
        }
        let recent: Vec<f64> = self.window.iter().copied().collect();
        let med = crate::util::stats::median(&recent);
        if med > base * self.cfg.degrade_factor {
            DriftStatus::Drifted
        } else {
            DriftStatus::Healthy
        }
    }

    /// Reset after a re-embedding (new landmarks => new baseline). The
    /// calibration set, baseline median and window are all discarded, so
    /// the next `cfg.calibration` pushes re-arm the baseline from fresh
    /// post-refresh samples — the stale pre-drift median is never
    /// carried across a signal.
    pub fn reset(&mut self) {
        self.calibration.clear();
        self.baseline_median = None;
        self.window.clear();
    }

    /// Calibration-median baseline, once enough queries have been seen.
    pub fn baseline(&self) -> Option<f64> {
        self.baseline_median
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn cfg() -> DriftConfig {
        DriftConfig { window: 50, calibration: 50, degrade_factor: 1.5 }
    }

    #[test]
    fn warms_up_then_reports_healthy_on_stationary_stream() {
        let mut m = DriftMonitor::new(cfg());
        let mut rng = Rng::new(1);
        let mut statuses = Vec::new();
        for _ in 0..200 {
            statuses.push(m.push(0.3 + rng.next_f64() * 0.02));
        }
        assert!(statuses[..49].iter().all(|s| *s == DriftStatus::Warmup));
        assert_eq!(*statuses.last().unwrap(), DriftStatus::Healthy);
        assert!(m.baseline().unwrap() > 0.29);
    }

    #[test]
    fn detects_sustained_degradation() {
        let mut m = DriftMonitor::new(cfg());
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            m.push(0.3 + rng.next_f64() * 0.02);
        }
        // drift: scores double
        let mut last = DriftStatus::Healthy;
        for _ in 0..60 {
            last = m.push(0.65 + rng.next_f64() * 0.02);
        }
        assert_eq!(last, DriftStatus::Drifted);
    }

    #[test]
    fn tolerates_transient_spikes() {
        let mut m = DriftMonitor::new(cfg());
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            m.push(0.3 + rng.next_f64() * 0.02);
        }
        // a handful of outliers must NOT flip the median-based signal
        for _ in 0..5 {
            assert_ne!(m.push(5.0), DriftStatus::Drifted);
        }
        let mut rng2 = Rng::new(4);
        assert_eq!(m.push(0.3 + rng2.next_f64() * 0.02), DriftStatus::Healthy);
    }

    #[test]
    fn signal_reset_resignal_cycle_rearms_baseline_from_fresh_samples() {
        let mut m = DriftMonitor::new(cfg());
        // calibrate at 0.3, then drift to 0.65 until the signal fires
        for _ in 0..100 {
            m.push(0.3);
        }
        let mut last = DriftStatus::Healthy;
        for _ in 0..60 {
            last = m.push(0.65);
        }
        assert_eq!(last, DriftStatus::Drifted);

        // the refresh consumed the signal: reset re-arms from the NEW
        // distribution, so 0.65 must now read Healthy, not Drifted —
        // i.e. the stale 0.3 baseline is gone
        m.reset();
        assert_eq!(m.baseline(), None);
        for _ in 0..100 {
            m.push(0.65);
        }
        assert!(
            (m.baseline().unwrap() - 0.65).abs() < 1e-12,
            "baseline must re-arm from post-reset samples, got {:?}",
            m.baseline()
        );
        assert_eq!(m.status(), DriftStatus::Healthy);

        // a second drift on top of the re-armed baseline signals again
        let mut last = DriftStatus::Healthy;
        for _ in 0..60 {
            last = m.push(1.3);
        }
        assert_eq!(last, DriftStatus::Drifted, "second cycle must re-signal");
    }

    #[test]
    fn non_finite_scores_are_ignored_during_calibration() {
        let mut m = DriftMonitor::new(cfg());
        // NaN/inf interleaved with real samples must not enter the
        // calibration set (a NaN baseline would disarm the monitor
        // forever: every median-vs-baseline comparison would be false)
        for _ in 0..50 {
            m.push(f64::NAN);
            m.push(f64::INFINITY);
            m.push(0.3);
        }
        assert!((m.baseline().unwrap() - 0.3).abs() < 1e-12);
        for _ in 0..60 {
            m.push(0.65);
        }
        assert_eq!(m.status(), DriftStatus::Drifted);
    }

    #[test]
    fn non_finite_scores_are_ignored_in_the_window() {
        let mut m = DriftMonitor::new(cfg());
        for _ in 0..100 {
            m.push(0.3);
        }
        assert_eq!(m.status(), DriftStatus::Healthy);
        // a burst of NaNs must neither panic the median sort nor change
        // the reported status
        for _ in 0..200 {
            assert_eq!(m.push(f64::NAN), DriftStatus::Healthy);
        }
        assert_eq!(m.push(0.3), DriftStatus::Healthy);
    }

    #[test]
    fn reset_requires_recalibration() {
        let mut m = DriftMonitor::new(cfg());
        for _ in 0..120 {
            m.push(0.3);
        }
        m.reset();
        assert_eq!(m.push(0.3), DriftStatus::Warmup);
        assert!(m.baseline().is_none());
    }

    #[test]
    fn score_normalises_by_delta_mass() {
        let mut rng = Rng::new(5);
        let lm = Matrix::random_normal(&mut rng, 10, 3, 1.0);
        let deltas = vec![1.0f32; 10];
        let y = vec![0.0f32; 3];
        let s = DriftMonitor::score(&lm, &deltas, &y);
        assert!(s.is_finite() && s >= 0.0);
        // doubling all dissimilarities roughly rescales the proxy
        let deltas2 = vec![2.0f32; 10];
        let s2 = DriftMonitor::score(&lm, &deltas2, &y);
        assert!(s2.is_finite());
    }
}
