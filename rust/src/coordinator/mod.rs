//! L3 coordinator — the paper's system layer: the two-stage large-scale
//! embedding pipeline, the NN-OSE trainer, the streaming service with
//! dynamic batching, run configuration and serving metrics. Every numeric
//! graph executes through the [`crate::runtime::ComputeBackend`] seam.

pub mod config;
pub mod embedder;
pub mod methods;
pub mod metrics;
pub mod server;
pub mod stream;
pub mod trainer;

pub use config::RunConfig;
pub use embedder::{
    embed_corpus, embed_dataset, solve_base_source, BaseSolver, OseBackend,
    PipelineConfig, PipelineResult,
};
pub use methods::{BackendNn, BackendOpt};
pub use metrics::{Metrics, Snapshot};
pub use server::{BatcherConfig, DriftHook, QueryResult, Server, ServerHandle};
pub use stream::{DriftConfig, DriftMonitor, DriftStatus};
pub use trainer::{train_backend, train_rust, TrainConfig, TrainReport};
