//! L3 coordinator — the paper's system layer: the two-stage large-scale
//! embedding pipeline, the NN-OSE trainer, the streaming service with
//! dynamic batching, sharded serving behind a binary-protocol network
//! front door, run configuration and serving metrics. Every numeric
//! graph executes through the [`crate::runtime::ComputeBackend`] seam.

pub mod config;
pub mod embedder;
pub mod error;
pub mod methods;
pub mod metrics;
pub mod net;
pub mod proto;
pub mod refresh;
pub mod server;
pub mod shard;
pub mod stream;
pub mod trainer;

pub use config::RunConfig;
pub use embedder::{
    embed_corpus, embed_dataset, solve_base_source, solve_base_source_warm,
    BaseSolver, OseBackend, PipelineConfig, PipelineResult,
};
pub use error::ServeError;
pub use methods::{BackendNn, BackendOpt};
pub use metrics::{Metrics, Snapshot};
pub use net::{NetConfig, NetServer, QueryService};
pub use proto::{Deframer, Frame};
pub use refresh::{RefreshConfig, RefreshController, RefreshReport};
pub use server::{
    BatcherConfig, DriftHook, QueryResult, Request, Server, ServerBuilder,
    ServerHandle, Ticket,
};
pub use shard::{ShardConfig, ShardedHandle, ShardedServer};
pub use stream::{DriftConfig, DriftMonitor, DriftStatus};
pub use trainer::{train_backend, train_rust, TrainConfig, TrainReport};
