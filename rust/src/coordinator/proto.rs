//! Length-prefixed binary wire protocol for the network front door.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//!  u32 payload_len | payload
//!  payload = u8 frame_type | u64 request_id | body
//! ```
//!
//! Frame types and bodies:
//!
//! | type | frame      | body                                         |
//! |------|------------|----------------------------------------------|
//! | 1    | QueryText  | UTF-8 object bytes                           |
//! | 2    | QueryDelta | u32 count, then count x f32 delta row        |
//! | 3    | Result     | u8 degraded, u32 latency_us, u32 k, k x f32  |
//! | 4    | Error      | u16 code, u64 detail, UTF-8 message          |
//! | 5    | Ping       | empty                                        |
//! | 6    | Pong       | empty                                        |
//!
//! Error frames carry the stable [`ServeError`] wire codes
//! (`to_wire`/`from_wire`), so a typed error round-trips the socket.
//! Frames above [`MAX_FRAME`] bytes are a protocol violation — the limit
//! bounds per-connection buffering on both sides.

use super::error::ServeError;

/// Hard cap on one frame's payload (1 MiB): bounds per-connection memory
/// and rejects garbage length prefixes early.
pub const MAX_FRAME: usize = 1 << 20;

const TYPE_QUERY_TEXT: u8 = 1;
const TYPE_QUERY_DELTA: u8 = 2;
const TYPE_RESULT: u8 = 3;
const TYPE_ERROR: u8 = 4;
const TYPE_PING: u8 = 5;
const TYPE_PONG: u8 = 6;

/// One protocol frame, client- or server-originated.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Client query: embed this object (the server computes the delta).
    QueryText {
        /// Caller-chosen request id, echoed on the reply.
        id: u64,
        /// The object, UTF-8.
        text: String,
    },
    /// Client query with a precomputed delta row.
    QueryDelta {
        /// Caller-chosen request id, echoed on the reply.
        id: u64,
        /// One distance per landmark.
        delta: Vec<f32>,
    },
    /// Server reply: embedded coordinates.
    Result {
        /// Echo of the request id.
        id: u64,
        /// True when reduced from a partial shard quorum.
        degraded: bool,
        /// Server-measured latency, microseconds (saturating).
        latency_us: u32,
        /// Embedded coordinates (length K).
        coords: Vec<f32>,
    },
    /// Server reply: the request failed (see [`ServeError::from_wire`]).
    Error {
        /// Echo of the request id (0 for connection-level errors).
        id: u64,
        /// Stable [`ServeError`] wire code.
        code: u16,
        /// Variant-specific numeric detail (e.g. shard index).
        detail: u64,
        /// Human-readable message.
        message: String,
    },
    /// Liveness probe.
    Ping {
        /// Caller-chosen id, echoed on the pong.
        id: u64,
    },
    /// Liveness reply.
    Pong {
        /// Echo of the ping id.
        id: u64,
    },
}

impl Frame {
    /// Build an [`Frame::Error`] reply from a typed serving error.
    pub fn from_error(id: u64, e: &ServeError) -> Frame {
        let (code, detail, message) = e.to_wire();
        Frame::Error { id, code, detail, message }
    }

    /// Reconstruct the typed error an [`Frame::Error`] carries.
    /// `None` for every other frame type.
    pub fn to_error(&self) -> Option<ServeError> {
        match self {
            Frame::Error { code, detail, message, .. } => {
                Some(ServeError::from_wire(*code, *detail, message.clone()))
            }
            _ => None,
        }
    }

    /// The frame's request id.
    pub fn id(&self) -> u64 {
        match self {
            Frame::QueryText { id, .. }
            | Frame::QueryDelta { id, .. }
            | Frame::Result { id, .. }
            | Frame::Error { id, .. }
            | Frame::Ping { id }
            | Frame::Pong { id } => *id,
        }
    }

    /// Append the full frame (length prefix included) to `out`.
    pub fn encode(&self, out: &mut Vec<u8>) {
        let start = out.len();
        out.extend_from_slice(&0u32.to_le_bytes()); // length, patched below
        match self {
            Frame::QueryText { id, text } => {
                out.push(TYPE_QUERY_TEXT);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(text.as_bytes());
            }
            Frame::QueryDelta { id, delta } => {
                out.push(TYPE_QUERY_DELTA);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&(delta.len() as u32).to_le_bytes());
                for v in delta {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::Result { id, degraded, latency_us, coords } => {
                out.push(TYPE_RESULT);
                out.extend_from_slice(&id.to_le_bytes());
                out.push(u8::from(*degraded));
                out.extend_from_slice(&latency_us.to_le_bytes());
                out.extend_from_slice(&(coords.len() as u32).to_le_bytes());
                for v in coords {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::Error { id, code, detail, message } => {
                out.push(TYPE_ERROR);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&code.to_le_bytes());
                out.extend_from_slice(&detail.to_le_bytes());
                out.extend_from_slice(message.as_bytes());
            }
            Frame::Ping { id } => {
                out.push(TYPE_PING);
                out.extend_from_slice(&id.to_le_bytes());
            }
            Frame::Pong { id } => {
                out.push(TYPE_PONG);
                out.extend_from_slice(&id.to_le_bytes());
            }
        }
        let len = (out.len() - start - 4) as u32;
        out[start..start + 4].copy_from_slice(&len.to_le_bytes());
    }

    /// Encode into a fresh buffer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode(&mut out);
        out
    }

    /// Decode one payload (the bytes AFTER the length prefix).
    pub fn decode(payload: &[u8]) -> Result<Frame, ServeError> {
        let mut c = Cursor { buf: payload, pos: 0 };
        let ty = c.u8()?;
        let id = c.u64()?;
        let frame = match ty {
            TYPE_QUERY_TEXT => Frame::QueryText { id, text: c.rest_utf8()? },
            TYPE_QUERY_DELTA => {
                let n = c.u32()? as usize;
                if n > MAX_FRAME / 4 {
                    return Err(ServeError::Protocol {
                        reason: format!("delta row of {n} entries exceeds the frame cap"),
                    });
                }
                let mut delta = Vec::with_capacity(n);
                for _ in 0..n {
                    delta.push(c.f32()?);
                }
                Frame::QueryDelta { id, delta }
            }
            TYPE_RESULT => {
                let degraded = c.u8()? != 0;
                let latency_us = c.u32()?;
                let n = c.u32()? as usize;
                if n > MAX_FRAME / 4 {
                    return Err(ServeError::Protocol {
                        reason: format!("{n} coordinates exceed the frame cap"),
                    });
                }
                let mut coords = Vec::with_capacity(n);
                for _ in 0..n {
                    coords.push(c.f32()?);
                }
                Frame::Result { id, degraded, latency_us, coords }
            }
            TYPE_ERROR => {
                let code = c.u16()?;
                let detail = c.u64()?;
                Frame::Error { id, code, detail, message: c.rest_utf8()? }
            }
            TYPE_PING => Frame::Ping { id },
            TYPE_PONG => Frame::Pong { id },
            other => {
                return Err(ServeError::Protocol {
                    reason: format!("unknown frame type {other}"),
                })
            }
        };
        if !c.at_end() && !matches!(frame, Frame::QueryText { .. } | Frame::Error { .. }) {
            return Err(ServeError::Protocol {
                reason: format!("{} trailing bytes after the frame body", c.remaining()),
            });
        }
        Ok(frame)
    }
}

/// Byte cursor over one frame payload; every read is bounds-checked into
/// a [`ServeError::Protocol`].
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8], ServeError> {
        if self.pos + n > self.buf.len() {
            return Err(ServeError::Protocol {
                reason: format!(
                    "truncated frame: wanted {n} bytes at offset {}, have {}",
                    self.pos,
                    self.buf.len()
                ),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Fixed-size read: `take` has already bounds-checked the slice, so
    /// the copy into the array cannot fail (no panicking `try_into` here —
    /// this is a no-panic serving path).
    fn array<const N: usize>(&mut self) -> Result<[u8; N], ServeError> {
        let s = self.take(N)?;
        let mut out = [0u8; N];
        out.copy_from_slice(s);
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, ServeError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, ServeError> {
        Ok(u16::from_le_bytes(self.array()?))
    }

    fn u32(&mut self) -> Result<u32, ServeError> {
        Ok(u32::from_le_bytes(self.array()?))
    }

    fn u64(&mut self) -> Result<u64, ServeError> {
        Ok(u64::from_le_bytes(self.array()?))
    }

    fn f32(&mut self) -> Result<f32, ServeError> {
        Ok(f32::from_le_bytes(self.array()?))
    }

    fn rest_utf8(&mut self) -> Result<String, ServeError> {
        let rest = &self.buf[self.pos..];
        self.pos = self.buf.len();
        String::from_utf8(rest.to_vec()).map_err(|_| ServeError::Protocol {
            reason: "frame body is not valid UTF-8".into(),
        })
    }

    fn at_end(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Incremental frame extractor for a nonblocking byte stream: feed
/// whatever arrived, pull out complete frames as they materialise.
#[derive(Default)]
pub struct Deframer {
    buf: Vec<u8>,
}

impl Deframer {
    /// Fresh, empty deframer.
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    /// Append newly received bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pull the next complete frame, if one is buffered. `Ok(None)` means
    /// "need more bytes"; a protocol error poisons the connection (the
    /// caller should reply and close).
    pub fn next(&mut self) -> Result<Option<Frame>, ServeError> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let len =
            u32::from_le_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]) as usize;
        if len > MAX_FRAME {
            return Err(ServeError::Protocol {
                reason: format!("frame of {len} bytes exceeds the {MAX_FRAME} cap"),
            });
        }
        if self.buf.len() < 4 + len {
            return Ok(None);
        }
        let frame = Frame::decode(&self.buf[4..4 + len])?;
        self.buf.drain(..4 + len);
        Ok(Some(frame))
    }

    /// Bytes currently buffered (diagnostics).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }
}

/// Blocking-read one frame from a stream (the client-side helper; the
/// server never blocks per-connection). Protocol violations surface as
/// `InvalidData` IO errors.
pub fn read_frame<R: std::io::Read>(r: &mut R) -> std::io::Result<Frame> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {MAX_FRAME} cap"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Frame::decode(&payload)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
}

/// Blocking-write one frame to a stream (client-side helper).
pub fn write_frame<W: std::io::Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&frame.to_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{prop_assert, property};

    fn round_trip(f: &Frame) -> Frame {
        let bytes = f.to_bytes();
        let len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
        assert_eq!(len + 4, bytes.len(), "length prefix covers the payload");
        Frame::decode(&bytes[4..]).expect("decode")
    }

    #[test]
    fn every_frame_type_round_trips() {
        property("proto frame round-trip", 300, |g| {
            let id = g.u64();
            let frames = vec![
                Frame::QueryText { id, text: g.unicode_string(0, 40) },
                Frame::QueryDelta { id, delta: g.vec_f32(0, 64, 10.0) },
                Frame::Result {
                    id,
                    degraded: g.bool(),
                    latency_us: g.u64() as u32,
                    coords: g.vec_f32(0, 16, 5.0),
                },
                Frame::Error {
                    id,
                    code: g.u64() as u16,
                    detail: g.u64(),
                    message: g.unicode_string(0, 40),
                },
                Frame::Ping { id },
                Frame::Pong { id },
            ];
            for f in frames {
                if round_trip(&f) != f {
                    return Err(format!("{f:?} did not round-trip"));
                }
            }
            prop_assert(true, "ok")
        });
    }

    #[test]
    fn error_frames_round_trip_typed_errors() {
        property("proto error frame carries ServeError", 200, |g| {
            let errors = vec![
                ServeError::BadInput { reason: g.unicode_string(0, 30) },
                ServeError::Overloaded,
                ServeError::Shutdown,
                ServeError::ReplicaPanic { reason: g.string(0, 30) },
                ServeError::ShardUnavailable {
                    shard: g.usize_in(0, 64),
                    reason: g.string(0, 30),
                },
                ServeError::Timeout,
                ServeError::Protocol { reason: g.string(0, 30) },
                ServeError::Internal { reason: g.string(0, 30) },
            ];
            let id = g.u64();
            for e in errors {
                let f = Frame::from_error(id, &e);
                let back = round_trip(&f).to_error().expect("error frame");
                if back != e {
                    return Err(format!("{e:?} -> {back:?}"));
                }
            }
            prop_assert(true, "ok")
        });
    }

    #[test]
    fn deframer_reassembles_byte_dribble() {
        property("deframer handles arbitrary splits", 100, |g| {
            let frames = vec![
                Frame::Ping { id: g.u64() },
                Frame::QueryDelta { id: g.u64(), delta: g.vec_f32(1, 32, 3.0) },
                Frame::QueryText { id: g.u64(), text: g.string(0, 20) },
            ];
            let mut wire = Vec::new();
            for f in &frames {
                f.encode(&mut wire);
            }
            let mut d = Deframer::new();
            let mut got = Vec::new();
            let mut pos = 0;
            while pos < wire.len() {
                let n = g.usize_in(1, 7).min(wire.len() - pos);
                d.extend(&wire[pos..pos + n]);
                pos += n;
                while let Some(f) = d.next().expect("clean stream") {
                    got.push(f);
                }
            }
            prop_assert(got == frames && d.buffered() == 0, "all frames recovered")
        });
    }

    #[test]
    fn oversized_and_garbage_frames_are_protocol_errors() {
        let mut d = Deframer::new();
        d.extend(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert!(matches!(d.next(), Err(ServeError::Protocol { .. })));

        // unknown frame type
        let mut d = Deframer::new();
        let payload = [99u8, 0, 0, 0, 0, 0, 0, 0, 0];
        d.extend(&(payload.len() as u32).to_le_bytes());
        d.extend(&payload);
        assert!(matches!(d.next(), Err(ServeError::Protocol { .. })));

        // truncated body: QueryDelta announcing more floats than present
        let f = Frame::QueryDelta { id: 7, delta: vec![1.0, 2.0, 3.0] };
        let bytes = f.to_bytes();
        assert!(matches!(
            Frame::decode(&bytes[4..bytes.len() - 2]),
            Err(ServeError::Protocol { .. })
        ));

        // invalid UTF-8 text
        let mut payload = vec![1u8]; // QueryText
        payload.extend_from_slice(&7u64.to_le_bytes());
        payload.extend_from_slice(&[0xff, 0xfe]);
        assert!(matches!(
            Frame::decode(&payload),
            Err(ServeError::Protocol { .. })
        ));
    }

    #[test]
    fn blocking_helpers_match_the_deframer() {
        let frames = vec![
            Frame::Result {
                id: 3,
                degraded: true,
                latency_us: 1500,
                coords: vec![0.5, -0.25],
            },
            Frame::Pong { id: 3 },
        ];
        let mut wire = Vec::new();
        for f in &frames {
            write_frame(&mut wire, f).unwrap();
        }
        let mut r = &wire[..];
        assert_eq!(read_frame(&mut r).unwrap(), frames[0]);
        assert_eq!(read_frame(&mut r).unwrap(), frames[1]);
        assert!(read_frame(&mut r).is_err(), "EOF is an error");
    }
}
