//! PJRT-backed OSE methods: the production implementations of the paper's
//! two techniques, executing the AOT artifacts through the runtime handle.
//!
//! Both pad a request batch up to the nearest available artifact batch
//! size (executables are shape-monomorphic) and slice the padding off the
//! result. Padding rows are all-zeros — for `ose_opt` they converge to the
//! landmark centroid, for `mlp_fwd` they cost one wasted row of matmul;
//! either way they never escape the runtime boundary.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::mds::Matrix;
use crate::nn::MlpParams;
use crate::ose::OseMethod;
use crate::runtime::{OwnedArg, RuntimeHandle};

/// Unique binding keys for device-resident argument sets.
static BINDING_ID: AtomicU64 = AtomicU64::new(0);

fn fresh_binding_key(prefix: &str) -> String {
    format!("{prefix}-{}", BINDING_ID.fetch_add(1, Ordering::Relaxed))
}

/// Select the smallest available batch-size variant >= n (or the largest
/// one if n exceeds all variants — the caller then chunks).
pub fn pick_batch(available: &[usize], n: usize) -> Option<usize> {
    available
        .iter()
        .copied()
        .filter(|b| *b >= n)
        .min()
        .or_else(|| available.iter().copied().max())
}

fn pad_rows(m: &Matrix, rows: usize) -> Matrix {
    if m.rows == rows {
        return m.clone();
    }
    let mut out = Matrix::zeros(rows, m.cols);
    out.data[..m.data.len()].copy_from_slice(&m.data);
    out
}

/// The neural-network OSE (paper Sec. 4.2) over the fused-MLP artifact.
pub struct PjrtNn {
    pub handle: RuntimeHandle,
    /// Flattened parameters in artifact order (w1,b1,...,w4,b4).
    pub params: Vec<Vec<f32>>,
    pub l: usize,
    pub k: usize,
    pub hidden: [usize; 3],
    /// Device binding for the weights (uploaded lazily, once; the argument
    /// positions 1..=8 are identical across all B variants of `mlp_fwd`).
    binding: String,
    bound: bool,
}

impl PjrtNn {
    pub fn new(handle: RuntimeHandle, params: &MlpParams) -> Self {
        Self {
            l: params.shape.input,
            k: params.shape.output,
            hidden: params.shape.hidden,
            params: params.flatten(),
            handle,
            binding: fresh_binding_key("mlp-weights"),
            bound: false,
        }
    }

    /// Upload the weights to the device once (keyed per instance).
    fn ensure_bound(&mut self, spec_args: &[crate::runtime::manifest::ArgSpec]) -> Result<()> {
        if self.bound {
            return Ok(());
        }
        let mut args = Vec::with_capacity(8);
        for (i, p) in self.params.iter().enumerate() {
            let sh = &spec_args[1 + i].shape;
            let arg = if sh.len() == 2 {
                OwnedArg::Mat(Matrix::from_vec(sh[0], sh[1], p.clone()))
            } else {
                OwnedArg::Vec1(p.clone())
            };
            args.push((1 + i, arg));
        }
        self.handle.bind(&self.binding, args)?;
        self.bound = true;
        Ok(())
    }

    /// Dim constraints identifying `mlp_fwd` artifacts of this shape.
    fn constraints(&self) -> Vec<(&'static str, usize)> {
        vec![
            ("L", self.l),
            ("H1", self.hidden[0]),
            ("H2", self.hidden[1]),
            ("H3", self.hidden[2]),
            ("K", self.k),
        ]
    }

    fn embed_chunk(&mut self, deltas: &Matrix) -> Result<Matrix> {
        let avail = self
            .handle
            .manifest()
            .available_dims("mlp_fwd", "B", &self.constraints());
        let b = pick_batch(&avail, deltas.rows)
            .with_context(|| format!("no mlp_fwd artifact for L={}", self.l))?;
        let n = deltas.rows.min(b);
        let padded = pad_rows(deltas, b);
        let spec = self
            .handle
            .manifest()
            .find("mlp_fwd", &{
                let mut c = self.constraints();
                c.push(("B", b));
                c
            })
            .context("artifact vanished")?
            .clone();
        self.ensure_bound(&spec.args)?;
        // hot path: only the input tile crosses host->device
        let out = self
            .handle
            .execute_bound(&spec.name, &self.binding, vec![(0, OwnedArg::Mat(padded))])?
            .remove(0)
            .into_matrix();
        let mut res = Matrix::zeros(n, self.k);
        res.data.copy_from_slice(&out.data[..n * self.k]);
        Ok(res)
    }
}

impl OseMethod for PjrtNn {
    fn embed(&mut self, deltas: &Matrix) -> Result<Matrix> {
        anyhow::ensure!(deltas.cols == self.l, "bad input width");
        let avail = self
            .handle
            .manifest()
            .available_dims("mlp_fwd", "B", &self.constraints());
        let max_b = avail.iter().copied().max().unwrap_or(0).max(1);
        if deltas.rows <= max_b {
            return self.embed_chunk(deltas);
        }
        // chunk oversized batches through the largest variant
        let mut out = Matrix::zeros(deltas.rows, self.k);
        let mut start = 0;
        while start < deltas.rows {
            let end = (start + max_b).min(deltas.rows);
            let chunk = Matrix::from_vec(
                end - start,
                deltas.cols,
                deltas.data[start * deltas.cols..end * deltas.cols].to_vec(),
            );
            let y = self.embed_chunk(&chunk)?;
            out.data[start * self.k..end * self.k].copy_from_slice(&y.data);
            start = end;
        }
        Ok(out)
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn landmarks(&self) -> usize {
        self.l
    }

    fn name(&self) -> &'static str {
        "nn-pjrt"
    }
}

/// The optimisation OSE (paper Sec. 4.1) over the batched `ose_opt`
/// artifact (T majorization steps per call, iterated to convergence).
pub struct PjrtOpt {
    pub handle: RuntimeHandle,
    pub landmarks: Matrix,
    /// Total majorization steps to run per embedding; the artifact's T
    /// inner steps are iterated ceil(total_steps / T) times.
    pub total_steps: usize,
    /// Step size; `None` = 1/(2L) majorization.
    pub lr: Option<f64>,
    binding: String,
    bound: bool,
}

impl PjrtOpt {
    /// Defaults matching the pure-Rust optimiser's convergence budget.
    pub fn with_defaults(handle: RuntimeHandle, landmarks: Matrix) -> Self {
        Self {
            handle,
            landmarks,
            total_steps: 200,
            lr: None,
            binding: fresh_binding_key("ose-landmarks"),
            bound: false,
        }
    }
}

impl PjrtOpt {
    fn embed_chunk(&mut self, deltas: &Matrix) -> Result<Matrix> {
        let l = self.landmarks.rows;
        let k = self.landmarks.cols;
        let avail = self
            .handle
            .manifest()
            .available_dims("ose_opt", "B", &[("L", l)]);
        let b = pick_batch(&avail, deltas.rows)
            .with_context(|| format!("no ose_opt artifact for L={l}"))?;
        let spec_name = self
            .handle
            .manifest()
            .find("ose_opt", &[("L", l), ("B", b)])
            .context("artifact vanished")?
            .name
            .clone();
        let inner_t = self
            .handle
            .manifest()
            .find("ose_opt", &[("L", l), ("B", b)])
            .and_then(|s| s.dim("T"))
            .unwrap_or(60)
            .max(1);
        let outer = self.total_steps.div_ceil(inner_t).max(1);
        let n = deltas.rows.min(b);
        let padded = pad_rows(deltas, b);
        let lr = self.lr.unwrap_or(1.0 / (2.0 * l as f64)) as f32;
        // landmarks live on-device across all calls (position 0)
        if !self.bound {
            self.handle.bind(
                &self.binding,
                vec![(0, OwnedArg::Mat(self.landmarks.clone()))],
            )?;
            self.bound = true;
        }
        // paper's zero initial guess; subsequent outer iters warm-start
        let mut y = Matrix::zeros(b, k);
        for _ in 0..outer {
            let out = self.handle.execute_bound(
                &spec_name,
                &self.binding,
                vec![
                    (1, OwnedArg::Mat(padded.clone())),
                    (2, OwnedArg::Mat(y)),
                    (3, OwnedArg::Scalar(lr)),
                ],
            )?;
            y = out.into_iter().next().unwrap().into_matrix();
        }
        let mut res = Matrix::zeros(n, k);
        res.data.copy_from_slice(&y.data[..n * k]);
        Ok(res)
    }
}

impl OseMethod for PjrtOpt {
    fn embed(&mut self, deltas: &Matrix) -> Result<Matrix> {
        anyhow::ensure!(deltas.cols == self.landmarks.rows, "bad input width");
        let l = self.landmarks.rows;
        let k = self.landmarks.cols;
        let avail = self
            .handle
            .manifest()
            .available_dims("ose_opt", "B", &[("L", l)]);
        let max_b = avail.iter().copied().max().unwrap_or(0).max(1);
        if deltas.rows <= max_b {
            return self.embed_chunk(deltas);
        }
        let mut out = Matrix::zeros(deltas.rows, k);
        let mut start = 0;
        while start < deltas.rows {
            let end = (start + max_b).min(deltas.rows);
            let chunk = Matrix::from_vec(
                end - start,
                deltas.cols,
                deltas.data[start * deltas.cols..end * deltas.cols].to_vec(),
            );
            let y = self.embed_chunk(&chunk)?;
            out.data[start * k..end * k].copy_from_slice(&y.data);
            start = end;
        }
        Ok(out)
    }

    fn dim(&self) -> usize {
        self.landmarks.cols
    }

    fn landmarks(&self) -> usize {
        self.landmarks.rows
    }

    fn name(&self) -> &'static str {
        "opt-pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_batch_prefers_smallest_fit() {
        assert_eq!(pick_batch(&[1, 64, 256], 1), Some(1));
        assert_eq!(pick_batch(&[1, 64, 256], 2), Some(64));
        assert_eq!(pick_batch(&[1, 64, 256], 64), Some(64));
        assert_eq!(pick_batch(&[1, 64, 256], 65), Some(256));
        assert_eq!(pick_batch(&[1, 64, 256], 1000), Some(256)); // chunked
        assert_eq!(pick_batch(&[], 4), None);
    }

    #[test]
    fn pad_rows_zero_fills() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0]]);
        let p = pad_rows(&m, 3);
        assert_eq!(p.rows, 3);
        assert_eq!(p.row(0), &[1.0, 2.0]);
        assert_eq!(p.row(2), &[0.0, 0.0]);
    }
}
