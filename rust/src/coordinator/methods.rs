//! Backend-generic OSE methods: the production implementations of the
//! paper's two techniques, executing through the [`ComputeBackend`] seam.
//! With the native backend they run batched, row-parallel pure-Rust math;
//! with the PJRT backend (cargo feature `pjrt`) the same calls execute the
//! AOT artifacts, padding/chunking and device-resident operand reuse
//! handled inside the backend.

use std::sync::Arc;

use anyhow::Result;

use crate::mds::graph::{nearest_k, GraphConfig, LandmarkGraph};
use crate::mds::Matrix;
use crate::nn::MlpParams;
use crate::ose::{factory_fn, OseMethod, OseMethodFactory};
use crate::runtime::{Backend, ComputeBackend};

/// The neural-network OSE (paper Sec. 4.2): a trained MLP maps a row of
/// landmark distances straight to coordinates.
pub struct BackendNn {
    /// Compute backend the forward pass runs on.
    pub backend: Backend,
    /// Trained MLP parameters.
    pub params: MlpParams,
}

impl BackendNn {
    /// Wrap trained parameters for serving on `backend`.
    pub fn new(backend: Backend, params: MlpParams) -> Self {
        Self { backend, params }
    }

    /// Replica factory for the serving executor pool: every `build()`
    /// yields an independent instance over the same trained parameters.
    pub fn replica_factory(
        backend: Backend,
        params: MlpParams,
    ) -> Arc<dyn OseMethodFactory> {
        factory_fn(move || Box::new(Self::new(backend.clone(), params.clone())))
    }
}

impl OseMethod for BackendNn {
    fn embed(&mut self, deltas: &Matrix) -> Result<Matrix> {
        anyhow::ensure!(
            deltas.cols == self.params.shape.input,
            "expected {} landmark distances, got {}",
            self.params.shape.input,
            deltas.cols
        );
        self.backend.mlp_fwd(&self.params, deltas)
    }

    fn dim(&self) -> usize {
        self.params.shape.output
    }

    fn landmarks(&self) -> usize {
        self.params.shape.input
    }

    fn name(&self) -> &'static str {
        match self.backend.name() {
            "pjrt" => "nn-pjrt",
            _ => "nn-native",
        }
    }
}

/// The optimisation OSE (paper Sec. 4.1): batched majorization of Eq. 2
/// against a fixed landmark configuration, with convergence-based early
/// stopping over the per-chunk objectives the backend reports (matching
/// the serial oracle's `rel_tol` behaviour at batch granularity).
pub struct BackendOpt {
    /// Compute backend the majorization steps run on.
    pub backend: Backend,
    /// L x K landmark configuration the objective is anchored to.
    pub landmarks: Matrix,
    /// Total majorization steps per embedding (iterated in backend-sized
    /// chunks, warm-starting each chunk from the previous iterate).
    pub total_steps: usize,
    /// Step size; `None` = 1/(2L) majorization.
    pub lr: Option<f64>,
    /// Stop once the batch-mean Eq.-2 objective improves less than this
    /// (relative, scaled by the steps per chunk). 0.0 disables early
    /// stopping (always run `total_steps`).
    pub rel_tol: f64,
    /// Sparse query restriction: majorize each embedding against only its
    /// `query_k` nearest landmarks (docs/QUERY_PATH.md). `0` — or any
    /// value ≥ L — takes the dense path, bit-identical to a `BackendOpt`
    /// without the restriction.
    pub query_k: usize,
    /// Landmark graph used to find the k nearest landmarks in O(k log L).
    /// `None` with `query_k > 0` falls back to the exact O(L) row scan
    /// ([`nearest_k`]) — same per-step sparsity, no sub-linear selection.
    pub graph: Option<Arc<LandmarkGraph>>,
}

impl BackendOpt {
    /// Defaults matching the serial oracle's convergence budget
    /// (`OseOptConfig::default()`: 200 steps, rel_tol 1e-7).
    pub fn with_defaults(backend: Backend, landmarks: Matrix) -> Self {
        Self {
            backend,
            landmarks,
            total_steps: 200,
            lr: None,
            rel_tol: 1e-7,
            query_k: 0,
            graph: None,
        }
    }

    /// Replica factory for the serving executor pool (default budget).
    pub fn replica_factory(
        backend: Backend,
        landmarks: Matrix,
    ) -> Arc<dyn OseMethodFactory> {
        factory_fn(move || {
            Box::new(Self::with_defaults(backend.clone(), landmarks.clone()))
        })
    }

    /// Replica factory with an explicit fixed budget: every embedding
    /// runs exactly `total_steps` majorization steps (early stopping
    /// disabled). Fixed work makes chunked/streamed embedding
    /// bit-identical across chunk sizes — the mode the out-of-core
    /// pipeline uses for reproducible large-N runs — and bounds
    /// worst-case latency for benches.
    pub fn replica_factory_budget(
        backend: Backend,
        landmarks: Matrix,
        total_steps: usize,
    ) -> Arc<dyn OseMethodFactory> {
        factory_fn(move || {
            Box::new(Self {
                backend: backend.clone(),
                landmarks: landmarks.clone(),
                total_steps,
                lr: None,
                rel_tol: 0.0,
                query_k: 0,
                graph: None,
            })
        })
    }

    /// Replica factory with the sparse `query_k` restriction: each
    /// embedding majorizes against only its `query_k` nearest landmarks,
    /// found through a [`LandmarkGraph`] built once here and shared
    /// (read-only) by every replica. `total_steps = 0` keeps the adaptive
    /// default budget (200 steps, rel_tol 1e-7); a positive value fixes
    /// the budget with early stopping disabled, exactly like
    /// [`replica_factory_budget`](Self::replica_factory_budget).
    /// `query_k = 0` (or ≥ L) degenerates to the corresponding dense
    /// factory, bit-identically — no graph is built.
    pub fn replica_factory_sparse(
        backend: Backend,
        landmarks: Matrix,
        total_steps: usize,
        query_k: usize,
        gcfg: &GraphConfig,
    ) -> Arc<dyn OseMethodFactory> {
        let graph = (query_k > 0 && query_k < landmarks.rows)
            .then(|| Arc::new(LandmarkGraph::build(&landmarks, gcfg)));
        factory_fn(move || {
            let mut m = match total_steps {
                0 => Self::with_defaults(backend.clone(), landmarks.clone()),
                steps => Self {
                    backend: backend.clone(),
                    landmarks: landmarks.clone(),
                    total_steps: steps,
                    lr: None,
                    rel_tol: 0.0,
                    query_k: 0,
                    graph: None,
                },
            };
            m.query_k = query_k;
            m.graph = graph.clone();
            Box::new(m)
        })
    }

    /// The dense chunked majorization loop over an explicit landmark
    /// block — the pre-`query_k` `embed` body verbatim, shared by the
    /// dense path (full landmark matrix) and the sparse path (per-query
    /// k-row gather), so `query_k ∈ {0, L}` stays bit-identical to the
    /// historical dense behaviour.
    fn optimise_block(&self, landmarks: &Matrix, deltas: &Matrix) -> Result<Matrix> {
        let l = landmarks.rows;
        let k = landmarks.cols;
        let lr = self.lr.unwrap_or(1.0 / (2.0 * l as f64)) as f32;
        let total = self.total_steps.max(1);
        // chunk = the backend's natural granularity (PJRT: the artifact's
        // unrolled T; usize::MAX = no preference, see the trait docs), and
        // a backend with no preference gets a chunk small enough for early
        // stopping to bite
        let backend_chunk = self.backend.ose_opt_step_chunk(l);
        let chunk = if backend_chunk == usize::MAX {
            25.min(total)
        } else {
            backend_chunk.max(1).min(total)
        };
        // paper's zero initial guess; chunks warm-start from the iterate
        let mut y = Matrix::zeros(deltas.rows, k);
        let mut prev = f64::INFINITY;
        let mut done = 0usize;
        while done < total {
            let steps = chunk.min(total - done);
            let (y2, obj) =
                self.backend.ose_opt_steps(landmarks, deltas, &y, lr, steps)?;
            y = y2;
            done += steps;
            if self.rel_tol > 0.0 && !obj.is_empty() {
                let mean =
                    obj.iter().map(|o| *o as f64).sum::<f64>() / obj.len() as f64;
                // relative ABSOLUTE change, mirroring `embed_point`: an
                // objective increase is not convergence
                if prev.is_finite()
                    && (prev - mean).abs() / prev.abs().max(1e-30)
                        < self.rel_tol * steps as f64
                {
                    break;
                }
                prev = mean;
            }
        }
        Ok(y)
    }

    /// Sparse `query_k` path: per query row, find the k nearest landmarks
    /// (graph search when one is attached, exact row scan otherwise),
    /// gather the k x K sub-problem, and run the same chunked majorization
    /// on it. `optimise_block` derives lr = 1/(2k) from the gathered block,
    /// matching the restricted Eq.-2 majorization step.
    fn embed_sparse(&self, deltas: &Matrix) -> Result<Matrix> {
        let k = self.query_k;
        let mut out = Matrix::zeros(deltas.rows, self.landmarks.cols);
        for r in 0..deltas.rows {
            let row = deltas.row(r);
            let idx = match &self.graph {
                Some(g) => g.knn_delta(row, k),
                None => nearest_k(row, k),
            };
            let sub = self.landmarks.select_rows(&idx);
            let dsub = Matrix::from_vec(
                1,
                idx.len(),
                idx.iter().map(|&i| row[i]).collect(),
            );
            let y = self.optimise_block(&sub, &dsub)?;
            out.row_mut(r).copy_from_slice(y.row(0));
        }
        Ok(out)
    }
}

impl OseMethod for BackendOpt {
    fn embed(&mut self, deltas: &Matrix) -> Result<Matrix> {
        anyhow::ensure!(
            deltas.cols == self.landmarks.rows,
            "expected {} landmark distances, got {}",
            self.landmarks.rows,
            deltas.cols
        );
        if self.query_k > 0 && self.query_k < self.landmarks.rows {
            return self.embed_sparse(deltas);
        }
        Self::optimise_block(&*self, &self.landmarks, deltas)
    }

    fn dim(&self) -> usize {
        self.landmarks.cols
    }

    fn landmarks(&self) -> usize {
        self.landmarks.rows
    }

    fn name(&self) -> &'static str {
        match self.backend.name() {
            "pjrt" => "opt-pjrt",
            _ => "opt-native",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::MlpShape;
    use crate::ose::optimise::{embed_point, OseOptConfig};
    use crate::util::prng::Rng;

    #[test]
    fn backend_opt_matches_serial_oracle_budget() {
        let mut rng = Rng::new(7);
        let lm = Matrix::random_normal(&mut rng, 20, 3, 1.0);
        let deltas = Matrix::from_vec(
            5,
            20,
            (0..100).map(|_| rng.next_f32() * 2.0 + 0.5).collect(),
        );
        let mut method = BackendOpt::with_defaults(Backend::native(), lm.clone());
        method.rel_tol = 0.0; // run the full budget for exact comparison
        let y = method.embed(&deltas).unwrap();
        assert_eq!((y.rows, y.cols), (5, 3));
        // fixed-step majorization from zeros == the oracle run without
        // early stopping for the same budget
        let cfg = OseOptConfig { max_iters: 200, rel_tol: 0.0 };
        for r in 0..5 {
            let p = embed_point(&lm, deltas.row(r), None, &cfg);
            for c in 0..3 {
                assert!(
                    (y.at(r, c) - p.coords[c]).abs() < 1e-5,
                    "row {r} col {c}: {} vs {}",
                    y.at(r, c),
                    p.coords[c]
                );
            }
        }
        assert_eq!(method.name(), "opt-native");
        assert_eq!(method.landmarks(), 20);
        assert_eq!(method.dim(), 3);
    }

    #[test]
    fn backend_opt_early_stopping_stays_close_to_full_budget() {
        // realisable deltas converge quickly; the early-stopped run must
        // land within numerical noise of the full 200-step run
        let mut rng = Rng::new(9);
        let lm = Matrix::random_normal(&mut rng, 15, 3, 1.0);
        let target = [0.3f32, -0.4, 0.2];
        let deltas = Matrix::from_vec(
            1,
            15,
            (0..15)
                .map(|i| crate::strdist::euclidean(lm.row(i), &target) as f32)
                .collect(),
        );
        let mut early = BackendOpt::with_defaults(Backend::native(), lm.clone());
        let mut full = BackendOpt::with_defaults(Backend::native(), lm);
        full.rel_tol = 0.0;
        let ye = early.embed(&deltas).unwrap();
        let yf = full.embed(&deltas).unwrap();
        assert!(
            ye.max_abs_diff(&yf) < 1e-3,
            "early stop diverged: {}",
            ye.max_abs_diff(&yf)
        );
    }

    #[test]
    fn sparse_query_k_zero_and_full_l_take_the_dense_path_bit_identically() {
        let mut rng = Rng::new(17);
        let lm = Matrix::random_normal(&mut rng, 24, 3, 1.0);
        let deltas = Matrix::from_vec(
            3,
            24,
            (0..72).map(|_| rng.next_f32() * 2.0 + 0.5).collect(),
        );
        let mut dense = BackendOpt::with_defaults(Backend::native(), lm.clone());
        let y_dense = dense.embed(&deltas).unwrap();
        for query_k in [0usize, 24, 500] {
            let mut m = BackendOpt {
                query_k,
                ..BackendOpt::with_defaults(Backend::native(), lm.clone())
            };
            let y = m.embed(&deltas).unwrap();
            assert_eq!(y.data, y_dense.data, "query_k={query_k} diverged");
        }
    }

    #[test]
    fn sparse_query_k_stays_close_to_dense_on_realisable_deltas() {
        use crate::mds::graph::{GraphConfig, LandmarkGraph};
        let mut rng = Rng::new(19);
        let lm = Matrix::random_normal(&mut rng, 64, 3, 1.0);
        let targets = Matrix::random_normal(&mut rng, 6, 3, 0.5);
        let mut deltas = Matrix::zeros(6, 64);
        for r in 0..6 {
            for i in 0..64 {
                let d = crate::strdist::euclidean(lm.row(i), targets.row(r));
                deltas.set(r, i, d as f32);
            }
        }
        let mut dense = BackendOpt::with_defaults(Backend::native(), lm.clone());
        let y_dense = dense.embed(&deltas).unwrap();
        let graph =
            Arc::new(LandmarkGraph::build(&lm, &GraphConfig::default()));
        for (query_k, graph) in
            [(16usize, None), (16, Some(graph.clone())), (32, Some(graph))]
        {
            let mut m = BackendOpt {
                query_k,
                graph,
                ..BackendOpt::with_defaults(Backend::native(), lm.clone())
            };
            let y = m.embed(&deltas).unwrap();
            assert_eq!((y.rows, y.cols), (6, 3));
            for r in 0..6 {
                let d = crate::strdist::euclidean(y.row(r), y_dense.row(r));
                assert!(d < 0.15, "query_k={query_k} row {r}: off by {d}");
            }
        }
    }

    #[test]
    fn backend_nn_embeds_with_native_backend() {
        let mut rng = Rng::new(8);
        let params = MlpParams::init(
            &MlpShape { input: 12, hidden: [8, 8, 8], output: 3 },
            &mut rng,
        );
        let mut method = BackendNn::new(Backend::native(), params);
        let deltas = Matrix::from_vec(
            4,
            12,
            (0..48).map(|_| rng.next_f32() + 0.5).collect(),
        );
        let y = method.embed(&deltas).unwrap();
        assert_eq!((y.rows, y.cols), (4, 3));
        assert!(y.data.iter().all(|v| v.is_finite()));
        assert_eq!(method.name(), "nn-native");
        // wrong width rejected
        assert!(method.embed(&Matrix::zeros(2, 11)).is_err());
    }
}
