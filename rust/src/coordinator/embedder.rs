//! The two-stage large-scale pipeline (paper Sec. 4): (1) LSMDS on the
//! landmarks (O(L^2)), (2) OSE of the remaining M = N - L objects using
//! only their distances to the landmarks (O(L·M)). This is what makes
//! LSMDS practical beyond ~10^4 points.
//!
//! All numeric work flows through the [`ComputeBackend`] seam, so the same
//! pipeline runs on the pure-Rust native backend (default) or the PJRT
//! artifact backend (`--features pjrt`) without a single branch here.
//!
//! With [`PipelineConfig::stream_chunk`] set, stage (2) runs through the
//! bounded-memory streaming pipeline ([`crate::ose::pipeline`]): the
//! `(N-L) x L` dissimilarity matrix is never materialised, and block
//! construction overlaps embedding.

use std::borrow::Borrow;

use anyhow::Result;

use crate::data::source::{TableDelta, TableMetric};
use crate::mds::dissimilarity::{cross_matrix, full_matrix};
use crate::mds::divide::{
    block_seed, divide_solve_with, fps_anchors, partition_blocks,
    sampled_normalized_stress, DeltaSource, DivideConfig, SubsetDelta,
};
use crate::mds::graph::{graph_landmarks, GraphConfig};
use crate::mds::landmarks::{random_landmarks, select_landmarks};
use crate::mds::{LandmarkMethod, LsmdsConfig, Matrix};
use crate::nn::MlpShape;
use crate::ose::pipeline::{embed_stream_blocks, StreamStats, DEFAULT_STREAM_CHUNK};
use crate::ose::{OseMethod, OseMethodFactory};
use crate::runtime::{Backend, ComputeBackend};
use crate::strdist::Dissimilarity;
use crate::util::prng::Rng;

use super::methods::{BackendNn, BackendOpt};
use super::trainer::{train_backend, TrainConfig};

/// Which OSE technique maps the non-landmark points.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OseBackend {
    /// Neural network (Sec. 4.2): train an MLP on distance rows, serve
    /// with a single forward pass.
    Nn,
    /// Optimisation method (Sec. 4.1): batched Eq.-2 majorization.
    Opt,
}

impl OseBackend {
    /// Parse the config/CLI name of an OSE backend.
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "nn" | "neural" => Some(Self::Nn),
            "opt" | "optimisation" | "optimization" => Some(Self::Opt),
            _ => None,
        }
    }
}

/// How stage (1) — the base MDS on the landmark sample — is solved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaseSolver {
    /// One LSMDS over the full L x L matrix: O(L^2) per iteration, the
    /// highest-fidelity option, practical to L ~ 10^4.
    Monolithic,
    /// Divide-and-conquer ([`crate::mds::divide`]): B overlapping blocks
    /// sharing `anchors` FPS-selected points, solved concurrently and
    /// stitched with orthogonal Procrustes fits — O(L^2/B) work per
    /// sweep, blocks in parallel. `anchors = 0` picks
    /// [`crate::mds::divide::auto_anchors`].
    DivideConquer {
        /// Number of blocks B (>= 1).
        blocks: usize,
        /// Shared anchor count A (0 = auto).
        anchors: usize,
    },
}

impl BaseSolver {
    /// Parse the config/CLI name; `blocks`/`anchors` supply the divide
    /// shape (ignored for the monolithic solver).
    pub fn from_name(s: &str, blocks: usize, anchors: usize) -> Option<Self> {
        match s {
            "monolithic" | "mono" | "full" => Some(Self::Monolithic),
            "divide" | "dc" | "divide-conquer" | "divide_conquer" => {
                Some(Self::DivideConquer { blocks, anchors })
            }
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
/// Everything the two-stage pipeline needs to run (see
/// [`embed_dataset`] / [`embed_corpus`] for the in-memory and
/// out-of-core drivers that consume it).
///
/// ```
/// use lmds_ose::coordinator::embedder::{embed_dataset, OseBackend, PipelineConfig};
/// use lmds_ose::mds::LsmdsConfig;
/// use lmds_ose::runtime::Backend;
/// use lmds_ose::strdist::Levenshtein;
///
/// let names = ["anna", "annie", "bob", "bobby", "carol", "carla",
///              "dan", "danny", "erin", "erica", "frank", "frances"];
/// let cfg = PipelineConfig {
///     dim: 2,
///     landmarks: 6,
///     backend: OseBackend::Opt,
///     lsmds: LsmdsConfig { dim: 2, max_iters: 40, ..Default::default() },
///     ..Default::default()
/// };
/// let r = embed_dataset(&names, &Levenshtein, &cfg, &Backend::native()).unwrap();
/// assert_eq!((r.coords.rows, r.coords.cols), (12, 2));
/// assert_eq!(r.landmark_idx.len(), 6);
/// ```
pub struct PipelineConfig {
    /// Embedding dimension K.
    pub dim: usize,
    /// Landmark count L.
    pub landmarks: usize,
    /// How the landmark sample is chosen.
    pub landmark_method: LandmarkMethod,
    /// Which OSE technique maps non-landmark points.
    pub backend: OseBackend,
    /// Stage-1 LSMDS solver settings (dim/seed are overridden per run).
    pub lsmds: LsmdsConfig,
    /// NN backend: trainer settings.
    pub train: TrainConfig,
    /// Hidden sizes of the NN head.
    pub hidden: [usize; 3],
    /// NN backend only: bootstrap the training set by first mapping the
    /// non-landmark points with the optimisation OSE and using those
    /// coordinates as labels. This recovers the paper's protocol (the NN
    /// trains on the distance rows of ALL N points, Sec. 4.2) in the
    /// two-stage pipeline where only landmarks have LSMDS coordinates.
    /// Off, the NN trains on the L landmark rows alone — much weaker.
    pub nn_bootstrap: bool,
    /// `Some(chunk)`: drive the OSE stage through the bounded-memory
    /// streaming pipeline ([`crate::ose::pipeline`]) in chunks of this many
    /// rows instead of materialising the full `(N-L) x L` dissimilarity
    /// matrix — peak transient memory becomes `O(L² + 2·chunk·L)`
    /// regardless of N, and block construction overlaps embedding.
    /// `Some(0)` is treated as `None` (monolithic), matching the config
    /// layer's "0 disables" contract. In streaming mode the NN trains on
    /// the L landmark rows only (`nn_bootstrap` is ignored: bootstrap
    /// labels would need the full matrix the mode exists to avoid).
    pub stream_chunk: Option<usize>,
    /// How the landmark base MDS (stage 1) is solved.
    pub base_solver: BaseSolver,
    /// Optimisation-OSE budget override: `Some(steps)` runs every
    /// embedding for exactly that many majorization steps with early
    /// stopping disabled. Fixed work makes streamed output bit-identical
    /// across chunk sizes (adaptive stopping decides per chunk, see
    /// [`crate::ose::pipeline`]) and bounds per-row cost for benches;
    /// `None` keeps the adaptive default (200 steps, rel_tol 1e-7).
    /// Ignored by the NN backend.
    pub ose_steps: Option<usize>,
    /// Base PRNG seed for the run (landmark selection and solver init
    /// streams are derived from it).
    pub seed: u64,
    /// Optimisation OSE only: majorize each embedding against only its
    /// `query_k` nearest landmarks, located through the landmark
    /// small-world graph ([`crate::mds::graph`], docs/QUERY_PATH.md).
    /// 0 = dense (bit-identical to the classic all-landmark path).
    /// Ignored by the NN backend.
    pub query_k: usize,
    /// Landmark-graph construction/search parameters, used when
    /// `query_k > 0` (replica-side k-nearest search) and by the
    /// graph-assisted out-of-core landmark selector.
    pub graph: GraphConfig,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            dim: 7,
            landmarks: 300,
            landmark_method: LandmarkMethod::Fps,
            backend: OseBackend::Nn,
            lsmds: LsmdsConfig::default(),
            train: TrainConfig::default(),
            hidden: [256, 128, 64],
            nn_bootstrap: true,
            stream_chunk: None,
            base_solver: BaseSolver::Monolithic,
            ose_steps: None,
            seed: 1234,
            query_k: 0,
            graph: GraphConfig::default(),
        }
    }
}

/// Build the optimisation-OSE replica factory honouring
/// [`PipelineConfig::ose_steps`] and [`PipelineConfig::query_k`]
/// (shared with the refresh controller, which rebuilds the factory —
/// landmark graph included — around a re-solved configuration).
pub(crate) fn opt_factory(
    cfg: &PipelineConfig,
    backend: &Backend,
    landmarks: Matrix,
) -> std::sync::Arc<dyn OseMethodFactory> {
    if cfg.query_k > 0 {
        return BackendOpt::replica_factory_sparse(
            backend.clone(),
            landmarks,
            cfg.ose_steps.map_or(0, |s| s.max(1)),
            cfg.query_k,
            &cfg.graph,
        );
    }
    match cfg.ose_steps {
        Some(steps) => {
            BackendOpt::replica_factory_budget(backend.clone(), landmarks, steps.max(1))
        }
        None => BackendOpt::replica_factory(backend.clone(), landmarks),
    }
}

/// Everything a downstream consumer needs from a pipeline run.
pub struct PipelineResult {
    /// Indices (into the input object list) of the selected landmarks.
    pub landmark_idx: Vec<usize>,
    /// L x K landmark configuration.
    pub landmark_config: Matrix,
    /// N x K coordinates for every input object (landmarks at their LSMDS
    /// positions, the rest OSE-mapped).
    pub coords: Matrix,
    /// The OSE method, ready to map future streaming queries.
    pub method: Box<dyn OseMethod>,
    /// Replica factory over the same trained state: hand this to
    /// [`crate::coordinator::Server`] to serve with `R` independent,
    /// restartable executor replicas.
    pub factory: std::sync::Arc<dyn OseMethodFactory>,
    /// Normalised stress of the landmark configuration.
    pub landmark_stress: f64,
    /// Wall-clock breakdown of the pipeline phases.
    pub timings: PipelineTimings,
}

#[derive(Clone, Debug, Default)]
/// Per-phase wall-clock seconds of one pipeline run. In streaming mode
/// the dissimilarity and OSE stages overlap, so their sum can exceed
/// the end-to-end wall time.
pub struct PipelineTimings {
    /// Landmark selection.
    pub select_s: f64,
    /// L x L dissimilarity build (or its out-of-core equivalent).
    pub delta_ll_s: f64,
    /// Base MDS solve.
    pub lsmds_s: f64,
    /// NN training (0 for the optimisation backend).
    pub train_s: f64,
    /// Out-of-sample dissimilarity rows (producer side when streaming).
    pub delta_ml_s: f64,
    /// OSE embedding (consumer side when streaming).
    pub ose_s: f64,
}

/// Run LSMDS on a landmark dissimilarity matrix through a compute backend,
/// checking convergence between backend-sized step chunks. Returns the
/// configuration alone — no trailing exact-stress pass. That pass is
/// O(N^2) and serial, so the divide solver's per-block closure and the
/// benches call this; callers that want the stress use
/// [`lsmds_landmarks`].
pub fn lsmds_landmarks_config(
    delta: &Matrix,
    cfg: &LsmdsConfig,
    backend: &Backend,
) -> Result<Matrix> {
    let mut rng = Rng::new(cfg.seed);
    let mut x = Matrix::random_normal(&mut rng, delta.rows, cfg.dim, cfg.init_sigma);
    x.center_columns();
    lsmds_iterate(x, delta, cfg, backend)
}

/// [`lsmds_landmarks_config`] warm-started from `init` instead of a
/// fresh random configuration. The refresh controller's shadow solve
/// seeds each re-solve with the previous generation's coordinates, so
/// the majorization resumes near the old optimum instead of restarting
/// from noise. `init` is used as-is — no re-centering, the caller's
/// frame is preserved (the refresh path Procrustes-aligns afterwards
/// anyway, which absorbs any residual translation).
pub fn lsmds_landmarks_config_from(
    delta: &Matrix,
    cfg: &LsmdsConfig,
    backend: &Backend,
    init: Matrix,
) -> Result<Matrix> {
    anyhow::ensure!(
        init.rows == delta.rows && init.cols == cfg.dim,
        "warm init is {}x{}, expected {}x{}",
        init.rows,
        init.cols,
        delta.rows,
        cfg.dim
    );
    lsmds_iterate(init, delta, cfg, backend)
}

/// The chunked backend-stepped majorization loop shared by the cold-
/// and warm-started entry points: step `x` against `delta` until the
/// relative stress change flattens or `max_iters` is exhausted.
fn lsmds_iterate(
    mut x: Matrix,
    delta: &Matrix,
    cfg: &LsmdsConfig,
    backend: &Backend,
) -> Result<Matrix> {
    let n = delta.rows;
    let lr = cfg.lr.unwrap_or(1.0 / (2.0 * n as f64)) as f32;
    let chunk = backend.lsmds_step_chunk(n).max(1);
    let mut prev = f64::INFINITY;
    let mut done = 0usize;
    while done < cfg.max_iters {
        let steps = chunk.min(cfg.max_iters - done);
        let (x2, sigma) = backend.lsmds_steps(&x, delta, lr, steps)?;
        x = x2;
        done += steps;
        if sigma < 1e-10 {
            break; // absolute floor: relative checks are meaningless at ~0
        }
        if prev.is_finite() {
            let rel = (prev - sigma) / prev.max(1e-30);
            if rel.abs() < cfg.rel_tol * steps as f64 {
                break;
            }
        }
        prev = sigma;
    }
    Ok(x)
}

/// [`lsmds_landmarks_config`] plus the exact normalised stress of the
/// result (one O(N^2) pass over `delta`).
pub fn lsmds_landmarks(
    delta: &Matrix,
    cfg: &LsmdsConfig,
    backend: &Backend,
) -> Result<(Matrix, f64)> {
    let x = lsmds_landmarks_config(delta, cfg, backend)?;
    let stress = crate::mds::stress::normalized_stress(&x, delta);
    Ok((x, stress))
}

/// Solve the base embedding of a landmark dissimilarity matrix with the
/// chosen [`BaseSolver`], returning (configuration, normalised stress).
///
/// Both paths run through the compute backend: the monolithic solver via
/// [`lsmds_landmarks`], the divide-and-conquer solver by routing every
/// block's sub-matrix through the same backend-stepped LSMDS before the
/// Procrustes stitch.
pub fn solve_base(
    delta: &Matrix,
    cfg: &LsmdsConfig,
    solver: BaseSolver,
    backend: &Backend,
) -> Result<(Matrix, f64)> {
    match solver {
        BaseSolver::Monolithic => lsmds_landmarks(delta, cfg, backend),
        BaseSolver::DivideConquer { blocks, anchors } => {
            let config = divide_base_config(delta, cfg, blocks, anchors, backend)?;
            let stress = crate::mds::stress::normalized_stress(&config, delta);
            Ok((config, stress))
        }
    }
}

/// Pairs sampled by the out-of-core quality estimate
/// ([`crate::mds::divide::sampled_normalized_stress`]) when the exact
/// O(L^2) stress would require materialising the matrix the out-of-core
/// path exists to avoid.
pub const OUT_OF_CORE_STRESS_PAIRS: usize = 100_000;

/// The shared divide-and-conquer driver behind [`solve_base`] and
/// [`solve_base_source`]: one code path, so a disk-backed source and the
/// equivalent materialised matrix produce bit-identical configurations
/// (the contract of the parity suite in `tests/outofcore.rs`).
fn divide_base_config<S>(
    source: &S,
    cfg: &LsmdsConfig,
    blocks: usize,
    anchors: usize,
    backend: &Backend,
) -> Result<Matrix>
where
    S: DeltaSource + ?Sized,
{
    let dcfg = DivideConfig { blocks, anchors };
    let r = divide_solve_with(source, cfg.dim, &dcfg, cfg.seed, |b, sub| {
        let mut c = cfg.clone();
        c.seed = block_seed(cfg.seed, b as u64);
        lsmds_landmarks_config(sub, &c, backend)
    })?;
    log::debug!(
        "divide base solve: {} blocks (sizes {:?}), {} anchors, \
         stitch rmsd {:?}",
        r.block_sizes.len(),
        r.block_sizes,
        r.anchor_idx.len(),
        r.align_rmsd
    );
    Ok(r.config)
}

/// [`solve_base`] over any [`DeltaSource`] — the entry point when the
/// landmark dissimilarities live behind a matrix-free or disk-backed
/// source instead of a materialised `Matrix`.
///
/// The monolithic solver still needs the full L x L sub-matrix and
/// materialises it here (that path is chosen for fidelity, not memory);
/// the divide-and-conquer solver reads only per-block sub-matrices and
/// scores quality with the sampled stress estimator
/// ([`OUT_OF_CORE_STRESS_PAIRS`] pairs, deterministic in the seed) so no
/// O(L^2) pass over the source is ever made.
pub fn solve_base_source<S>(
    source: &S,
    cfg: &LsmdsConfig,
    solver: BaseSolver,
    backend: &Backend,
) -> Result<(Matrix, f64)>
where
    S: DeltaSource + ?Sized,
{
    match solver {
        BaseSolver::Monolithic => {
            let all: Vec<usize> = (0..source.len()).collect();
            let delta = source.sub_matrix(&all);
            lsmds_landmarks(&delta, cfg, backend)
        }
        BaseSolver::DivideConquer { blocks, anchors } => {
            let config = divide_base_config(source, cfg, blocks, anchors, backend)?;
            let stress = sampled_normalized_stress(
                source,
                &config,
                OUT_OF_CORE_STRESS_PAIRS,
                cfg.seed,
            );
            Ok((config, stress))
        }
    }
}

/// [`solve_base_source`] warm-started from a full `L x K` initial
/// configuration (row `i` of `init` seeds source row `i`). This is the
/// refresh controller's shadow solve: after drift, the landmark base is
/// re-solved against the updated corpus starting from the previous
/// generation's coordinates, so most of the majorization budget goes to
/// absorbing the drift rather than rediscovering the old structure.
///
/// With the divide-and-conquer solver the block partition is recomputed
/// with [`partition_blocks`] — deterministic in `(dim, shape, seed)`, so
/// it reproduces exactly the layout [`divide_solve_with`] uses — and
/// each block's warm rows are gathered from `init` by the block's
/// global indices. Stress comes from the same estimators as the
/// cold-start path (exact for monolithic, sampled for divide).
pub fn solve_base_source_warm<S>(
    source: &S,
    cfg: &LsmdsConfig,
    solver: BaseSolver,
    backend: &Backend,
    init: &Matrix,
) -> Result<(Matrix, f64)>
where
    S: DeltaSource + ?Sized,
{
    anyhow::ensure!(
        init.rows == source.len() && init.cols == cfg.dim,
        "warm init is {}x{}, expected {}x{}",
        init.rows,
        init.cols,
        source.len(),
        cfg.dim
    );
    match solver {
        BaseSolver::Monolithic => {
            let all: Vec<usize> = (0..source.len()).collect();
            let delta = source.sub_matrix(&all);
            let x = lsmds_landmarks_config_from(&delta, cfg, backend, init.clone())?;
            let stress = crate::mds::stress::normalized_stress(&x, &delta);
            Ok((x, stress))
        }
        BaseSolver::DivideConquer { blocks, anchors } => {
            let dcfg = DivideConfig { blocks, anchors };
            let part = partition_blocks(source, cfg.dim, &dcfg, cfg.seed);
            let r = divide_solve_with(source, cfg.dim, &dcfg, cfg.seed, |b, sub| {
                let mut c = cfg.clone();
                c.seed = block_seed(cfg.seed, b as u64);
                let warm = init.select_rows(&part.block_idx[b]);
                lsmds_landmarks_config_from(sub, &c, backend, warm)
            })?;
            let stress = sampled_normalized_stress(
                source,
                &r.config,
                OUT_OF_CORE_STRESS_PAIRS,
                cfg.seed,
            );
            Ok((r.config, stress))
        }
    }
}

/// The full pipeline over string objects.
pub fn embed_dataset<T: Sync + ?Sized>(
    objects: &[&T],
    metric: &dyn Dissimilarity<T>,
    cfg: &PipelineConfig,
    backend: &Backend,
) -> Result<PipelineResult> {
    anyhow::ensure!(
        cfg.landmarks <= objects.len(),
        "more landmarks ({}) than objects ({})",
        cfg.landmarks,
        objects.len()
    );
    let mut rng = Rng::new(cfg.seed);
    let mut timings = PipelineTimings::default();

    // 1. landmark selection
    let t0 = std::time::Instant::now();
    let landmark_idx =
        select_landmarks(cfg.landmark_method, &mut rng, objects, cfg.landmarks, metric);
    timings.select_s = t0.elapsed().as_secs_f64();
    let landmark_objs: Vec<&T> = landmark_idx.iter().map(|&i| objects[i]).collect();

    // 2. L x L dissimilarities + LSMDS
    let t0 = std::time::Instant::now();
    let delta_ll = full_matrix(&landmark_objs, metric);
    timings.delta_ll_s = t0.elapsed().as_secs_f64();

    let t0 = std::time::Instant::now();
    let mut lcfg = cfg.lsmds.clone();
    lcfg.dim = cfg.dim;
    lcfg.seed = cfg.seed ^ 0x5eed;
    let (landmark_config, landmark_stress) =
        solve_base(&delta_ll, &lcfg, cfg.base_solver, backend)?;
    timings.lsmds_s = t0.elapsed().as_secs_f64();

    // 3. distances from every object to the landmarks (training inputs for
    //    the NN; query rows for the optimiser). In streaming mode the
    //    matrix is never materialised — blocks are built and embedded
    //    chunk-by-chunk in step 5.
    let rest_idx: Vec<usize> = (0..objects.len())
        .filter(|i| landmark_idx.binary_search(i).is_err())
        .collect();
    let rest_objs: Vec<&T> = rest_idx.iter().map(|&i| objects[i]).collect();
    // Some(0) is normalised to monolithic here so direct PipelineConfig
    // users get the same "0 disables" contract as the config layer
    let stream_chunk = cfg.stream_chunk.filter(|&c| c > 0);
    let delta_ml = match stream_chunk {
        Some(_) => None,
        None => {
            let t0 = std::time::Instant::now();
            let m = cross_matrix(&rest_objs, &landmark_objs, metric);
            timings.delta_ml_s = t0.elapsed().as_secs_f64();
            Some(m)
        }
    };

    // 4. build the OSE method (as a replica factory, so serving can run
    //    and restart R independent instances over the same trained state)
    let t0 = std::time::Instant::now();
    let factory: std::sync::Arc<dyn OseMethodFactory> = match cfg.backend {
        OseBackend::Nn => {
            // Training set (paper Sec. 4.2: distance rows of ALL N points):
            // landmarks carry exact LSMDS coordinates; when bootstrapping,
            // the remaining points are labelled by the optimisation OSE
            // (the NN then amortises that optimiser at serving time).
            let shape = MlpShape {
                input: cfg.landmarks,
                hidden: cfg.hidden,
                output: cfg.dim,
            };
            let (inputs, labels) = match &delta_ml {
                Some(dml) if cfg.nn_bootstrap && dml.rows > 0 => {
                    let rest_labels =
                        BackendOpt::with_defaults(backend.clone(), landmark_config.clone())
                            .embed(dml)?;
                    (delta_ll.vstack(dml), landmark_config.vstack(&rest_labels))
                }
                _ => {
                    if cfg.nn_bootstrap && stream_chunk.is_some() && !rest_idx.is_empty() {
                        log::warn!(
                            "stream mode: nn_bootstrap skipped — the NN trains on the \
                             {} landmark rows only (weaker than the bootstrapped \
                             protocol; use the opt backend or monolithic mode if \
                             quality matters more than memory)",
                            delta_ll.rows
                        );
                    }
                    (delta_ll.clone(), landmark_config.clone())
                }
            };
            let (params, report) =
                train_backend(backend, &shape, &inputs, &labels, 256, &cfg.train)?;
            log::info!(
                "nn-ose trained: epochs={} loss={:.4} ({:.2}s)",
                report.epochs_run,
                report.final_loss,
                report.wall_s
            );
            timings.train_s = report.wall_s;
            BackendNn::replica_factory(backend.clone(), params)
        }
        OseBackend::Opt => opt_factory(cfg, backend, landmark_config.clone()),
    };
    let mut method = factory.build();

    // 5. OSE the remaining points, assembling the full coordinate table
    //    (step 6) as results arrive
    let mut coords = Matrix::zeros(objects.len(), cfg.dim);
    for (r, &i) in landmark_idx.iter().enumerate() {
        coords.row_mut(i).copy_from_slice(landmark_config.row(r));
    }
    match &delta_ml {
        Some(dml) => {
            let rest_coords = if rest_idx.is_empty() {
                Matrix::zeros(0, cfg.dim)
            } else {
                method.embed(dml)?
            };
            timings.ose_s = t0.elapsed().as_secs_f64() - timings.train_s;
            for (r, &i) in rest_idx.iter().enumerate() {
                coords.row_mut(i).copy_from_slice(rest_coords.row(r));
            }
        }
        None => {
            // streaming: dissimilarity-block construction overlaps the
            // embedding of the previous block; rows land in the output as
            // soon as their chunk is embedded
            let chunk = stream_chunk.expect("delta_ml is None only when streaming");
            let stats = crate::ose::pipeline::embed_stream_with(
                &rest_objs,
                &landmark_objs,
                metric,
                &mut *method,
                chunk,
                |start, block| {
                    for r in 0..block.rows {
                        coords
                            .row_mut(rest_idx[start + r])
                            .copy_from_slice(block.row(r));
                    }
                    Ok(())
                },
            )?;
            timings.delta_ml_s = stats.produce_s;
            timings.ose_s = stats.embed_s;
        }
    }

    Ok(PipelineResult {
        landmark_idx,
        landmark_config,
        coords,
        method,
        factory,
        landmark_stress,
        timings,
    })
}

/// The full pipeline over an out-of-core corpus: both stages run against
/// a [`TableDelta`] whose objects stay on disk, so peak memory is
/// O(L² + cache budget + stream chunks + N·K output) — independent of
/// the corpus payload size.
///
/// Differences from [`embed_dataset`] (which holds all N objects in
/// RAM):
///
/// - **Landmark selection** runs on the [`DeltaSource`] itself:
///   [`LandmarkMethod::Random`] samples indices without touching the
///   data; [`LandmarkMethod::Fps`] uses exact
///   [`fps_anchors`](crate::mds::divide::fps_anchors) (O(L·N) metric
///   evaluations at the storage layer); [`LandmarkMethod::MaxMinPool`]
///   uses the graph-assisted selector
///   [`graph_landmarks`](crate::mds::graph::graph_landmarks), which
///   bounds the scan to a candidate pool navigated through a
///   small-world graph.
/// - **Stage 1** solves the landmark sample through
///   [`solve_base_source`] over a [`SubsetDelta`] view — with the
///   divide-and-conquer solver the L x L matrix is only materialised
///   when the NN backend needs it as a training set.
/// - **Stage 2** always streams ([`crate::ose::pipeline`]): the producer
///   reads each chunk's rows straight from the table
///   (`stream_chunk` rows at a time, default
///   [`DEFAULT_STREAM_CHUNK`]), builds the chunk's dissimilarity block
///   and hands it across the rendezvous channel while the previous
///   block embeds. `nn_bootstrap` is skipped exactly as in streaming
///   mode — bootstrap labels would need the full N x L matrix.
pub fn embed_corpus(
    source: &TableDelta<'_>,
    cfg: &PipelineConfig,
    backend: &Backend,
) -> Result<PipelineResult> {
    let table = source.table();
    let n = table.len();
    anyhow::ensure!(
        cfg.landmarks <= n,
        "more landmarks ({}) than corpus records ({n})",
        cfg.landmarks
    );
    let mut timings = PipelineTimings::default();

    // 1. landmark selection at the storage layer
    let t0 = std::time::Instant::now();
    let landmark_idx = match cfg.landmark_method {
        LandmarkMethod::Random => {
            random_landmarks(&mut Rng::new(cfg.seed), n, cfg.landmarks)
        }
        LandmarkMethod::Fps => fps_anchors(source, cfg.landmarks, cfg.seed),
        // the pooled flavour gets the graph-assisted selector: a bounded
        // candidate pool with a small-world graph standing in for the
        // O(N·L) full scan (docs/QUERY_PATH.md "landmark selection")
        LandmarkMethod::MaxMinPool => {
            graph_landmarks(source, cfg.landmarks, &cfg.graph, cfg.seed)
        }
    };
    timings.select_s = t0.elapsed().as_secs_f64();

    // 2. base solve over the landmark subset. The L x L matrix is
    //    materialised only when a consumer genuinely needs it (the
    //    monolithic solver, or the NN training set); the divide solver
    //    reads per-block sub-matrices off the source.
    let sub = SubsetDelta::new(source, &landmark_idx);
    let mut lcfg = cfg.lsmds.clone();
    lcfg.dim = cfg.dim;
    lcfg.seed = cfg.seed ^ 0x5eed;
    let needs_delta_ll = matches!(cfg.base_solver, BaseSolver::Monolithic)
        || cfg.backend == OseBackend::Nn;
    let t0 = std::time::Instant::now();
    let delta_ll: Option<Matrix> = if needs_delta_ll {
        let all: Vec<usize> = (0..sub.len()).collect();
        Some(sub.sub_matrix(&all))
    } else {
        None
    };
    timings.delta_ll_s = t0.elapsed().as_secs_f64();

    let t0 = std::time::Instant::now();
    let (landmark_config, landmark_stress) = match (cfg.base_solver, &delta_ll) {
        (BaseSolver::Monolithic, Some(d)) => lsmds_landmarks(d, &lcfg, backend)?,
        (BaseSolver::Monolithic, None) => unreachable!("needs_delta_ll is true"),
        (BaseSolver::DivideConquer { blocks, anchors }, delta_ll) => {
            let config = divide_base_config(&sub, &lcfg, blocks, anchors, backend)?;
            let stress = match delta_ll {
                Some(d) => crate::mds::stress::normalized_stress(&config, d),
                None => sampled_normalized_stress(
                    &sub,
                    &config,
                    OUT_OF_CORE_STRESS_PAIRS,
                    lcfg.seed,
                ),
            };
            (config, stress)
        }
    };
    timings.lsmds_s = t0.elapsed().as_secs_f64();

    // 3. OSE method factory (identical replica semantics to
    //    embed_dataset)
    let factory: std::sync::Arc<dyn OseMethodFactory> = match cfg.backend {
        OseBackend::Nn => {
            let delta_ll = delta_ll.as_ref().expect("needs_delta_ll covers Nn");
            if cfg.nn_bootstrap && n > landmark_idx.len() {
                log::warn!(
                    "out-of-core mode: nn_bootstrap skipped — the NN trains on \
                     the {} landmark rows only (weaker than the bootstrapped \
                     protocol; use the opt backend if quality matters more \
                     than memory)",
                    delta_ll.rows
                );
            }
            let shape = MlpShape {
                input: cfg.landmarks,
                hidden: cfg.hidden,
                output: cfg.dim,
            };
            let (params, report) =
                train_backend(backend, &shape, delta_ll, &landmark_config, 256, &cfg.train)?;
            log::info!(
                "nn-ose trained: epochs={} loss={:.4} ({:.2}s)",
                report.epochs_run,
                report.final_loss,
                report.wall_s
            );
            timings.train_s = report.wall_s;
            BackendNn::replica_factory(backend.clone(), params)
        }
        OseBackend::Opt => opt_factory(cfg, backend, landmark_config.clone()),
    };
    let mut method = factory.build();

    // 4. landmark objects are the only rows pinned in RAM (L of them);
    //    everything else streams through stage 2
    let rest_idx: Vec<usize> = (0..n)
        .filter(|i| landmark_idx.binary_search(i).is_err())
        .collect();
    let mut coords = Matrix::zeros(n, cfg.dim);
    for (r, &i) in landmark_idx.iter().enumerate() {
        coords.row_mut(i).copy_from_slice(landmark_config.row(r));
    }
    let chunk = cfg.stream_chunk.filter(|&c| c > 0).unwrap_or(DEFAULT_STREAM_CHUNK);
    let stats = match source.metric() {
        TableMetric::Text(metric) => {
            let lm_owned = table.text_rows(&landmark_idx);
            let lm_refs: Vec<&str> = lm_owned.iter().map(String::as_str).collect();
            stream_corpus_chunks(
                &rest_idx,
                &lm_refs,
                *metric,
                &mut *method,
                chunk,
                |idx| table.text_rows(idx),
                &mut coords,
            )?
        }
        TableMetric::Vector(metric) => {
            let lm_owned = table.vector_rows(&landmark_idx);
            let lm_refs: Vec<&[f32]> = lm_owned.iter().map(Vec::as_slice).collect();
            stream_corpus_chunks(
                &rest_idx,
                &lm_refs,
                *metric,
                &mut *method,
                chunk,
                |idx| table.vector_rows(idx),
                &mut coords,
            )?
        }
    };
    timings.delta_ml_s = stats.produce_s;
    timings.ose_s = stats.embed_s;

    Ok(PipelineResult {
        landmark_idx,
        landmark_config,
        coords,
        method,
        factory,
        landmark_stress,
        timings,
    })
}

/// Stage-2 driver for [`embed_corpus`]: stream the non-landmark rows
/// through the bounded-memory pipeline, fetching each chunk's objects
/// from storage on the producer thread (`fetch` materialises at most one
/// chunk of owned rows at a time) and scattering embedded rows into
/// `coords` by their global index.
fn stream_corpus_chunks<T, O, F>(
    rest_idx: &[usize],
    landmark_refs: &[&T],
    metric: &dyn Dissimilarity<T>,
    method: &mut dyn OseMethod,
    chunk: usize,
    fetch: F,
    coords: &mut Matrix,
) -> Result<StreamStats>
where
    T: Sync + ?Sized,
    O: Borrow<T>,
    F: Fn(&[usize]) -> Vec<O> + Send,
{
    anyhow::ensure!(
        landmark_refs.len() == method.landmarks(),
        "method expects {} landmarks, got {}",
        method.landmarks(),
        landmark_refs.len()
    );
    embed_stream_blocks(
        rest_idx.len(),
        chunk,
        // move: the producer closure crosses into the producer thread,
        // so it owns `fetch` (the shared refs it also captures are Copy)
        move |start, end| {
            let owned = fetch(&rest_idx[start..end]);
            let refs: Vec<&T> = owned.iter().map(Borrow::borrow).collect();
            cross_matrix(&refs, landmark_refs, metric)
        },
        method,
        |start, block| {
            for r in 0..block.rows {
                coords
                    .row_mut(rest_idx[start + r])
                    .copy_from_slice(block.row(r));
            }
            Ok(())
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Geco, GecoConfig};
    use crate::strdist::Levenshtein;

    #[test]
    fn pipeline_runs_native_nn() {
        let mut geco = Geco::new(GecoConfig { seed: 11, ..Default::default() });
        let names = geco.generate_unique(120);
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let cfg = PipelineConfig {
            dim: 3,
            landmarks: 40,
            backend: OseBackend::Nn,
            hidden: [32, 16, 8],
            train: TrainConfig { epochs: 30, ..Default::default() },
            lsmds: LsmdsConfig { max_iters: 120, dim: 3, ..Default::default() },
            ..Default::default()
        };
        let r = embed_dataset(&objs, &Levenshtein, &cfg, &Backend::native()).unwrap();
        assert_eq!(r.coords.rows, 120);
        assert_eq!(r.coords.cols, 3);
        assert_eq!(r.landmark_idx.len(), 40);
        assert_eq!(r.method.name(), "nn-native");
        assert!(r.coords.data.iter().all(|v| v.is_finite()));
        assert!(r.landmark_stress < 0.6, "stress {}", r.landmark_stress);
    }

    #[test]
    fn pipeline_runs_native_opt() {
        let mut geco = Geco::new(GecoConfig { seed: 12, ..Default::default() });
        let names = geco.generate_unique(80);
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let cfg = PipelineConfig {
            dim: 3,
            landmarks: 30,
            backend: OseBackend::Opt,
            lsmds: LsmdsConfig { max_iters: 120, dim: 3, ..Default::default() },
            ..Default::default()
        };
        let mut r =
            embed_dataset(&objs, &Levenshtein, &cfg, &Backend::native()).unwrap();
        assert_eq!(r.coords.rows, 80);
        assert_eq!(r.method.name(), "opt-native");
        // the returned method can embed fresh queries
        let q = crate::mds::dissimilarity::cross_matrix(
            &["newname sample"],
            &r.landmark_idx.iter().map(|&i| objs[i]).collect::<Vec<_>>(),
            &Levenshtein,
        );
        let y = r.method.embed(&q).unwrap();
        assert_eq!((y.rows, y.cols), (1, 3));
    }

    #[test]
    fn streaming_pipeline_matches_monolithic_opt() {
        let mut geco = Geco::new(GecoConfig { seed: 14, ..Default::default() });
        let names = geco.generate_unique(90);
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let base = PipelineConfig {
            dim: 3,
            landmarks: 25,
            backend: OseBackend::Opt,
            lsmds: LsmdsConfig { max_iters: 80, dim: 3, ..Default::default() },
            ..Default::default()
        };
        let mono =
            embed_dataset(&objs, &Levenshtein, &base, &Backend::native()).unwrap();
        let streamed_cfg = PipelineConfig { stream_chunk: Some(7), ..base };
        let streamed =
            embed_dataset(&objs, &Levenshtein, &streamed_cfg, &Backend::native())
                .unwrap();
        assert_eq!(mono.landmark_idx, streamed.landmark_idx);
        // BackendOpt's batch-mean early stopping decides per chunk in
        // streaming mode, so the two paths agree to convergence tolerance
        // here; tests/streaming.rs pins the bit-exact contract for fixed
        // step budgets.
        assert!(
            mono.coords.max_abs_diff(&streamed.coords) < 2e-2,
            "streamed diverges by {}",
            mono.coords.max_abs_diff(&streamed.coords)
        );
        // Some(0) is normalised to the monolithic path, not 1-row chunks
        let zero_cfg = PipelineConfig { stream_chunk: Some(0), ..streamed_cfg };
        let zero =
            embed_dataset(&objs, &Levenshtein, &zero_cfg, &Backend::native()).unwrap();
        assert_eq!(zero.coords.data, mono.coords.data);
    }

    #[test]
    fn streaming_pipeline_runs_nn_backend() {
        let mut geco = Geco::new(GecoConfig { seed: 15, ..Default::default() });
        let names = geco.generate_unique(70);
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let cfg = PipelineConfig {
            dim: 2,
            landmarks: 20,
            backend: OseBackend::Nn,
            hidden: [16, 8, 8],
            train: TrainConfig { epochs: 15, ..Default::default() },
            lsmds: LsmdsConfig { max_iters: 60, dim: 2, ..Default::default() },
            stream_chunk: Some(16),
            ..Default::default()
        };
        let r = embed_dataset(&objs, &Levenshtein, &cfg, &Backend::native()).unwrap();
        assert_eq!(r.coords.rows, 70);
        assert!(r.coords.data.iter().all(|v| v.is_finite()));
        assert_eq!(r.method.name(), "nn-native");
    }

    #[test]
    fn pipeline_runs_divide_conquer_base_solver() {
        let mut geco = Geco::new(GecoConfig { seed: 16, ..Default::default() });
        let names = geco.generate_unique(140);
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let base = PipelineConfig {
            dim: 3,
            landmarks: 60,
            backend: OseBackend::Opt,
            lsmds: LsmdsConfig { max_iters: 200, dim: 3, ..Default::default() },
            ..Default::default()
        };
        let mono = embed_dataset(&objs, &Levenshtein, &base, &Backend::native()).unwrap();
        let dc_cfg = PipelineConfig {
            base_solver: BaseSolver::DivideConquer { blocks: 3, anchors: 14 },
            ..base
        };
        let dc = embed_dataset(&objs, &Levenshtein, &dc_cfg, &Backend::native()).unwrap();
        assert_eq!(dc.coords.rows, 140);
        assert!(dc.coords.data.iter().all(|v| v.is_finite()));
        assert_eq!(mono.landmark_idx, dc.landmark_idx, "selection is base-agnostic");
        // string metrics are non-realizable, so the stitched solve is an
        // approximation of the monolithic optimum — hold it to a band, not
        // equality (the realizable-band contract lives in tests/divide.rs)
        assert!(
            dc.landmark_stress < mono.landmark_stress + 0.15,
            "divide stress {} vs monolithic {}",
            dc.landmark_stress,
            mono.landmark_stress
        );
    }

    fn write_name_corpus(seed: u64, n: usize) -> std::path::PathBuf {
        let mut geco = Geco::new(GecoConfig { seed, ..Default::default() });
        let names = geco.generate_unique(n);
        let mut path = std::env::temp_dir();
        path.push(format!("lmds_embedder_corpus_{seed}_{n}_{}", std::process::id()));
        let mut w = crate::data::source::CorpusWriter::create_text(&path).unwrap();
        for name in &names {
            w.push_text(name).unwrap();
        }
        w.finish().unwrap();
        path
    }

    #[test]
    fn corpus_pipeline_runs_and_is_chunk_invariant() {
        let path = write_name_corpus(21, 90);
        let table =
            crate::data::source::ObjectTable::open(&path, 1 << 20).unwrap();
        let source = TableDelta::text(&table, &Levenshtein).unwrap();
        let base = PipelineConfig {
            dim: 3,
            landmarks: 25,
            backend: OseBackend::Opt,
            lsmds: LsmdsConfig { max_iters: 80, dim: 3, ..Default::default() },
            base_solver: BaseSolver::DivideConquer { blocks: 3, anchors: 8 },
            stream_chunk: Some(16),
            // fixed-work mode: adaptive early stopping decides per chunk,
            // which would break the bit-equality assertion below
            ose_steps: Some(12),
            ..Default::default()
        };
        let a = embed_corpus(&source, &base, &Backend::native()).unwrap();
        assert_eq!((a.coords.rows, a.coords.cols), (90, 3));
        assert_eq!(a.landmark_idx.len(), 25);
        assert!(a.coords.data.iter().all(|v| v.is_finite()));
        for (row, &i) in a.landmark_idx.iter().enumerate() {
            assert_eq!(a.coords.row(i), a.landmark_config.row(row));
        }
        // the opt method embeds rows independently with a fixed step
        // budget: chunking must not change a single bit
        let b = embed_corpus(
            &source,
            &PipelineConfig { stream_chunk: Some(7), ..base.clone() },
            &Backend::native(),
        )
        .unwrap();
        assert_eq!(a.landmark_idx, b.landmark_idx);
        assert_eq!(a.coords.data, b.coords.data, "chunk size changed the result");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corpus_pipeline_monolithic_nn_and_random_selection() {
        let path = write_name_corpus(22, 70);
        let table =
            crate::data::source::ObjectTable::open(&path, 1 << 20).unwrap();
        let source = TableDelta::text(&table, &Levenshtein).unwrap();
        let cfg = PipelineConfig {
            dim: 2,
            landmarks: 20,
            landmark_method: LandmarkMethod::Random,
            backend: OseBackend::Nn,
            hidden: [16, 8, 8],
            train: TrainConfig { epochs: 15, ..Default::default() },
            lsmds: LsmdsConfig { max_iters: 60, dim: 2, ..Default::default() },
            ..Default::default()
        };
        let r = embed_corpus(&source, &cfg, &Backend::native()).unwrap();
        assert_eq!(r.coords.rows, 70);
        assert_eq!(r.method.name(), "nn-native");
        assert!(r.coords.data.iter().all(|v| v.is_finite()));
        assert!(r.landmark_stress < 0.6, "stress {}", r.landmark_stress);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn solve_base_source_matches_solve_base_on_matrices() {
        // the same divide solve through both entry points must agree on
        // the configuration bits (stress estimators legitimately differ)
        let mut geco = Geco::new(GecoConfig { seed: 23, ..Default::default() });
        let names = geco.generate_unique(40);
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let delta = full_matrix(&objs, &Levenshtein);
        let lcfg = LsmdsConfig { dim: 2, max_iters: 60, ..Default::default() };
        let solver = BaseSolver::DivideConquer { blocks: 2, anchors: 6 };
        let (a, exact) =
            solve_base(&delta, &lcfg, solver, &Backend::native()).unwrap();
        let (b, sampled) =
            solve_base_source(&delta, &lcfg, solver, &Backend::native()).unwrap();
        assert_eq!(a.data, b.data);
        assert!(
            (exact - sampled).abs() < 0.1 * (1.0 + exact),
            "exact {exact} vs sampled {sampled}"
        );
        // monolithic path: source version materialises, then identical
        let (c, _) =
            solve_base(&delta, &lcfg, BaseSolver::Monolithic, &Backend::native())
                .unwrap();
        let (d, _) = solve_base_source(
            &delta,
            &lcfg,
            BaseSolver::Monolithic,
            &Backend::native(),
        )
        .unwrap();
        assert_eq!(c.data, d.data);
    }

    #[test]
    fn warm_started_base_solve_stays_near_its_init_optimum() {
        let mut geco = Geco::new(GecoConfig { seed: 24, ..Default::default() });
        let names = geco.generate_unique(50);
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let delta = full_matrix(&objs, &Levenshtein);
        let lcfg = LsmdsConfig { dim: 3, max_iters: 150, ..Default::default() };
        let (cold, cold_stress) =
            solve_base(&delta, &lcfg, BaseSolver::Monolithic, &Backend::native())
                .unwrap();

        // warm-started from the converged optimum, a short budget must
        // not walk away from it
        let short = LsmdsConfig { max_iters: 10, ..lcfg.clone() };
        let (warm, warm_stress) = solve_base_source_warm(
            &delta,
            &short,
            BaseSolver::Monolithic,
            &Backend::native(),
            &cold,
        )
        .unwrap();
        assert_eq!((warm.rows, warm.cols), (50, 3));
        assert!(warm.data.iter().all(|v| v.is_finite()));
        assert!(
            warm_stress <= cold_stress + 0.05,
            "warm restart degraded stress: {warm_stress} vs {cold_stress}"
        );

        // the divide flavour gathers per-block warm rows from the global
        // init and must come back finite with a sensible sampled stress
        let (dc, dc_stress) = solve_base_source_warm(
            &delta,
            &lcfg,
            BaseSolver::DivideConquer { blocks: 3, anchors: 8 },
            &Backend::native(),
            &cold,
        )
        .unwrap();
        assert_eq!((dc.rows, dc.cols), (50, 3));
        assert!(dc.data.iter().all(|v| v.is_finite()));
        assert!(dc_stress.is_finite() && dc_stress >= 0.0);

        // a mis-shaped init is rejected, not silently truncated
        let bad = Matrix::zeros(10, 3);
        assert!(solve_base_source_warm(
            &delta,
            &lcfg,
            BaseSolver::Monolithic,
            &Backend::native(),
            &bad
        )
        .is_err());
    }

    #[test]
    fn landmark_positions_preserved_in_output() {
        let mut geco = Geco::new(GecoConfig { seed: 13, ..Default::default() });
        let names = geco.generate_unique(60);
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let cfg = PipelineConfig {
            dim: 2,
            landmarks: 20,
            backend: OseBackend::Opt,
            lsmds: LsmdsConfig { max_iters: 60, dim: 2, ..Default::default() },
            ..Default::default()
        };
        let r = embed_dataset(&objs, &Levenshtein, &cfg, &Backend::native()).unwrap();
        for (row, &i) in r.landmark_idx.iter().enumerate() {
            assert_eq!(r.coords.row(i), r.landmark_config.row(row));
        }
    }
}
