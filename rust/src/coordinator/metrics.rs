//! Serving metrics: request counters, bounded latency distributions, and
//! the replica-supervision / drift-monitor surface. Shared (`Arc<Metrics>`)
//! between the frontend, the executor replicas and observers.
//!
//! Every distribution here is FIXED-SIZE: log-bucketed histograms plus a
//! bounded reservoir ([`BoundedDist`]) replace the old unbounded
//! `Mutex<Vec<f64>>` sample vectors, which leaked memory for the lifetime
//! of any long-running deployment. `footprint()` exposes the retained slot
//! count so tests can pin memory flatness under million-request soaks.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::{BoundedDist, Running};

use super::stream::DriftStatus;

/// Drift status encoding for the atomic cell: 0 = no monitor attached.
const DRIFT_NONE: u8 = 0;
const DRIFT_WARMUP: u8 = 1;
const DRIFT_HEALTHY: u8 = 2;
const DRIFT_DRIFTED: u8 = 3;

/// Lock-light serving counters + bounded latency/batch distributions.
/// Everything is safe to bump from any thread; [`Metrics::snapshot`]
/// produces a consistent-enough point-in-time view for reporting.
pub struct Metrics {
    /// Requests accepted.
    pub requests: AtomicU64,
    /// Requests answered successfully.
    pub completed: AtomicU64,
    /// Requests answered with an error.
    pub failed: AtomicU64,
    /// Executor batches dispatched.
    pub batches: AtomicU64,
    /// Total points across all dispatched batches.
    pub batched_points: AtomicU64,
    /// Batches whose embed panicked (the whole batch got error replies).
    pub panics: AtomicU64,
    /// Replicas rebuilt from the factory after a panic.
    pub replica_restarts: AtomicU64,
    /// Executor replica count (gauge, set at server start).
    replicas: AtomicU64,
    /// Shard count (gauge; 1 = unsharded serving).
    shards: AtomicU64,
    /// Per-query shard dispatch/collect failures (timeouts, dead shards,
    /// full shard queues). One query can contribute several.
    pub shard_failures: AtomicU64,
    /// Queries answered from a quorum but missing at least one shard.
    pub degraded: AtomicU64,
    /// Network connections accepted over the lifetime of the front door.
    pub conns_opened: AtomicU64,
    /// Network connections currently open (gauge).
    conns_active: AtomicU64,
    /// Queries refused with `Overloaded` by the front door (load shed).
    pub shed: AtomicU64,
    /// Malformed wire frames (each also closes its connection).
    pub proto_errors: AtomicU64,
    drift_status: AtomicU8,
    /// Times the drift monitor reported `Drifted` (re-embed signals).
    drift_signals: AtomicU64,
    /// Serving model generation (gauge; 0 = boot generation, bumped by
    /// every successful hot-refresh swap).
    generation: AtomicU64,
    /// Successful drift-triggered refreshes (shadow solve + swap).
    pub refreshes: AtomicU64,
    /// Refresh attempts that failed, leaving the old generation serving.
    pub refresh_failures: AtomicU64,
    /// Milliseconds the latest generation swap spent draining in-flight
    /// work on the old executors (gauge).
    swap_drain_ms: AtomicU64,
    /// per-request end-to-end latency (seconds), bounded
    latency: Mutex<BoundedDist>,
    /// per-batch execute latency (seconds), bounded
    batch_latency: Mutex<BoundedDist>,
    /// distance-computation latency (seconds), bounded
    dist_latency: Mutex<BoundedDist>,
    batch_sizes: Mutex<Running>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_points: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            replica_restarts: AtomicU64::new(0),
            replicas: AtomicU64::new(1),
            shards: AtomicU64::new(1),
            shard_failures: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            conns_opened: AtomicU64::new(0),
            conns_active: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            proto_errors: AtomicU64::new(0),
            drift_status: AtomicU8::new(DRIFT_NONE),
            drift_signals: AtomicU64::new(0),
            generation: AtomicU64::new(0),
            refreshes: AtomicU64::new(0),
            refresh_failures: AtomicU64::new(0),
            swap_drain_ms: AtomicU64::new(0),
            latency: Mutex::new(BoundedDist::for_latency(0x1a7)),
            batch_latency: Mutex::new(BoundedDist::for_latency(0xba7c)),
            dist_latency: Mutex::new(BoundedDist::for_latency(0xd157)),
            batch_sizes: Mutex::new(Running::new()),
        }
    }
}

impl Metrics {
    /// Fresh, zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one accepted request.
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one success and fold its end-to-end latency in.
    pub fn record_completed(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency.lock().unwrap().push(latency.as_secs_f64());
    }

    /// Count one failed request.
    pub fn record_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dispatched batch of `size` points and its execution time.
    pub fn record_batch(&self, size: usize, exec: Duration) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_points.fetch_add(size as u64, Ordering::Relaxed);
        self.batch_latency.lock().unwrap().push(exec.as_secs_f64());
        self.batch_sizes.lock().unwrap().push(size as f64);
    }

    /// Record one frontend distance-computation duration.
    pub fn record_dist(&self, d: Duration) {
        self.dist_latency.lock().unwrap().push(d.as_secs_f64());
    }

    /// Count one executor panic (the batch it poisoned was error-replied).
    pub fn record_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one replica rebuilt from the factory after a panic.
    pub fn record_replica_restart(&self) {
        self.replica_restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the current executor replica count.
    pub fn set_replicas(&self, n: usize) {
        self.replicas.store(n as u64, Ordering::Relaxed);
    }

    /// Record the shard count (gauge; 1 = unsharded).
    pub fn set_shards(&self, n: usize) {
        self.shards.store(n as u64, Ordering::Relaxed);
    }

    /// Count one failed shard dispatch/collect (timeout, dead shard or
    /// full shard queue) for one query.
    pub fn record_shard_failure(&self) {
        self.shard_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one query answered degraded (quorum met, shards missing).
    pub fn record_degraded(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one accepted network connection (bumps the active gauge).
    pub fn record_conn_open(&self) {
        self.conns_opened.fetch_add(1, Ordering::Relaxed);
        self.conns_active.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one closed network connection (drops the active gauge).
    pub fn record_conn_close(&self) {
        // saturating: a stray double-close must not wrap the gauge
        let _ = self.conns_active.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| v.checked_sub(1),
        );
    }

    /// Count one query refused with `Overloaded` by the front door.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one malformed wire frame.
    pub fn record_proto_error(&self) {
        self.proto_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one drift-monitor status into the gauges.
    pub fn record_drift(&self, status: DriftStatus) {
        let enc = match status {
            DriftStatus::Warmup => DRIFT_WARMUP,
            DriftStatus::Healthy => DRIFT_HEALTHY,
            DriftStatus::Drifted => DRIFT_DRIFTED,
        };
        self.drift_status.store(enc, Ordering::Relaxed);
        if status == DriftStatus::Drifted {
            self.drift_signals.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record the serving model generation after a successful swap.
    pub fn set_generation(&self, g: u64) {
        self.generation.store(g, Ordering::Relaxed);
    }

    /// Count one successful hot refresh (shadow solve + swap).
    pub fn record_refresh(&self) {
        self.refreshes.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one failed refresh attempt (old generation kept serving).
    pub fn record_refresh_failure(&self) {
        self.refresh_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Record how long the latest generation swap drained in-flight work
    /// on the old executors.
    pub fn record_swap_drain(&self, drain: Duration) {
        self.swap_drain_ms.store(drain.as_millis() as u64, Ordering::Relaxed);
    }

    /// Total retained sample slots across every distribution — constant
    /// after construction, whatever the request volume (the bounded-memory
    /// guarantee the soak test pins).
    pub fn footprint(&self) -> usize {
        self.latency.lock().unwrap().footprint()
            + self.batch_latency.lock().unwrap().footprint()
            + self.dist_latency.lock().unwrap().footprint()
    }

    /// Point-in-time view of every counter and distribution.
    pub fn snapshot(&self) -> Snapshot {
        let lat = self.latency.lock().unwrap();
        let (p50, p95, p99) = lat.percentiles();
        let mean_latency_s = lat.mean();
        drop(lat);
        let mean_batch_exec_s = self.batch_latency.lock().unwrap().mean();
        let mean_dist_s = self.dist_latency.lock().unwrap().mean();
        let sizes = self.batch_sizes.lock().unwrap().clone();
        let drift_status = match self.drift_status.load(Ordering::Relaxed) {
            DRIFT_WARMUP => Some(DriftStatus::Warmup),
            DRIFT_HEALTHY => Some(DriftStatus::Healthy),
            DRIFT_DRIFTED => Some(DriftStatus::Drifted),
            _ => None,
        };
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            replica_restarts: self.replica_restarts.load(Ordering::Relaxed),
            replicas: self.replicas.load(Ordering::Relaxed),
            shards: self.shards.load(Ordering::Relaxed),
            shard_failures: self.shard_failures.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            conns_opened: self.conns_opened.load(Ordering::Relaxed),
            conns_active: self.conns_active.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            proto_errors: self.proto_errors.load(Ordering::Relaxed),
            p50_s: p50,
            p95_s: p95,
            p99_s: p99,
            mean_latency_s,
            mean_batch_size: sizes.mean(),
            mean_batch_exec_s,
            mean_dist_s,
            drift_status,
            drift_signals: self.drift_signals.load(Ordering::Relaxed),
            generation: self.generation.load(Ordering::Relaxed),
            refreshes: self.refreshes.load(Ordering::Relaxed),
            refresh_failures: self.refresh_failures.load(Ordering::Relaxed),
            swap_drain_ms: self.swap_drain_ms.load(Ordering::Relaxed),
            metrics_footprint: self.footprint(),
        }
    }
}

#[derive(Clone, Debug)]
/// Point-in-time serving metrics (see [`Metrics::snapshot`]).
pub struct Snapshot {
    /// Requests accepted.
    pub requests: u64,
    /// Requests answered successfully.
    pub completed: u64,
    /// Requests answered with an error.
    pub failed: u64,
    /// Executor batches dispatched.
    pub batches: u64,
    /// Executor panics caught and isolated.
    pub panics: u64,
    /// Replicas rebuilt after panics.
    pub replica_restarts: u64,
    /// Executor replicas currently serving.
    pub replicas: u64,
    /// Shards currently serving (1 = unsharded).
    pub shards: u64,
    /// Per-query shard failures (timeouts, dead shards, full queues).
    pub shard_failures: u64,
    /// Queries answered degraded (quorum met, shards missing).
    pub degraded: u64,
    /// Network connections accepted over the front door's lifetime.
    pub conns_opened: u64,
    /// Network connections currently open.
    pub conns_active: u64,
    /// Queries load-shed with `Overloaded` by the front door.
    pub shed: u64,
    /// Malformed wire frames seen by the front door.
    pub proto_errors: u64,
    /// Median request latency (seconds).
    pub p50_s: f64,
    /// 95th-percentile request latency (seconds).
    pub p95_s: f64,
    /// 99th-percentile request latency (seconds).
    pub p99_s: f64,
    /// Mean request latency (seconds).
    pub mean_latency_s: f64,
    /// Mean points per dispatched batch.
    pub mean_batch_size: f64,
    /// Mean batch execution time (seconds).
    pub mean_batch_exec_s: f64,
    /// Mean frontend distance-computation time (seconds).
    pub mean_dist_s: f64,
    /// None when no drift monitor is attached to the server.
    pub drift_status: Option<DriftStatus>,
    /// Cumulative count of `Drifted` observations (re-embed signals).
    pub drift_signals: u64,
    /// Serving model generation (0 = boot; bumped per successful swap).
    pub generation: u64,
    /// Successful hot refreshes over the server's lifetime.
    pub refreshes: u64,
    /// Failed refresh attempts (old generation kept serving).
    pub refresh_failures: u64,
    /// Drain time of the latest generation swap, in milliseconds.
    pub swap_drain_ms: u64,
    /// Retained metric sample slots (constant — bounded-memory guarantee).
    pub metrics_footprint: usize,
}

impl Snapshot {
    /// One-line human-readable summary for logs and CLI output.
    pub fn report(&self) -> String {
        let drift = match self.drift_status {
            None => String::new(),
            Some(s) => {
                format!(" drift={} signals={}", s.as_str(), self.drift_signals)
            }
        };
        let shard = if self.shards > 1 || self.shard_failures > 0 {
            format!(
                " shards={} shard_failures={} degraded={}",
                self.shards, self.shard_failures, self.degraded
            )
        } else {
            String::new()
        };
        let net = if self.conns_opened > 0 || self.shed > 0 || self.proto_errors > 0 {
            format!(
                " conns={}/{} shed={} proto_errors={}",
                self.conns_active, self.conns_opened, self.shed, self.proto_errors
            )
        } else {
            String::new()
        };
        let refresh = if self.refreshes > 0 || self.refresh_failures > 0 {
            format!(
                " gen={} refreshes={} refresh_failures={} swap_drain={}ms",
                self.generation, self.refreshes, self.refresh_failures, self.swap_drain_ms
            )
        } else {
            String::new()
        };
        format!(
            "requests={} completed={} failed={} batches={} \
             latency p50={:.3}ms p95={:.3}ms p99={:.3}ms \
             mean_batch={:.1} mean_exec={:.3}ms \
             replicas={} panics={} restarts={}{shard}{net}{refresh}{drift}",
            self.requests,
            self.completed,
            self.failed,
            self.batches,
            self.p50_s * 1e3,
            self.p95_s * 1e3,
            self.p99_s * 1e3,
            self.mean_batch_size,
            self.mean_batch_exec_s * 1e3,
            self.replicas,
            self.panics,
            self.replica_restarts,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        for i in 0..100 {
            m.record_request();
            m.record_completed(Duration::from_micros(100 + i));
        }
        m.record_batch(32, Duration::from_millis(2));
        m.record_batch(16, Duration::from_millis(1));
        m.record_failed();
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.completed, 100);
        assert_eq!(s.failed, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 24.0).abs() < 1e-9);
        assert!(s.p50_s > 0.0 && s.p50_s <= s.p99_s);
        assert!(s.report().contains("requests=100"));
        assert_eq!(s.panics, 0);
        assert_eq!(s.drift_status, None);
    }

    #[test]
    fn empty_snapshot_is_nan_not_panic() {
        let s = Metrics::new().snapshot();
        assert!(s.p50_s.is_nan());
    }

    #[test]
    fn million_request_soak_keeps_metrics_memory_flat() {
        let m = Metrics::new();
        // warm up, then pin the footprint across a 1M-request soak — the
        // old Vec-based metrics grew by 8 bytes per request forever
        for i in 0..1_000u64 {
            m.record_request();
            m.record_completed(Duration::from_micros(50 + (i % 997)));
            m.record_dist(Duration::from_nanos(200 + (i % 101)));
        }
        let baseline = m.footprint();
        for i in 0..1_000_000u64 {
            m.record_request();
            m.record_completed(Duration::from_micros(50 + (i % 997)));
            if i % 8 == 0 {
                m.record_batch(8, Duration::from_micros(300));
            }
            if i % 3 == 0 {
                m.record_dist(Duration::from_nanos(200 + (i % 101)));
            }
        }
        assert_eq!(m.footprint(), baseline, "metrics memory grew under soak");
        let s = m.snapshot();
        assert_eq!(s.completed, 1_001_000);
        assert!(s.p50_s > 0.0 && s.p50_s <= s.p95_s && s.p95_s <= s.p99_s);
        // percentiles stay in the pushed range (~50..1050µs)
        assert!(s.p99_s < 2e-3, "p99 {}", s.p99_s);
        assert_eq!(s.metrics_footprint, baseline);
    }

    #[test]
    fn shard_and_net_counters_surface_in_snapshot() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.shards, 1);
        // quiet unsharded, un-networked server keeps the classic report
        assert!(!s.report().contains("shards="));
        assert!(!s.report().contains("conns="));
        m.set_shards(4);
        m.record_shard_failure();
        m.record_degraded();
        m.record_conn_open();
        m.record_conn_open();
        m.record_conn_close();
        m.record_shed();
        m.record_proto_error();
        let s = m.snapshot();
        assert_eq!(s.shards, 4);
        assert_eq!(s.shard_failures, 1);
        assert_eq!(s.degraded, 1);
        assert_eq!(s.conns_opened, 2);
        assert_eq!(s.conns_active, 1);
        assert_eq!(s.shed, 1);
        assert_eq!(s.proto_errors, 1);
        let r = s.report();
        assert!(r.contains("shards=4 shard_failures=1 degraded=1"), "{r}");
        assert!(r.contains("conns=1/2 shed=1 proto_errors=1"), "{r}");
        // double-close saturates instead of wrapping the gauge
        m.record_conn_close();
        m.record_conn_close();
        assert_eq!(m.snapshot().conns_active, 0);
    }

    #[test]
    fn refresh_counters_surface_in_snapshot() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.generation, s.refreshes, s.refresh_failures), (0, 0, 0));
        // a server that never refreshed keeps the classic report line
        assert!(!s.report().contains("gen="));
        let baseline = m.footprint();
        m.record_refresh_failure();
        m.set_generation(1);
        m.record_refresh();
        m.record_swap_drain(Duration::from_millis(37));
        let s = m.snapshot();
        assert_eq!(s.generation, 1);
        assert_eq!(s.refreshes, 1);
        assert_eq!(s.refresh_failures, 1);
        assert_eq!(s.swap_drain_ms, 37);
        let r = s.report();
        assert!(
            r.contains("gen=1 refreshes=1 refresh_failures=1 swap_drain=37ms"),
            "{r}"
        );
        // plain atomics: the new counters retain no samples, so the
        // flat-footprint guarantee of the 1M-request soak is untouched
        assert_eq!(m.footprint(), baseline);
    }

    #[test]
    fn drift_and_supervision_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.set_replicas(4);
        m.record_panic();
        m.record_replica_restart();
        m.record_drift(DriftStatus::Healthy);
        assert_eq!(m.snapshot().drift_status, Some(DriftStatus::Healthy));
        m.record_drift(DriftStatus::Drifted);
        m.record_drift(DriftStatus::Drifted);
        let s = m.snapshot();
        assert_eq!(s.replicas, 4);
        assert_eq!(s.panics, 1);
        assert_eq!(s.replica_restarts, 1);
        assert_eq!(s.drift_status, Some(DriftStatus::Drifted));
        assert_eq!(s.drift_signals, 2);
        assert!(s.report().contains("restarts=1"));
        assert!(s.report().contains("drift=drifted"));
    }
}
