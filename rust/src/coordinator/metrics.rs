//! Serving metrics: request counters, latency distributions, queue gauges.
//! Shared (`Arc<Metrics>`) between the frontend, batcher and executor.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::{percentiles, Running};

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_points: AtomicU64,
    /// per-request end-to-end latency samples (seconds)
    latency: Mutex<Vec<f64>>,
    /// per-batch execute latency (seconds)
    batch_latency: Mutex<Vec<f64>>,
    /// distance-computation latency (seconds)
    dist_latency: Mutex<Vec<f64>>,
    batch_sizes: Mutex<Running>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_completed(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency.lock().unwrap().push(latency.as_secs_f64());
    }

    pub fn record_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize, exec: Duration) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_points.fetch_add(size as u64, Ordering::Relaxed);
        self.batch_latency.lock().unwrap().push(exec.as_secs_f64());
        self.batch_sizes.lock().unwrap().push(size as f64);
    }

    pub fn record_dist(&self, d: Duration) {
        self.dist_latency.lock().unwrap().push(d.as_secs_f64());
    }

    pub fn snapshot(&self) -> Snapshot {
        let lat = self.latency.lock().unwrap().clone();
        let (p50, p95, p99) = if lat.is_empty() {
            (f64::NAN, f64::NAN, f64::NAN)
        } else {
            percentiles(&lat)
        };
        let batch_lat = self.batch_latency.lock().unwrap().clone();
        let sizes = self.batch_sizes.lock().unwrap().clone();
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            p50_s: p50,
            p95_s: p95,
            p99_s: p99,
            mean_batch_size: sizes.mean(),
            mean_batch_exec_s: crate::util::stats::mean(&batch_lat),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_batch_size: f64,
    pub mean_batch_exec_s: f64,
}

impl Snapshot {
    pub fn report(&self) -> String {
        format!(
            "requests={} completed={} failed={} batches={} \
             latency p50={:.3}ms p95={:.3}ms p99={:.3}ms \
             mean_batch={:.1} mean_exec={:.3}ms",
            self.requests,
            self.completed,
            self.failed,
            self.batches,
            self.p50_s * 1e3,
            self.p95_s * 1e3,
            self.p99_s * 1e3,
            self.mean_batch_size,
            self.mean_batch_exec_s * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        for i in 0..100 {
            m.record_request();
            m.record_completed(Duration::from_micros(100 + i));
        }
        m.record_batch(32, Duration::from_millis(2));
        m.record_batch(16, Duration::from_millis(1));
        m.record_failed();
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.completed, 100);
        assert_eq!(s.failed, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 24.0).abs() < 1e-9);
        assert!(s.p50_s > 0.0 && s.p50_s <= s.p99_s);
        assert!(s.report().contains("requests=100"));
    }

    #[test]
    fn empty_snapshot_is_nan_not_panic() {
        let s = Metrics::new().snapshot();
        assert!(s.p50_s.is_nan());
    }
}
