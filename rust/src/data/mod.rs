//! Dataset substrates: the Geco/FEBRL-style name generator the paper's
//! evaluation uses (Sec. 5.1), synthetic metric-space workloads for the
//! examples, and the out-of-core [`source`] layer (disk-backed object
//! tables whose dissimilarities are evaluated at the storage layer).

pub mod corpora;
pub mod geco;
pub mod source;
pub mod synthetic;

pub use geco::{Geco, GecoConfig, Record};
pub use source::{
    CorpusKind, CorpusSummary, CorpusWriter, ObjectTable, TableDelta, TableMetric,
    DEFAULT_CACHE_BUDGET,
};
