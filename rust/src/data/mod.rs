//! Dataset substrates: the Geco/FEBRL-style name generator the paper's
//! evaluation uses (Sec. 5.1) and synthetic metric-space workloads for the
//! examples.

pub mod corpora;
pub mod geco;
pub mod synthetic;

pub use geco::{Geco, GecoConfig, Record};
