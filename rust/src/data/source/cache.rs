//! Sharded LRU block cache with a hard byte budget — the resident-set
//! governor of the pread storage path ([`super::table::ObjectTable`]).
//!
//! The out-of-core contract is that reading a corpus row costs O(row)
//! transient memory, not O(corpus). On platforms (or callers) without
//! mmap the table reads fixed row-groups ("blocks") through this cache:
//! a miss loads the block from disk once, a hit hands back the resident
//! `Arc` without touching the file, and insertion evicts
//! least-recently-used blocks until the configured byte budget holds
//! again. The budget is *hard* in the only sense that matters for RSS:
//! resident bytes never exceed `budget.max(largest live block)` — a
//! budget smaller than a single block degrades to exactly one resident
//! block rather than failing.
//!
//! Concurrency: the cache is sharded by block id, each shard behind its
//! own mutex, so the divide solver's per-block workers and the streaming
//! producer thread do not serialise on one lock. Lookups clone the `Arc`
//! and drop the lock before the caller touches the data, so the metric
//! evaluation itself never holds a shard lock.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of independently locked shards. Block ids are assigned
/// round-robin across shards (`id % SHARDS`), which for the sequential
/// access patterns here (streaming chunks, block sub-matrix reads)
/// spreads neighbouring blocks over different locks.
const SHARDS: usize = 8;

/// Point-in-time cache counters (see [`BlockCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from a resident block.
    pub hits: u64,
    /// Lookups that had to load the block from storage.
    pub misses: u64,
    /// Blocks evicted to keep the byte budget.
    pub evictions: u64,
    /// Bytes currently resident across all shards.
    pub resident_bytes: usize,
    /// Blocks currently resident across all shards.
    pub resident_blocks: usize,
}

struct Entry<T> {
    data: Arc<[T]>,
    /// Last-touch tick: larger = more recently used.
    last_used: u64,
}

struct Shard<T> {
    map: HashMap<usize, Entry<T>>,
    bytes: usize,
}

/// A byte-budgeted LRU cache of `Arc<[T]>` blocks keyed by block id.
///
/// `T` is the storage unit (`u8` for text payloads, `f32` for vector
/// payloads — decoding to `f32` once per block keeps per-row access free
/// of endianness work and alignment hazards).
pub struct BlockCache<T> {
    shards: Vec<Mutex<Shard<T>>>,
    /// Per-shard byte budget (total budget / SHARDS, min 1).
    shard_budget: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl<T> BlockCache<T> {
    /// Create a cache that keeps at most `budget_bytes` resident across
    /// all shards (see the module docs for the one-block floor).
    pub fn new(budget_bytes: usize) -> Self {
        let shard_budget = (budget_bytes / SHARDS).max(1);
        Self {
            shards: (0..SHARDS)
                .map(|_| Mutex::new(Shard { map: HashMap::new(), bytes: 0 }))
                .collect(),
            shard_budget,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Fetch block `id`, loading it with `load` on a miss. The returned
    /// `Arc` stays valid after eviction (eviction only drops the cache's
    /// reference), so callers may hold it across further lookups.
    pub fn get_or_load<E>(
        &self,
        id: usize,
        load: impl FnOnce() -> Result<Arc<[T]>, E>,
    ) -> Result<Arc<[T]>, E> {
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        let shard = &self.shards[id % SHARDS];
        {
            let mut s = shard.lock().expect("cache shard poisoned");
            if let Some(e) = s.map.get_mut(&id) {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Arc::clone(&e.data));
            }
        }
        // Load outside the lock: concurrent misses on the same block may
        // read the file twice, but neither blocks the whole shard on I/O.
        self.misses.fetch_add(1, Ordering::Relaxed);
        let data = load()?;
        let block_bytes = data.len() * std::mem::size_of::<T>();
        let mut s = shard.lock().expect("cache shard poisoned");
        if let Some(e) = s.map.get_mut(&id) {
            // lost a load race; keep the resident copy
            e.last_used = tick;
            return Ok(Arc::clone(&e.data));
        }
        s.bytes += block_bytes;
        s.map.insert(id, Entry { data: Arc::clone(&data), last_used: tick });
        // Evict LRU blocks until the budget holds; the block just
        // inserted is the most recently used, so it survives even when
        // it alone exceeds the budget (the one-block floor).
        while s.bytes > self.shard_budget && s.map.len() > 1 {
            let (&victim, _) = s
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .expect("map.len() > 1");
            let e = s.map.remove(&victim).expect("victim resident");
            s.bytes -= e.data.len() * std::mem::size_of::<T>();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        Ok(data)
    }

    /// Current counters (approximate under concurrency: each counter is
    /// individually exact, the set is not a consistent snapshot).
    pub fn stats(&self) -> CacheStats {
        let mut resident_bytes = 0usize;
        let mut resident_blocks = 0usize;
        for shard in &self.shards {
            let s = shard.lock().expect("cache shard poisoned");
            resident_bytes += s.bytes;
            resident_blocks += s.map.len();
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_bytes,
            resident_blocks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(v: u8, len: usize) -> Arc<[u8]> {
        vec![v; len].into()
    }

    #[test]
    fn hit_after_miss_and_counters() {
        let c: BlockCache<u8> = BlockCache::new(1 << 20);
        let a = c.get_or_load(3, || Ok::<_, ()>(block(3, 100))).unwrap();
        let b = c.get_or_load(3, || panic!("must be a hit")).unwrap();
        assert_eq!(&a[..], &b[..]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.resident_bytes, 100);
        assert_eq!(s.resident_blocks, 1);
    }

    #[test]
    fn budget_evicts_lru_not_mru() {
        let c: BlockCache<u8> = BlockCache::new(0); // per-shard floor: 1 byte
        // same shard (ids congruent mod SHARDS) so evictions interact
        let id0 = 0;
        let id1 = SHARDS;
        c.get_or_load(id0, || Ok::<_, ()>(block(1, 64))).unwrap();
        c.get_or_load(id1, || Ok::<_, ()>(block(2, 64))).unwrap();
        // id0 was least recently used -> evicted; id1 resident
        let s = c.stats();
        assert_eq!(s.resident_blocks, 1);
        assert_eq!(s.evictions, 1);
        c.get_or_load(id1, || panic!("mru must still be resident")).unwrap();
        // id0 must reload
        c.get_or_load(id0, || Ok::<_, ()>(block(1, 64))).unwrap();
        assert_eq!(c.stats().misses, 3);
    }

    #[test]
    fn one_block_floor_keeps_oversized_block() {
        let c: BlockCache<u8> = BlockCache::new(16);
        let a = c.get_or_load(0, || Ok::<_, ()>(block(9, 4096))).unwrap();
        assert_eq!(a.len(), 4096);
        assert_eq!(c.stats().resident_blocks, 1, "oversized block stays");
        c.get_or_load(0, || panic!("must be a hit")).unwrap();
    }

    #[test]
    fn load_errors_propagate_and_leave_no_entry() {
        let c: BlockCache<u8> = BlockCache::new(1 << 10);
        let r = c.get_or_load(5, || Err::<Arc<[u8]>, &str>("io"));
        assert_eq!(r.unwrap_err(), "io");
        assert_eq!(c.stats().resident_blocks, 0);
        // a later successful load works
        c.get_or_load(5, || Ok::<_, &str>(block(1, 8))).unwrap();
        assert_eq!(c.stats().resident_blocks, 1);
    }

    #[test]
    fn arcs_survive_eviction() {
        let c: BlockCache<u8> = BlockCache::new(0);
        let kept = c.get_or_load(0, || Ok::<_, ()>(block(7, 32))).unwrap();
        c.get_or_load(SHARDS, || Ok::<_, ()>(block(8, 32))).unwrap(); // evicts id 0
        assert!(kept.iter().all(|&b| b == 7), "evicted Arc data still valid");
    }

    #[test]
    fn concurrent_access_is_consistent() {
        let c: BlockCache<u64> = BlockCache::new(1 << 12);
        std::thread::scope(|scope| {
            for t in 0..4 {
                let c = &c;
                scope.spawn(move || {
                    for i in 0..200usize {
                        let id = (i * 7 + t) % 32;
                        let b = c
                            .get_or_load(id, || {
                                Ok::<_, ()>(vec![id as u64; 16].into())
                            })
                            .unwrap();
                        assert!(b.iter().all(|&v| v == id as u64));
                    }
                });
            }
        });
        let s = c.stats();
        assert_eq!(s.hits + s.misses, 800);
    }
}
