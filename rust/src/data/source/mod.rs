//! Out-of-core data sources: corpora that live on disk and are consumed
//! by the solvers without ever materialising in RAM.
//!
//! This is the storage layer named by the paper's scaling story: landmark
//! MDS plus out-of-sample embedding keeps the *algorithmic* cost linear
//! in N, but every concrete input until this module was an in-memory
//! `Matrix` or object slice, so N was capped by host RAM. Here the
//! dissimilarities are evaluated *at the storage layer* instead (the
//! reference-set design of arXiv:2408.04129): an [`ObjectTable`] holds
//! the raw objects on disk — fixed-record `[f32]` vectors or
//! offset-indexed UTF-8 strings ([`format`]) — and [`TableDelta`] turns
//! it into a [`DeltaSource`](crate::mds::divide::DeltaSource) by fetching
//! the two rows lazily (zero-copy under mmap, through a byte-budgeted
//! LRU block cache under pread; [`cache`]) and running the configured
//! [`Dissimilarity`] metric on them at access time.
//!
//! Both pipeline stages consume it: the divide-and-conquer base solver
//! reads block sub-matrices straight off the table
//! ([`crate::coordinator::embedder::solve_base_source`]), and the
//! streaming OSE pass builds its dissimilarity chunks from table rows
//! ([`crate::coordinator::embedder::embed_corpus`]). Peak resident
//! memory is O(L² + cache budget + stream chunks + output), independent
//! of N — the property pinned by `tests/outofcore_memory.rs` and
//! `benches/bench_outofcore.rs`.

pub mod cache;
pub mod format;
pub mod table;

pub use cache::{BlockCache, CacheStats};
pub use format::{CorpusKind, CorpusSummary, CorpusWriter, Header};
pub use table::{mmap_supported, CorpusTruncated, ObjectTable, DEFAULT_CACHE_BUDGET};

use anyhow::Result;

use crate::mds::divide::DeltaSource;
use crate::strdist::Dissimilarity;

/// The metric half of a disk-backed source: which object domain the
/// table's rows belong to, and how to compare two of them.
pub enum TableMetric<'a> {
    /// String metric over text records (e.g. Levenshtein).
    Text(&'a dyn Dissimilarity<str>),
    /// Vector metric over `[f32]` records (e.g. Euclidean).
    Vector(&'a dyn Dissimilarity<[f32]>),
}

impl TableMetric<'_> {
    /// Human-readable metric name (for logs and reports).
    pub fn name(&self) -> &'static str {
        match self {
            TableMetric::Text(m) => m.name(),
            TableMetric::Vector(m) => m.name(),
        }
    }
}

/// A disk-backed [`DeltaSource`]: `dist(i, j)` fetches rows `i` and `j`
/// from the [`ObjectTable`] lazily and evaluates the metric at access
/// time, so the L x L (or N x N) dissimilarity matrix never exists.
///
/// Bit-compatibility: the metric sees exactly the bytes that were
/// written (f32 payloads round-trip exactly through the little-endian
/// file format), so a `TableDelta` produces bit-identical distances to
/// the equivalent in-memory source — the contract the disk-vs-RAM
/// parity suite in `tests/outofcore.rs` enforces through `solve_base`.
pub struct TableDelta<'a> {
    table: &'a ObjectTable,
    metric: TableMetric<'a>,
}

impl<'a> TableDelta<'a> {
    /// Pair a table with a metric, rejecting domain mismatches (a string
    /// metric over a vector table or vice versa).
    pub fn new(table: &'a ObjectTable, metric: TableMetric<'a>) -> Result<TableDelta<'a>> {
        let ok = matches!(
            (&metric, table.kind()),
            (TableMetric::Text(_), CorpusKind::Text)
                | (TableMetric::Vector(_), CorpusKind::VecF32)
        );
        anyhow::ensure!(
            ok,
            "metric domain does not match corpus kind {:?}",
            table.kind()
        );
        Ok(TableDelta { table, metric })
    }

    /// Shorthand for [`TableDelta::new`] over a text table.
    pub fn text(
        table: &'a ObjectTable,
        metric: &'a dyn Dissimilarity<str>,
    ) -> Result<TableDelta<'a>> {
        Self::new(table, TableMetric::Text(metric))
    }

    /// Shorthand for [`TableDelta::new`] over a vector table.
    pub fn vectors(
        table: &'a ObjectTable,
        metric: &'a dyn Dissimilarity<[f32]>,
    ) -> Result<TableDelta<'a>> {
        Self::new(table, TableMetric::Vector(metric))
    }

    /// The underlying object table.
    pub fn table(&self) -> &'a ObjectTable {
        self.table
    }

    /// The metric evaluated at the storage layer.
    pub fn metric(&self) -> &TableMetric<'a> {
        &self.metric
    }
}

impl DeltaSource for TableDelta<'_> {
    fn len(&self) -> usize {
        self.table.len()
    }

    fn dist(&self, i: usize, j: usize) -> f32 {
        match &self.metric {
            TableMetric::Text(m) => self
                .table
                .with_text(i, |a| self.table.with_text(j, |b| m.dist(a, b)))
                as f32,
            TableMetric::Vector(m) => self
                .table
                .with_vector(i, |a| self.table.with_vector(j, |b| m.dist(a, b)))
                as f32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strdist::{Euclidean, Levenshtein};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lmds_src_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn table_delta_matches_in_memory_metric_bit_for_bit() {
        let p = tmp("delta_vec");
        let rows: Vec<Vec<f32>> = (0..40)
            .map(|i| (0..3).map(|d| ((i * 7 + d * 13) % 11) as f32 * 0.37).collect())
            .collect();
        let mut w = CorpusWriter::create_vectors(&p, 3).unwrap();
        for r in &rows {
            w.push_vector(r).unwrap();
        }
        w.finish().unwrap();
        let t = ObjectTable::open(&p, DEFAULT_CACHE_BUDGET).unwrap();
        let src = TableDelta::vectors(&t, &Euclidean).unwrap();
        assert_eq!(src.len(), 40);
        for i in 0..40 {
            for j in 0..40 {
                let want = crate::strdist::euclidean(&rows[i], &rows[j]) as f32;
                let got = src.dist(i, j);
                assert!(got == want, "({i},{j}): {got} != {want}");
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn table_delta_text_matches_levenshtein() {
        let p = tmp("delta_txt");
        let names = ["anna", "bob", "carol", "dan", "anna"];
        let mut w = CorpusWriter::create_text(&p).unwrap();
        for n in names {
            w.push_text(n).unwrap();
        }
        w.finish().unwrap();
        let t = ObjectTable::open(&p, DEFAULT_CACHE_BUDGET).unwrap();
        let src = TableDelta::text(&t, &Levenshtein).unwrap();
        assert_eq!(src.dist(0, 1), 4.0);
        assert_eq!(src.dist(0, 4), 0.0, "duplicate records are distance 0");
        assert_eq!(src.dist(2, 3), src.dist(3, 2), "symmetric");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn metric_domain_mismatch_rejected() {
        let p = tmp("delta_mm");
        let mut w = CorpusWriter::create_text(&p).unwrap();
        w.push_text("x").unwrap();
        w.finish().unwrap();
        let t = ObjectTable::open(&p, 1 << 10).unwrap();
        assert!(TableDelta::vectors(&t, &Euclidean).is_err());
        assert!(TableDelta::text(&t, &Levenshtein).is_ok());
        std::fs::remove_file(&p).ok();
    }
}
