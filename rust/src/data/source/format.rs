//! The on-disk corpus format and its streaming writer.
//!
//! A corpus file ("object table") is the unit the out-of-core pipeline
//! consumes: a header, a payload of records, and — for variable-length
//! records — a trailing offset index. Everything is little-endian.
//!
//! ```text
//! offset  size  field
//! 0       8     magic  "LMDSTBL\0"
//! 8       4     version (u32, currently 1)
//! 12      4     kind    (u32: 1 = fixed f32 vectors, 2 = UTF-8 text)
//! 16      8     count   (u64, number of records)
//! 24      8     dim     (u64, f32s per record for vectors; 0 for text)
//! 32      8     payload_off (u64, always 64 in version 1)
//! 40      8     index_off   (u64, text only: offset of the index; 0 for
//!                            vectors)
//! 48      16    reserved (zero)
//! 64      ...   payload: vectors = count*dim f32 LE, densely packed;
//!                        text = concatenated UTF-8 bytes
//! index   ...   text only: (count+1) u64 LE offsets relative to
//!               payload_off; record i spans [off[i], off[i+1])
//! ```
//!
//! The fixed-record layout gives O(1) row addressing with zero index
//! memory; the offset-indexed layout gives O(1) row addressing for
//! ragged records at 8 bytes of index per record, read on demand (never
//! materialised wholesale by the reader).
//!
//! The writer streams: records go straight through a [`std::io::BufWriter`];
//! only the text offset list (8 bytes per record) is buffered in memory,
//! so writing an N-record corpus needs O(N) index memory for text and
//! O(1) for vectors — never the payload itself.

use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// File magic, first 8 bytes of every corpus file.
pub const MAGIC: [u8; 8] = *b"LMDSTBL\0";
/// Current format version.
pub const VERSION: u32 = 1;
/// Header size in bytes; also the payload offset in version 1 (keeping
/// the payload 64-byte aligned means f32 vector rows stay 4-byte aligned
/// under mmap for free).
pub const HEADER_LEN: u64 = 64;

/// What a corpus file stores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    /// Fixed-length `[f32; dim]` records (coordinate workloads).
    VecF32,
    /// Variable-length UTF-8 text records (string workloads).
    Text,
}

impl CorpusKind {
    pub(crate) fn code(self) -> u32 {
        match self {
            CorpusKind::VecF32 => 1,
            CorpusKind::Text => 2,
        }
    }

    pub(crate) fn from_code(c: u32) -> Option<Self> {
        match c {
            1 => Some(CorpusKind::VecF32),
            2 => Some(CorpusKind::Text),
            _ => None,
        }
    }
}

/// Parsed corpus header (see the module docs for the byte layout).
#[derive(Clone, Copy, Debug)]
pub struct Header {
    /// Record layout stored in the file.
    pub kind: CorpusKind,
    /// Number of records.
    pub count: u64,
    /// f32s per record (vectors) or 0 (text).
    pub dim: u64,
    /// Byte offset of the payload section.
    pub payload_off: u64,
    /// Byte offset of the text offset index (0 for vectors).
    pub index_off: u64,
}

impl Header {
    /// Serialise to the fixed 64-byte header block.
    pub fn to_bytes(&self) -> [u8; HEADER_LEN as usize] {
        let mut b = [0u8; HEADER_LEN as usize];
        b[0..8].copy_from_slice(&MAGIC);
        b[8..12].copy_from_slice(&VERSION.to_le_bytes());
        b[12..16].copy_from_slice(&self.kind.code().to_le_bytes());
        b[16..24].copy_from_slice(&self.count.to_le_bytes());
        b[24..32].copy_from_slice(&self.dim.to_le_bytes());
        b[32..40].copy_from_slice(&self.payload_off.to_le_bytes());
        b[40..48].copy_from_slice(&self.index_off.to_le_bytes());
        b
    }

    /// Parse and validate a header block.
    pub fn parse(b: &[u8]) -> Result<Header> {
        anyhow::ensure!(b.len() >= HEADER_LEN as usize, "corpus file shorter than its header");
        anyhow::ensure!(b[0..8] == MAGIC, "not a corpus file (bad magic)");
        let u32_at = |o: usize| u32::from_le_bytes(b[o..o + 4].try_into().unwrap());
        let u64_at = |o: usize| u64::from_le_bytes(b[o..o + 8].try_into().unwrap());
        let version = u32_at(8);
        anyhow::ensure!(
            version == VERSION,
            "unsupported corpus version {version} (expected {VERSION})"
        );
        let kind = CorpusKind::from_code(u32_at(12))
            .with_context(|| format!("unknown corpus kind code {}", u32_at(12)))?;
        let h = Header {
            kind,
            count: u64_at(16),
            dim: u64_at(24),
            payload_off: u64_at(32),
            index_off: u64_at(40),
        };
        anyhow::ensure!(h.payload_off >= HEADER_LEN, "payload overlaps the header");
        match kind {
            CorpusKind::VecF32 => {
                anyhow::ensure!(h.dim > 0, "vector corpus with dim 0");
                anyhow::ensure!(h.payload_off % 4 == 0, "vector payload misaligned");
            }
            CorpusKind::Text => {
                anyhow::ensure!(
                    h.index_off >= h.payload_off,
                    "text corpus index overlaps the payload"
                );
            }
        }
        Ok(h)
    }
}

/// What [`CorpusWriter::finish`] reports about the file it produced.
#[derive(Clone, Debug)]
pub struct CorpusSummary {
    /// Path the corpus was written to.
    pub path: PathBuf,
    /// Record layout written.
    pub kind: CorpusKind,
    /// Records written.
    pub count: u64,
    /// Total file size in bytes (header + payload + index).
    pub bytes: u64,
}

/// Streaming corpus writer — see the module docs for the format.
///
/// Records are appended with [`push_vector`](CorpusWriter::push_vector)
/// or [`push_text`](CorpusWriter::push_text) and the file becomes valid
/// only after [`finish`](CorpusWriter::finish) patches the header (and,
/// for text, appends the offset index). A writer dropped without
/// `finish` leaves a file with `count = 0` that readers reject as empty
/// rather than mis-reading a truncated payload.
pub struct CorpusWriter {
    out: BufWriter<File>,
    path: PathBuf,
    kind: CorpusKind,
    dim: usize,
    count: u64,
    payload_bytes: u64,
    /// Text only: record start offsets relative to the payload.
    offsets: Vec<u64>,
}

impl CorpusWriter {
    /// Create a fixed-record `[f32; dim]` corpus at `path` (truncating).
    pub fn create_vectors(path: &Path, dim: usize) -> Result<CorpusWriter> {
        anyhow::ensure!(dim > 0, "vector corpus needs dim >= 1");
        Self::create(path, CorpusKind::VecF32, dim)
    }

    /// Create a variable-record UTF-8 text corpus at `path` (truncating).
    pub fn create_text(path: &Path) -> Result<CorpusWriter> {
        Self::create(path, CorpusKind::Text, 0)
    }

    fn create(path: &Path, kind: CorpusKind, dim: usize) -> Result<CorpusWriter> {
        let file = File::create(path)
            .with_context(|| format!("creating corpus {path:?}"))?;
        let mut out = BufWriter::new(file);
        // Placeholder header: count = 0 until finish() patches it, so a
        // truncated write never looks like a complete corpus.
        let h = Header {
            kind,
            count: 0,
            dim: dim as u64,
            payload_off: HEADER_LEN,
            index_off: 0,
        };
        out.write_all(&h.to_bytes()).context("writing corpus header")?;
        Ok(CorpusWriter {
            out,
            path: path.to_path_buf(),
            kind,
            dim,
            count: 0,
            payload_bytes: 0,
            offsets: Vec::new(),
        })
    }

    /// Reopen a *finished* corpus at `path` for appending — the refresh
    /// loop's ingest path. The header is parsed and validated, existing
    /// records are preserved, and new records continue after the current
    /// payload (for text, overwriting the old offset index, which
    /// [`finish`](CorpusWriter::finish) rewrites past the grown payload).
    ///
    /// Crash safety mirrors [`create`](CorpusWriter::create_text): the
    /// header is re-set to the `count = 0` placeholder while the writer
    /// is open, so a writer dropped mid-append leaves a file readers
    /// treat as empty rather than one whose stale index points into
    /// overwritten bytes. `finish` must be called again to make the file
    /// valid; reopening and finishing with no records appended rewrites
    /// a byte-identical file.
    pub fn append(path: &Path) -> Result<CorpusWriter> {
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("reopening corpus {path:?}"))?;
        let file_len = file.metadata().context("stat corpus")?.len();
        let mut head = [0u8; HEADER_LEN as usize];
        file.read_exact(&mut head)
            .with_context(|| format!("reading corpus header of {path:?}"))?;
        let h = Header::parse(&head)?;
        anyhow::ensure!(
            h.payload_off == HEADER_LEN,
            "cannot append to corpus {path:?}: non-standard payload offset {}",
            h.payload_off
        );
        let (payload_bytes, offsets) = match h.kind {
            CorpusKind::VecF32 => {
                let payload = h.count * h.dim * 4;
                let need = h.payload_off + payload;
                anyhow::ensure!(
                    file_len >= need,
                    "corpus {path:?} is truncated: {file_len} bytes, layout needs {need}"
                );
                (payload, Vec::new())
            }
            CorpusKind::Text => {
                let need = h.index_off + 8 * (h.count + 1);
                anyhow::ensure!(
                    file_len >= need,
                    "corpus {path:?} is truncated: {file_len} bytes, layout needs {need}"
                );
                // Recover the per-record offsets; the end sentinel is
                // dropped (push_text re-derives it from payload_bytes).
                file.seek(SeekFrom::Start(h.index_off))?;
                let mut idx = vec![0u8; 8 * (h.count as usize + 1)];
                file.read_exact(&mut idx).context("reading corpus text index")?;
                let offs: Vec<u64> = idx
                    .chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let payload = *offs.last().unwrap_or(&0);
                (payload, offs[..h.count as usize].to_vec())
            }
        };
        // Placeholder header for the duration of the append (see above).
        let placeholder = Header {
            kind: h.kind,
            count: 0,
            dim: h.dim,
            payload_off: HEADER_LEN,
            index_off: 0,
        };
        file.seek(SeekFrom::Start(0))?;
        file.write_all(&placeholder.to_bytes()).context("arming corpus append header")?;
        file.seek(SeekFrom::Start(h.payload_off + payload_bytes))?;
        Ok(CorpusWriter {
            out: BufWriter::new(file),
            path: path.to_path_buf(),
            kind: h.kind,
            dim: h.dim as usize,
            count: h.count,
            payload_bytes,
            offsets,
        })
    }

    /// Records appended so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Append one fixed-length vector record.
    pub fn push_vector(&mut self, row: &[f32]) -> Result<()> {
        anyhow::ensure!(self.kind == CorpusKind::VecF32, "not a vector corpus");
        anyhow::ensure!(
            row.len() == self.dim,
            "record has {} f32s, corpus dim is {}",
            row.len(),
            self.dim
        );
        for v in row {
            self.out.write_all(&v.to_le_bytes())?;
        }
        self.payload_bytes += (self.dim * 4) as u64;
        self.count += 1;
        Ok(())
    }

    /// Append one text record.
    pub fn push_text(&mut self, s: &str) -> Result<()> {
        anyhow::ensure!(self.kind == CorpusKind::Text, "not a text corpus");
        self.offsets.push(self.payload_bytes);
        self.out.write_all(s.as_bytes())?;
        self.payload_bytes += s.len() as u64;
        self.count += 1;
        Ok(())
    }

    /// Write the index (text), patch the header and flush. The file is
    /// not a valid corpus until this returns.
    pub fn finish(mut self) -> Result<CorpusSummary> {
        let index_off = match self.kind {
            CorpusKind::VecF32 => 0,
            CorpusKind::Text => {
                self.offsets.push(self.payload_bytes); // end sentinel
                for off in &self.offsets {
                    self.out.write_all(&off.to_le_bytes())?;
                }
                HEADER_LEN + self.payload_bytes
            }
        };
        let h = Header {
            kind: self.kind,
            count: self.count,
            dim: self.dim as u64,
            payload_off: HEADER_LEN,
            index_off,
        };
        let bytes = match self.kind {
            CorpusKind::VecF32 => HEADER_LEN + self.payload_bytes,
            CorpusKind::Text => index_off + 8 * self.offsets.len() as u64,
        };
        self.out.flush().context("flushing corpus payload")?;
        let mut file = self.out.into_inner().context("flushing corpus payload")?;
        file.seek(SeekFrom::Start(0))?;
        file.write_all(&h.to_bytes()).context("patching corpus header")?;
        file.sync_all().context("syncing corpus file")?;
        Ok(CorpusSummary { path: self.path, kind: self.kind, count: self.count, bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lmds_fmt_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn header_round_trips() {
        let h = Header {
            kind: CorpusKind::Text,
            count: 123,
            dim: 0,
            payload_off: HEADER_LEN,
            index_off: 999,
        };
        let b = h.to_bytes();
        let back = Header::parse(&b).unwrap();
        assert_eq!(back.kind, CorpusKind::Text);
        assert_eq!(back.count, 123);
        assert_eq!(back.index_off, 999);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Header::parse(b"short").is_err());
        let mut b = Header {
            kind: CorpusKind::VecF32,
            count: 1,
            dim: 2,
            payload_off: HEADER_LEN,
            index_off: 0,
        }
        .to_bytes();
        b[0] = b'X'; // bad magic
        assert!(Header::parse(&b).is_err());
        let mut b2 = Header {
            kind: CorpusKind::VecF32,
            count: 1,
            dim: 0, // invalid for vectors
            payload_off: HEADER_LEN,
            index_off: 0,
        }
        .to_bytes();
        assert!(Header::parse(&b2).is_err());
        b2[8..12].copy_from_slice(&7u32.to_le_bytes()); // bad version
        assert!(Header::parse(&b2).is_err());
    }

    #[test]
    fn writer_produces_expected_vector_bytes() {
        let p = tmp("vec");
        let mut w = CorpusWriter::create_vectors(&p, 2).unwrap();
        w.push_vector(&[1.0, 2.0]).unwrap();
        w.push_vector(&[3.0, -4.5]).unwrap();
        assert!(w.push_vector(&[1.0]).is_err(), "wrong dim rejected");
        assert!(w.push_text("nope").is_err(), "wrong kind rejected");
        let s = w.finish().unwrap();
        assert_eq!(s.count, 2);
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(bytes.len() as u64, s.bytes);
        let h = Header::parse(&bytes).unwrap();
        assert_eq!(h.count, 2);
        assert_eq!(h.dim, 2);
        let f = f32::from_le_bytes(bytes[64 + 12..64 + 16].try_into().unwrap());
        assert_eq!(f, -4.5);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn writer_produces_expected_text_index() {
        let p = tmp("txt");
        let mut w = CorpusWriter::create_text(&p).unwrap();
        w.push_text("ab").unwrap();
        w.push_text("").unwrap(); // empty records are legal
        w.push_text("xyz").unwrap();
        let s = w.finish().unwrap();
        assert_eq!(s.count, 3);
        let bytes = std::fs::read(&p).unwrap();
        let h = Header::parse(&bytes).unwrap();
        assert_eq!(h.count, 3);
        assert_eq!(h.index_off, 64 + 5);
        let off = |i: usize| {
            u64::from_le_bytes(
                bytes[h.index_off as usize + 8 * i..h.index_off as usize + 8 * i + 8]
                    .try_into()
                    .unwrap(),
            )
        };
        assert_eq!([off(0), off(1), off(2), off(3)], [0, 2, 2, 5]);
        assert_eq!(&bytes[64..69], b"abxyz");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_append_round_trips_across_reopens() {
        let p = tmp("txt_append");
        let mut w = CorpusWriter::create_text(&p).unwrap();
        w.push_text("alpha").unwrap();
        w.push_text("").unwrap();
        w.finish().unwrap();

        // reopen-finish-reopen: two append generations
        let mut w = CorpusWriter::append(&p).unwrap();
        assert_eq!(w.count(), 2);
        w.push_text("beta").unwrap();
        let s = w.finish().unwrap();
        assert_eq!(s.count, 3);
        let mut w = CorpusWriter::append(&p).unwrap();
        w.push_text("gamma-longer-record").unwrap();
        w.push_text("d").unwrap();
        let s = w.finish().unwrap();
        assert_eq!(s.count, 5);

        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(bytes.len() as u64, s.bytes);
        let h = Header::parse(&bytes).unwrap();
        assert_eq!(h.count, 5);
        let payload = &bytes[HEADER_LEN as usize..h.index_off as usize];
        assert_eq!(payload, b"alphabetagamma-longer-recordd");
        let off = |i: usize| {
            u64::from_le_bytes(
                bytes[h.index_off as usize + 8 * i..h.index_off as usize + 8 * i + 8]
                    .try_into()
                    .unwrap(),
            )
        };
        assert_eq!(
            [off(0), off(1), off(2), off(3), off(4), off(5)],
            [0, 5, 5, 9, 28, 29]
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn vector_append_round_trips_across_reopens() {
        let p = tmp("vec_append");
        let mut w = CorpusWriter::create_vectors(&p, 2).unwrap();
        w.push_vector(&[1.0, 2.0]).unwrap();
        w.finish().unwrap();
        let mut w = CorpusWriter::append(&p).unwrap();
        assert_eq!(w.count(), 1);
        w.push_vector(&[3.0, 4.0]).unwrap();
        let s = w.finish().unwrap();
        assert_eq!(s.count, 2);
        let bytes = std::fs::read(&p).unwrap();
        let h = Header::parse(&bytes).unwrap();
        assert_eq!((h.count, h.dim), (2, 2));
        let f = f32::from_le_bytes(bytes[64 + 12..64 + 16].try_into().unwrap());
        assert_eq!(f, 4.0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn append_header_patch_is_idempotent() {
        // reopening a finished corpus and finishing without appending
        // anything must rewrite a byte-identical file
        let p = tmp("txt_idem");
        let mut w = CorpusWriter::create_text(&p).unwrap();
        w.push_text("one").unwrap();
        w.push_text("two-longer").unwrap();
        w.finish().unwrap();
        let before = std::fs::read(&p).unwrap();
        CorpusWriter::append(&p).unwrap().finish().unwrap();
        let after = std::fs::read(&p).unwrap();
        assert_eq!(before, after);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn append_dropped_without_finish_leaves_empty_readable_file() {
        let p = tmp("txt_drop");
        let mut w = CorpusWriter::create_text(&p).unwrap();
        w.push_text("seed-record").unwrap();
        w.finish().unwrap();
        {
            let mut w = CorpusWriter::append(&p).unwrap();
            w.push_text("lost-on-drop").unwrap();
            // dropped without finish
        }
        let bytes = std::fs::read(&p).unwrap();
        let h = Header::parse(&bytes).unwrap();
        assert_eq!(h.count, 0, "torn append must read as empty, not corrupt");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn append_rejects_unfinished_and_missing_files() {
        let p = tmp("txt_badappend");
        {
            let mut w = CorpusWriter::create_text(&p).unwrap();
            w.push_text("never finished").unwrap();
            // dropped: placeholder header has index_off = 0, which the
            // text-kind header validation rejects at reopen
        }
        assert!(CorpusWriter::append(&p).is_err());
        std::fs::remove_file(&p).ok();
        assert!(CorpusWriter::append(&p).is_err(), "missing file");
    }
}
