//! Read side of the corpus format: [`ObjectTable`], an out-of-core row
//! store whose rows are handed to the string/vector metrics lazily.
//!
//! Two storage backends sit behind one accessor API:
//!
//! - **mmap** (64-bit unix): the file is mapped read-only once and every
//!   row access is a zero-copy slice into the mapping. Residency is
//!   managed by the OS page cache, so the process heap never grows with
//!   the corpus.
//! - **pread** (portable fallback, and the backend with an *explicit*
//!   budget): rows are read in fixed row-groups through the sharded LRU
//!   [`BlockCache`], whose byte budget bounds resident corpus data no
//!   matter the access pattern.
//!
//! Open-time validation (header sanity, file-length arithmetic) makes
//! row access infallible afterwards; an I/O error or corrupt index hit
//! mid-run panics with context rather than silently degrading — the
//! solvers consume distances through [`crate::mds::divide::DeltaSource`],
//! whose `dist` has no error channel by design.

use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::cache::{BlockCache, CacheStats};
use super::format::{CorpusKind, Header, HEADER_LEN};

/// Default byte budget for the pread block cache (64 MiB): large enough
/// that landmark-sized working sets stay resident, small next to any
/// corpus worth streaming.
pub const DEFAULT_CACHE_BUDGET: usize = 64 << 20;

/// Target bytes per vector row-group block in pread mode.
const VEC_BLOCK_BYTES: usize = 256 << 10;
/// Maximum rows per text row-group block in pread mode.
const TEXT_ROWS_PER_BLOCK: usize = 1024;
/// Minimum number of row-groups a non-trivial table splits into: small
/// corpora shrink their blocks so the LRU cache still has granularity
/// to evict at (one giant block per corpus would make any byte budget
/// meaningless).
const MIN_BLOCKS: usize = 64;

/// Rows per row-group for a table of `count` rows whose natural block
/// holds `natural` rows.
fn rows_per_block(count: usize, natural: usize) -> usize {
    natural.max(1).min(count.div_ceil(MIN_BLOCKS).max(1))
}

#[cfg(all(unix, target_pointer_width = "64"))]
mod mmap {
    //! Minimal read-only mmap binding (no libc crate in the image; the
    //! symbols come from the C runtime std already links).

    use std::fs::File;
    use std::os::raw::{c_int, c_void};
    use std::os::unix::io::AsRawFd;

    use anyhow::{Context, Result};

    const PROT_READ: c_int = 1;
    const MAP_PRIVATE: c_int = 2;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    /// A whole-file read-only private mapping, unmapped on drop.
    pub struct MmapRegion {
        ptr: *const u8,
        len: usize,
    }

    // SAFETY: the mapping is read-only for its whole lifetime, so the
    // owning handle can move to another thread freely.
    unsafe impl Send for MmapRegion {}
    // SAFETY: likewise for shared references — no interior mutability,
    // every access path is a plain read of immutable pages.
    unsafe impl Sync for MmapRegion {}

    impl MmapRegion {
        /// Map `file` in its entirety (empty files map to an empty
        /// region without touching the syscall, which rejects len 0).
        pub fn map(file: &File) -> Result<MmapRegion> {
            let len = file.metadata().context("stat for mmap")?.len() as usize;
            if len == 0 {
                return Ok(MmapRegion { ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(), len: 0 });
            }
            // SAFETY: fd is valid for the duration of the call; we map
            // read-only/private so no aliasing with writers matters.
            let p = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
            };
            anyhow::ensure!(
                p as isize != -1,
                "mmap failed ({})",
                std::io::Error::last_os_error()
            );
            Ok(MmapRegion { ptr: p as *const u8, len })
        }

        /// The mapped bytes.
        pub fn bytes(&self) -> &[u8] {
            if self.len == 0 {
                return &[];
            }
            // SAFETY: ptr/len describe a live read-only mapping.
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
    }

    impl Drop for MmapRegion {
        fn drop(&mut self) {
            if self.len > 0 {
                // SAFETY: exactly the region returned by mmap.
                unsafe { munmap(self.ptr as *mut c_void, self.len) };
            }
        }
    }
}

/// Positioned read without moving the file cursor (shared `&File`).
fn read_exact_at(file: &File, buf: &mut [u8], off: u64) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        file.read_exact_at(buf, off)
    }
    #[cfg(windows)]
    {
        use std::os::windows::fs::FileExt;
        let mut done = 0usize;
        while done < buf.len() {
            let n = file.seek_read(&mut buf[done..], off + done as u64)?;
            if n == 0 {
                return Err(std::io::ErrorKind::UnexpectedEof.into());
            }
            done += n;
        }
        Ok(())
    }
    #[cfg(not(any(unix, windows)))]
    {
        let _ = (file, buf, off);
        Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "no positioned-read primitive on this platform",
        ))
    }
}

enum Storage {
    /// Zero-copy whole-file mapping.
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mmap(mmap::MmapRegion),
    /// Positioned reads of vector row-groups through the LRU cache.
    PreadVec {
        file: File,
        cache: BlockCache<f32>,
        rows_per_block: usize,
    },
    /// Positioned reads of text row-groups: payload bytes and the
    /// matching offset-index slice are cached per group.
    PreadText {
        file: File,
        payload: BlockCache<u8>,
        offsets: BlockCache<u64>,
        rows_per_block: usize,
    },
}

/// True when this build can mmap corpus files (64-bit unix).
pub fn mmap_supported() -> bool {
    cfg!(all(unix, target_pointer_width = "64"))
}

/// Typed open-time failure: the file is shorter than the layout its
/// header describes — a torn or truncated write (e.g. a crash mid-way
/// through a [`super::format::CorpusWriter`] append). Every `open*`
/// path returns it inside the [`anyhow::Error`] chain, so callers that
/// need to distinguish torn writes from other I/O failures can
/// `err.downcast_ref::<CorpusTruncated>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CorpusTruncated {
    /// The offending corpus file.
    pub path: PathBuf,
    /// Actual file length in bytes.
    pub file_len: u64,
    /// Minimum length the header's layout requires.
    pub need: u64,
}

impl std::fmt::Display for CorpusTruncated {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "corpus {:?} is truncated: {} bytes, layout needs {}",
            self.path, self.file_len, self.need
        )
    }
}

impl std::error::Error for CorpusTruncated {}

/// An open corpus file: O(1) random row access over data that never
/// fully materialises in the process heap. See the module docs for the
/// storage backends and [`super::format`] for the byte layout.
pub struct ObjectTable {
    header: Header,
    count: usize,
    dim: usize,
    storage: Storage,
}

impl ObjectTable {
    /// Open with the preferred backend: mmap where supported, otherwise
    /// pread with `cache_budget_bytes` of block cache.
    pub fn open(path: &Path, cache_budget_bytes: usize) -> Result<ObjectTable> {
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            let _ = cache_budget_bytes;
            Self::open_mmap(path)
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            Self::open_pread(path, cache_budget_bytes)
        }
    }

    /// Open through the mmap backend (zero-copy rows, OS-managed
    /// residency).
    #[cfg(all(unix, target_pointer_width = "64"))]
    pub fn open_mmap(path: &Path) -> Result<ObjectTable> {
        let file = File::open(path).with_context(|| format!("opening corpus {path:?}"))?;
        let region = mmap::MmapRegion::map(&file)
            .with_context(|| format!("mapping corpus {path:?}"))?;
        let header = Header::parse(region.bytes())
            .with_context(|| format!("reading corpus header of {path:?}"))?;
        Self::validate_len(&header, region.bytes().len() as u64, path)?;
        Ok(ObjectTable {
            count: header.count as usize,
            dim: header.dim as usize,
            header,
            storage: Storage::Mmap(region),
        })
    }

    /// Open through the pread backend with an explicit cache byte
    /// budget — the mode whose resident corpus bytes are bounded by
    /// `cache_budget_bytes` regardless of access pattern.
    pub fn open_pread(path: &Path, cache_budget_bytes: usize) -> Result<ObjectTable> {
        let file = File::open(path).with_context(|| format!("opening corpus {path:?}"))?;
        let file_len = file.metadata().context("stat corpus")?.len();
        let mut head = [0u8; HEADER_LEN as usize];
        read_exact_at(&file, &mut head, 0)
            .with_context(|| format!("reading corpus header of {path:?}"))?;
        let header = Header::parse(&head)?;
        Self::validate_len(&header, file_len, path)?;
        let count = header.count as usize;
        let storage = match header.kind {
            CorpusKind::VecF32 => {
                let row_bytes = header.dim as usize * 4;
                Storage::PreadVec {
                    file,
                    cache: BlockCache::new(cache_budget_bytes),
                    rows_per_block: rows_per_block(count, VEC_BLOCK_BYTES / row_bytes),
                }
            }
            CorpusKind::Text => Storage::PreadText {
                file,
                // ~7/8 of the budget for payload bytes, the rest for the
                // 8-byte-per-row offset slices riding alongside
                payload: BlockCache::new(cache_budget_bytes - cache_budget_bytes / 8),
                offsets: BlockCache::new((cache_budget_bytes / 8).max(1)),
                rows_per_block: rows_per_block(count, TEXT_ROWS_PER_BLOCK),
            },
        };
        Ok(ObjectTable {
            count: header.count as usize,
            dim: header.dim as usize,
            header,
            storage,
        })
    }

    fn validate_len(h: &Header, file_len: u64, path: &Path) -> Result<()> {
        let need = match h.kind {
            CorpusKind::VecF32 => h.payload_off + h.count * h.dim * 4,
            CorpusKind::Text => h.index_off + 8 * (h.count + 1),
        };
        if file_len < need {
            return Err(CorpusTruncated {
                path: path.to_path_buf(),
                file_len,
                need,
            }
            .into());
        }
        Ok(())
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.count
    }

    /// True when the table holds no records.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Record layout of this table.
    pub fn kind(&self) -> CorpusKind {
        self.header.kind
    }

    /// f32s per record (vector tables; 0 for text).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Storage backend name, for logs and reports.
    pub fn storage_name(&self) -> &'static str {
        match &self.storage {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Storage::Mmap(_) => "mmap",
            Storage::PreadVec { .. } => "pread",
            Storage::PreadText { .. } => "pread",
        }
    }

    /// Block-cache counters (`None` under mmap, which has no cache).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        match &self.storage {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Storage::Mmap(_) => None,
            Storage::PreadVec { cache, .. } => Some(cache.stats()),
            Storage::PreadText { payload, offsets, .. } => {
                let mut s = payload.stats();
                let o = offsets.stats();
                s.resident_bytes += o.resident_bytes;
                s.resident_blocks += o.resident_blocks;
                s.hits += o.hits;
                s.misses += o.misses;
                s.evictions += o.evictions;
                Some(s)
            }
        }
    }

    /// Hand row `i` of a vector table to `f` without copying out of the
    /// storage layer (mmap: a slice into the mapping; pread: a slice
    /// into the resident cache block).
    ///
    /// # Panics
    /// On a text table, an out-of-range index, or an I/O failure.
    pub fn with_vector<R>(&self, i: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        assert!(self.header.kind == CorpusKind::VecF32, "with_vector on a text table");
        assert!(i < self.count, "row {i} out of range ({} records)", self.count);
        match &self.storage {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Storage::Mmap(region) => {
                let start = self.header.payload_off as usize + i * self.dim * 4;
                let bytes = &region.bytes()[start..start + self.dim * 4];
                // SAFETY: payload_off is validated 4-aligned, the mapping
                // is page-aligned and the slice length is dim f32s inside
                // the validated payload; f32 has no invalid bit patterns.
                let row = unsafe {
                    std::slice::from_raw_parts(bytes.as_ptr() as *const f32, self.dim)
                };
                f(row)
            }
            Storage::PreadVec { file, cache, rows_per_block } => {
                let rpb = *rows_per_block;
                let g = i / rpb;
                let block = cache
                    .get_or_load(g, || self.load_vec_block(file, g, rpb))
                    .unwrap_or_else(|e| panic!("corpus read failed: {e:#}"));
                let local = (i - g * rpb) * self.dim;
                f(&block[local..local + self.dim])
            }
            Storage::PreadText { .. } => unreachable!("kind checked above"),
        }
    }

    /// Hand row `i` of a text table to `f` (zero-copy under mmap, a
    /// cache-block slice under pread).
    ///
    /// # Panics
    /// On a vector table, an out-of-range index, an I/O failure, or
    /// invalid UTF-8/offsets in the file.
    pub fn with_text<R>(&self, i: usize, f: impl FnOnce(&str) -> R) -> R {
        assert!(self.header.kind == CorpusKind::Text, "with_text on a vector table");
        assert!(i < self.count, "row {i} out of range ({} records)", self.count);
        match &self.storage {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Storage::Mmap(region) => {
                let bytes = region.bytes();
                let idx = self.header.index_off as usize;
                let off = |k: usize| {
                    u64::from_le_bytes(
                        bytes[idx + 8 * k..idx + 8 * k + 8].try_into().unwrap(),
                    ) as usize
                };
                let (start, end) = (off(i), off(i + 1));
                let payload = self.header.payload_off as usize;
                let s = std::str::from_utf8(&bytes[payload + start..payload + end])
                    .expect("corpus text record is not valid UTF-8");
                f(s)
            }
            Storage::PreadText { file, payload, offsets, rows_per_block } => {
                let rpb = *rows_per_block;
                let g = i / rpb;
                let offs = offsets
                    .get_or_load(g, || self.load_offset_block(file, g, rpb))
                    .unwrap_or_else(|e| panic!("corpus index read failed: {e:#}"));
                let block = payload
                    .get_or_load(g, || self.load_text_block(file, &offs))
                    .unwrap_or_else(|e| panic!("corpus read failed: {e:#}"));
                let local = i - g * rpb;
                let base = offs[0] as usize;
                let (start, end) = (offs[local] as usize, offs[local + 1] as usize);
                let s = std::str::from_utf8(&block[start - base..end - base])
                    .expect("corpus text record is not valid UTF-8");
                f(s)
            }
            Storage::PreadVec { .. } => unreachable!("kind checked above"),
        }
    }

    /// Copy row `i` of a vector table out as an owned vector.
    pub fn vector_row(&self, i: usize) -> Vec<f32> {
        self.with_vector(i, |r| r.to_vec())
    }

    /// Copy row `i` of a text table out as an owned string.
    pub fn text_row(&self, i: usize) -> String {
        self.with_text(i, str::to_owned)
    }

    /// Materialise the given rows of a vector table (e.g. the landmark
    /// sample, or one streaming chunk).
    pub fn vector_rows(&self, idx: &[usize]) -> Vec<Vec<f32>> {
        idx.iter().map(|&i| self.vector_row(i)).collect()
    }

    /// Materialise the given rows of a text table.
    pub fn text_rows(&self, idx: &[usize]) -> Vec<String> {
        idx.iter().map(|&i| self.text_row(i)).collect()
    }

    fn load_vec_block(
        &self,
        file: &File,
        g: usize,
        rows_per_block: usize,
    ) -> std::io::Result<Arc<[f32]>> {
        let first = g * rows_per_block;
        let rows = rows_per_block.min(self.count - first);
        let mut bytes = vec![0u8; rows * self.dim * 4];
        read_exact_at(
            file,
            &mut bytes,
            self.header.payload_off + (first * self.dim * 4) as u64,
        )?;
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(floats.into())
    }

    fn load_offset_block(
        &self,
        file: &File,
        g: usize,
        rows_per_block: usize,
    ) -> std::io::Result<Arc<[u64]>> {
        let first = g * rows_per_block;
        let rows = rows_per_block.min(self.count - first);
        let mut bytes = vec![0u8; (rows + 1) * 8];
        read_exact_at(file, &mut bytes, self.header.index_off + (first * 8) as u64)?;
        let offs: Vec<u64> = bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        for w in offs.windows(2) {
            if w[1] < w[0] {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "corpus offset index is not monotonic",
                ));
            }
        }
        Ok(offs.into())
    }

    fn load_text_block(&self, file: &File, offs: &[u64]) -> std::io::Result<Arc<[u8]>> {
        let base = offs[0];
        let end = offs[offs.len() - 1];
        let mut bytes = vec![0u8; (end - base) as usize];
        read_exact_at(file, &mut bytes, self.header.payload_off + base)?;
        Ok(bytes.into())
    }
}

#[cfg(test)]
mod tests {
    use super::super::format::CorpusWriter;
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lmds_tbl_{name}_{}", std::process::id()));
        p
    }

    fn write_vec_corpus(path: &Path, n: usize, dim: usize) -> Vec<Vec<f32>> {
        let mut w = CorpusWriter::create_vectors(path, dim).unwrap();
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..dim).map(|d| (i * dim + d) as f32 * 0.5 - 3.0).collect())
            .collect();
        for r in &rows {
            w.push_vector(r).unwrap();
        }
        w.finish().unwrap();
        rows
    }

    fn write_text_corpus(path: &Path, n: usize) -> Vec<String> {
        let mut w = CorpusWriter::create_text(path).unwrap();
        let rows: Vec<String> = (0..n)
            .map(|i| format!("record-{i}-{}", "x".repeat(i % 17)))
            .collect();
        for r in &rows {
            w.push_text(r).unwrap();
        }
        w.finish().unwrap();
        rows
    }

    fn open_both(path: &Path, budget: usize) -> Vec<ObjectTable> {
        let mut v = vec![ObjectTable::open_pread(path, budget).unwrap()];
        #[cfg(all(unix, target_pointer_width = "64"))]
        v.push(ObjectTable::open_mmap(path).unwrap());
        v
    }

    #[test]
    fn vector_rows_round_trip_on_all_backends() {
        let p = tmp("vec_rt");
        let rows = write_vec_corpus(&p, 137, 5);
        for t in open_both(&p, 1 << 20) {
            assert_eq!(t.len(), 137);
            assert_eq!(t.dim(), 5);
            assert_eq!(t.kind(), CorpusKind::VecF32);
            for (i, want) in rows.iter().enumerate() {
                assert_eq!(&t.vector_row(i), want, "row {i} via {}", t.storage_name());
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn text_rows_round_trip_on_all_backends() {
        let p = tmp("txt_rt");
        let rows = write_text_corpus(&p, 211);
        for t in open_both(&p, 1 << 20) {
            assert_eq!(t.len(), 211);
            assert_eq!(t.kind(), CorpusKind::Text);
            for (i, want) in rows.iter().enumerate() {
                assert_eq!(&t.text_row(i), want, "row {i} via {}", t.storage_name());
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn tiny_cache_budget_still_reads_correctly() {
        let p = tmp("vec_tiny");
        let rows = write_vec_corpus(&p, 500, 3);
        // budget far below the payload: every stride forces eviction
        let t = ObjectTable::open_pread(&p, 64).unwrap();
        for i in (0..500).rev().step_by(7) {
            assert_eq!(t.vector_row(i), rows[i]);
        }
        let s = t.cache_stats().expect("pread has a cache");
        assert!(s.evictions > 0, "tiny budget must evict ({s:?})");
        assert!(s.resident_blocks >= 1);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn repeated_scans_hit_the_cache() {
        let p = tmp("txt_hits");
        write_text_corpus(&p, 300);
        let t = ObjectTable::open_pread(&p, 1 << 20).unwrap();
        for i in 0..300 {
            t.with_text(i, |_| ());
        }
        let first = t.cache_stats().unwrap();
        assert!(first.misses > 0, "{first:?}");
        // the corpus fits the budget, so a second scan is all hits
        for i in 0..300 {
            t.with_text(i, |_| ());
        }
        let second = t.cache_stats().unwrap();
        assert_eq!(second.misses, first.misses, "second scan must not re-read");
        assert_eq!(second.hits, first.hits + 2 * 300, "{second:?}");
        assert_eq!(second.evictions, 0, "{second:?}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_file_rejected_at_open() {
        let p = tmp("trunc");
        write_vec_corpus(&p, 50, 4);
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 10]).unwrap();
        assert!(ObjectTable::open_pread(&p, 1 << 20).is_err());
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(ObjectTable::open_mmap(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn torn_write_surfaces_typed_error_on_all_backends() {
        // a tail record torn off a text corpus (crash mid-write) must be
        // detected at open with the typed CorpusTruncated error — not a
        // panic, and not a generic string error
        let p = tmp("torn");
        write_text_corpus(&p, 40);
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 6]).unwrap();
        let mut errs = vec![ObjectTable::open_pread(&p, 1 << 20).unwrap_err()];
        #[cfg(all(unix, target_pointer_width = "64"))]
        errs.push(ObjectTable::open_mmap(&p).unwrap_err());
        for e in errs {
            let t = e
                .downcast_ref::<CorpusTruncated>()
                .expect("torn write must yield CorpusTruncated");
            assert_eq!(t.path, p);
            assert_eq!(t.file_len, full.len() as u64 - 6);
            assert_eq!(t.need, full.len() as u64);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_table_is_valid() {
        let p = tmp("empty");
        CorpusWriter::create_text(&p).unwrap().finish().unwrap();
        for t in open_both(&p, 1 << 10) {
            assert!(t.is_empty());
            assert_eq!(t.len(), 0);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    #[should_panic(expected = "with_vector on a text table")]
    fn kind_mismatch_panics() {
        let p = tmp("kindmm");
        write_text_corpus(&p, 3);
        let t = ObjectTable::open_pread(&p, 1 << 10).unwrap();
        let _ = std::fs::remove_file(&p);
        t.with_vector(0, |_| ());
    }

    #[test]
    fn concurrent_readers_agree() {
        let p = tmp("conc");
        let rows = write_vec_corpus(&p, 400, 4);
        let t = ObjectTable::open_pread(&p, 4 << 10);
        let t = t.unwrap();
        std::thread::scope(|scope| {
            for k in 0..4usize {
                let (t, rows) = (&t, &rows);
                scope.spawn(move || {
                    for i in (k..400).step_by(4) {
                        assert_eq!(t.vector_row(i), rows[i]);
                    }
                });
            }
        });
        std::fs::remove_file(&p).ok();
    }
}
