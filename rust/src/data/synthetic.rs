//! Synthetic metric-space datasets: Gaussian mixtures for generic DR tests
//! and a noisy sensor-network scenario (the paper's motivating application
//! [1]: map sensors from pairwise distances, then localise new targets).

use crate::util::prng::Rng;

/// Points drawn from `clusters` spherical Gaussians in R^dim.
pub fn gaussian_clusters(
    rng: &mut Rng,
    n: usize,
    dim: usize,
    clusters: usize,
    spread: f64,
) -> Vec<Vec<f32>> {
    assert!(clusters > 0 && dim > 0);
    let centers: Vec<Vec<f64>> = (0..clusters)
        .map(|_| (0..dim).map(|_| rng.next_normal() * 5.0).collect())
        .collect();
    (0..n)
        .map(|i| {
            let c = &centers[i % clusters];
            c.iter()
                .map(|&m| (m + rng.next_normal() * spread) as f32)
                .collect()
        })
        .collect()
}

/// A grid of sensors in the unit square with jitter, in row-major order.
/// Returns 2-D ground-truth positions.
pub fn sensor_grid(rng: &mut Rng, side: usize, jitter: f64) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(side * side);
    for i in 0..side {
        for j in 0..side {
            let x = (i as f64 + 0.5) / side as f64 + rng.next_normal() * jitter;
            let y = (j as f64 + 0.5) / side as f64 + rng.next_normal() * jitter;
            out.push(vec![x as f32, y as f32]);
        }
    }
    out
}

/// Noisy range measurement between two positions: multiplicative log-normal
/// noise, the standard ranging model in sensor-localisation work.
pub fn noisy_range(rng: &mut Rng, a: &[f32], b: &[f32], noise: f64) -> f64 {
    let d = crate::strdist::euclidean(a, b);
    d * (rng.next_normal() * noise).exp()
}

/// Swiss-roll-like curve embedded in 3-D (a classic non-linear manifold for
/// DR sanity checks): returns points and their 1-D manifold parameter.
pub fn swiss_roll(rng: &mut Rng, n: usize, noise: f64) -> (Vec<Vec<f32>>, Vec<f64>) {
    let mut pts = Vec::with_capacity(n);
    let mut ts = Vec::with_capacity(n);
    for _ in 0..n {
        let t = 1.5 * std::f64::consts::PI * (1.0 + 2.0 * rng.next_f64());
        let h = rng.next_f64() * 10.0;
        let x = t * t.cos() + rng.next_normal() * noise;
        let y = h + rng.next_normal() * noise;
        let z = t * t.sin() + rng.next_normal() * noise;
        pts.push(vec![x as f32, y as f32, z as f32]);
        ts.push(t);
    }
    (pts, ts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strdist::euclidean;

    #[test]
    fn clusters_have_expected_shape() {
        let mut rng = Rng::new(1);
        let pts = gaussian_clusters(&mut rng, 120, 4, 3, 0.5);
        assert_eq!(pts.len(), 120);
        assert!(pts.iter().all(|p| p.len() == 4));
        // same-cluster points should on average be closer than cross-cluster
        let same = euclidean(&pts[0], &pts[3]); // both cluster 0
        let cross = euclidean(&pts[0], &pts[1]); // clusters 0 vs 1
        // statistical, but with 5-sigma-separated centers it's near-certain
        assert!(same < cross * 3.0);
    }

    #[test]
    fn sensor_grid_covers_unit_square() {
        let mut rng = Rng::new(2);
        let pts = sensor_grid(&mut rng, 8, 0.0);
        assert_eq!(pts.len(), 64);
        for p in &pts {
            assert!((0.0..=1.0).contains(&p[0]) && (0.0..=1.0).contains(&p[1]));
        }
        // distinct cells are distinct points when jitter = 0
        assert!(euclidean(&pts[0], &pts[1]) > 0.0);
    }

    #[test]
    fn noisy_range_unbiased_in_log() {
        let mut rng = Rng::new(3);
        let a = [0.0f32, 0.0];
        let b = [1.0f32, 0.0];
        let mut sum_log = 0.0;
        let n = 20_000;
        for _ in 0..n {
            sum_log += noisy_range(&mut rng, &a, &b, 0.1).ln();
        }
        assert!((sum_log / n as f64).abs() < 0.01);
    }

    #[test]
    fn swiss_roll_parameter_orders_arclength() {
        let mut rng = Rng::new(4);
        let (pts, ts) = swiss_roll(&mut rng, 200, 0.0);
        assert_eq!(pts.len(), ts.len());
        assert!(ts.iter().all(|t| *t >= 1.5 * std::f64::consts::PI - 1e-9));
    }
}
