//! Built-in name corpora for the Geco-style generator.
//!
//! The paper generates entity names with the Geco tool from FEBRL
//! (Christen & Vatsalan, CIKM'13), which samples given/surnames from
//! frequency tables. We embed compact frequency-weighted tables (top
//! Anglo-Australian names, matching FEBRL's shipped lookup files in spirit)
//! so data generation needs no external files. Frequencies are Zipf-like
//! ranks, not exact census counts — only the *distance distribution between
//! name strings* matters for MDS behaviour.

/// (name, relative frequency weight)
pub const GIVEN_NAMES: &[(&str, f64)] = &[
    ("james", 100.0), ("john", 97.0), ("robert", 95.0), ("michael", 93.0),
    ("william", 90.0), ("david", 88.0), ("richard", 80.0), ("joseph", 78.0),
    ("thomas", 76.0), ("charles", 74.0), ("christopher", 72.0), ("daniel", 70.0),
    ("matthew", 68.0), ("anthony", 66.0), ("mark", 64.0), ("donald", 62.0),
    ("steven", 60.0), ("paul", 58.0), ("andrew", 56.0), ("joshua", 54.0),
    ("kenneth", 52.0), ("kevin", 50.0), ("brian", 49.0), ("george", 48.0),
    ("timothy", 47.0), ("ronald", 46.0), ("edward", 45.0), ("jason", 44.0),
    ("jeffrey", 43.0), ("ryan", 42.0), ("jacob", 41.0), ("gary", 40.0),
    ("nicholas", 39.0), ("eric", 38.0), ("jonathan", 37.0), ("stephen", 36.0),
    ("larry", 35.0), ("justin", 34.0), ("scott", 33.0), ("brandon", 32.0),
    ("benjamin", 31.0), ("samuel", 30.0), ("gregory", 29.0), ("alexander", 28.0),
    ("patrick", 27.0), ("frank", 26.0), ("raymond", 25.0), ("jack", 24.0),
    ("dennis", 23.0), ("jerry", 22.0), ("tyler", 21.0), ("aaron", 20.0),
    ("mary", 100.0), ("patricia", 96.0), ("jennifer", 94.0), ("linda", 92.0),
    ("elizabeth", 90.0), ("barbara", 88.0), ("susan", 84.0), ("jessica", 82.0),
    ("sarah", 80.0), ("karen", 78.0), ("lisa", 76.0), ("nancy", 74.0),
    ("betty", 72.0), ("margaret", 70.0), ("sandra", 68.0), ("ashley", 66.0),
    ("kimberly", 64.0), ("emily", 62.0), ("donna", 60.0), ("michelle", 58.0),
    ("carol", 56.0), ("amanda", 54.0), ("dorothy", 52.0), ("melissa", 50.0),
    ("deborah", 48.0), ("stephanie", 46.0), ("rebecca", 44.0), ("sharon", 42.0),
    ("laura", 40.0), ("cynthia", 38.0), ("kathleen", 36.0), ("amy", 34.0),
    ("angela", 32.0), ("shirley", 30.0), ("anna", 28.0), ("brenda", 26.0),
    ("pamela", 24.0), ("emma", 22.0), ("nicole", 20.0), ("helen", 18.0),
    ("samantha", 16.0), ("katherine", 14.0), ("christine", 12.0), ("debra", 10.0),
    ("rachel", 9.0), ("carolyn", 8.0), ("janet", 7.0), ("catherine", 6.0),
    ("maria", 5.0), ("heather", 4.0), ("diane", 3.0), ("ruth", 2.0),
];

/// (surname, relative frequency weight)
pub const SURNAMES: &[(&str, f64)] = &[
    ("smith", 100.0), ("jones", 95.0), ("williams", 92.0), ("brown", 90.0),
    ("wilson", 88.0), ("taylor", 86.0), ("johnson", 82.0), ("white", 80.0),
    ("martin", 78.0), ("anderson", 76.0), ("thompson", 74.0), ("nguyen", 72.0),
    ("thomas", 70.0), ("walker", 68.0), ("harris", 66.0), ("lee", 64.0),
    ("ryan", 62.0), ("robinson", 60.0), ("kelly", 58.0), ("king", 56.0),
    ("davis", 54.0), ("wright", 52.0), ("evans", 50.0), ("roberts", 48.0),
    ("green", 46.0), ("hall", 44.0), ("wood", 42.0), ("jackson", 40.0),
    ("clarke", 38.0), ("patel", 36.0), ("khan", 34.0), ("lewis", 32.0),
    ("james", 30.0), ("phillips", 29.0), ("mason", 28.0), ("mitchell", 27.0),
    ("rose", 26.0), ("davies", 25.0), ("rodriguez", 24.0), ("cox", 23.0),
    ("alexander", 22.0), ("garden", 21.0), ("campbell", 20.0), ("johnston", 19.0),
    ("moore", 18.0), ("smyth", 17.0), ("oneill", 16.0), ("doyle", 15.0),
    ("mcdonald", 14.0), ("stewart", 13.0), ("quinn", 12.0), ("murphy", 11.0),
    ("graham", 10.0), ("mclean", 9.5), ("hernandez", 9.0), ("fernandez", 8.5),
    ("lopez", 8.0), ("gonzalez", 7.5), ("perez", 7.0), ("sanchez", 6.5),
    ("ramirez", 6.0), ("torres", 5.5), ("flores", 5.0), ("rivera", 4.5),
    ("gomez", 4.0), ("diaz", 3.5), ("reyes", 3.0), ("morales", 2.8),
    ("cruz", 2.6), ("ortiz", 2.4), ("gutierrez", 2.2), ("chavez", 2.0),
    ("ramos", 1.9), ("gonzales", 1.8), ("ruiz", 1.7), ("alvarez", 1.6),
    ("mendoza", 1.5), ("vasquez", 1.4), ("castillo", 1.3), ("jimenez", 1.2),
    ("moreno", 1.1), ("romero", 1.0), ("herrera", 0.9), ("medina", 0.8),
    ("aguilar", 0.7), ("garza", 0.6), ("castro", 0.5), ("vargas", 0.4),
];

/// Keyboard-adjacency table for realistic typographic substitutions
/// (FEBRL's `qwerty` corruption model).
pub fn keyboard_neighbours(c: char) -> &'static str {
    match c {
        'a' => "qwsz", 'b' => "vghn", 'c' => "xdfv", 'd' => "serfcx",
        'e' => "wsdr", 'f' => "drtgvc", 'g' => "ftyhbv", 'h' => "gyujnb",
        'i' => "ujko", 'j' => "huikmn", 'k' => "jiolm", 'l' => "kop",
        'm' => "njk", 'n' => "bhjm", 'o' => "iklp", 'p' => "ol",
        'q' => "wa", 'r' => "edft", 's' => "awedxz", 't' => "rfgy",
        'u' => "yhji", 'v' => "cfgb", 'w' => "qase", 'x' => "zsdc",
        'y' => "tghu", 'z' => "asx",
        _ => "",
    }
}

/// OCR confusion pairs (FEBRL's `ocr` corruption model, abridged).
pub const OCR_CONFUSIONS: &[(&str, &str)] = &[
    ("m", "rn"), ("rn", "m"), ("cl", "d"), ("d", "cl"), ("w", "vv"),
    ("l", "1"), ("1", "l"), ("o", "0"), ("0", "o"), ("s", "5"), ("5", "s"),
    ("b", "6"), ("g", "9"), ("i", "l"), ("e", "c"), ("c", "e"), ("u", "v"),
    ("v", "u"), ("nn", "m"), ("ri", "n"),
];

/// Phonetic substitution rules (FEBRL's `phonetic` model, abridged):
/// (pattern, replacement).
pub const PHONETIC_RULES: &[(&str, &str)] = &[
    ("ph", "f"), ("f", "ph"), ("ck", "k"), ("k", "ck"), ("wr", "r"),
    ("gh", "g"), ("ee", "ea"), ("ea", "ee"), ("ie", "y"), ("y", "ie"),
    ("mb", "m"), ("dg", "g"), ("tio", "sho"), ("ough", "off"), ("qu", "kw"),
    ("x", "ks"), ("z", "s"), ("s", "z"), ("ai", "ay"), ("ay", "ai"),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpora_nonempty_and_weighted() {
        assert!(GIVEN_NAMES.len() >= 100);
        assert!(SURNAMES.len() >= 80);
        assert!(GIVEN_NAMES.iter().all(|(n, w)| !n.is_empty() && *w > 0.0));
        assert!(SURNAMES.iter().all(|(n, w)| !n.is_empty() && *w > 0.0));
    }

    #[test]
    fn names_are_lowercase_ascii() {
        for (n, _) in GIVEN_NAMES.iter().chain(SURNAMES.iter()) {
            assert!(n.chars().all(|c| c.is_ascii_lowercase()), "{n}");
        }
    }

    #[test]
    fn keyboard_neighbours_are_symmetric_enough() {
        // spot-check symmetry for a few canonical pairs
        assert!(keyboard_neighbours('a').contains('s'));
        assert!(keyboard_neighbours('s').contains('a'));
        assert!(keyboard_neighbours('q').contains('w'));
        assert!(keyboard_neighbours('w').contains('q'));
        assert_eq!(keyboard_neighbours('é'), "");
    }

    #[test]
    fn rules_have_nonempty_sides() {
        for (a, b) in OCR_CONFUSIONS.iter().chain(PHONETIC_RULES.iter()) {
            assert!(!a.is_empty() && !b.is_empty());
        }
    }
}
