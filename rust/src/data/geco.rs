//! Geco/FEBRL-style synthetic entity-name generation (paper Sec. 5.1).
//!
//! The paper's datasets are "entity name strings … generated using the Geco
//! tool in FEBRL", with controllable size, duplicate rate, and error
//! characteristics. This module reproduces that behaviour: frequency-
//! weighted sampling of `given-name surname` pairs, plus FEBRL's corruption
//! operator families (keyboard typos, OCR confusions, phonetic respellings,
//! character edits) for generating duplicate records with errors.
//!
//! DESIGN.md §Substitutions records why this stands in for the original
//! tool: MDS only consumes the pairwise distance distribution of the
//! strings, which this generator matches in kind (realistic name lengths,
//! shared prefixes/suffixes, Zipf-weighted repetition of components).

use std::collections::HashSet;

use crate::util::prng::Rng;

use super::corpora;

/// Corruption operator families, mirroring FEBRL's corruptor classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Corruption {
    /// Substitute a character with a keyboard neighbour.
    KeyboardSub,
    /// Insert a keyboard-neighbour character.
    Insert,
    /// Delete a character.
    Delete,
    /// Transpose two adjacent characters.
    Transpose,
    /// Apply an OCR confusion (e.g. "m" -> "rn").
    Ocr,
    /// Apply a phonetic respelling (e.g. "ph" -> "f").
    Phonetic,
}

/// Every corruption model, for uniform sampling.
pub const ALL_CORRUPTIONS: &[Corruption] = &[
    Corruption::KeyboardSub,
    Corruption::Insert,
    Corruption::Delete,
    Corruption::Transpose,
    Corruption::Ocr,
    Corruption::Phonetic,
];

/// Generator configuration (mirrors the Geco CLI knobs we need).
#[derive(Clone, Debug)]
pub struct GecoConfig {
    /// PRNG seed.
    pub seed: u64,
    /// Probability that a generated record is a corrupted duplicate of an
    /// earlier record (0.0 = all unique entities, the paper's main setting).
    pub duplicate_rate: f64,
    /// Number of corruption operations applied to each duplicate.
    pub corruptions_per_duplicate: usize,
    /// Enabled corruption families.
    pub corruptions: Vec<Corruption>,
}

impl Default for GecoConfig {
    fn default() -> Self {
        Self {
            seed: 0x9ec0,
            duplicate_rate: 0.0,
            corruptions_per_duplicate: 2,
            corruptions: ALL_CORRUPTIONS.to_vec(),
        }
    }
}

/// A generated record: the name string plus provenance for evaluation.
#[derive(Clone, Debug)]
pub struct Record {
    /// The (possibly corrupted) generated name.
    pub name: String,
    /// Index of the original record this is a duplicate of (None = original).
    pub duplicate_of: Option<usize>,
}

/// Geco/FEBRL-style generator of weighted name samples with optional
/// corrupted duplicates (paper Sec. 5.1).
pub struct Geco {
    cfg: GecoConfig,
    rng: Rng,
    given_weights: Vec<f64>,
    surname_weights: Vec<f64>,
}

impl Geco {
    /// Generator over the built-in corpora with the given settings.
    pub fn new(cfg: GecoConfig) -> Self {
        let rng = Rng::new(cfg.seed);
        Self {
            given_weights: corpora::GIVEN_NAMES.iter().map(|(_, w)| *w).collect(),
            surname_weights: corpora::SURNAMES.iter().map(|(_, w)| *w).collect(),
            cfg,
            rng,
        }
    }

    /// Sample one clean `given surname` string.
    pub fn sample_name(&mut self) -> String {
        let g = corpora::GIVEN_NAMES[self.rng.weighted_index(&self.given_weights)].0;
        let s = corpora::SURNAMES[self.rng.weighted_index(&self.surname_weights)].0;
        format!("{g} {s}")
    }

    /// Generate `n` records. With `duplicate_rate == 0` all records are
    /// *unique* entity names (the paper's setting: "We will be mainly using
    /// unique entity names").
    pub fn generate(&mut self, n: usize) -> Vec<Record> {
        let mut out: Vec<Record> = Vec::with_capacity(n);
        let mut seen: HashSet<String> = HashSet::with_capacity(n);
        let mut attempts = 0usize;
        while out.len() < n {
            attempts += 1;
            let make_dup = !out.is_empty()
                && self.rng.next_f64() < self.cfg.duplicate_rate;
            if make_dup {
                let src = self.rng.index(out.len());
                let mut name = out[src].name.clone();
                for _ in 0..self.cfg.corruptions_per_duplicate {
                    name = self.corrupt(&name);
                }
                out.push(Record { name, duplicate_of: Some(src) });
            } else {
                let name = self.sample_name();
                // uniqueness matters only for originals; a bounded number of
                // retries keeps generation total even for large n (the name
                // space is ~ 10^4; beyond that we disambiguate numerically,
                // like Geco's record-id suffixing)
                if seen.contains(&name) && attempts < n * 20 {
                    continue;
                }
                let name = if seen.contains(&name) {
                    format!("{name} {}", out.len())
                } else {
                    name
                };
                seen.insert(name.clone());
                out.push(Record { name, duplicate_of: None });
            }
        }
        out
    }

    /// Stream `n` records through `sink` without materialising them —
    /// the corpus-writer-facing equivalent of [`Geco::generate`] for
    /// datasets that must never sit in memory whole.
    ///
    /// Uniqueness state spans the entire run (unlike calling
    /// [`Geco::generate`] in batches, which would restart its seen-set
    /// every batch and re-emit the same ~10^4 clean combinations):
    /// originals are de-duplicated against the set of *base* names ever
    /// emitted — bounded by the corpus name space, not by `n`, since
    /// numerically disambiguated names are unique by construction — and
    /// duplicates corrupt one of the most recent 1024 originals
    /// (`duplicate_of` carries that original's global record index), so
    /// memory stays O(name space + pool) for any `n`. A `sink` error
    /// aborts the stream.
    pub fn generate_with<E>(
        &mut self,
        n: usize,
        mut sink: impl FnMut(Record) -> Result<(), E>,
    ) -> Result<(), E> {
        const DUP_POOL: usize = 1024;
        let mut seen: HashSet<String> = HashSet::new();
        let mut pool: std::collections::VecDeque<(usize, String)> =
            std::collections::VecDeque::with_capacity(DUP_POOL);
        let mut emitted = 0usize;
        let mut attempts = 0usize;
        while emitted < n {
            attempts += 1;
            let make_dup = !pool.is_empty()
                && self.rng.next_f64() < self.cfg.duplicate_rate;
            let record = if make_dup {
                let (src, base) = &pool[self.rng.index(pool.len())];
                let mut name = base.clone();
                for _ in 0..self.cfg.corruptions_per_duplicate {
                    name = self.corrupt(&name);
                }
                Record { name, duplicate_of: Some(*src) }
            } else {
                let name = self.sample_name();
                // same retry budget as `generate`: bounded retries keep
                // generation total; past the budget, disambiguate with
                // the global record index (Geco's record-id suffixing)
                if seen.contains(&name) && attempts < n.saturating_mul(20) {
                    continue;
                }
                let name = if seen.contains(&name) {
                    format!("{name} {emitted}")
                } else {
                    seen.insert(name.clone());
                    name
                };
                if pool.len() == DUP_POOL {
                    pool.pop_front();
                }
                pool.push_back((emitted, name.clone()));
                Record { name, duplicate_of: None }
            };
            sink(record)?;
            emitted += 1;
        }
        Ok(())
    }

    /// Convenience: `n` unique clean names only.
    pub fn generate_unique(&mut self, n: usize) -> Vec<String> {
        let saved = self.cfg.duplicate_rate;
        self.cfg.duplicate_rate = 0.0;
        let recs = self.generate(n);
        self.cfg.duplicate_rate = saved;
        recs.into_iter().map(|r| r.name).collect()
    }

    /// Apply one randomly chosen corruption operation.
    pub fn corrupt(&mut self, s: &str) -> String {
        let op = *self
            .cfg
            .corruptions
            .get(self.rng.index(self.cfg.corruptions.len().max(1)))
            .unwrap_or(&Corruption::KeyboardSub);
        self.apply(op, s)
    }

    fn apply(&mut self, op: Corruption, s: &str) -> String {
        let chars: Vec<char> = s.chars().collect();
        match op {
            Corruption::KeyboardSub => {
                // pick a letter position with non-empty neighbours
                let idxs: Vec<usize> = (0..chars.len())
                    .filter(|&i| !corpora::keyboard_neighbours(chars[i]).is_empty())
                    .collect();
                if idxs.is_empty() {
                    return s.to_string();
                }
                let i = idxs[self.rng.index(idxs.len())];
                let nbrs: Vec<char> =
                    corpora::keyboard_neighbours(chars[i]).chars().collect();
                let mut out = chars.clone();
                out[i] = nbrs[self.rng.index(nbrs.len())];
                out.into_iter().collect()
            }
            Corruption::Insert => {
                let i = self.rng.index(chars.len() + 1);
                let c = (b'a' + self.rng.index(26) as u8) as char;
                let mut out = chars.clone();
                out.insert(i, c);
                out.into_iter().collect()
            }
            Corruption::Delete => {
                if chars.len() <= 1 {
                    return s.to_string();
                }
                let i = self.rng.index(chars.len());
                let mut out = chars.clone();
                out.remove(i);
                out.into_iter().collect()
            }
            Corruption::Transpose => {
                if chars.len() < 2 {
                    return s.to_string();
                }
                let i = self.rng.index(chars.len() - 1);
                let mut out = chars.clone();
                out.swap(i, i + 1);
                out.into_iter().collect()
            }
            Corruption::Ocr => self.rule_sub(s, corpora::OCR_CONFUSIONS),
            Corruption::Phonetic => self.rule_sub(s, corpora::PHONETIC_RULES),
        }
    }

    /// Apply one applicable (pattern -> replacement) rule at a random
    /// occurrence; identity if no rule matches.
    fn rule_sub(&mut self, s: &str, rules: &[(&str, &str)]) -> String {
        let applicable: Vec<&(&str, &str)> =
            rules.iter().filter(|(p, _)| s.contains(p)).collect();
        if applicable.is_empty() {
            return s.to_string();
        }
        let (pat, rep) = *applicable[self.rng.index(applicable.len())];
        // choose a random occurrence
        let positions: Vec<usize> = s
            .match_indices(pat)
            .map(|(i, _)| i)
            .collect();
        let pos = positions[self.rng.index(positions.len())];
        let mut out = String::with_capacity(s.len());
        out.push_str(&s[..pos]);
        out.push_str(rep);
        out.push_str(&s[pos + pat.len()..]);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strdist::levenshtein;
    use crate::util::quickcheck::{prop_assert, property};

    #[test]
    fn deterministic_for_seed() {
        let mut a = Geco::new(GecoConfig { seed: 1, ..Default::default() });
        let mut b = Geco::new(GecoConfig { seed: 1, ..Default::default() });
        assert_eq!(a.generate_unique(50), b.generate_unique(50));
    }

    #[test]
    fn generate_with_streams_globally_unique_originals() {
        // far beyond any batch size a batched caller would use: the
        // streaming generator must keep its uniqueness state for the
        // whole run, not per chunk
        let mut g = Geco::new(GecoConfig { seed: 9, ..Default::default() });
        let mut names = Vec::new();
        g.generate_with(20_000, |r| {
            assert!(r.duplicate_of.is_none(), "rate 0 means no duplicates");
            names.push(r.name);
            Ok::<_, ()>(())
        })
        .unwrap();
        let set: HashSet<&String> = names.iter().collect();
        assert_eq!(set.len(), names.len(), "cross-batch duplicates leaked");
    }

    #[test]
    fn generate_with_duplicates_reference_recent_originals() {
        let mut g = Geco::new(GecoConfig {
            seed: 10,
            duplicate_rate: 0.3,
            ..Default::default()
        });
        let mut records = Vec::new();
        g.generate_with(500, |r| {
            records.push(r);
            Ok::<_, ()>(())
        })
        .unwrap();
        let dups = records.iter().filter(|r| r.duplicate_of.is_some()).count();
        assert!(dups > 50, "expected duplicates at rate 0.3, got {dups}");
        for (i, r) in records.iter().enumerate() {
            if let Some(src) = r.duplicate_of {
                assert!(src < i, "duplicate must reference an earlier record");
                assert!(
                    records[src].duplicate_of.is_none(),
                    "duplicates corrupt originals, not other duplicates"
                );
            }
        }
    }

    #[test]
    fn generate_with_sink_error_aborts() {
        let mut g = Geco::new(GecoConfig::default());
        let mut calls = 0usize;
        let r = g.generate_with(100, |_| {
            calls += 1;
            if calls == 3 {
                Err("stop")
            } else {
                Ok(())
            }
        });
        assert_eq!(r.unwrap_err(), "stop");
        assert_eq!(calls, 3);
    }

    #[test]
    fn unique_generation_has_no_duplicates() {
        let mut g = Geco::new(GecoConfig::default());
        let names = g.generate_unique(2000);
        let set: HashSet<&String> = names.iter().collect();
        assert_eq!(set.len(), names.len());
        assert!(names.iter().all(|n| n.contains(' ')));
    }

    #[test]
    fn duplicate_rate_produces_duplicates() {
        let mut g = Geco::new(GecoConfig {
            seed: 3,
            duplicate_rate: 0.4,
            ..Default::default()
        });
        let recs = g.generate(500);
        let dups = recs.iter().filter(|r| r.duplicate_of.is_some()).count();
        assert!((100..300).contains(&dups), "dups = {dups}");
        // a duplicate should be close (in edit distance) to its source
        for r in recs.iter().filter(|r| r.duplicate_of.is_some()).take(50) {
            let src = &recs[r.duplicate_of.unwrap()].name;
            let d = levenshtein(&r.name, src);
            assert!(d <= 2 * 4, "{src:?} -> {:?} (d={d})", r.name);
        }
    }

    #[test]
    fn corruptions_change_little() {
        property("corruption is a small edit", 200, |g| {
            let seed = g.u64();
            let mut geco = Geco::new(GecoConfig { seed, ..Default::default() });
            let name = geco.sample_name();
            let corrupted = geco.corrupt(&name);
            let d = levenshtein(&name, &corrupted);
            // every operator family changes at most ~4 code points
            prop_assert(d <= 4, &format!("{name:?} -> {corrupted:?} d={d}"))
        });
    }

    #[test]
    fn each_operator_applies() {
        let mut geco = Geco::new(GecoConfig { seed: 9, ..Default::default() });
        for op in ALL_CORRUPTIONS {
            // find some input it actually changes
            let mut changed = false;
            for _ in 0..50 {
                let name = geco.sample_name();
                if geco.apply(*op, &name) != name {
                    changed = true;
                    break;
                }
            }
            assert!(changed, "{op:?} never fired");
        }
    }

    #[test]
    fn name_lengths_realistic() {
        let mut g = Geco::new(GecoConfig::default());
        let names = g.generate_unique(1000);
        let lens: Vec<usize> = names.iter().map(|n| n.chars().count()).collect();
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        assert!((8.0..20.0).contains(&mean), "mean len {mean}");
        assert!(lens.iter().all(|&l| l < 64), "Myers fast path holds");
    }
}
