//! Figure harnesses: one function per figure/table of the paper's Sec. 5,
//! each printing the same rows/series the paper reports and writing a JSON
//! record under `results/`. Everything runs through the compute backend
//! (native by default, PJRT artifacts with `--features pjrt`).
//!
//! | paper artifact | function    | what it reports                        |
//! |----------------|-------------|----------------------------------------|
//! | Figure 1       | `fig1`      | Err(m) vs L, both OSE methods          |
//! | Figures 2 & 3  | `fig23`     | per-point PErr pairs + distributions   |
//! | Figure 4       | `fig4`      | mean RT of mapping one point vs L      |
//! | Sec. 5.3.3     | `headline`  | NN/opt speed ratio, train time, <1 ms  |

use anyhow::Result;

use crate::coordinator::methods::{BackendNn, BackendOpt};
use crate::coordinator::trainer::{train_backend, TrainConfig, TrainReport};
use crate::mds::stress::{point_error_normalized, total_error};
use crate::mds::Matrix;
use crate::nn::MlpShape;
use crate::ose::OseMethod;
use crate::runtime::{Backend, ComputeBackend};
use crate::util::bench::{bench, fmt_duration, BenchConfig};
use crate::util::json::Json;
use crate::util::stats::{mean, median, percentiles, Histogram};

use super::protocol::{results_dir, ExperimentData};

/// Hidden sizes used at each scale (must match shapes.py for PJRT use).
fn hidden_for(data: &ExperimentData) -> [usize; 3] {
    match data.scale {
        super::Scale::Smoke => [32, 16, 8],
        _ => [256, 128, 64],
    }
}

/// Train the NN head for a landmark set through the backend.
pub fn train_nn(
    data: &ExperimentData,
    landmark_idx: &[usize],
    backend: &Backend,
    epochs: usize,
) -> Result<(crate::nn::MlpParams, TrainReport)> {
    let l = landmark_idx.len();
    let shape = MlpShape { input: l, hidden: hidden_for(data), output: data.dim };
    let inputs = data.train_inputs(landmark_idx);
    let labels = &data.config_ref;
    let cfg = TrainConfig {
        epochs,
        lr: 3e-3, // tuned: Keras-default 1e-3 underfits in this epoch budget
        rel_tol: 1e-5,
        patience: 12,
        seed: 0x42 ^ l as u64,
    };
    train_backend(backend, &shape, &inputs, labels, 256, &cfg)
}

/// Map the held-out points with the NN method. Returns (coords, method).
pub fn run_nn(
    data: &ExperimentData,
    landmark_idx: &[usize],
    backend: &Backend,
    epochs: usize,
) -> Result<(Matrix, Box<dyn OseMethod>, TrainReport)> {
    let (params, report) = train_nn(data, landmark_idx, backend, epochs)?;
    let mut method: Box<dyn OseMethod> =
        Box::new(BackendNn::new(backend.clone(), params));
    let queries = data.query_inputs(landmark_idx);
    let y = method.embed(&queries)?;
    Ok((y, method, report))
}

/// Map the held-out points with the optimisation method.
pub fn run_opt(
    data: &ExperimentData,
    landmark_idx: &[usize],
    backend: &Backend,
) -> Result<(Matrix, Box<dyn OseMethod>)> {
    let lm_config = data.landmark_config(landmark_idx);
    let mut method: Box<dyn OseMethod> =
        Box::new(BackendOpt::with_defaults(backend.clone(), lm_config));
    let queries = data.query_inputs(landmark_idx);
    let y = method.embed(&queries)?;
    Ok((y, method))
}

// ---------------------------------------------------------------------------
// Figure 1: Err(m) vs L
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
/// One Figure-1 point: total embedding error vs landmark count.
pub struct Fig1Row {
    /// Landmark count L.
    pub l: usize,
    /// Err(m) (Eq. 5) of the optimisation method.
    pub err_opt: f64,
    /// Err(m) (Eq. 5) of the NN method.
    pub err_nn: f64,
}

/// Reproduce Figure 1: Err(m) as a function of L for both OSE
/// methods. Writes `fig1_<scale>.json` into the results directory.
pub fn fig1(
    data: &ExperimentData,
    backend: &Backend,
    epochs: usize,
) -> Result<Vec<Fig1Row>> {
    let mut rows = Vec::new();
    println!("# Figure 1 — total error Err(m) vs number of landmarks L");
    println!("# scale={} N={} m={} K={} (ref stress {:.4})",
             data.scale.name(), data.names_ref.len(), data.names_new.len(),
             data.dim, data.ref_stress);
    println!("{:>6} {:>14} {:>14} {:>10}", "L", "Err_opt(m)", "Err_nn(m)", "nn/opt");
    for l in data.scale.sweep() {
        let lm = data.landmarks(l);
        let (y_opt, _) = run_opt(data, &lm, backend)?;
        let (y_nn, _, _) = run_nn(data, &lm, backend, epochs)?;
        let err_opt = total_error(&data.config_ref, &data.delta_new, &y_opt);
        let err_nn = total_error(&data.config_ref, &data.delta_new, &y_nn);
        println!(
            "{l:>6} {err_opt:>14.4} {err_nn:>14.4} {:>10.3}",
            err_nn / err_opt
        );
        rows.push(Fig1Row { l, err_opt, err_nn });
    }
    let json = Json::obj(vec![
        ("figure", Json::Str("fig1".into())),
        ("scale", Json::Str(data.scale.name().into())),
        ("backend", Json::Str(backend.name().into())),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("L", Json::Num(r.l as f64)),
                            ("err_opt", Json::Num(r.err_opt)),
                            ("err_nn", Json::Num(r.err_nn)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(
        results_dir().join(format!("fig1_{}.json", data.scale.name())),
        json.to_string_pretty(),
    )?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Figures 2 & 3: per-point errors and their distributions
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
/// Per-point error distributions behind Figures 2-3, at one L.
pub struct Fig23Result {
    /// Landmark count L.
    pub l: usize,
    /// normalised PErr per out-of-sample point, optimisation method
    pub perr_opt: Vec<f64>,
    /// normalised PErr per out-of-sample point, NN method
    pub perr_nn: Vec<f64>,
}

/// Reproduce Figures 2-3: per-point normalised PErr scatter/CDF data
/// at the scale's contrast pair of landmark counts. Writes
/// `fig23_<scale>.json`.
pub fn fig23(
    data: &ExperimentData,
    backend: &Backend,
    epochs: usize,
) -> Result<Vec<Fig23Result>> {
    let (lo, hi) = data.scale.contrast_pair();
    let mut out = Vec::new();
    println!("# Figures 2-3 — per-point errors PErr(y), L in {{{lo}, {hi}}}");
    for l in [lo, hi] {
        let lm = data.landmarks(l);
        let (y_opt, _) = run_opt(data, &lm, backend)?;
        let (y_nn, _, _) = run_nn(data, &lm, backend, epochs)?;
        let m = data.names_new.len();
        let mut perr_opt = Vec::with_capacity(m);
        let mut perr_nn = Vec::with_capacity(m);
        for j in 0..m {
            perr_opt.push(point_error_normalized(
                &data.config_ref,
                data.delta_new.row(j),
                y_opt.row(j),
            ));
            perr_nn.push(point_error_normalized(
                &data.config_ref,
                data.delta_new.row(j),
                y_nn.row(j),
            ));
        }
        let below = perr_nn
            .iter()
            .zip(perr_opt.iter())
            .filter(|(nn, opt)| nn < opt)
            .count();
        println!("\n## L = {l}");
        println!(
            "  opt: median {:.4}  p95 {:.4}  max {:.4}",
            median(&perr_opt),
            percentiles(&perr_opt).1,
            perr_opt.iter().cloned().fold(0.0, f64::max)
        );
        println!(
            "  nn : median {:.4}  p95 {:.4}  max {:.4}",
            median(&perr_nn),
            percentiles(&perr_nn).1,
            perr_nn.iter().cloned().fold(0.0, f64::max)
        );
        println!(
            "  NN better on {below}/{m} points ({:.0}%)",
            100.0 * below as f64 / m as f64
        );
        let max_all = perr_opt
            .iter()
            .chain(perr_nn.iter())
            .cloned()
            .fold(0.0, f64::max)
            .max(1e-9);
        let mut h_opt = Histogram::new(0.0, max_all, 40);
        let mut h_nn = Histogram::new(0.0, max_all, 40);
        perr_opt.iter().for_each(|&x| h_opt.push(x));
        perr_nn.iter().for_each(|&x| h_nn.push(x));
        println!("  opt dist [0,{max_all:.3}]: {}", h_opt.render(40));
        println!("  nn  dist [0,{max_all:.3}]: {}", h_nn.render(40));
        out.push(Fig23Result { l, perr_opt, perr_nn });
    }
    let json = Json::obj(vec![
        ("figure", Json::Str("fig2_fig3".into())),
        ("scale", Json::Str(data.scale.name().into())),
        ("backend", Json::Str(backend.name().into())),
        (
            "results",
            Json::Arr(
                out.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("L", Json::Num(r.l as f64)),
                            ("perr_opt", Json::arr_f64(&r.perr_opt)),
                            ("perr_nn", Json::arr_f64(&r.perr_nn)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(
        results_dir().join(format!("fig23_{}.json", data.scale.name())),
        json.to_string_pretty(),
    )?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Figure 4: average RT of mapping a single point vs L
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
/// One Figure-4 point: single-point mapping runtime vs landmark count.
pub struct Fig4Row {
    /// Landmark count L.
    pub l: usize,
    /// seconds per single-point mapping
    pub rt_opt: f64,
    /// Seconds per single-point mapping, NN method.
    pub rt_nn: f64,
}

/// Bench the single-point mapping RT of one method (the paper's protocol:
/// both methods map a single out-of-sample point at a time).
fn bench_single_point(
    name: &str,
    cfg: &BenchConfig,
    method: &mut dyn OseMethod,
    queries: &Matrix,
) -> f64 {
    let m = queries.rows;
    let l = queries.cols;
    let mut j = 0usize;
    bench(name, cfg, || {
        let row = Matrix::from_vec(1, l, queries.row(j % m).to_vec());
        j += 1;
        method.embed(&row).unwrap()
    })
    .median_s
}

/// Reproduce Figure 4: serving-time per point vs L for both OSE
/// methods. Writes `fig4_<scale>.json`.
pub fn fig4(
    data: &ExperimentData,
    backend: &Backend,
    epochs: usize,
) -> Result<Vec<Fig4Row>> {
    let cfg = BenchConfig {
        warmup: std::time::Duration::from_millis(50),
        measure: std::time::Duration::from_millis(400),
        max_iters: 2000,
        min_iters: 5,
    };
    let mut rows = Vec::new();
    println!("# Figure 4 — mean RT of mapping ONE out-of-sample point vs L");
    println!("{:>6} {:>14} {:>14} {:>12}", "L", "RT_opt", "RT_nn", "opt/nn");
    for l in data.scale.sweep() {
        let lm = data.landmarks(l);
        let queries = data.query_inputs(&lm);
        let lm_config = data.landmark_config(&lm);

        let mut opt = BackendOpt::with_defaults(backend.clone(), lm_config);
        let rt_opt = bench_single_point(
            &format!("opt-{} L={l}", backend.name()),
            &cfg,
            &mut opt,
            &queries,
        );

        // NN method (training amortised, as in the paper's protocol)
        let (params, _) = train_nn(data, &lm, backend, epochs)?;
        let mut nn = BackendNn::new(backend.clone(), params);
        let rt_nn = bench_single_point(
            &format!("nn-{} L={l}", backend.name()),
            &cfg,
            &mut nn,
            &queries,
        );

        println!(
            "{l:>6} {:>14} {:>14} {:>12.1}x",
            fmt_duration(rt_opt),
            fmt_duration(rt_nn),
            rt_opt / rt_nn
        );
        rows.push(Fig4Row { l, rt_opt, rt_nn });
    }
    let json = Json::obj(vec![
        ("figure", Json::Str("fig4".into())),
        ("scale", Json::Str(data.scale.name().into())),
        ("backend", Json::Str(backend.name().into())),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("L", Json::Num(r.l as f64)),
                            ("rt_opt_s", Json::Num(r.rt_opt)),
                            ("rt_nn_s", Json::Num(r.rt_nn)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(
        results_dir().join(format!("fig4_{}.json", data.scale.name())),
        json.to_string_pretty(),
    )?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Headline numbers (Sec. 5.3.3 / Sec. 6)
// ---------------------------------------------------------------------------

/// Reproduce the headline numbers of Sec. 5.3.3 / Sec. 6 (quality and
/// runtime of both methods at the scale's largest L).
pub fn headline(
    data: &ExperimentData,
    backend: &Backend,
    epochs: usize,
) -> Result<()> {
    // pick the two largest mid-sweep L values (the paper quotes L=1000,1500)
    let sweep = data.scale.sweep();
    let pick: Vec<usize> = sweep.iter().rev().take(2).rev().copied().collect();
    println!("# Headline (paper Sec. 5.3.3): NN vs optimisation at L = {pick:?}");
    let mut ratios = Vec::new();
    for &l in &pick {
        let rows = fig4_single(data, backend, epochs, l)?;
        ratios.push(rows.rt_opt / rows.rt_nn);
        println!(
            "  L={l}: opt {} / nn {} -> ratio {:.0}x  (nn < 1ms: {})",
            fmt_duration(rows.rt_opt),
            fmt_duration(rows.rt_nn),
            rows.rt_opt / rows.rt_nn,
            rows.rt_nn < 1e-3
        );
    }
    // training cost (the paper quotes ~1.2 s)
    let lm = data.landmarks(pick[0]);
    let t0 = std::time::Instant::now();
    let (_, report) = train_nn(data, &lm, backend, epochs)?;
    println!(
        "  NN training at L={}: {:.2}s wall ({} epochs, loss {:.4}) [paper: ~1.2s]",
        pick[0],
        t0.elapsed().as_secs_f64(),
        report.epochs_run,
        report.final_loss
    );
    println!(
        "  mean speed ratio opt/nn: {:.0}x [paper: 3.8e3 vs R optim]",
        mean(&ratios)
    );
    Ok(())
}

fn fig4_single(
    data: &ExperimentData,
    backend: &Backend,
    epochs: usize,
    l: usize,
) -> Result<Fig4Row> {
    let cfg = BenchConfig {
        warmup: std::time::Duration::from_millis(50),
        measure: std::time::Duration::from_millis(300),
        max_iters: 1000,
        min_iters: 5,
    };
    let lm = data.landmarks(l);
    let queries = data.query_inputs(&lm);
    let lm_config = data.landmark_config(&lm);
    let mut opt = BackendOpt::with_defaults(backend.clone(), lm_config);
    let rt_opt = bench_single_point("opt", &cfg, &mut opt, &queries);
    let (params, _) = train_nn(data, &lm, backend, epochs)?;
    let mut nn = BackendNn::new(backend.clone(), params);
    let rt_nn = bench_single_point("nn", &cfg, &mut nn, &queries);
    Ok(Fig4Row { l, rt_opt, rt_nn })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::protocol::{load_or_build, Scale};

    #[test]
    fn fig1_smoke_shapes_hold() {
        let backend = Backend::native();
        let data = load_or_build(Scale::Smoke, 3, &backend).unwrap();
        let rows = fig1(&data, &backend, 15).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.err_opt.is_finite() && r.err_opt >= 0.0);
            assert!(r.err_nn.is_finite() && r.err_nn >= 0.0);
        }
        // more landmarks must help the optimisation method
        assert!(
            rows[1].err_opt <= rows[0].err_opt * 1.2,
            "opt error should not grow with L: {rows:?}"
        );
    }

    #[test]
    fn fig23_smoke_produces_per_point_errors() {
        let backend = Backend::native();
        let data = load_or_build(Scale::Smoke, 3, &backend).unwrap();
        let res = fig23(&data, &backend, 15).unwrap();
        assert_eq!(res.len(), 2);
        for r in &res {
            assert_eq!(r.perr_opt.len(), 16);
            assert_eq!(r.perr_nn.len(), 16);
            assert!(r.perr_opt.iter().all(|x| x.is_finite() && *x >= 0.0));
        }
    }
}
