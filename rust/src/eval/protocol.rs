//! The paper's experiment protocol (Sec. 5.3): N reference name strings are
//! embedded with full LSMDS into K = 7 dimensions; m held-out names are the
//! out-of-sample points; landmarks are FPS-selected among the references;
//! both OSE methods map the held-out points using only distances to the
//! landmarks, and are scored with Err(m) / PErr(y) against ALL references.
//!
//! Two scales: `paper` (N = 5000, m = 500, L in [100, 2100]) and `small`
//! (N = 1200, m = 200, L in [50, 1000]) for quick CI runs. The reference
//! configuration is cached under `results/` because full LSMDS is the one
//! genuinely expensive step.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::coordinator::embedder::{solve_base, BaseSolver};
use crate::data::{Geco, GecoConfig};
use crate::mds::dissimilarity::{cross_matrix, full_matrix};
use crate::mds::landmarks::fps_landmarks;
use crate::mds::{LsmdsConfig, Matrix};
use crate::runtime::{Backend, ComputeBackend};
use crate::strdist::Levenshtein;
use crate::util::json::Json;
use crate::util::prng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Experiment scale: how much data the figure reproductions run on.
pub enum Scale {
    /// Seconds-fast sanity scale for CI.
    Smoke,
    /// Minutes-scale default for local runs.
    Small,
    /// The paper's full N (slow; figures-grade).
    Paper,
}

impl Scale {
    /// Parse a scale name (smoke|small|paper).
    pub fn from_name(s: &str) -> Option<Scale> {
        match s {
            "smoke" => Some(Scale::Smoke),
            "small" => Some(Scale::Small),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }

    /// (N reference points, m out-of-sample points)
    pub fn sizes(self) -> (usize, usize) {
        match self {
            Scale::Smoke => (64, 16),
            Scale::Small => (1200, 200),
            Scale::Paper => (5000, 500),
        }
    }

    /// Landmark sweep for Figures 1 and 4 (must match shapes.py so PJRT
    /// artifacts exist for every point of the sweep).
    pub fn sweep(self) -> Vec<usize> {
        match self {
            Scale::Smoke => vec![16, 32],
            Scale::Small => vec![50, 100, 200, 300, 400, 600, 800, 1000],
            Scale::Paper => {
                vec![100, 300, 500, 700, 900, 1100, 1300, 1500, 1800, 2100]
            }
        }
    }

    /// The (low, high) L pair for Figures 2-3.
    pub fn contrast_pair(self) -> (usize, usize) {
        match self {
            Scale::Smoke => (16, 32),
            Scale::Small => (100, 800),
            Scale::Paper => (100, 1500),
        }
    }

    /// Canonical name (for file names and logs).
    pub fn name(self) -> &'static str {
        match self {
            Scale::Smoke => "smoke",
            Scale::Small => "small",
            Scale::Paper => "paper",
        }
    }

    /// LSMDS iteration budget appropriate to the scale.
    pub fn lsmds_iters(self) -> usize {
        match self {
            Scale::Smoke => 60,
            Scale::Small => 250,
            Scale::Paper => 250,
        }
    }
}

/// Everything the figure harnesses consume.
pub struct ExperimentData {
    /// Scale this data set was built for.
    pub scale: Scale,
    /// Reference sample (landmark pool).
    pub names_ref: Vec<String>,
    /// Out-of-sample query set.
    pub names_new: Vec<String>,
    /// N x N reference dissimilarities (Levenshtein).
    pub delta_ref: Matrix,
    /// N x K reference configuration (full LSMDS).
    pub config_ref: Matrix,
    /// m x N dissimilarities from each new point to each reference.
    pub delta_new: Matrix,
    /// Normalised stress of the reference configuration.
    pub ref_stress: f64,
    /// Embedding dimension K of the reference solve.
    pub dim: usize,
}

impl ExperimentData {
    /// FPS landmark indices (into the references) for a given L —
    /// deterministic per (scale, L) so both methods share landmarks, as in
    /// the paper.
    pub fn landmarks(&self, l: usize) -> Vec<usize> {
        let mut rng = Rng::new(0xFA5 ^ (l as u64) << 8 ^ self.scale.sizes().0 as u64);
        let objs: Vec<&str> = self.names_ref.iter().map(|s| s.as_str()).collect();
        fps_landmarks(&mut rng, &objs, l, &Levenshtein)
    }

    /// N x L training inputs for the NN (distances of every reference to
    /// the landmarks — column selection of delta_ref).
    pub fn train_inputs(&self, landmark_idx: &[usize]) -> Matrix {
        let n = self.delta_ref.rows;
        let mut out = Matrix::zeros(n, landmark_idx.len());
        for r in 0..n {
            let row = self.delta_ref.row(r);
            for (c, &li) in landmark_idx.iter().enumerate() {
                out.set(r, c, row[li]);
            }
        }
        out
    }

    /// m x L query rows (distances of the new points to the landmarks —
    /// column selection of delta_new).
    pub fn query_inputs(&self, landmark_idx: &[usize]) -> Matrix {
        let m = self.delta_new.rows;
        let mut out = Matrix::zeros(m, landmark_idx.len());
        for r in 0..m {
            let row = self.delta_new.row(r);
            for (c, &li) in landmark_idx.iter().enumerate() {
                out.set(r, c, row[li]);
            }
        }
        out
    }

    /// L x K landmark coordinates in the reference configuration.
    pub fn landmark_config(&self, landmark_idx: &[usize]) -> Matrix {
        self.config_ref.select_rows(landmark_idx)
    }
}

/// Directory figure JSON/SVG outputs are written to
/// (`$LMDS_RESULTS` or `<repo>/results`).
pub fn results_dir() -> PathBuf {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Build (or load from cache) the experiment dataset for a scale.
pub fn load_or_build(
    scale: Scale,
    dim: usize,
    backend: &Backend,
) -> Result<ExperimentData> {
    let (n, m) = scale.sizes();
    let mut geco = Geco::new(GecoConfig { seed: 0x9ec0 + n as u64, ..Default::default() });
    let all = geco.generate_unique(n + m);
    let names_ref = all[..n].to_vec();
    let names_new = all[n..].to_vec();

    let objs_ref: Vec<&str> = names_ref.iter().map(|s| s.as_str()).collect();
    let objs_new: Vec<&str> = names_new.iter().map(|s| s.as_str()).collect();

    log::info!("{}: building {n}x{n} reference dissimilarities", scale.name());
    let t0 = std::time::Instant::now();
    let delta_ref = full_matrix(&objs_ref, &Levenshtein);
    log::info!("delta_ref built in {:.2}s", t0.elapsed().as_secs_f64());

    // reference configuration: cached across invocations
    let cache = results_dir().join(format!("refconfig_{}_{dim}.json", scale.name()));
    let config_ref: Matrix = match load_cached_config(&cache, n, dim) {
        Some(cfg) => {
            log::info!("loaded cached reference configuration from {cache:?}");
            cfg
        }
        None => {
            log::info!("running full LSMDS on {n} references (K={dim})");
            let t0 = std::time::Instant::now();
            let lcfg = LsmdsConfig {
                dim,
                max_iters: scale.lsmds_iters(),
                seed: 0x5eed,
                ..Default::default()
            };
            // Above ~2000 points the interpret-mode Pallas artifact (grid
            // loops become sequential XLA while-iterations on CPU) loses to
            // the native row-parallel Rust gradient; see EXPERIMENTS.md
            // SSPerf. On real TPU hardware the artifact path wins — the
            // cutover is a CPU-testbed artifact.
            let native;
            let solve = if n > 2000 && backend.name() == "pjrt" {
                native = Backend::native();
                &native
            } else {
                backend
            };
            // The reference solve is the one O(N^2)-per-iteration step of
            // the protocol; LMDS_BASE_SOLVER=divide swaps in the
            // partitioned parallel solver (coordinator::embedder::
            // solve_base) for it, with the default divide shape.
            let solver = match std::env::var("LMDS_BASE_SOLVER").ok().as_deref() {
                None | Some("") => BaseSolver::Monolithic,
                Some(name) => BaseSolver::from_name(name, 8, 0)
                    .with_context(|| format!("LMDS_BASE_SOLVER={name}"))?,
            };
            let (cfg, stress) = solve_base(&delta_ref, &lcfg, solver, solve)?;
            log::info!(
                "LSMDS done in {:.1}s (normalized stress {:.4})",
                t0.elapsed().as_secs_f64(),
                stress
            );
            save_cached_config(&cache, &cfg)?;
            cfg
        }
    };
    let ref_stress = crate::mds::stress::normalized_stress(&config_ref, &delta_ref);

    log::info!("building {m}x{n} out-of-sample dissimilarities");
    let delta_new = cross_matrix(&objs_new, &objs_ref, &Levenshtein);

    Ok(ExperimentData {
        scale,
        names_ref,
        names_new,
        delta_ref,
        config_ref,
        delta_new,
        ref_stress,
        dim,
    })
}

fn load_cached_config(path: &PathBuf, n: usize, k: usize) -> Option<Matrix> {
    let text = std::fs::read_to_string(path).ok()?;
    let json = Json::parse(&text).ok()?;
    let rows = json.get("rows")?.as_usize()?;
    let cols = json.get("cols")?.as_usize()?;
    if rows != n || cols != k {
        return None;
    }
    let data: Option<Vec<f32>> = json
        .get("data")?
        .as_arr()?
        .iter()
        .map(|v| v.as_f64().map(|x| x as f32))
        .collect();
    Some(Matrix::from_vec(rows, cols, data?))
}

fn save_cached_config(path: &PathBuf, cfg: &Matrix) -> Result<()> {
    let json = Json::obj(vec![
        ("rows", Json::Num(cfg.rows as f64)),
        ("cols", Json::Num(cfg.cols as f64)),
        (
            "data",
            Json::Arr(cfg.data.iter().map(|x| Json::Num(*x as f64)).collect()),
        ),
    ]);
    std::fs::write(path, json.to_string()).context("writing config cache")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_scale_builds_quickly() {
        let data = load_or_build(Scale::Smoke, 3, &Backend::native()).unwrap();
        assert_eq!(data.names_ref.len(), 64);
        assert_eq!(data.names_new.len(), 16);
        assert_eq!(data.delta_ref.rows, 64);
        assert_eq!(data.config_ref.cols, 3);
        assert_eq!(data.delta_new.rows, 16);
        assert!(data.ref_stress.is_finite());
        // landmark helpers are consistent
        let lm = data.landmarks(16);
        assert_eq!(lm.len(), 16);
        let ti = data.train_inputs(&lm);
        assert_eq!((ti.rows, ti.cols), (64, 16));
        let qi = data.query_inputs(&lm);
        assert_eq!((qi.rows, qi.cols), (16, 16));
        let lc = data.landmark_config(&lm);
        assert_eq!((lc.rows, lc.cols), (16, 3));
        // train inputs really are the delta columns
        assert_eq!(ti.at(3, 2), data.delta_ref.at(3, lm[2]));
    }

    #[test]
    fn landmark_selection_deterministic() {
        let data = load_or_build(Scale::Smoke, 3, &Backend::native()).unwrap();
        assert_eq!(data.landmarks(16), data.landmarks(16));
    }

    #[test]
    fn scale_tables() {
        assert_eq!(Scale::Paper.sizes(), (5000, 500));
        assert_eq!(Scale::Small.sweep().len(), 8);
        assert_eq!(Scale::from_name("paper"), Some(Scale::Paper));
        assert_eq!(Scale::from_name("bogus"), None);
        let (lo, hi) = Scale::Paper.contrast_pair();
        assert!(lo < hi);
    }
}
