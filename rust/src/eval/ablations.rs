//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! - `landmark_methods`: random vs FPS vs maxmin-pool selection (paper
//!   Sec. 4 recommends random for speed, FPS for reproducibility — we
//!   quantify the accuracy side).
//! - `ose_baselines`: the paper's two methods vs prior work (I-MDS kNN
//!   interpolation; Trosset-Priebe classical OSE) on the same data.
//! - `step_size`: majorization lr = 1/(2L) vs smaller/larger fixed steps
//!   (why the artifact defaults to the majorization step).
//! - `nn_hidden`: MLP capacity sweep.
//!
//! Each prints a table and appends a JSON record under `results/`.

use anyhow::Result;

use crate::mds::landmarks::{fps_landmarks, maxmin_pool_landmarks, random_landmarks};
use crate::mds::stress::total_error;
use crate::mds::Matrix;
use crate::nn::MlpShape;
use crate::ose::{ClassicalOse, Imds, ImdsConfig, OseMethod, OseOptConfig, RustNn};
use crate::runtime::Backend;
use crate::strdist::Levenshtein;
use crate::util::json::Json;
use crate::util::prng::Rng;

use super::figures::{run_nn, run_opt};
use super::protocol::{results_dir, ExperimentData};

/// Landmark-selection ablation: Err(m) of the optimisation OSE under the
/// three selection strategies at a fixed L.
pub fn landmark_methods(
    data: &ExperimentData,
    backend: &Backend,
    l: usize,
) -> Result<Vec<(String, f64)>> {
    println!("# Ablation — landmark selection at L = {l}");
    let objs: Vec<&str> = data.names_ref.iter().map(|s| s.as_str()).collect();
    let mut rows = Vec::new();
    for method in ["random", "fps", "maxmin-pool"] {
        let mut rng = Rng::new(0xAB1 ^ l as u64);
        let idx = match method {
            "random" => random_landmarks(&mut rng, objs.len(), l),
            "fps" => fps_landmarks(&mut rng, &objs, l, &Levenshtein),
            _ => maxmin_pool_landmarks(&mut rng, &objs, l, 4, &Levenshtein),
        };
        let (y, _) = run_opt_with_idx(data, &idx, backend)?;
        let err = total_error(&data.config_ref, &data.delta_new, &y);
        println!("  {method:<12} Err(m) = {err:>12.2}");
        rows.push((method.to_string(), err));
    }
    write_json("ablation_landmarks", data, &rows);
    Ok(rows)
}

fn run_opt_with_idx(
    data: &ExperimentData,
    idx: &[usize],
    backend: &Backend,
) -> Result<(Matrix, Box<dyn OseMethod>)> {
    run_opt(data, idx, backend)
}

/// OSE-method shootout: paper's two methods vs I-MDS vs Trosset-Priebe.
pub fn ose_baselines(
    data: &ExperimentData,
    backend: &Backend,
    l: usize,
    epochs: usize,
) -> Result<Vec<(String, f64, f64)>> {
    println!("# Ablation — OSE methods at L = {l} (err, seconds-per-point)");
    let lm = data.landmarks(l);
    let lm_config = data.landmark_config(&lm);
    let queries = data.query_inputs(&lm);
    let m = queries.rows as f64;
    let mut rows: Vec<(String, f64, f64)> = Vec::new();

    // paper: optimisation method
    let t0 = std::time::Instant::now();
    let (y_opt, _) = run_opt(data, &lm, backend)?;
    rows.push((
        "opt (paper 4.1)".into(),
        total_error(&data.config_ref, &data.delta_new, &y_opt),
        t0.elapsed().as_secs_f64() / m,
    ));

    // paper: NN method (training excluded from per-point cost, as amortised)
    let (y_nn, _, _) = run_nn(data, &lm, backend, epochs)?;
    let t0 = std::time::Instant::now();
    let _ = run_nn_inference_only(data, &lm, backend, epochs);
    let nn_rt = t0.elapsed().as_secs_f64() / m;
    rows.push((
        "nn (paper 4.2)".into(),
        total_error(&data.config_ref, &data.delta_new, &y_nn),
        nn_rt,
    ));

    // I-MDS kNN interpolation (Bae et al.)
    for k in [5usize, 20] {
        let mut imds = Imds {
            landmarks: lm_config.clone(),
            cfg: ImdsConfig { k, opt: OseOptConfig::default() },
        };
        let t0 = std::time::Instant::now();
        let y = imds.embed(&queries)?;
        rows.push((
            format!("imds k={k}"),
            total_error(&data.config_ref, &data.delta_new, &y),
            t0.elapsed().as_secs_f64() / m,
        ));
    }

    // Trosset-Priebe classical OSE: uses distances to ALL N configured
    // points (the O(N) cost the paper criticises) over the LSMDS config
    let mut tp = ClassicalOse::new(data.config_ref.clone(), &data.delta_ref);
    let t0 = std::time::Instant::now();
    let y = tp.embed(&data.delta_new)?;
    rows.push((
        "trosset-priebe (O(N)/query)".into(),
        total_error(&data.config_ref, &data.delta_new, &y),
        t0.elapsed().as_secs_f64() / m,
    ));

    for (name, err, rt) in &rows {
        println!("  {name:<28} Err(m) {err:>12.2}   {:.3} ms/pt", rt * 1e3);
    }
    let json_rows: Vec<(String, f64)> = rows.iter().map(|(n, e, _)| (n.clone(), *e)).collect();
    write_json("ablation_ose_baselines", data, &json_rows);
    Ok(rows)
}

fn run_nn_inference_only(
    data: &ExperimentData,
    lm: &[usize],
    backend: &Backend,
    _epochs: usize,
) -> Result<()> {
    // cheap stand-in: single batched embed through the backend MLP to time
    // the pure inference path without retraining
    let mut rng = Rng::new(1);
    let params = crate::nn::MlpParams::init(
        &MlpShape { input: lm.len(), hidden: [256, 128, 64], output: data.dim },
        &mut rng,
    );
    let mut m = crate::coordinator::BackendNn::new(backend.clone(), params);
    let _ = m.embed(&data.query_inputs(lm))?;
    Ok(())
}

/// Step-size ablation: final Eq.-2 objective after a fixed step budget.
pub fn step_size(data: &ExperimentData, l: usize) -> Result<Vec<(f64, f64)>> {
    println!("# Ablation — OSE step size at L = {l} (120-step budget)");
    let lm_idx = data.landmarks(l);
    let lm = data.landmark_config(&lm_idx);
    let queries = data.query_inputs(&lm_idx);
    let major = 1.0 / (2.0 * l as f64);
    let mut rows = Vec::new();
    for scale in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let lr = major * scale;
        let mut total = 0.0f64;
        let mut diverged = 0usize;
        for r in 0..queries.rows {
            let mut y = vec![0.0f32; lm.cols];
            for _ in 0..120 {
                let (_, g) =
                    crate::ose::optimise::objective_and_grad(&lm, queries.row(r), &y);
                for c in 0..lm.cols {
                    y[c] -= (lr * g[c]) as f32;
                }
            }
            let (obj, _) =
                crate::ose::optimise::objective_and_grad(&lm, queries.row(r), &y);
            if obj.is_finite() {
                total += obj;
            } else {
                diverged += 1;
            }
        }
        println!(
            "  lr = {scale:>5.2} x 1/(2L): mean objective {:>12.3}  (diverged {diverged})",
            total / queries.rows as f64
        );
        rows.push((scale, total / queries.rows as f64));
    }
    write_json(
        "ablation_step_size",
        data,
        &rows.iter().map(|(s, o)| (format!("{s}x"), *o)).collect::<Vec<_>>(),
    );
    Ok(rows)
}

/// Hidden-size ablation for the NN head.
pub fn nn_hidden(data: &ExperimentData, l: usize, epochs: usize) -> Result<()> {
    println!("# Ablation — NN hidden sizes at L = {l}");
    let lm = data.landmarks(l);
    let inputs = data.train_inputs(&lm);
    let labels = &data.config_ref;
    let queries = data.query_inputs(&lm);
    for hidden in [[32, 16, 8], [64, 32, 16], [128, 64, 32], [256, 128, 64]] {
        let shape = MlpShape { input: l, hidden, output: data.dim };
        let (params, report) = crate::coordinator::trainer::train_rust(
            &shape,
            &inputs,
            labels,
            256,
            &crate::coordinator::TrainConfig {
                epochs,
                lr: 3e-3,
                ..Default::default()
            },
        );
        let mut m = RustNn { params };
        let y = m.embed(&queries)?;
        let err = total_error(&data.config_ref, &data.delta_new, &y);
        println!(
            "  hidden {hidden:?}: Err(m) {err:>12.2}  (loss {:.4}, {} epochs, {:.1}s)",
            report.final_loss, report.epochs_run, report.wall_s
        );
    }
    Ok(())
}

fn write_json(name: &str, data: &ExperimentData, rows: &[(String, f64)]) {
    let json = Json::obj(vec![
        ("ablation", Json::Str(name.into())),
        ("scale", Json::Str(data.scale.name().into())),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|(k, v)| {
                        Json::obj(vec![
                            ("name", Json::Str(k.clone())),
                            ("value", Json::Num(*v)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let _ = std::fs::write(
        results_dir().join(format!("{name}_{}.json", data.scale.name())),
        json.to_string_pretty(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::protocol::{load_or_build, Scale};

    #[test]
    fn step_size_identifies_majorization_as_stable() {
        let data = load_or_build(Scale::Smoke, 3, &Backend::native()).unwrap();
        let rows = step_size(&data, 16).unwrap();
        // all candidate steps <= 2x majorization must stay finite, and the
        // majorization step must be at least as good as the 4x step
        let get = |s: f64| rows.iter().find(|(x, _)| *x == s).unwrap().1;
        assert!(get(1.0).is_finite());
        assert!(get(0.25).is_finite());
        assert!(get(1.0) <= get(0.25) * 1.5, "slow step should not win big");
    }

    #[test]
    fn ose_baselines_rank_sanely_on_smoke() {
        let backend = Backend::native();
        let data = load_or_build(Scale::Smoke, 3, &backend).unwrap();
        let rows = ose_baselines(&data, &backend, 16, 20).unwrap();
        let err_of = |name: &str| {
            rows.iter()
                .find(|(n, _, _)| n.starts_with(name))
                .map(|(_, e, _)| *e)
                .unwrap()
        };
        // full-information optimisation must beat the k=5 interpolation
        assert!(err_of("opt") <= err_of("imds k=5") * 1.05);
        // every method stays finite
        assert!(rows.iter().all(|(_, e, _)| e.is_finite()));
    }
}
