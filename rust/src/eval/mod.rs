//! Experiment harness: regenerates every figure of the paper's evaluation
//! (Sec. 5) and the headline numbers of Sec. 5.3.3.

pub mod ablations;
pub mod figures;
pub mod protocol;

pub use protocol::{ExperimentData, Scale};
