//! String and vector dissimilarities (paper Sec. 2.2) — the Rust equivalent
//! of the R `stringdist` package the authors used, plus Minkowski metrics.
//!
//! Everything implements [`Dissimilarity`], the single interface the MDS and
//! OSE layers consume. MDS only ever sees a dissimilarity *function*, which
//! is exactly the generality the paper leans on ("the only input is a
//! dissimilarity function"; metric or non-metric).

pub mod jaro;
pub mod levenshtein;
pub mod metric;
pub mod phonetic;
pub mod qgram;

pub use jaro::{jaro_distance, jaro_winkler_distance};
pub use levenshtein::{damerau_osa, levenshtein, levenshtein_bounded, levenshtein_dp};
pub use metric::{chebyshev, euclidean, euclidean_sq, manhattan, minkowski};
pub use phonetic::{nysiis, soundex, soundex_distance, SoundexDist};
pub use qgram::{qgram_cosine_distance, qgram_distance};

/// A dissimilarity over an object domain `T`.
///
/// Object-safe so heterogeneous configurations can box it; `Sync` so the
/// parallel dissimilarity-matrix builder can share it across threads.
pub trait Dissimilarity<T: ?Sized>: Sync {
    /// Dissimilarity between `a` and `b` (>= 0; 0 for identical objects).
    fn dist(&self, a: &T, b: &T) -> f64;

    /// Human-readable name (for configs, logs and reports).
    fn name(&self) -> &'static str;
}

/// Levenshtein edit distance on strings (the paper's primary choice).
#[derive(Clone, Copy, Debug, Default)]
pub struct Levenshtein;

impl Dissimilarity<str> for Levenshtein {
    fn dist(&self, a: &str, b: &str) -> f64 {
        levenshtein(a, b) as f64
    }

    fn name(&self) -> &'static str {
        "levenshtein"
    }
}

/// Damerau (OSA) edit distance.
#[derive(Clone, Copy, Debug, Default)]
pub struct DamerauOsa;

impl Dissimilarity<str> for DamerauOsa {
    fn dist(&self, a: &str, b: &str) -> f64 {
        damerau_osa(a, b) as f64
    }

    fn name(&self) -> &'static str {
        "damerau-osa"
    }
}

/// Jaro-Winkler distance.
#[derive(Clone, Copy, Debug, Default)]
pub struct JaroWinkler;

impl Dissimilarity<str> for JaroWinkler {
    fn dist(&self, a: &str, b: &str) -> f64 {
        jaro_winkler_distance(a, b)
    }

    fn name(&self) -> &'static str {
        "jaro-winkler"
    }
}

/// q-gram distance with configurable q.
#[derive(Clone, Copy, Debug)]
pub struct QGram(pub usize);

impl Default for QGram {
    fn default() -> Self {
        QGram(2)
    }
}

impl Dissimilarity<str> for QGram {
    fn dist(&self, a: &str, b: &str) -> f64 {
        qgram_distance(a, b, self.0) as f64
    }

    fn name(&self) -> &'static str {
        "qgram"
    }
}

/// Euclidean distance on coordinate vectors.
#[derive(Clone, Copy, Debug, Default)]
pub struct Euclidean;

impl Dissimilarity<[f32]> for Euclidean {
    fn dist(&self, a: &[f32], b: &[f32]) -> f64 {
        euclidean(a, b)
    }

    fn name(&self) -> &'static str {
        "euclidean"
    }
}

/// Look up a string comparator by config name.
pub fn string_metric_by_name(
    name: &str,
) -> Option<Box<dyn Dissimilarity<str> + Send>> {
    match name {
        "levenshtein" | "lv" => Some(Box::new(Levenshtein)),
        "damerau" | "osa" => Some(Box::new(DamerauOsa)),
        "jaro-winkler" | "jw" => Some(Box::new(JaroWinkler)),
        "qgram" | "qgram2" => Some(Box::new(QGram(2))),
        "qgram3" => Some(Box::new(QGram(3))),
        "soundex" => Some(Box::new(SoundexDist)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trait_objects_dispatch() {
        let metrics: Vec<Box<dyn Dissimilarity<str> + Send>> = vec![
            Box::new(Levenshtein),
            Box::new(DamerauOsa),
            Box::new(JaroWinkler),
            Box::new(QGram(2)),
        ];
        for m in &metrics {
            assert_eq!(m.dist("same", "same"), 0.0, "{}", m.name());
            assert!(m.dist("abc", "xyz") > 0.0, "{}", m.name());
        }
    }

    #[test]
    fn lookup_by_name() {
        for name in ["levenshtein", "lv", "jw", "qgram", "osa", "qgram3"] {
            assert!(string_metric_by_name(name).is_some(), "{name}");
        }
        assert!(string_metric_by_name("nope").is_none());
    }

    #[test]
    fn euclidean_trait_impl() {
        let e = Euclidean;
        assert_eq!(e.dist(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
    }
}
