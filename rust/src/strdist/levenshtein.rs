//! Levenshtein (edit) distance — the paper's primary dissimilarity for name
//! strings (Sec. 2.2), equivalent to R's `stringdist(method = "lv")`.
//!
//! Three implementations:
//! - `levenshtein_dp`: classic two-row dynamic program — the oracle.
//! - `levenshtein_myers`: Myers' 1999 bit-parallel algorithm, O(N·M/64).
//!   Entity names are short (< 64 chars), so the whole pattern fits one
//!   machine word and the inner loop is ~10 instructions per text char.
//!   This is the production path for the O(L·M) dissimilarity matrices.
//! - `levenshtein_bounded`: DP with early exit once the band exceeds a
//!   cutoff (used by FPS landmark selection where only comparisons against
//!   the current maximum matter).
//!
//! All operate on Unicode scalar values (chars), matching `stringdist`'s
//! default of comparing code points.

/// Classic two-row DP. O(N*M) time, O(min(N,M)) space. The reference.
pub fn levenshtein_dp(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let (short, long) = if a.len() <= b.len() { (&a, &b) } else { (&b, &a) };
    if short.is_empty() {
        return long.len();
    }
    let mut prev: Vec<usize> = (0..=short.len()).collect();
    let mut cur = vec![0usize; short.len() + 1];
    for (i, lc) in long.iter().enumerate() {
        cur[0] = i + 1;
        for (j, sc) in short.iter().enumerate() {
            let sub = prev[j] + usize::from(lc != sc);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[short.len()]
}

/// Myers bit-parallel edit distance for patterns up to 64 chars; falls back
/// to the DP for longer inputs. Exact (not approximate).
pub fn levenshtein_myers(a: &str, b: &str) -> usize {
    let pat: Vec<char> = a.chars().collect();
    let txt: Vec<char> = b.chars().collect();
    if pat.is_empty() {
        return txt.len();
    }
    if txt.is_empty() {
        return pat.len();
    }
    if pat.len() > 64 {
        // rare for names; swap if the other side fits, else DP
        if txt.len() <= 64 {
            return levenshtein_myers(b, a);
        }
        return levenshtein_dp(a, b);
    }

    // Pattern-character bitmasks. Names draw from a small alphabet, so a
    // tiny open-addressed probe over a fixed array beats a HashMap here.
    let m = pat.len();
    let mut keys = [0u32; 128];
    let mut vals = [0u64; 128];
    let mut used = [false; 128];
    let mask_for = |keys: &[u32; 128], vals: &[u64; 128], used: &[bool; 128], c: char| -> u64 {
        let mut h = (c as u32).wrapping_mul(2654435761) as usize % 128;
        loop {
            if !used[h] {
                return 0;
            }
            if keys[h] == c as u32 {
                return vals[h];
            }
            h = (h + 1) % 128;
        }
    };
    for (i, &c) in pat.iter().enumerate() {
        let mut h = (c as u32).wrapping_mul(2654435761) as usize % 128;
        loop {
            if !used[h] {
                used[h] = true;
                keys[h] = c as u32;
                vals[h] = 1u64 << i;
                break;
            }
            if keys[h] == c as u32 {
                vals[h] |= 1u64 << i;
                break;
            }
            h = (h + 1) % 128;
        }
    }

    let mut pv = !0u64;
    let mut mv = 0u64;
    let mut score = m;
    let high = 1u64 << (m - 1);

    for &c in &txt {
        let eq = mask_for(&keys, &vals, &used, c);
        let xv = eq | mv;
        let xh = (((eq & pv).wrapping_add(pv)) ^ pv) | eq;
        let mut ph = mv | !(xh | pv);
        let mut mh = pv & xh;
        if ph & high != 0 {
            score += 1;
        }
        if mh & high != 0 {
            score -= 1;
        }
        ph = (ph << 1) | 1;
        mh <<= 1;
        pv = mh | !(xv | ph);
        mv = ph & xv;
    }
    score
}

/// DP with early termination: returns `None` if the distance exceeds
/// `bound`, else `Some(distance)`. Uses the fact that the minimum over a DP
/// row never decreases.
pub fn levenshtein_bounded(a: &str, b: &str, bound: usize) -> Option<usize> {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.len().abs_diff(b.len()) > bound {
        return None;
    }
    let (short, long) = if a.len() <= b.len() { (&a, &b) } else { (&b, &a) };
    let mut prev: Vec<usize> = (0..=short.len()).collect();
    let mut cur = vec![0usize; short.len() + 1];
    for (i, lc) in long.iter().enumerate() {
        cur[0] = i + 1;
        let mut row_min = cur[0];
        for (j, sc) in short.iter().enumerate() {
            let sub = prev[j] + usize::from(lc != sc);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
            row_min = row_min.min(cur[j + 1]);
        }
        if row_min > bound {
            return None;
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    let d = prev[short.len()];
    (d <= bound).then_some(d)
}

/// Production entry point: Myers when possible, DP otherwise.
#[inline]
pub fn levenshtein(a: &str, b: &str) -> usize {
    levenshtein_myers(a, b)
}

/// Damerau-Levenshtein (optimal string alignment variant): also counts a
/// transposition of adjacent characters as one edit. Geco-style typo
/// corruption generates exactly these, so the OSA distance is offered as an
/// alternative dissimilarity.
pub fn damerau_osa(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    let w = b.len() + 1;
    let mut rows = vec![vec![0usize; w]; a.len() + 1];
    for (j, row0) in rows[0].iter_mut().enumerate() {
        *row0 = j;
    }
    for i in 1..=a.len() {
        rows[i][0] = i;
        for j in 1..=b.len() {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            let mut d = (rows[i - 1][j] + 1)
                .min(rows[i][j - 1] + 1)
                .min(rows[i - 1][j - 1] + cost);
            if i > 1 && j > 1 && a[i - 1] == b[j - 2] && a[i - 2] == b[j - 1] {
                d = d.min(rows[i - 2][j - 2] + 1);
            }
            rows[i][j] = d;
        }
    }
    rows[a.len()][b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{prop_assert, property};

    #[test]
    fn known_values() {
        let cases = [
            ("", "", 0),
            ("abc", "", 3),
            ("", "abc", 3),
            ("kitten", "sitting", 3),
            ("flaw", "lawn", 2),
            ("gumbo", "gambol", 2),
            ("saturday", "sunday", 3),
            ("same", "same", 0),
            ("a", "b", 1),
        ];
        for (a, b, want) in cases {
            assert_eq!(levenshtein_dp(a, b), want, "dp {a:?} {b:?}");
            assert_eq!(levenshtein_myers(a, b), want, "myers {a:?} {b:?}");
            assert_eq!(levenshtein_bounded(a, b, 10), Some(want));
        }
    }

    #[test]
    fn unicode_code_points() {
        assert_eq!(levenshtein_dp("café", "cafe"), 1);
        assert_eq!(levenshtein_myers("café", "cafe"), 1);
        assert_eq!(levenshtein_myers("日本語", "日本"), 1);
    }

    #[test]
    fn myers_equals_dp_property() {
        property("myers == dp", 400, |g| {
            let a = g.unicode_string(0, 40);
            let b = g.unicode_string(0, 40);
            prop_assert(
                levenshtein_myers(&a, &b) == levenshtein_dp(&a, &b),
                &format!("{a:?} vs {b:?}"),
            )
        });
    }

    #[test]
    fn myers_long_pattern_falls_back() {
        let a: String = "ab".repeat(50); // 100 chars > 64
        let b: String = "ba".repeat(50);
        assert_eq!(levenshtein_myers(&a, &b), levenshtein_dp(&a, &b));
        // one side fits in 64 -> swapped Myers path
        let c: String = "ab".repeat(20);
        assert_eq!(levenshtein_myers(&a, &c), levenshtein_dp(&a, &c));
    }

    #[test]
    fn metric_axioms_property() {
        property("levenshtein metric axioms", 200, |g| {
            let a = g.string(0, 16);
            let b = g.string(0, 16);
            let c = g.string(0, 16);
            let dab = levenshtein(&a, &b);
            let dba = levenshtein(&b, &a);
            let dac = levenshtein(&a, &c);
            let dcb = levenshtein(&c, &b);
            prop_assert(dab == dba, "symmetry")?;
            prop_assert((dab == 0) == (a == b), "identity")?;
            prop_assert(dab <= dac + dcb, "triangle inequality")
        });
    }

    #[test]
    fn bounded_agrees_or_exceeds() {
        property("bounded == dp when within bound", 300, |g| {
            let a = g.string(0, 20);
            let b = g.string(0, 20);
            let bound = g.usize_in(0, 8);
            let d = levenshtein_dp(&a, &b);
            match levenshtein_bounded(&a, &b, bound) {
                Some(got) => prop_assert(got == d && d <= bound, "within-bound value"),
                None => prop_assert(d > bound, "exceed claim"),
            }
        });
    }

    #[test]
    fn osa_counts_transpositions() {
        assert_eq!(damerau_osa("ab", "ba"), 1);
        assert_eq!(levenshtein("ab", "ba"), 2);
        assert_eq!(damerau_osa("smith", "simth"), 1);
        assert_eq!(damerau_osa("abc", "abc"), 0);
        assert_eq!(damerau_osa("", "xy"), 2);
    }

    #[test]
    fn osa_never_exceeds_levenshtein() {
        property("osa <= levenshtein", 300, |g| {
            let a = g.string(0, 14);
            let b = g.string(0, 14);
            prop_assert(damerau_osa(&a, &b) <= levenshtein_dp(&a, &b), "osa bound")
        });
    }
}
