//! Jaro and Jaro-Winkler similarity — the second string comparator family
//! named by the paper (Sec. 2.2), equivalent to `stringdist(method="jw")`.
//!
//! Returned as *distances* in [0, 1] (1 - similarity) so they slot into the
//! same `Dissimilarity` interface as the edit distances.

/// Jaro similarity in [0, 1]; 1 means identical.
pub fn jaro_similarity(a: &str, b: &str) -> f64 {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    if a.is_empty() || b.is_empty() {
        return 0.0;
    }
    let window = (a.len().max(b.len()) / 2).saturating_sub(1);
    let mut b_used = vec![false; b.len()];
    let mut matches_a = Vec::with_capacity(a.len());

    for (i, ca) in a.iter().enumerate() {
        let lo = i.saturating_sub(window);
        let hi = (i + window + 1).min(b.len());
        for j in lo..hi {
            if !b_used[j] && b[j] == *ca {
                b_used[j] = true;
                matches_a.push((i, j));
                break;
            }
        }
    }
    let m = matches_a.len();
    if m == 0 {
        return 0.0;
    }
    // transpositions: matched chars of b in a-match order
    let mut t = 0usize;
    let mut b_seq: Vec<usize> = matches_a.iter().map(|&(_, j)| j).collect();
    let b_sorted = {
        let mut v = b_seq.clone();
        v.sort_unstable();
        v
    };
    // matches_a is already ordered by i; the b-side order determines t
    b_seq.sort_by_key(|&j| {
        matches_a.iter().position(|&(_, jj)| jj == j).unwrap()
    });
    for (x, y) in b_seq.iter().zip(b_sorted.iter()) {
        if x != y {
            t += 1;
        }
    }
    let t = t as f64 / 2.0;
    let m = m as f64;
    (m / a.len() as f64 + m / b.len() as f64 + (m - t) / m) / 3.0
}

/// Jaro-Winkler similarity with the standard scaling p=0.1 and prefix cap 4.
pub fn jaro_winkler_similarity(a: &str, b: &str) -> f64 {
    let jaro = jaro_similarity(a, b);
    let prefix = a
        .chars()
        .zip(b.chars())
        .take(4)
        .take_while(|(x, y)| x == y)
        .count() as f64;
    jaro + prefix * 0.1 * (1.0 - jaro)
}

/// Jaro distance = 1 - similarity.
pub fn jaro_distance(a: &str, b: &str) -> f64 {
    1.0 - jaro_similarity(a, b)
}

/// Jaro-Winkler distance = 1 - similarity.
pub fn jaro_winkler_distance(a: &str, b: &str) -> f64 {
    1.0 - jaro_winkler_similarity(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{prop_assert, property};

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-3
    }

    #[test]
    fn textbook_values() {
        // canonical examples used by Winkler / stringdist docs
        assert!(close(jaro_similarity("MARTHA", "MARHTA"), 0.944_444));
        assert!(close(jaro_similarity("DIXON", "DICKSONX"), 0.766_667));
        assert!(close(jaro_similarity("JELLYFISH", "SMELLYFISH"), 0.896_296));
        assert!(close(jaro_winkler_similarity("MARTHA", "MARHTA"), 0.961_111));
        assert!(close(jaro_winkler_similarity("DIXON", "DICKSONX"), 0.813_333));
    }

    #[test]
    fn edge_cases() {
        assert_eq!(jaro_similarity("", ""), 1.0);
        assert_eq!(jaro_similarity("a", ""), 0.0);
        assert_eq!(jaro_similarity("abc", "abc"), 1.0);
        assert_eq!(jaro_similarity("abc", "xyz"), 0.0);
        assert_eq!(jaro_distance("abc", "abc"), 0.0);
    }

    #[test]
    fn properties() {
        property("jaro in [0,1], symmetric, identity", 300, |g| {
            let a = g.unicode_string(0, 16);
            let b = g.unicode_string(0, 16);
            let s = jaro_similarity(&a, &b);
            prop_assert((0.0..=1.0).contains(&s), "range")?;
            prop_assert(
                close(s, jaro_similarity(&b, &a)),
                &format!("symmetry {a:?} {b:?}"),
            )?;
            prop_assert(
                !(a == b) || close(s, 1.0),
                "identical strings have similarity 1",
            )
        });
    }

    #[test]
    fn winkler_boosts_common_prefix() {
        property("jw >= jaro", 200, |g| {
            let a = g.string(0, 12);
            let b = g.string(0, 12);
            prop_assert(
                jaro_winkler_similarity(&a, &b) >= jaro_similarity(&a, &b) - 1e-12,
                "prefix boost is non-negative",
            )
        });
        // a shared prefix should strictly increase similarity
        let plain = jaro_similarity("prefixed", "prefixxx");
        let boosted = jaro_winkler_similarity("prefixed", "prefixxx");
        assert!(boosted > plain);
    }
}
