//! q-gram distance — the third comparator family the paper names
//! (Sec. 2.2): the L1 distance between the q-gram occurrence profiles of two
//! strings (`stringdist(method = "qgram")`).

use std::collections::HashMap;

/// Multiset of q-grams of a string (as char windows).
fn profile(s: &str, q: usize) -> HashMap<Vec<char>, i64> {
    let chars: Vec<char> = s.chars().collect();
    let mut map = HashMap::new();
    if chars.len() >= q && q > 0 {
        for w in chars.windows(q) {
            *map.entry(w.to_vec()).or_insert(0) += 1;
        }
    }
    map
}

/// q-gram distance: sum over all q-grams of |count_a - count_b|.
pub fn qgram_distance(a: &str, b: &str, q: usize) -> usize {
    assert!(q > 0, "q must be positive");
    let pa = profile(a, q);
    let pb = profile(b, q);
    let mut total = 0i64;
    for (g, ca) in &pa {
        total += (ca - pb.get(g).copied().unwrap_or(0)).abs();
    }
    for (g, cb) in &pb {
        if !pa.contains_key(g) {
            total += cb.abs();
        }
    }
    total as usize
}

/// Cosine distance between q-gram profiles (bonus comparator; useful when
/// string lengths vary a lot).
pub fn qgram_cosine_distance(a: &str, b: &str, q: usize) -> f64 {
    let pa = profile(a, q);
    let pb = profile(b, q);
    if pa.is_empty() || pb.is_empty() {
        return if a == b { 0.0 } else { 1.0 };
    }
    let dot: i64 = pa
        .iter()
        .filter_map(|(g, ca)| pb.get(g).map(|cb| ca * cb))
        .sum();
    let na: i64 = pa.values().map(|c| c * c).sum();
    let nb: i64 = pb.values().map(|c| c * c).sum();
    1.0 - dot as f64 / ((na as f64).sqrt() * (nb as f64).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{prop_assert, property};

    #[test]
    fn known_values() {
        // profiles: "abc" {ab, bc}, "abd" {ab, bd} -> distance 2
        assert_eq!(qgram_distance("abc", "abd", 2), 2);
        assert_eq!(qgram_distance("abc", "abc", 2), 0);
        assert_eq!(qgram_distance("aaaa", "aa", 2), 2); // counts matter
        assert_eq!(qgram_distance("", "abc", 2), 2);
        assert_eq!(qgram_distance("a", "b", 2), 0); // both too short: empty profiles
    }

    #[test]
    fn symmetry_and_identity() {
        property("qgram symmetric & identity", 300, |g| {
            let a = g.string(0, 14);
            let b = g.string(0, 14);
            let q = g.usize_in(1, 3);
            prop_assert(
                qgram_distance(&a, &b, q) == qgram_distance(&b, &a, q),
                "symmetry",
            )?;
            prop_assert(qgram_distance(&a, &a, q) == 0, "identity")
        });
    }

    #[test]
    fn triangle_inequality_property() {
        // q-gram distance is an L1 distance between profiles => metric on
        // profiles (pseudo-metric on strings).
        property("qgram triangle", 200, |g| {
            let a = g.string(0, 10);
            let b = g.string(0, 10);
            let c = g.string(0, 10);
            let q = 2;
            prop_assert(
                qgram_distance(&a, &b, q)
                    <= qgram_distance(&a, &c, q) + qgram_distance(&c, &b, q),
                "triangle",
            )
        });
    }

    #[test]
    fn cosine_range_and_identity() {
        property("qgram cosine in [0,1]", 200, |g| {
            let a = g.string(0, 12);
            let b = g.string(0, 12);
            let d = qgram_cosine_distance(&a, &b, 2);
            prop_assert((-1e-12..=1.0 + 1e-12).contains(&d), "range")?;
            let da = qgram_cosine_distance(&a, &a, 2);
            prop_assert(da.abs() < 1e-9 || a.chars().count() < 2, "identity")
        });
    }
}
