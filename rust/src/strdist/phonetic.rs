//! Phonetic encodings — Soundex and NYSIIS, the comparator family FEBRL
//! pairs with its name generator. Encoding-equality gives a cheap blocking
//! predicate, and the edit distance between encodings is a (non-metric)
//! dissimilarity robust to spelling variation — exactly the kind of
//! non-metric input the paper's LSMDS pipeline is designed to accept.

/// American Soundex (4-character code, e.g. "robert" -> "R163").
pub fn soundex(s: &str) -> String {
    let letters: Vec<char> = s
        .chars()
        .filter(|c| c.is_ascii_alphabetic())
        .map(|c| c.to_ascii_uppercase())
        .collect();
    let Some(&first) = letters.first() else {
        return String::new();
    };

    fn code(c: char) -> u8 {
        match c {
            'B' | 'F' | 'P' | 'V' => 1,
            'C' | 'G' | 'J' | 'K' | 'Q' | 'S' | 'X' | 'Z' => 2,
            'D' | 'T' => 3,
            'L' => 4,
            'M' | 'N' => 5,
            'R' => 6,
            _ => 0, // vowels + H/W/Y
        }
    }

    let mut out = String::new();
    out.push(first);
    let mut prev = code(first);
    for &c in &letters[1..] {
        let d = code(c);
        if d != 0 && d != prev {
            out.push((b'0' + d) as char);
            if out.len() == 4 {
                break;
            }
        }
        // H and W are transparent: the previous code survives across them
        if !(c == 'H' || c == 'W') {
            prev = d;
        }
    }
    while out.len() < 4 {
        out.push('0');
    }
    out
}

/// NYSIIS (New York State Identification and Intelligence System) encoding
/// — better suited to non-Anglo surnames than Soundex. Standard algorithm,
/// truncated to the conventional 6 characters.
pub fn nysiis(s: &str) -> String {
    let mut w: Vec<char> = s
        .chars()
        .filter(|c| c.is_ascii_alphabetic())
        .map(|c| c.to_ascii_uppercase())
        .collect();
    if w.is_empty() {
        return String::new();
    }
    // leading transformations
    let prefix_rules: &[(&str, &str)] = &[
        ("MAC", "MCC"),
        ("KN", "NN"),
        ("K", "C"),
        ("PH", "FF"),
        ("PF", "FF"),
        ("SCH", "SSS"),
    ];
    for (pat, rep) in prefix_rules {
        let p: Vec<char> = pat.chars().collect();
        if w.len() >= p.len() && w[..p.len()] == p[..] {
            let mut nw: Vec<char> = rep.chars().collect();
            nw.extend_from_slice(&w[p.len()..]);
            w = nw;
            break;
        }
    }
    // trailing transformations
    let suffix_rules: &[(&str, &str)] = &[
        ("EE", "Y"),
        ("IE", "Y"),
        ("DT", "D"),
        ("RT", "D"),
        ("RD", "D"),
        ("NT", "D"),
        ("ND", "D"),
    ];
    for (pat, rep) in suffix_rules {
        let p: Vec<char> = pat.chars().collect();
        if w.len() >= p.len() && w[w.len() - p.len()..] == p[..] {
            w.truncate(w.len() - p.len());
            w.extend(rep.chars());
            break;
        }
    }

    let first = w[0];
    let mut key = vec![first];
    let is_vowel = |c: char| matches!(c, 'A' | 'E' | 'I' | 'O' | 'U');
    let mut i = 1;
    while i < w.len() {
        let c = w[i];
        let mut repl: Vec<char> = match c {
            'E' if i + 1 < w.len() && w[i + 1] == 'V' => {
                i += 1;
                vec!['A', 'F']
            }
            c if is_vowel(c) => vec!['A'],
            'Q' => vec!['G'],
            'Z' => vec!['S'],
            'M' => vec!['N'],
            'K' => {
                if i + 1 < w.len() && w[i + 1] == 'N' {
                    i += 1;
                    vec!['N', 'N']
                } else {
                    vec!['C']
                }
            }
            'S' if i + 2 < w.len() && w[i + 1] == 'C' && w[i + 2] == 'H' => {
                i += 2;
                vec!['S', 'S', 'S']
            }
            'P' if i + 1 < w.len() && w[i + 1] == 'H' => {
                i += 1;
                vec!['F', 'F']
            }
            'H' => {
                let prev = *key.last().unwrap();
                let next_v = i + 1 < w.len() && is_vowel(w[i + 1]);
                if !is_vowel(prev) || !next_v {
                    vec![prev]
                } else {
                    vec!['H']
                }
            }
            'W' => {
                let prev = *key.last().unwrap();
                if is_vowel(prev) {
                    vec![prev]
                } else {
                    vec!['W']
                }
            }
            c => vec![c],
        };
        // append without immediate duplicates
        for r in repl.drain(..) {
            if *key.last().unwrap() != r {
                key.push(r);
            }
        }
        i += 1;
    }
    // terminal cleanups
    if key.last() == Some(&'S') && key.len() > 1 {
        key.pop();
    }
    if key.len() >= 2 && key[key.len() - 2..] == ['A', 'Y'] {
        key.remove(key.len() - 2);
    }
    if key.last() == Some(&'A') && key.len() > 1 {
        key.pop();
    }
    key.truncate(6);
    key.into_iter().collect()
}

/// Non-metric dissimilarity: edit distance between Soundex codes (0..=4).
pub fn soundex_distance(a: &str, b: &str) -> usize {
    super::levenshtein(&soundex(a), &soundex(b))
}

/// Soundex-distance comparator for the `Dissimilarity` interface.
#[derive(Clone, Copy, Debug, Default)]
pub struct SoundexDist;

impl super::Dissimilarity<str> for SoundexDist {
    fn dist(&self, a: &str, b: &str) -> f64 {
        soundex_distance(a, b) as f64
    }

    fn name(&self) -> &'static str {
        "soundex"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{prop_assert, property};

    #[test]
    fn soundex_canonical_values() {
        // classic reference vectors (US National Archives)
        for (name, code) in [
            ("Robert", "R163"),
            ("Rupert", "R163"),
            ("Ashcraft", "A261"),
            ("Ashcroft", "A261"),
            ("Tymczak", "T522"),
            ("Pfister", "P236"),
            ("Washington", "W252"),
            ("Lee", "L000"),
            ("Gutierrez", "G362"),
            ("Jackson", "J250"),
        ] {
            assert_eq!(soundex(name), code, "{name}");
        }
    }

    #[test]
    fn soundex_ignores_case_and_nonletters() {
        assert_eq!(soundex("o'brien"), soundex("OBrien"));
        assert_eq!(soundex("smith-jones"), soundex("smithjones"));
        assert_eq!(soundex(""), "");
        assert_eq!(soundex("123"), "");
    }

    #[test]
    fn soundex_shape_property() {
        property("soundex is letter + 3 digits", 300, |g| {
            let s = g.string(1, 20);
            let c = soundex(&s);
            prop_assert(c.len() == 4, "length")?;
            prop_assert(
                c.chars().next().unwrap().is_ascii_uppercase(),
                "leading letter",
            )?;
            prop_assert(
                c.chars().skip(1).all(|d| d.is_ascii_digit()),
                "digit tail",
            )
        });
    }

    #[test]
    fn soundex_robust_to_phonetic_typos() {
        // the whole point: common misspellings encode identically
        assert_eq!(soundex("smith"), soundex("smyth"));
        // the first letter is kept verbatim, so C/K variants share the
        // digit tail only
        assert_eq!(soundex("catherine")[1..], soundex("katherine")[1..]);
        // Soundex treats ph/f identically (both code 1)
        assert_eq!(soundex("philip")[1..], soundex("filip")[1..]);
    }

    #[test]
    fn nysiis_known_values() {
        // spot values consistent with the standard algorithm
        assert_eq!(nysiis("knight"), "NAGT");
        assert_eq!(nysiis("mitchell"), "MATCAL");
        assert_eq!(nysiis("mcdonald"), "MCDANA");
        assert_eq!(nysiis(""), "");
    }

    #[test]
    fn nysiis_groups_spelling_variants() {
        // classic equivalences the algorithm does guarantee
        assert_eq!(nysiis("brian"), nysiis("brien"));
        assert_eq!(nysiis("catherine"), nysiis("katherine"));
        assert_eq!(nysiis("philip"), nysiis("filip"));
    }

    #[test]
    fn nysiis_shape_property() {
        property("nysiis <= 6 uppercase letters", 300, |g| {
            let s = g.string(1, 20);
            let c = nysiis(&s);
            prop_assert(c.len() <= 6, "length")?;
            prop_assert(c.chars().all(|d| d.is_ascii_uppercase()), "letters")
        });
    }

    #[test]
    fn soundex_distance_is_bounded_pseudometric() {
        property("soundex distance bounds", 200, |g| {
            let a = g.string(1, 14);
            let b = g.string(1, 14);
            let d = soundex_distance(&a, &b);
            prop_assert(d <= 4, "bounded by code length")?;
            prop_assert(
                soundex_distance(&a, &a) == 0,
                "identity of indiscernibles (weak)",
            )?;
            prop_assert(d == soundex_distance(&b, &a), "symmetry")
        });
    }
}
