//! Minkowski metrics on coordinate vectors (paper Sec. 2.2): the metric-
//! space counterpart of the string comparators, used for the sensor-network
//! example and any pre-vectorised input data.

/// Euclidean distance (p = 2) — the paper's metric-space default.
#[inline]
pub fn euclidean(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc.sqrt()
}

/// Squared Euclidean distance (avoids the sqrt on hot comparison paths).
#[inline]
pub fn euclidean_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc
}

/// Manhattan distance (p = 1).
#[inline]
pub fn manhattan(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| ((*x - *y) as f64).abs())
        .sum()
}

/// Chebyshev distance (p = inf).
#[inline]
pub fn chebyshev(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| ((*x - *y) as f64).abs())
        .fold(0.0, f64::max)
}

/// General Minkowski L^p distance, p >= 1.
pub fn minkowski(a: &[f32], b: &[f32], p: f64) -> f64 {
    assert!(p >= 1.0, "minkowski requires p >= 1 (got {p})");
    if p == 1.0 {
        return manhattan(a, b);
    }
    if p == 2.0 {
        return euclidean(a, b);
    }
    if p.is_infinite() {
        return chebyshev(a, b);
    }
    let sum: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| ((*x - *y) as f64).abs().powf(p))
        .sum();
    sum.powf(1.0 / p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{prop_assert, prop_assert_close, property};

    #[test]
    fn known_values() {
        let a = [0.0f32, 0.0];
        let b = [3.0f32, 4.0];
        assert_eq!(euclidean(&a, &b), 5.0);
        assert_eq!(euclidean_sq(&a, &b), 25.0);
        assert_eq!(manhattan(&a, &b), 7.0);
        assert_eq!(chebyshev(&a, &b), 4.0);
        assert!((minkowski(&a, &b, 3.0) - 91.0f64.powf(1.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn p_special_cases_dispatch() {
        let a = [1.0f32, -2.0, 0.5];
        let b = [0.0f32, 1.0, 2.5];
        assert_eq!(minkowski(&a, &b, 1.0), manhattan(&a, &b));
        assert_eq!(minkowski(&a, &b, 2.0), euclidean(&a, &b));
        assert_eq!(minkowski(&a, &b, f64::INFINITY), chebyshev(&a, &b));
    }

    #[test]
    fn metric_axioms() {
        property("minkowski metric axioms", 200, |g| {
            let k = g.usize_in(1, 6);
            let a = g.vec_f32(k, k, 2.0);
            let b = g.vec_f32(k, k, 2.0);
            let c = g.vec_f32(k, k, 2.0);
            let p = *g.choose(&[1.0, 1.5, 2.0, 3.0]);
            let dab = minkowski(&a, &b, p);
            prop_assert_close(dab, minkowski(&b, &a, p), 1e-9, "symmetry")?;
            prop_assert(dab >= 0.0, "non-negativity")?;
            prop_assert_close(minkowski(&a, &a, p), 0.0, 1e-9, "identity")?;
            // f32 inputs: collinear points make the triangle inequality an
            // exact equality, so allow f32-scale rounding slack
            prop_assert(
                dab <= minkowski(&a, &c, p) + minkowski(&c, &b, p)
                    + 1e-5 * (1.0 + dab),
                "triangle",
            )
        });
    }

    #[test]
    fn minkowski_monotone_in_p() {
        // L^p norms are non-increasing in p
        property("||.||_p non-increasing in p", 100, |g| {
            let k = g.usize_in(1, 5);
            let a = g.vec_f32(k, k, 1.0);
            let b = g.vec_f32(k, k, 1.0);
            let d1 = minkowski(&a, &b, 1.0);
            let d2 = minkowski(&a, &b, 2.0);
            let d3 = minkowski(&a, &b, 4.0);
            prop_assert(d1 >= d2 - 1e-9 && d2 >= d3 - 1e-9, "monotone")
        });
    }
}
