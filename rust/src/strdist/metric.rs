//! Minkowski metrics on coordinate vectors (paper Sec. 2.2): the metric-
//! space counterpart of the string comparators, used for the sensor-network
//! example and any pre-vectorised input data.
//!
//! The Euclidean and Manhattan metrics are the hot path — they are what
//! the storage layer evaluates per landmark in
//! [`crate::data::source::TableDelta`] and what the LSMDS/OSE solvers
//! call per row pair — so they dispatch through the kernel tier
//! ([`crate::runtime::simd`]). Their f64 accumulation order is
//! **explicit and canonical**: element `j` contributes to lane `j % 8`
//! and the lanes combine in the fixed stride-4 pairwise tree, on every
//! tier (AVX2, NEON, scalar) — bit-identical results by construction,
//! pinned by the `canonical_reduction_order_is_pinned` regression test
//! below. The historical strictly-serial sum differs from the canonical
//! order only by ordinary f64 rounding.

/// Euclidean distance (p = 2) — the paper's metric-space default.
/// Canonical 8-lane tile reduction via the kernel tier; panics if the
/// operand lengths differ.
#[inline]
pub fn euclidean(a: &[f32], b: &[f32]) -> f64 {
    crate::runtime::simd::euclidean_sq(a, b).sqrt()
}

/// Squared Euclidean distance (avoids the sqrt on hot comparison paths).
/// Canonical 8-lane tile reduction via the kernel tier; panics if the
/// operand lengths differ.
#[inline]
pub fn euclidean_sq(a: &[f32], b: &[f32]) -> f64 {
    crate::runtime::simd::euclidean_sq(a, b)
}

/// Manhattan distance (p = 1). Canonical 8-lane tile reduction via the
/// kernel tier; panics if the operand lengths differ.
#[inline]
pub fn manhattan(a: &[f32], b: &[f32]) -> f64 {
    crate::runtime::simd::manhattan(a, b)
}

/// Chebyshev distance (p = inf).
#[inline]
pub fn chebyshev(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| ((*x - *y) as f64).abs())
        .fold(0.0, f64::max)
}

/// General Minkowski L^p distance, p >= 1.
pub fn minkowski(a: &[f32], b: &[f32], p: f64) -> f64 {
    assert!(p >= 1.0, "minkowski requires p >= 1 (got {p})");
    if p == 1.0 {
        return manhattan(a, b);
    }
    if p == 2.0 {
        return euclidean(a, b);
    }
    if p.is_infinite() {
        return chebyshev(a, b);
    }
    let sum: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| ((*x - *y) as f64).abs().powf(p))
        .sum();
    sum.powf(1.0 / p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{prop_assert, prop_assert_close, property};

    #[test]
    fn known_values() {
        let a = [0.0f32, 0.0];
        let b = [3.0f32, 4.0];
        assert_eq!(euclidean(&a, &b), 5.0);
        assert_eq!(euclidean_sq(&a, &b), 25.0);
        assert_eq!(manhattan(&a, &b), 7.0);
        assert_eq!(chebyshev(&a, &b), 4.0);
        assert!((minkowski(&a, &b, 3.0) - 91.0f64.powf(1.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn canonical_reduction_order_is_pinned() {
        // Absorption-prone input: one huge square (2^54, whose f64 ulp is
        // 4) among eighteen 1.0 squares. A strictly serial sum absorbs
        // every +1.0 into the huge partial sum (each is below half an
        // ulp), giving exactly 2^54; the canonical order accumulates the
        // ones in big-free lanes first, so they survive (2^54 + 12). The
        // result *depends* on summation order, and this pins the
        // documented canonical one — lane j % 8, then the stride-4
        // pairwise tree — to the exact bit.
        let n = 19; // covers a remainder tile (19 % 8 = 3)
        let a: Vec<f32> =
            (0..n).map(|j| if j == 0 { 134217728.0 } else { 1.0 }).collect(); // 2^27
        let b = vec![0.0f32; n];
        let mut lanes = [0.0f64; 8];
        for j in 0..n {
            let d = (a[j] - b[j]) as f64;
            lanes[j & 7] += d * d;
        }
        let t = [
            lanes[0] + lanes[4],
            lanes[1] + lanes[5],
            lanes[2] + lanes[6],
            lanes[3] + lanes[7],
        ];
        let expected = (t[0] + t[2]) + (t[1] + t[3]);
        assert_eq!(euclidean_sq(&a, &b).to_bits(), expected.to_bits());
        assert_eq!(euclidean(&a, &b).to_bits(), expected.sqrt().to_bits());
        // ... the input really is order-sensitive (a regression to the
        // serial order cannot sneak past the bit assert above) ...
        let serial: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(x, y)| {
                let d = (*x - *y) as f64;
                d * d
            })
            .sum();
        assert_ne!(expected.to_bits(), serial.to_bits());
        // ... and the canonical order stays within the documented 1e-6
        // relative band of the historical serial sum
        assert!((expected - serial).abs() <= 1e-6 * serial.abs());
    }

    #[test]
    fn p_special_cases_dispatch() {
        let a = [1.0f32, -2.0, 0.5];
        let b = [0.0f32, 1.0, 2.5];
        assert_eq!(minkowski(&a, &b, 1.0), manhattan(&a, &b));
        assert_eq!(minkowski(&a, &b, 2.0), euclidean(&a, &b));
        assert_eq!(minkowski(&a, &b, f64::INFINITY), chebyshev(&a, &b));
    }

    #[test]
    fn metric_axioms() {
        property("minkowski metric axioms", 200, |g| {
            let k = g.usize_in(1, 6);
            let a = g.vec_f32(k, k, 2.0);
            let b = g.vec_f32(k, k, 2.0);
            let c = g.vec_f32(k, k, 2.0);
            let p = *g.choose(&[1.0, 1.5, 2.0, 3.0]);
            let dab = minkowski(&a, &b, p);
            prop_assert_close(dab, minkowski(&b, &a, p), 1e-9, "symmetry")?;
            prop_assert(dab >= 0.0, "non-negativity")?;
            prop_assert_close(minkowski(&a, &a, p), 0.0, 1e-9, "identity")?;
            // f32 inputs: collinear points make the triangle inequality an
            // exact equality, so allow f32-scale rounding slack
            prop_assert(
                dab <= minkowski(&a, &c, p) + minkowski(&c, &b, p)
                    + 1e-5 * (1.0 + dab),
                "triangle",
            )
        });
    }

    #[test]
    fn minkowski_monotone_in_p() {
        // L^p norms are non-increasing in p
        property("||.||_p non-increasing in p", 100, |g| {
            let k = g.usize_in(1, 5);
            let a = g.vec_f32(k, k, 1.0);
            let b = g.vec_f32(k, k, 1.0);
            let d1 = minkowski(&a, &b, 1.0);
            let d2 = minkowski(&a, &b, 2.0);
            let d3 = minkowski(&a, &b, 4.0);
            prop_assert(d1 >= d2 - 1e-9 && d2 >= d3 - 1e-9, "monotone")
        });
    }
}
