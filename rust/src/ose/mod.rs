//! Out-of-sample embedding methods (the paper's contribution, Sec. 4):
//! the optimisation method (Eq. 2) and the neural-network method, behind a
//! single [`OseMethod`] interface the coordinator routes requests to, plus
//! the bounded-memory streaming driver ([`pipeline`]) that overlaps
//! dissimilarity-block construction with embedding.

pub mod classical_ose;
pub mod imds;
pub mod optimise;
pub mod pipeline;

pub use classical_ose::ClassicalOse;
pub use imds::{Imds, ImdsConfig};
pub use optimise::{embed_batch, embed_point, OseOptConfig, OsePoint};
pub use pipeline::{
    embed_stream, embed_stream_blocks, embed_stream_with, StreamStats,
    DEFAULT_STREAM_CHUNK,
};

use crate::mds::Matrix;

/// A strategy for mapping new objects into an existing configuration.
/// Inputs are always the distances from each new object to the landmarks
/// (B x L); output is the B x K coordinates.
pub trait OseMethod: Send {
    /// Embed a batch of new points given their landmark-distance rows.
    fn embed(&mut self, deltas: &Matrix) -> anyhow::Result<Matrix>;

    /// Embedding dimension K.
    fn dim(&self) -> usize;

    /// Number of landmarks L this method expects.
    fn landmarks(&self) -> usize;

    /// Human-readable method name (for configs, logs and reports).
    fn name(&self) -> &'static str;
}

/// Builds fresh, independent [`OseMethod`] replicas for the replicated
/// serving executor pool: each executor thread owns one replica, and a
/// replica whose `embed` panics is discarded and rebuilt from the factory
/// (its internal state may be poisoned mid-batch).
///
/// Implemented for free by any `Fn() -> Box<dyn OseMethod>` closure, so a
/// cloneable method becomes a factory with
/// `factory_fn(move || Box::new(method.clone()))`.
pub trait OseMethodFactory: Send + Sync {
    /// Construct one fresh replica over the shared trained state.
    fn build(&self) -> Box<dyn OseMethod>;
}

impl<F> OseMethodFactory for F
where
    F: Fn() -> Box<dyn OseMethod> + Send + Sync,
{
    fn build(&self) -> Box<dyn OseMethod> {
        self()
    }
}

/// Wrap a closure as a shareable replica factory.
pub fn factory_fn<F>(f: F) -> std::sync::Arc<dyn OseMethodFactory>
where
    F: Fn() -> Box<dyn OseMethod> + Send + Sync + 'static,
{
    std::sync::Arc::new(f)
}

/// Pure-Rust optimisation method (the serial R-protocol baseline).
pub struct RustOptimise {
    /// L x K landmark configuration.
    pub landmarks: Matrix,
    /// Per-point majorization budget.
    pub cfg: OseOptConfig,
}

impl OseMethod for RustOptimise {
    fn embed(&mut self, deltas: &Matrix) -> anyhow::Result<Matrix> {
        anyhow::ensure!(
            deltas.cols == self.landmarks.rows,
            "expected {} landmark distances, got {}",
            self.landmarks.rows,
            deltas.cols
        );
        Ok(embed_batch(&self.landmarks, deltas, &self.cfg))
    }

    fn dim(&self) -> usize {
        self.landmarks.cols
    }

    fn landmarks(&self) -> usize {
        self.landmarks.rows
    }

    fn name(&self) -> &'static str {
        "opt-rust"
    }
}

/// Pure-Rust NN method over trained parameters.
pub struct RustNn {
    /// Trained MLP parameters.
    pub params: crate::nn::MlpParams,
}

impl OseMethod for RustNn {
    fn embed(&mut self, deltas: &Matrix) -> anyhow::Result<Matrix> {
        anyhow::ensure!(
            deltas.cols == self.params.shape.input,
            "expected {} landmark distances, got {}",
            self.params.shape.input,
            deltas.cols
        );
        Ok(crate::nn::forward(&self.params, deltas))
    }

    fn dim(&self) -> usize {
        self.params.shape.output
    }

    fn landmarks(&self) -> usize {
        self.params.shape.input
    }

    fn name(&self) -> &'static str {
        "nn-rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{MlpParams, MlpShape};
    use crate::util::prng::Rng;

    #[test]
    fn trait_objects_embed_with_consistent_shapes() {
        let mut rng = Rng::new(1);
        let lm = Matrix::random_normal(&mut rng, 12, 3, 1.0);
        let deltas = Matrix::from_vec(
            5,
            12,
            (0..60).map(|_| rng.next_f32() + 0.5).collect(),
        );

        let mut methods: Vec<Box<dyn OseMethod>> = vec![
            Box::new(RustOptimise { landmarks: lm, cfg: OseOptConfig::default() }),
            Box::new(RustNn {
                params: MlpParams::init(
                    &MlpShape { input: 12, hidden: [8, 8, 8], output: 3 },
                    &mut rng,
                ),
            }),
        ];
        for m in methods.iter_mut() {
            assert_eq!(m.landmarks(), 12);
            assert_eq!(m.dim(), 3);
            let y = m.embed(&deltas).unwrap();
            assert_eq!((y.rows, y.cols), (5, 3), "{}", m.name());
            assert!(y.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn factory_builds_independent_replicas() {
        let mut rng = Rng::new(3);
        let lm = Matrix::random_normal(&mut rng, 10, 2, 1.0);
        let factory = factory_fn(move || {
            Box::new(RustOptimise { landmarks: lm.clone(), cfg: OseOptConfig::default() })
                as Box<dyn OseMethod>
        });
        let mut a = factory.build();
        let mut b = factory.build();
        let deltas = Matrix::from_vec(1, 10, vec![1.0; 10]);
        let ya = a.embed(&deltas).unwrap();
        let yb = b.embed(&deltas).unwrap();
        assert_eq!(ya.data, yb.data, "replicas must start from identical state");
        assert_eq!(a.landmarks(), 10);
    }

    #[test]
    fn embed_rejects_wrong_width() {
        let mut rng = Rng::new(2);
        let lm = Matrix::random_normal(&mut rng, 12, 3, 1.0);
        let mut m = RustOptimise { landmarks: lm, cfg: OseOptConfig::default() };
        let bad = Matrix::zeros(2, 11);
        assert!(m.embed(&bad).is_err());
    }
}
