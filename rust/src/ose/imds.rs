//! I-MDS interpolation baseline (Bae, Choi, Qiu & Fox, HPDC'10) — the
//! prior large-scale OSE the paper positions itself against (Sec. 3).
//!
//! For each new point, find its k nearest neighbours among the landmarks
//! (by original-space dissimilarity), then place the point by majorizing
//! the stress to those k neighbours only (the paper's Eq. 2 restricted to
//! the neighbour set, which is exactly Bae et al.'s per-point SMACOF).
//!
//! The limitations the paper calls out are visible in this implementation:
//! accuracy depends on k, and the placement ignores all non-neighbour
//! landmarks (global structure), which costs accuracy on non-Euclidean
//! string data — quantified by the `ose-baselines` ablation bench.

use anyhow::Result;

use crate::mds::Matrix;

use super::optimise::{embed_point, OseOptConfig};
use super::OseMethod;

#[derive(Clone, Debug)]
/// I-MDS settings: neighbourhood size + per-point optimiser budget.
pub struct ImdsConfig {
    /// Number of nearest landmarks used per point.
    pub k: usize,
    /// Per-point majorization budget of the local solve.
    pub opt: OseOptConfig,
}

impl Default for ImdsConfig {
    fn default() -> Self {
        Self { k: 10, opt: OseOptConfig::default() }
    }
}

/// I-MDS interpolation over a fixed landmark configuration.
pub struct Imds {
    /// L x K landmark configuration.
    pub landmarks: Matrix,
    /// Interpolation settings.
    pub cfg: ImdsConfig,
}

impl Imds {
    /// Place one point from its distances to ALL landmarks (the method
    /// itself then restricts to the k nearest).
    pub fn place(&self, deltas: &[f32]) -> Vec<f32> {
        assert_eq!(deltas.len(), self.landmarks.rows);
        let k = self.cfg.k.min(self.landmarks.rows).max(1);
        // indices of the k smallest dissimilarities
        let mut idx: Vec<usize> = (0..deltas.len()).collect();
        idx.sort_by(|&a, &b| deltas[a].partial_cmp(&deltas[b]).unwrap());
        idx.truncate(k);
        // restricted landmark set + dissimilarities
        let sub = self.landmarks.select_rows(&idx);
        let sub_d: Vec<f32> = idx.iter().map(|&i| deltas[i]).collect();
        // init at the mean of the neighbour positions (Bae et al.), plus a
        // deterministic nudge: starting exactly ON an anchor is a stationary
        // point of Eq. 2 (d = 0 zeroes the gradient) and would never move
        let k_dim = self.landmarks.cols;
        let mut y0 = vec![0.0f32; k_dim];
        for &i in &idx {
            for (c, v) in y0.iter_mut().enumerate() {
                *v += self.landmarks.at(i, c) / k as f32;
            }
        }
        y0[0] += 1e-3;
        embed_point(&sub, &sub_d, Some(&y0), &self.cfg.opt).coords
    }
}

impl OseMethod for Imds {
    fn embed(&mut self, deltas: &Matrix) -> Result<Matrix> {
        anyhow::ensure!(deltas.cols == self.landmarks.rows, "bad input width");
        let mut out = Matrix::zeros(deltas.rows, self.landmarks.cols);
        for r in 0..deltas.rows {
            let y = self.place(deltas.row(r));
            out.row_mut(r).copy_from_slice(&y);
        }
        Ok(out)
    }

    fn dim(&self) -> usize {
        self.landmarks.cols
    }

    fn landmarks(&self) -> usize {
        self.landmarks.rows
    }

    fn name(&self) -> &'static str {
        "imds-knn"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strdist::euclidean;
    use crate::util::prng::Rng;

    fn setup(seed: u64, l: usize, k: usize) -> (Matrix, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let lm = Matrix::random_normal(&mut rng, l, k, 1.0);
        let target: Vec<f32> = (0..k).map(|_| rng.next_normal() as f32 * 0.5).collect();
        let deltas: Vec<f32> = (0..l)
            .map(|i| euclidean(lm.row(i), &target) as f32)
            .collect();
        (lm, target, deltas)
    }

    #[test]
    fn recovers_point_with_enough_neighbours() {
        let (lm, target, deltas) = setup(1, 40, 3);
        let imds = Imds {
            landmarks: lm,
            cfg: ImdsConfig { k: 15, opt: OseOptConfig { max_iters: 2000, rel_tol: 1e-12 } },
        };
        let y = imds.place(&deltas);
        for c in 0..3 {
            assert!((y[c] - target[c]).abs() < 0.15, "{y:?} vs {target:?}");
        }
    }

    #[test]
    fn k_one_snaps_near_nearest_landmark() {
        let (lm, _, deltas) = setup(2, 20, 3);
        let nearest = (0..20)
            .min_by(|&a, &b| deltas[a].partial_cmp(&deltas[b]).unwrap())
            .unwrap();
        let imds = Imds {
            landmarks: lm.clone(),
            cfg: ImdsConfig { k: 1, ..Default::default() },
        };
        let y = imds.place(&deltas);
        // with a single anchor the point lies on the sphere around it
        let d = euclidean(&y, lm.row(nearest));
        assert!((d - deltas[nearest] as f64).abs() < 1e-2, "d={d}");
    }

    #[test]
    fn trait_impl_batches() {
        let (lm, _, deltas) = setup(3, 25, 4);
        let mut m = Imds { landmarks: lm, cfg: ImdsConfig::default() };
        let batch = Matrix::from_rows(&[deltas.clone(), deltas.clone()]);
        let y = m.embed(&batch).unwrap();
        assert_eq!((y.rows, y.cols), (2, 4));
        assert_eq!(y.row(0), y.row(1));
        assert_eq!(m.name(), "imds-knn");
    }

    #[test]
    fn more_neighbours_cannot_hurt_on_realizable_data() {
        let (lm, target, deltas) = setup(4, 60, 5);
        let err_of = |k: usize| {
            let imds = Imds {
                landmarks: lm.clone(),
                cfg: ImdsConfig {
                    k,
                    opt: OseOptConfig { max_iters: 1500, rel_tol: 1e-12 },
                },
            };
            let y = imds.place(&deltas);
            euclidean(&y, &target)
        };
        // realizable geometry: k=30 must beat k=2 clearly
        assert!(err_of(30) < err_of(2) + 1e-6);
    }
}
