//! The paper's optimisation OSE (Sec. 4.1) in its original one-point-at-a-
//! time form: minimise Eq. 2 for a single new object against the fixed
//! landmarks. The update is the per-point majorization step, which (see
//! `python/compile/model.py`) equals gradient descent with lr = 1/(2L) and
//! descends monotonically — matching the R `optim` result without line
//! searches.
//!
//! This pure-Rust path is (a) the single-query serving fallback, (b) the
//! baseline that stands in for the authors' R implementation in the RT
//! figures, and (c) the oracle the batched `ose_opt` PJRT artifact is
//! cross-checked against.

use crate::mds::Matrix;

#[derive(Clone, Debug)]
/// Per-point majorization budget (paper Sec. 4.1).
pub struct OseOptConfig {
    /// Maximum majorization iterations per point.
    pub max_iters: usize,
    /// Stop when the objective's relative improvement drops below this.
    pub rel_tol: f64,
}

impl Default for OseOptConfig {
    fn default() -> Self {
        Self { max_iters: 200, rel_tol: 1e-7 }
    }
}

/// Objective (Eq. 2) and gradient at `y` for landmarks `lm` (L x K) and
/// dissimilarities `delta` (len L).
pub fn objective_and_grad(lm: &Matrix, delta: &[f32], y: &[f32]) -> (f64, Vec<f64>) {
    let k = lm.cols;
    let mut obj = 0.0f64;
    let mut grad = vec![0.0f64; k];
    for i in 0..lm.rows {
        let li = lm.row(i);
        let mut sq = 0.0f64;
        for c in 0..k {
            let d = y[c] as f64 - li[c] as f64;
            sq += d * d;
        }
        let d = sq.sqrt();
        let resid = d - delta[i] as f64;
        obj += resid * resid;
        if d > 1e-12 {
            let coef = 2.0 * resid / d;
            for c in 0..k {
                grad[c] += coef * (y[c] as f64 - li[c] as f64);
            }
        }
    }
    (obj, grad)
}

/// Result of one embedding.
#[derive(Clone, Debug)]
pub struct OsePoint {
    /// Embedded coordinates (length K).
    pub coords: Vec<f32>,
    /// Final Eq.-2 objective value.
    pub objective: f64,
    /// Majorization iterations actually run.
    pub iters: usize,
    /// True when the run stopped because the relative objective change
    /// dropped below `rel_tol`; false when it exhausted `max_iters`.
    /// Callers can use this to distinguish a converged embedding from a
    /// stalled one that merely ran out of budget.
    pub converged: bool,
}

/// Embed one new point. `y0 = None` uses the paper's all-zeros initial
/// guess (Sec. 6 discusses this choice).
pub fn embed_point(
    lm: &Matrix,
    delta: &[f32],
    y0: Option<&[f32]>,
    cfg: &OseOptConfig,
) -> OsePoint {
    assert_eq!(lm.rows, delta.len());
    let k = lm.cols;
    let l = lm.rows as f64;
    let mut y: Vec<f32> = match y0 {
        Some(v) => v.to_vec(),
        None => vec![0.0; k],
    };
    let lr = 1.0 / (2.0 * l); // majorization step
    let mut prev = f64::INFINITY;
    let mut obj = 0.0;
    let mut iters = 0;
    let mut converged = false;
    for it in 0..cfg.max_iters {
        let (o, grad) = objective_and_grad(lm, delta, &y);
        obj = o;
        iters = it + 1;
        // relative ABSOLUTE change: a (numerically possible) objective
        // increase is not convergence — the old signed test treated any
        // increase as "improvement below tol" and stopped on the spot
        if prev.is_finite() && (prev - o).abs() / prev.abs().max(1e-30) < cfg.rel_tol {
            converged = true;
            break;
        }
        prev = o;
        for c in 0..k {
            y[c] -= (lr * grad[c]) as f32;
        }
    }
    OsePoint { coords: y, objective: obj, iters, converged }
}

/// Embed a batch serially (the R protocol: "both methods map a single
/// out-of-sample point at a time"). Returns an m x K matrix.
pub fn embed_batch(lm: &Matrix, deltas: &Matrix, cfg: &OseOptConfig) -> Matrix {
    assert_eq!(deltas.cols, lm.rows);
    let mut out = Matrix::zeros(deltas.rows, lm.cols);
    for r in 0..deltas.rows {
        let p = embed_point(lm, deltas.row(r), None, cfg);
        out.row_mut(r).copy_from_slice(&p.coords);
    }
    out
}

/// Embed one point against only the `idx`-selected landmark rows — the
/// sparse `query_k` restriction of Eq. 2 (docs/QUERY_PATH.md). The
/// majorization runs on the gathered k x K sub-problem, so the step size
/// becomes 1/(2k) and each iteration costs O(k·K) instead of O(L·K).
/// With `idx = 0..L` the gather is the identity and the result is
/// bit-identical to [`embed_point`].
///
/// `idx` entries must be in-range; callers get them from
/// [`LandmarkGraph::knn_delta`](crate::mds::graph::LandmarkGraph::knn_delta)
/// (O(k log L) graph search) or [`nearest_k`](crate::mds::graph::nearest_k)
/// (exact O(L) scan).
pub fn embed_point_k(
    lm: &Matrix,
    delta: &[f32],
    idx: &[usize],
    y0: Option<&[f32]>,
    cfg: &OseOptConfig,
) -> OsePoint {
    assert_eq!(lm.rows, delta.len());
    let sub = lm.select_rows(idx);
    let dsub: Vec<f32> = idx.iter().map(|&i| delta[i]).collect();
    embed_point(&sub, &dsub, y0, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strdist::euclidean;
    use crate::util::prng::Rng;

    fn landmarks(seed: u64, l: usize, k: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::random_normal(&mut rng, l, k, 1.0)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let lm = landmarks(1, 20, 4);
        let delta: Vec<f32> = (0..20).map(|i| 0.5 + (i as f32) * 0.1).collect();
        let y = [0.3f32, -0.2, 0.7, 0.1];
        let (_, grad) = objective_and_grad(&lm, &delta, &y);
        let h = 1e-4f32;
        for c in 0..4 {
            let mut yp = y;
            yp[c] += h;
            let mut ym = y;
            ym[c] -= h;
            let (op, _) = objective_and_grad(&lm, &delta, &yp);
            let (om, _) = objective_and_grad(&lm, &delta, &ym);
            let fd = (op - om) / (2.0 * h as f64);
            assert!(
                (fd - grad[c]).abs() < 1e-2 * (1.0 + grad[c].abs()),
                "c={c}: fd={fd} grad={}",
                grad[c]
            );
        }
    }

    #[test]
    fn recovers_exact_position_for_realisable_deltas() {
        let lm = landmarks(2, 50, 7);
        let mut rng = Rng::new(3);
        let target: Vec<f32> = (0..7).map(|_| rng.next_normal() as f32).collect();
        let delta: Vec<f32> = (0..50)
            .map(|i| euclidean(lm.row(i), &target) as f32)
            .collect();
        let p = embed_point(&lm, &delta, None, &OseOptConfig {
            max_iters: 3000,
            rel_tol: 1e-14,
        });
        assert!(p.objective < 1e-6, "objective {}", p.objective);
        for c in 0..7 {
            assert!(
                (p.coords[c] - target[c]).abs() < 0.02,
                "coord {c}: {} vs {}",
                p.coords[c],
                target[c]
            );
        }
    }

    #[test]
    fn objective_descends_monotonically() {
        let lm = landmarks(4, 30, 5);
        let delta: Vec<f32> = (0..30).map(|i| 1.0 + 0.05 * i as f32).collect();
        let mut y = vec![0.0f32; 5];
        let lr = 1.0 / 60.0;
        let mut prev = f64::INFINITY;
        for _ in 0..100 {
            let (o, g) = objective_and_grad(&lm, &delta, &y);
            assert!(o <= prev + 1e-9, "{prev} -> {o}");
            prev = o;
            for c in 0..5 {
                y[c] -= (lr * g[c]) as f32;
            }
        }
    }

    #[test]
    fn in_sample_landmark_embeds_onto_itself() {
        let lm = landmarks(5, 40, 7);
        let target = lm.row(7).to_vec();
        let delta: Vec<f32> = (0..40)
            .map(|i| euclidean(lm.row(i), &target) as f32)
            .collect();
        let p = embed_point(&lm, &delta, None, &OseOptConfig {
            max_iters: 5000,
            rel_tol: 1e-15,
        });
        for c in 0..7 {
            assert!((p.coords[c] - target[c]).abs() < 0.05);
        }
    }

    #[test]
    fn batch_matches_pointwise() {
        let lm = landmarks(6, 25, 3);
        let mut rng = Rng::new(7);
        let deltas = Matrix::from_vec(
            4,
            25,
            (0..100).map(|_| rng.next_f32() * 2.0 + 0.5).collect(),
        );
        let cfg = OseOptConfig::default();
        let batch = embed_batch(&lm, &deltas, &cfg);
        for r in 0..4 {
            let p = embed_point(&lm, deltas.row(r), None, &cfg);
            assert_eq!(batch.row(r), p.coords.as_slice());
        }
    }

    #[test]
    fn custom_initial_guess_is_used() {
        // with only one iteration, different starting points must lead to
        // different iterates (Sec. 6 discusses initial-guess sensitivity)
        let lm = landmarks(8, 10, 2);
        let delta = vec![1.0f32; 10];
        let cfg = OseOptConfig { max_iters: 1, rel_tol: 0.0 };
        let from_far = embed_point(&lm, &delta, Some(&[5.0, 5.0]), &cfg);
        let from_zero = embed_point(&lm, &delta, None, &cfg);
        assert_ne!(from_far.coords, from_zero.coords);
        // and iters reports the single step taken
        assert_eq!(from_far.iters, 1);
    }

    #[test]
    fn sparse_embed_with_full_index_set_is_bit_identical() {
        let lm = landmarks(11, 40, 5);
        let mut rng = Rng::new(12);
        let delta: Vec<f32> = (0..40).map(|_| rng.next_f32() * 2.0 + 0.5).collect();
        let cfg = OseOptConfig::default();
        let dense = embed_point(&lm, &delta, None, &cfg);
        let idx: Vec<usize> = (0..40).collect();
        let sparse = embed_point_k(&lm, &delta, &idx, None, &cfg);
        assert_eq!(dense.coords, sparse.coords);
        assert_eq!(dense.objective.to_bits(), sparse.objective.to_bits());
        assert_eq!(dense.iters, sparse.iters);
    }

    #[test]
    fn sparse_embed_recovers_realisable_target_from_k_nearest() {
        let lm = landmarks(13, 60, 4);
        let mut rng = Rng::new(14);
        let target: Vec<f32> = (0..4).map(|_| rng.next_normal() as f32).collect();
        let delta: Vec<f32> = (0..60)
            .map(|i| euclidean(lm.row(i), &target) as f32)
            .collect();
        let idx = crate::mds::graph::nearest_k(&delta, 16);
        let p = embed_point_k(&lm, &delta, &idx, None, &OseOptConfig {
            max_iters: 3000,
            rel_tol: 1e-14,
        });
        for c in 0..4 {
            assert!(
                (p.coords[c] - target[c]).abs() < 0.05,
                "coord {c}: {} vs {}",
                p.coords[c],
                target[c]
            );
        }
    }

    #[test]
    fn converged_flag_distinguishes_stall_from_success() {
        let lm = landmarks(9, 30, 3);
        // non-realisable deltas: the objective plateaus at a positive local
        // minimum, so the relative change genuinely vanishes there
        let delta = vec![1.0f32; 30];
        let ok = embed_point(&lm, &delta, None, &OseOptConfig {
            max_iters: 20_000,
            rel_tol: 1e-8,
        });
        assert!(ok.converged, "should converge (iters {})", ok.iters);
        assert!(ok.iters < 20_000);
        // a starved budget exhausts without meeting the tolerance
        let starved = embed_point(&lm, &delta, None, &OseOptConfig {
            max_iters: 2,
            rel_tol: 1e-12,
        });
        assert!(!starved.converged);
        assert_eq!(starved.iters, 2);
        // rel_tol = 0 disables the stop rule entirely (never "converged")
        let full = embed_point(&lm, &delta, None, &OseOptConfig {
            max_iters: 50,
            rel_tol: 0.0,
        });
        assert!(!full.converged);
        assert_eq!(full.iters, 50);
    }
}
