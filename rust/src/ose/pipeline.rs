//! Bounded-memory streaming OSE pipeline — the stage that turns the
//! two-phase design (paper Sec. 4) into a genuinely streaming system.
//!
//! The monolithic path materialises the full `N x L` out-of-sample
//! dissimilarity matrix (`mds::dissimilarity::cross_matrix`) before the
//! backend sees a single row, so peak memory grows linearly with N and the
//! dissimilarity stage never overlaps the embedding stage. This module
//! drives the same work in fixed-size chunks through a double-buffered
//! producer/consumer instead:
//!
//! ```text
//!   producer thread              rendezvous            consumer (caller)
//!   cross_matrix(chunk c+1, L) --- send/recv ---> method.embed(chunk c)
//!                                                 sink(start, coords)
//! ```
//!
//! The channel is a rendezvous (`sync_channel(0)`): the producer computes
//! the next `chunk x L` block while the consumer embeds the current one,
//! and blocks in `send` until the consumer takes it. At most **two**
//! `chunk x L` blocks are therefore alive at any instant, so transient
//! memory is `O(2·chunk·L)` regardless of N — and the two dominant costs
//! (Levenshtein block build, backend embedding) overlap in wall-clock.
//!
//! Caveat on the overlap: both stages parallelise internally over the
//! same `default_parallelism()` budget, so when *both* are CPU-bound the
//! machine is oversubscribed up to 2x and the wall-clock win over the
//! monolithic path is modest (the scheduler interleaves them). The
//! guaranteed property of this module is the memory bound; overlap pays
//! off most when one stage underuses the CPU (string metrics with ragged
//! costs, an accelerator-backed embed, or I/O-fed objects).
//!
//! Chunking is exact, not approximate: both OSE methods are row-independent
//! (per-point majorization; per-row MLP forward), so streaming output
//! matches the monolithic path bit-for-bit for a fixed step budget — the
//! contract enforced by `tests/streaming.rs`. (With `BackendOpt`'s
//! batch-mean early stopping enabled, the stopping decision is made per
//! chunk instead of per full batch, which can change results within the
//! convergence tolerance.) Row independence is also what lets the sparse
//! `query_k` path (`BackendOpt` over the landmark small-world graph,
//! [`crate::mds::graph`]; see docs/QUERY_PATH.md) drop in per row without
//! touching this module: chunked streaming composes with any `OseMethod`.
//!
//! This bounds stage (2). Stage (1) — the base MDS every streamed chunk
//! is anchored on — has its own scaling escape hatch: the divide-and-
//! conquer solver ([`crate::mds::divide`], selected via
//! [`crate::coordinator::embedder::BaseSolver`]) replaces the monolithic
//! O(L^2)-per-iteration landmark solve with B parallel block solves
//! stitched by Procrustes, so both stages of the pipeline stay bounded as
//! the sample and landmark counts grow.

use anyhow::Result;

use crate::mds::dissimilarity::cross_matrix;
use crate::mds::Matrix;
use crate::strdist::Dissimilarity;

use super::OseMethod;

/// Default rows per streamed chunk: at L = 300 landmarks two f32 blocks of
/// this size are ~2.5 MB — safely inside last-level cache pressure limits
/// while keeping per-chunk dispatch overhead negligible.
pub const DEFAULT_STREAM_CHUNK: usize = 1024;

/// What one streaming run did (timings are per-stage sums, so overlap
/// shows up as `produce_s + embed_s > wall`).
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    /// Total rows embedded.
    pub rows: usize,
    /// Number of chunks processed.
    pub chunks: usize,
    /// Largest chunk actually seen by the embedder (<= configured chunk;
    /// the final chunk may be ragged).
    pub max_chunk_rows: usize,
    /// Seconds spent building dissimilarity blocks (producer thread).
    pub produce_s: f64,
    /// Seconds spent embedding blocks (consumer thread).
    pub embed_s: f64,
}

/// Stream-embed `objects` against `landmarks` in chunks of `chunk` rows,
/// delivering each embedded block to `sink(start_row, coords)` in order.
///
/// `sink` receives every chunk exactly once, in ascending `start_row`
/// order; `coords` has one row per object of the chunk. Errors from the
/// method or the sink abort the stream (the producer notices the hang-up
/// and stops). Peak transient memory is two `chunk x L` blocks plus one
/// `chunk x K` coordinate block — independent of `objects.len()`.
pub fn embed_stream_with<T, F>(
    objects: &[&T],
    landmarks: &[&T],
    metric: &dyn Dissimilarity<T>,
    method: &mut dyn OseMethod,
    chunk: usize,
    sink: F,
) -> Result<StreamStats>
where
    T: Sync + ?Sized,
    F: FnMut(usize, &Matrix) -> Result<()>,
{
    anyhow::ensure!(
        landmarks.len() == method.landmarks(),
        "method expects {} landmarks, got {}",
        method.landmarks(),
        landmarks.len()
    );
    embed_stream_blocks(
        objects.len(),
        chunk,
        |start, end| cross_matrix(&objects[start..end], landmarks, metric),
        method,
        sink,
    )
}

/// The generic streaming driver under [`embed_stream_with`]: the double-
/// buffered producer/consumer over an arbitrary block producer.
///
/// `produce(start, end)` runs on the producer thread and must return the
/// `(end - start) x L` dissimilarity block for rows `start..end` — built
/// from an in-memory object slice ([`embed_stream_with`]), read out of a
/// disk-backed [`crate::data::source::ObjectTable`]
/// ([`crate::coordinator::embedder::embed_corpus`]), or anything else
/// that can serve rows by range. Exactly one `produce` call is in flight
/// at a time and calls arrive in ascending order, so a producer may keep
/// sequential state (file cursors, decompression windows).
///
/// Memory contract: at most two produced blocks are alive at any instant
/// (one being consumed, one in flight behind the rendezvous channel) —
/// the producer's own transient allocations ride inside its `produce`
/// call and die before the next send.
pub fn embed_stream_blocks<P, F>(
    rows: usize,
    chunk: usize,
    mut produce: P,
    method: &mut dyn OseMethod,
    mut sink: F,
) -> Result<StreamStats>
where
    P: FnMut(usize, usize) -> Matrix + Send,
    F: FnMut(usize, &Matrix) -> Result<()>,
{
    let chunk = chunk.max(1);
    let mut stats = StreamStats { rows, ..Default::default() };
    if rows == 0 {
        return Ok(stats);
    }
    let landmarks = method.landmarks();

    let (tx, rx) = std::sync::mpsc::sync_channel::<(usize, Matrix)>(0);
    let mut outcome: Result<()> = Ok(());
    let produce_s = std::thread::scope(|scope| {
        let producer = scope.spawn(move || {
            let mut produce_s = 0.0f64;
            let mut start = 0usize;
            while start < rows {
                let end = (start + chunk).min(rows);
                let t0 = std::time::Instant::now();
                let block = produce(start, end);
                produce_s += t0.elapsed().as_secs_f64();
                // a send error means the consumer bailed (embed/sink error
                // dropped the receiver): stop producing, not an error here
                if tx.send((start, block)).is_err() {
                    break;
                }
                start = end;
            }
            produce_s
        });

        for (start, block) in rx.iter() {
            stats.chunks += 1;
            stats.max_chunk_rows = stats.max_chunk_rows.max(block.rows);
            if block.cols != landmarks {
                outcome = Err(anyhow::anyhow!(
                    "producer built a {}-column block for a {landmarks}-landmark method",
                    block.cols
                ));
                break;
            }
            let t0 = std::time::Instant::now();
            let coords = match method.embed(&block) {
                Ok(c) => c,
                Err(e) => {
                    outcome = Err(e);
                    break;
                }
            };
            // a method that pads or drops rows (e.g. a batch-monomorphic
            // artifact backend) would silently corrupt neighbouring chunks
            // through the sink's start-offset arithmetic — reject it here
            if coords.rows != block.rows {
                outcome = Err(anyhow::anyhow!(
                    "method returned {} rows for a {}-row chunk",
                    coords.rows,
                    block.rows
                ));
                break;
            }
            stats.embed_s += t0.elapsed().as_secs_f64();
            if let Err(e) = sink(start, &coords) {
                outcome = Err(e);
                break;
            }
        }
        drop(rx); // hang up so a producer blocked in send() exits

        match producer.join() {
            Ok(s) => s,
            Err(panic) => std::panic::resume_unwind(panic),
        }
    });
    outcome?;
    stats.produce_s = produce_s;
    Ok(stats)
}

/// Stream-embed all objects and collect the result into an `N x K` matrix:
/// the drop-in bounded-memory replacement for `cross_matrix` + one
/// monolithic `method.embed` call. Only the output and two transient
/// `chunk x L` blocks are ever allocated — never an `N x L` matrix.
pub fn embed_stream<T: Sync + ?Sized>(
    objects: &[&T],
    landmarks: &[&T],
    metric: &dyn Dissimilarity<T>,
    method: &mut dyn OseMethod,
    chunk: usize,
) -> Result<(Matrix, StreamStats)> {
    let k = method.dim();
    let mut out = Matrix::zeros(objects.len(), k);
    let stats = embed_stream_with(
        objects,
        landmarks,
        metric,
        method,
        chunk,
        |start, coords| {
            anyhow::ensure!(coords.cols == k, "method changed output width");
            out.data[start * k..start * k + coords.data.len()]
                .copy_from_slice(&coords.data);
            Ok(())
        },
    )?;
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mds::Matrix;
    use crate::ose::{OseOptConfig, RustOptimise};
    use crate::strdist::Levenshtein;
    use crate::util::prng::Rng;

    fn setup(l: usize, k: usize) -> (Vec<String>, Matrix) {
        let landmarks: Vec<String> = (0..l).map(|i| format!("landmark{i:02}")).collect();
        let mut rng = Rng::new(0x57ea);
        (landmarks, Matrix::random_normal(&mut rng, l, k, 1.0))
    }

    #[test]
    fn streams_all_rows_in_order() {
        let (lm_names, lm_cfg) = setup(12, 3);
        let lm_refs: Vec<&str> = lm_names.iter().map(|s| s.as_str()).collect();
        let names: Vec<String> = (0..41).map(|i| format!("query {i}")).collect();
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let mut method =
            RustOptimise { landmarks: lm_cfg, cfg: OseOptConfig::default() };
        let mut seen_starts = Vec::new();
        let stats = embed_stream_with(
            &objs,
            &lm_refs,
            &Levenshtein,
            &mut method,
            8,
            |start, coords| {
                seen_starts.push(start);
                assert_eq!(coords.cols, 3);
                assert!(coords.data.iter().all(|v| v.is_finite()));
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(seen_starts, vec![0, 8, 16, 24, 32, 40]);
        assert_eq!(stats.rows, 41);
        assert_eq!(stats.chunks, 6);
        assert_eq!(stats.max_chunk_rows, 8); // final chunk is ragged (1 row)
    }

    #[test]
    fn empty_input_is_a_clean_no_op() {
        let (lm_names, lm_cfg) = setup(5, 2);
        let lm_refs: Vec<&str> = lm_names.iter().map(|s| s.as_str()).collect();
        let mut method =
            RustOptimise { landmarks: lm_cfg, cfg: OseOptConfig::default() };
        let objs: Vec<&str> = Vec::new();
        let (out, stats) =
            embed_stream(&objs, &lm_refs, &Levenshtein, &mut method, 16).unwrap();
        assert_eq!(out.rows, 0);
        assert_eq!(stats.chunks, 0);
    }

    #[test]
    fn landmark_count_mismatch_is_rejected() {
        let (lm_names, lm_cfg) = setup(6, 2);
        // method built for 6 landmarks, but only 4 passed in
        let lm_refs: Vec<&str> = lm_names[..4].iter().map(|s| s.as_str()).collect();
        let mut method =
            RustOptimise { landmarks: lm_cfg, cfg: OseOptConfig::default() };
        let err = embed_stream_with(
            &["q"],
            &lm_refs,
            &Levenshtein,
            &mut method,
            4,
            |_, _| Ok(()),
        );
        assert!(err.is_err());
    }

    #[test]
    fn blocks_driver_accepts_custom_producers() {
        let (_, lm_cfg) = setup(5, 2);
        let mut method =
            RustOptimise { landmarks: lm_cfg, cfg: OseOptConfig::default() };
        // synthetic producer: block values derived from the row index
        // alone, no object slice anywhere
        let mut rows_seen = 0usize;
        let stats = embed_stream_blocks(
            23,
            10,
            |start, end| {
                let mut m = Matrix::zeros(end - start, 5);
                for r in 0..m.rows {
                    for c in 0..5 {
                        m.set(r, c, 1.0 + ((start + r + c) % 7) as f32);
                    }
                }
                m
            },
            &mut method,
            |_, coords| {
                rows_seen += coords.rows;
                assert!(coords.data.iter().all(|v| v.is_finite()));
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(rows_seen, 23);
        assert_eq!(stats.chunks, 3);
    }

    #[test]
    fn blocks_driver_rejects_wrong_width_blocks() {
        let (_, lm_cfg) = setup(5, 2);
        let mut method =
            RustOptimise { landmarks: lm_cfg, cfg: OseOptConfig::default() };
        let r = embed_stream_blocks(
            8,
            4,
            |start, end| Matrix::zeros(end - start, 3), // 3 != 5 landmarks
            &mut method,
            |_, _| Ok(()),
        );
        assert!(r.is_err());
    }

    #[test]
    fn sink_error_aborts_stream() {
        let (lm_names, lm_cfg) = setup(6, 2);
        let lm_refs: Vec<&str> = lm_names.iter().map(|s| s.as_str()).collect();
        let names: Vec<String> = (0..100).map(|i| format!("q{i}")).collect();
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let mut method =
            RustOptimise { landmarks: lm_cfg, cfg: OseOptConfig::default() };
        let mut calls = 0usize;
        let r = embed_stream_with(
            &objs,
            &lm_refs,
            &Levenshtein,
            &mut method,
            10,
            |_, _| {
                calls += 1;
                anyhow::bail!("sink says stop")
            },
        );
        assert!(r.is_err());
        assert_eq!(calls, 1, "stream must stop at the first sink error");
    }
}
