//! Trosset & Priebe (2008) classical-MDS out-of-sample baseline (paper
//! Sec. 3): embed a new point into an existing *classical* MDS
//! configuration by least-squares matching of pairwise inner products
//! rather than distances.
//!
//! Given a centred configuration X (from classical MDS of Delta) and the
//! squared dissimilarities d2 of the new point y to the configured points,
//! the double-centred target inner products are
//!
//! ```text
//! b_i = -1/2 (d2_i - mean_i(d2) - rowmean2_i + grand2)
//! ```
//!
//! and the least-squares estimate solves  X^T X w = X^T b  (a K x K
//! system), i.e. w = (X^T X)^{-1} X^T b — closed form, no iteration. The
//! paper's criticism stands: it needs distances to ALL configured points
//! (O(N) per query, not O(L)) and assumes the classical (inner-product)
//! embedding, so it degrades on strongly non-Euclidean string data. Both
//! effects are measured by the `ose-baselines` ablation.

use anyhow::Result;

use crate::mds::Matrix;

use super::OseMethod;

/// Solve the K x K normal equations via Gaussian elimination with partial
/// pivoting (K <= ~10 here, numerical ceremony unnecessary).
fn solve(a: &mut [f64], b: &mut [f64], k: usize) -> Option<Vec<f64>> {
    for col in 0..k {
        // pivot
        let mut p = col;
        for r in (col + 1)..k {
            if a[r * k + col].abs() > a[p * k + col].abs() {
                p = r;
            }
        }
        if a[p * k + col].abs() < 1e-12 {
            return None;
        }
        if p != col {
            for c in 0..k {
                a.swap(col * k + c, p * k + c);
            }
            b.swap(col, p);
        }
        let piv = a[col * k + col];
        for r in (col + 1)..k {
            let f = a[r * k + col] / piv;
            if f == 0.0 {
                continue;
            }
            for c in col..k {
                a[r * k + c] -= f * a[col * k + c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0f64; k];
    for col in (0..k).rev() {
        let mut acc = b[col];
        for c in (col + 1)..k {
            acc -= a[col * k + c] * x[c];
        }
        x[col] = acc / a[col * k + col];
    }
    Some(x)
}

/// Classical-MDS OSE over a centred configuration.
pub struct ClassicalOse {
    /// Centred N x K configuration (classical MDS output).
    pub config: Matrix,
    /// Row means of the squared dissimilarity matrix of the configuration
    /// (precomputed from the original Delta).
    pub row_means_sq: Vec<f64>,
    /// Grand mean of the squared dissimilarity matrix.
    pub grand_mean_sq: f64,
}

impl ClassicalOse {
    /// Build from the original dissimilarity matrix.
    pub fn new(config: Matrix, delta: &Matrix) -> Self {
        let n = delta.rows;
        let mut row_means_sq = vec![0.0f64; n];
        let mut grand = 0.0f64;
        for i in 0..n {
            let mut acc = 0.0f64;
            for j in 0..n {
                let d = delta.at(i, j) as f64;
                acc += d * d;
            }
            row_means_sq[i] = acc / n as f64;
            grand += acc;
        }
        Self {
            config,
            row_means_sq,
            grand_mean_sq: grand / (n * n) as f64,
        }
    }

    /// Embed one point from its dissimilarities to ALL configured points.
    pub fn place(&self, deltas: &[f32]) -> Option<Vec<f32>> {
        let n = self.config.rows;
        let k = self.config.cols;
        assert_eq!(deltas.len(), n);
        let d2: Vec<f64> = deltas.iter().map(|d| (*d as f64) * (*d as f64)).collect();
        let mean_d2 = d2.iter().sum::<f64>() / n as f64;
        // target inner products b_i = x_i . y
        let b: Vec<f64> = (0..n)
            .map(|i| -0.5 * (d2[i] - mean_d2 - self.row_means_sq[i] + self.grand_mean_sq))
            .collect();
        // normal equations: (X^T X) w = X^T b
        let mut xtx = vec![0.0f64; k * k];
        let mut xtb = vec![0.0f64; k];
        for i in 0..n {
            let xi = self.config.row(i);
            for a in 0..k {
                xtb[a] += xi[a] as f64 * b[i];
                for c in a..k {
                    xtx[a * k + c] += xi[a] as f64 * xi[c] as f64;
                }
            }
        }
        for a in 0..k {
            for c in 0..a {
                xtx[a * k + c] = xtx[c * k + a];
            }
        }
        solve(&mut xtx, &mut xtb, k).map(|w| w.iter().map(|v| *v as f32).collect())
    }
}

impl OseMethod for ClassicalOse {
    fn embed(&mut self, deltas: &Matrix) -> Result<Matrix> {
        anyhow::ensure!(deltas.cols == self.config.rows, "bad input width");
        let mut out = Matrix::zeros(deltas.rows, self.config.cols);
        for r in 0..deltas.rows {
            let y = self
                .place(deltas.row(r))
                .ok_or_else(|| anyhow::anyhow!("degenerate configuration"))?;
            out.row_mut(r).copy_from_slice(&y);
        }
        Ok(out)
    }

    fn dim(&self) -> usize {
        self.config.cols
    }

    fn landmarks(&self) -> usize {
        self.config.rows
    }

    fn name(&self) -> &'static str {
        "classical-tp"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mds::classical::classical_mds;
    use crate::strdist::euclidean;
    use crate::util::prng::Rng;

    #[test]
    fn solver_inverts_known_system() {
        // A = [[2,1],[1,3]], b = [5, 10] -> x = [1, 3]
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![5.0, 10.0];
        let x = solve(&mut a, &mut b, 2).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn solver_rejects_singular() {
        let mut a = vec![1.0, 2.0, 2.0, 4.0];
        let mut b = vec![1.0, 2.0];
        assert!(solve(&mut a, &mut b, 2).is_none());
    }

    #[test]
    fn embeds_euclidean_point_exactly() {
        // For truly Euclidean data, Trosset-Priebe recovers the point (up
        // to the configuration's own reconstruction error).
        let mut rng = Rng::new(1);
        let n = 30;
        let truth = Matrix::random_normal(&mut rng, n, 3, 1.0);
        let mut delta = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                delta.set(i, j, euclidean(truth.row(i), truth.row(j)) as f32);
            }
        }
        let config = classical_mds(&delta, 3);
        let ose = ClassicalOse::new(config.clone(), &delta);

        // new point = a held-out location; its distances to all configured
        let y_true: Vec<f32> = (0..3).map(|_| rng.next_normal() as f32).collect();
        let deltas: Vec<f32> = (0..n)
            .map(|i| euclidean(truth.row(i), &y_true) as f32)
            .collect();
        let y = ose.place(&deltas).unwrap();
        // compare DISTANCES (configuration is rotated vs truth)
        for i in (0..n).step_by(7) {
            let got = euclidean(&y, config.row(i));
            let want = deltas[i] as f64;
            assert!(
                (got - want).abs() < 0.15 * (1.0 + want),
                "i={i}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn in_sample_point_maps_onto_itself() {
        let mut rng = Rng::new(2);
        let n = 25;
        let truth = Matrix::random_normal(&mut rng, n, 4, 1.0);
        let mut delta = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                delta.set(i, j, euclidean(truth.row(i), truth.row(j)) as f32);
            }
        }
        let config = classical_mds(&delta, 4);
        let ose = ClassicalOse::new(config.clone(), &delta);
        let y = ose.place(delta.row(5)).unwrap();
        for c in 0..4 {
            assert!(
                (y[c] - config.at(5, c)).abs() < 0.05,
                "{y:?} vs {:?}",
                config.row(5)
            );
        }
    }
}
