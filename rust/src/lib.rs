//! # lmds-ose
//!
//! Production-grade reproduction of *"High Performance Out-of-sample
//! Embedding Techniques for Multidimensional Scaling"* (Herath, Roughan,
//! Glonek, 2021) as a Rust system with a pluggable compute backend.
//!
//! - **L3 (this crate)**: dissimilarity engine, LSMDS/SMACOF/classical-MDS
//!   solvers, landmark selection, the two OSE methods, a streaming
//!   coordinator with dynamic batching, and the experiment harness for the
//!   paper's Figures 1-4.
//! - **Compute backends** ([`runtime`]): every numeric graph (LSMDS stress
//!   descent, batched OSE optimisation, fused MLP forward/train) executes
//!   through the [`runtime::ComputeBackend`] trait. The default **native**
//!   backend is pure Rust and always available; the **pjrt** backend
//!   (cargo feature `pjrt`) executes AOT artifacts lowered once by
//!   `python/compile/aot.py` — Python never runs on the request path.
//! - **Out-of-core data** ([`data::source`]): disk-backed object tables
//!   whose dissimilarities are evaluated at the storage layer, so both
//!   pipeline stages run against datasets that never fit in RAM.
//!
//! See README.md for the build matrix and docs/ARCHITECTURE.md for the
//! system map (pipeline stages, extension seams, per-stage memory model).

// Documentation is part of the public contract: every exported item
// carries rustdoc, enforced as an error by the CI docs job
// (RUSTDOCFLAGS="-D warnings").
#![warn(missing_docs)]
// Every unsafe operation must sit in its own `unsafe {}` block with a
// SAFETY comment, even inside `unsafe fn` — the per-block granularity is
// what lmds-lint's unsafe-audit rule keys on (`cargo run -p lmds-lint`).
#![deny(unsafe_op_in_unsafe_fn)]
// Style lints that fight the numeric-kernel idiom used throughout
// (index-based loops over matrix rows/cols, 7-arg update kernels).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::manual_memcpy,
    clippy::inherent_to_string_shadow_display,
    clippy::new_without_default,
    clippy::comparison_chain
)]

pub mod coordinator;
pub mod data;
pub mod eval;
pub mod mds;
pub mod nn;
pub mod ose;
pub mod runtime;
pub mod strdist;
pub mod util;
