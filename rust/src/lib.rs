//! # lmds-ose
//!
//! Production-grade reproduction of *"High Performance Out-of-sample
//! Embedding Techniques for Multidimensional Scaling"* (Herath, Roughan,
//! Glonek, 2021) as a three-layer Rust + JAX/Pallas + PJRT system.
//!
//! - **L3 (this crate)**: dissimilarity engine, LSMDS/SMACOF/classical-MDS
//!   solvers, landmark selection, the two OSE methods, a streaming
//!   coordinator with dynamic batching, and the experiment harness for the
//!   paper's Figures 1-4.
//! - **L2/L1 (`python/compile/`)**: the stress/OSE/MLP compute graphs and
//!   their Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt` once;
//!   Python never runs on the request path.
//! - **Runtime**: the [`runtime`] module loads artifacts through the PJRT
//!   CPU client (`xla` crate) and executes them from the serving path.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for measured
//! reproductions of every figure.

pub mod coordinator;
pub mod data;
pub mod eval;
pub mod mds;
pub mod nn;
pub mod ose;
pub mod runtime;
pub mod strdist;
pub mod util;
