//! Neural-network substrate: the pure-Rust MLP + Adam mirror of the PJRT
//! training/inference artifacts (paper Sec. 4.2).

pub mod mlp;

pub use mlp::{
    adam_update, backward, forward, forward_block, forward_blocked, mae_loss, Adam,
    Gradients, MlpParams, MlpShape,
};
