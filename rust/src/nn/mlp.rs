//! Pure-Rust MLP (3 hidden layers, ReLU) with backprop and Adam — the
//! paper's neural OSE model (Sec. 4.2) in its original Keras shape.
//!
//! Two roles:
//! - numerical mirror of the `mlp_train_step` / `mlp_fwd` PJRT artifacts
//!   (integration tests check both produce the same updates/predictions);
//! - standalone fallback trainer/inferencer when artifacts are unavailable
//!   (and the baseline that stands in for the authors' Keras setup).
//!
//! The loss is Eq. 3: mean over the batch of the Euclidean norm of the
//! residual. Gradients are exact (the sqrt is smoothed with the same eps
//! the JAX graph uses, so the two implementations match bit-for-bit-ish).

use crate::mds::Matrix;
use crate::util::prng::Rng;

/// Numerical floor guarding divisions/sqrts in the loss and Adam math.
pub const EPS: f32 = 1e-12;

/// Layer sizes: input L -> h1 -> h2 -> h3 -> K.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MlpShape {
    /// Input width (the landmark count L).
    pub input: usize,
    /// Hidden-layer widths.
    pub hidden: [usize; 3],
    /// Output width (the embedding dimension K).
    pub output: usize,
}

impl MlpShape {
    /// (in, out) dimensions of the four dense layers.
    pub fn layer_dims(&self) -> [(usize, usize); 4] {
        [
            (self.input, self.hidden[0]),
            (self.hidden[0], self.hidden[1]),
            (self.hidden[1], self.hidden[2]),
            (self.hidden[2], self.output),
        ]
    }

    /// Total trainable parameter count (weights + biases).
    pub fn param_count(&self) -> usize {
        self.layer_dims()
            .iter()
            .map(|(i, o)| i * o + o)
            .sum()
    }
}

/// Parameters: weights `w[l]` are (in x out) row-major, biases `b[l]`.
#[derive(Clone, Debug)]
pub struct MlpParams {
    /// Layer shape these parameters belong to.
    pub shape: MlpShape,
    /// Weight matrices, one per layer (in x out).
    pub w: [Matrix; 4],
    /// Bias vectors, one per layer.
    pub b: [Vec<f32>; 4],
}

impl MlpParams {
    /// He-uniform initialisation (Keras `relu` default family).
    pub fn init(shape: &MlpShape, rng: &mut Rng) -> Self {
        let mk = |rng: &mut Rng, i: usize, o: usize| {
            let limit = (6.0 / i as f64).sqrt() as f32;
            let data = (0..i * o)
                .map(|_| (rng.next_f32() * 2.0 - 1.0) * limit)
                .collect();
            Matrix::from_vec(i, o, data)
        };
        let dims = shape.layer_dims();
        Self {
            shape: shape.clone(),
            w: [
                mk(rng, dims[0].0, dims[0].1),
                mk(rng, dims[1].0, dims[1].1),
                mk(rng, dims[2].0, dims[2].1),
                mk(rng, dims[3].0, dims[3].1),
            ],
            b: [
                vec![0.0; dims[0].1],
                vec![0.0; dims[1].1],
                vec![0.0; dims[2].1],
                vec![0.0; dims[3].1],
            ],
        }
    }

    /// Flatten in the artifact argument order (w1,b1,...,w4,b4).
    pub fn flatten(&self) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(8);
        for l in 0..4 {
            out.push(self.w[l].data.clone());
            out.push(self.b[l].clone());
        }
        out
    }

    /// Rebuild from flattened artifact outputs.
    pub fn from_flat(shape: &MlpShape, flat: &[Vec<f32>]) -> Self {
        assert_eq!(flat.len(), 8);
        let dims = shape.layer_dims();
        let w = [
            Matrix::from_vec(dims[0].0, dims[0].1, flat[0].clone()),
            Matrix::from_vec(dims[1].0, dims[1].1, flat[2].clone()),
            Matrix::from_vec(dims[2].0, dims[2].1, flat[4].clone()),
            Matrix::from_vec(dims[3].0, dims[3].1, flat[6].clone()),
        ];
        let b = [flat[1].clone(), flat[3].clone(), flat[5].clone(), flat[7].clone()];
        Self { shape: shape.clone(), w, b }
    }
}

/// x (B x in) @ w (in x out) + b, into `out` (B x out).
fn affine(x: &Matrix, w: &Matrix, b: &[f32]) -> Matrix {
    assert_eq!(x.cols, w.rows);
    let mut out = Matrix::zeros(x.rows, w.cols);
    for r in 0..x.rows {
        let xr = x.row(r);
        let or = out.row_mut(r);
        or.copy_from_slice(b);
        for (i, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = w.row(i);
            for (o, wv) in or.iter_mut().zip(wr.iter()) {
                *o += xv * wv;
            }
        }
    }
    out
}

fn relu_inplace(m: &mut Matrix) {
    for v in m.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Forward pass: d (B x L) -> predictions (B x K).
pub fn forward(params: &MlpParams, d: &Matrix) -> Matrix {
    let mut h = affine(d, &params.w[0], &params.b[0]);
    relu_inplace(&mut h);
    let mut h2 = affine(&h, &params.w[1], &params.b[1]);
    relu_inplace(&mut h2);
    let mut h3 = affine(&h2, &params.w[2], &params.b[2]);
    relu_inplace(&mut h3);
    affine(&h3, &params.w[3], &params.b[3])
}

/// Forward a contiguous block of input rows (flat row-major `rows x L`)
/// through the MLP, writing predictions into `out` (flat `rows x K`).
///
/// This is the cache-blocked production kernel behind
/// [`ComputeBackend::mlp_fwd`](crate::runtime::ComputeBackend): each layer
/// accumulates `out_row += x[i] * w.row(i)` over unit-stride weight rows
/// (row-major axpy) through the kernel-tier
/// [`affine_into`](crate::runtime::simd::affine_into) microkernel —
/// explicitly vectorised under `--kernel-tier simd`, identical bits from
/// the scalar tier — instead of walking `w.at(i, c)` down a column per
/// output as the old per-row kernel did. The per-output accumulation order
/// (ascending input index, bias first) is identical to [`forward`]'s, so
/// the two agree to the last bit apart from `forward`'s skip of exact-zero
/// inputs (which only flips signed-zero sums).
pub fn forward_block(params: &MlpParams, input: &[f32], rows: usize, out: &mut [f32]) {
    let l = params.shape.input;
    let k = params.shape.output;
    assert_eq!(input.len(), rows * l, "input len != rows x L");
    assert_eq!(out.len(), rows * k, "out len != rows x K");
    let mut cur = input.to_vec();
    let mut width = l;
    for layer in 0..4 {
        let w = &params.w[layer];
        let b = &params.b[layer];
        let next_width = w.cols;
        let mut next = vec![0.0f32; rows * next_width];
        for r in 0..rows {
            let xr = &cur[r * width..(r + 1) * width];
            let or = &mut next[r * next_width..(r + 1) * next_width];
            crate::runtime::simd::affine_into(xr, w, b, or);
            if layer < 3 {
                for v in or.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
        }
        cur = next;
        width = next_width;
    }
    out.copy_from_slice(&cur);
}

/// Convenience wrapper over [`forward_block`] for a whole batch matrix.
/// Single-threaded; the native backend parallelises over row blocks.
pub fn forward_blocked(params: &MlpParams, d: &Matrix) -> Matrix {
    assert_eq!(d.cols, params.shape.input, "input width != L");
    let mut out = Matrix::zeros(d.rows, params.shape.output);
    forward_block(params, &d.data, d.rows, &mut out.data);
    out
}

/// Eq. 3 loss: mean_i ||pred_i - target_i||_2 (eps-smoothed).
pub fn mae_loss(pred: &Matrix, target: &Matrix) -> f64 {
    assert_eq!((pred.rows, pred.cols), (target.rows, target.cols));
    let mut acc = 0.0f64;
    for r in 0..pred.rows {
        let mut sq = 0.0f64;
        for (p, t) in pred.row(r).iter().zip(target.row(r).iter()) {
            let d = (*p - *t) as f64;
            sq += d * d;
        }
        acc += (sq + EPS as f64).sqrt();
    }
    acc / pred.rows as f64
}

/// Gradients of the Eq.-3 loss w.r.t. every parameter (exact backprop).
pub struct Gradients {
    /// Weight gradients, one per layer.
    pub w: [Matrix; 4],
    /// Bias gradients, one per layer.
    pub b: [Vec<f32>; 4],
}

/// Forward + backward pass for minibatch `d` against `target`:
/// returns the Eq.-3 loss and the parameter gradients.
pub fn backward(params: &MlpParams, d: &Matrix, target: &Matrix) -> (f64, Gradients) {
    let batch = d.rows as f32;

    // forward with cached activations
    let mut a1 = affine(d, &params.w[0], &params.b[0]);
    relu_inplace(&mut a1);
    let mut a2 = affine(&a1, &params.w[1], &params.b[1]);
    relu_inplace(&mut a2);
    let mut a3 = affine(&a2, &params.w[2], &params.b[2]);
    relu_inplace(&mut a3);
    let pred = affine(&a3, &params.w[3], &params.b[3]);

    // dL/dpred: residual / (B * ||residual||) per row
    let mut delta = Matrix::zeros(pred.rows, pred.cols);
    let mut loss = 0.0f64;
    for r in 0..pred.rows {
        let mut sq = 0.0f64;
        for (p, t) in pred.row(r).iter().zip(target.row(r).iter()) {
            let d = (*p - *t) as f64;
            sq += d * d;
        }
        let norm = (sq + EPS as f64).sqrt();
        loss += norm;
        let scale = 1.0 / (batch as f64 * norm);
        for c in 0..pred.cols {
            let resid = pred.at(r, c) - target.at(r, c);
            delta.set(r, c, (resid as f64 * scale) as f32);
        }
    }
    loss /= batch as f64;

    // backprop through the four affine layers
    let (gw4, gb4, mut d3) = affine_backward(&a3, &params.w[3], &delta);
    relu_backward(&a3, &mut d3);
    let (gw3, gb3, mut d2) = affine_backward(&a2, &params.w[2], &d3);
    relu_backward(&a2, &mut d2);
    let (gw2, gb2, mut d1) = affine_backward(&a1, &params.w[1], &d2);
    relu_backward(&a1, &mut d1);
    let (gw1, gb1, _) = affine_backward(d, &params.w[0], &d1);

    (
        loss,
        Gradients { w: [gw1, gw2, gw3, gw4], b: [gb1, gb2, gb3, gb4] },
    )
}

/// Given input x, weights w and upstream delta (B x out), produce
/// (dW (in x out), db (out), dx (B x in)).
fn affine_backward(x: &Matrix, w: &Matrix, delta: &Matrix) -> (Matrix, Vec<f32>, Matrix) {
    let mut gw = Matrix::zeros(w.rows, w.cols);
    let mut gb = vec![0.0f32; w.cols];
    let mut dx = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let xr = x.row(r);
        let dr = delta.row(r);
        for (c, d) in dr.iter().enumerate() {
            gb[c] += d;
        }
        for (i, &xv) in xr.iter().enumerate() {
            if xv != 0.0 {
                let gwr = gw.row_mut(i);
                for (c, d) in dr.iter().enumerate() {
                    gwr[c] += xv * d;
                }
            }
        }
        let dxr = dx.row_mut(r);
        for (i, dxv) in dxr.iter_mut().enumerate() {
            let wr = w.row(i);
            let mut acc = 0.0f32;
            for (c, d) in dr.iter().enumerate() {
                acc += wr[c] * d;
            }
            *dxv = acc;
        }
    }
    (gw, gb, dx)
}

/// Zero the upstream gradient where the forward activation was clamped.
fn relu_backward(activated: &Matrix, delta: &mut Matrix) {
    for (a, d) in activated.data.iter().zip(delta.data.iter_mut()) {
        if *a == 0.0 {
            *d = 0.0;
        }
    }
}

/// Adam optimiser state (beta1 = 0.9, beta2 = 0.999, eps = 1e-7: the Keras
/// defaults the paper used, mirrored by the JAX graph).
pub struct Adam {
    /// Learning rate.
    pub lr: f32,
    /// Step counter (f32 to match the artifact scalar slot).
    pub t: f32,
    m_w: [Matrix; 4],
    v_w: [Matrix; 4],
    m_b: [Vec<f32>; 4],
    v_b: [Vec<f32>; 4],
}

/// Adam first-moment decay.
pub const BETA1: f32 = 0.9;
/// Adam second-moment decay.
pub const BETA2: f32 = 0.999;
/// Adam denominator epsilon.
pub const ADAM_EPS: f32 = 1e-7;

impl Adam {
    /// Zeroed optimiser state for the given shape.
    pub fn new(shape: &MlpShape, lr: f32) -> Self {
        let dims = shape.layer_dims();
        let zw = |i: usize| Matrix::zeros(dims[i].0, dims[i].1);
        let zb = |i: usize| vec![0.0f32; dims[i].1];
        Self {
            lr,
            t: 0.0,
            m_w: [zw(0), zw(1), zw(2), zw(3)],
            v_w: [zw(0), zw(1), zw(2), zw(3)],
            m_b: [zb(0), zb(1), zb(2), zb(3)],
            v_b: [zb(0), zb(1), zb(2), zb(3)],
        }
    }

    /// Apply one Adam update to `params` from `grads`.
    pub fn step(&mut self, params: &mut MlpParams, grads: &Gradients) {
        self.t += 1.0;
        let bc1 = 1.0 - BETA1.powf(self.t);
        let bc2 = 1.0 - BETA2.powf(self.t);
        for l in 0..4 {
            adam_update(
                &mut params.w[l].data,
                &grads.w[l].data,
                &mut self.m_w[l].data,
                &mut self.v_w[l].data,
                self.lr,
                bc1,
                bc2,
            );
            adam_update(
                &mut params.b[l],
                &grads.b[l],
                &mut self.m_b[l],
                &mut self.v_b[l],
                self.lr,
                bc1,
                bc2,
            );
        }
    }
}

/// One elementwise Adam update with externally-supplied bias corrections
/// `bc1 = 1 - beta1^t`, `bc2 = 1 - beta2^t`. Shared by [`Adam::step`] and
/// the native compute backend's flat-state train step, so both produce
/// bit-identical parameter trajectories.
pub fn adam_update(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
) {
    for i in 0..p.len() {
        m[i] = BETA1 * m[i] + (1.0 - BETA1) * g[i];
        v[i] = BETA2 * v[i] + (1.0 - BETA2) * g[i] * g[i];
        let step = lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + ADAM_EPS);
        p[i] -= step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> MlpShape {
        MlpShape { input: 10, hidden: [8, 8, 8], output: 3 }
    }

    fn random_batch(rng: &mut Rng, b: usize, l: usize, k: usize) -> (Matrix, Matrix) {
        let d = Matrix::from_vec(
            b,
            l,
            (0..b * l).map(|_| rng.next_f32() * 3.0).collect(),
        );
        // learnable target: linear function of input
        let a = Matrix::random_normal(rng, l, k, 0.3);
        let mut t = Matrix::zeros(b, k);
        for r in 0..b {
            for c in 0..k {
                let mut acc = 0.0f32;
                for i in 0..l {
                    acc += d.at(r, i) * a.at(i, c);
                }
                t.set(r, c, acc);
            }
        }
        (d, t)
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(1);
        let p = MlpParams::init(&shape(), &mut rng);
        let d = Matrix::zeros(5, 10);
        let y = forward(&p, &d);
        assert_eq!((y.rows, y.cols), (5, 3));
    }

    #[test]
    fn forward_blocked_matches_forward() {
        let mut rng = Rng::new(7);
        let p = MlpParams::init(&shape(), &mut rng);
        for b in [1usize, 2, 9, 33] {
            let d = Matrix::from_vec(
                b,
                10,
                (0..b * 10).map(|_| rng.next_f32() * 3.0).collect(),
            );
            let serial = forward(&p, &d);
            let blocked = forward_blocked(&p, &d);
            assert_eq!((blocked.rows, blocked.cols), (b, 3));
            assert!(
                serial.max_abs_diff(&blocked) < 1e-6,
                "B={b}: diverges by {}",
                serial.max_abs_diff(&blocked)
            );
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Rng::new(2);
        let mut p = MlpParams::init(&shape(), &mut rng);
        // keep every ReLU strictly active: positive weights and biases, so
        // the finite-difference probe never crosses a kink (where one-sided
        // derivatives make fd meaningless)
        for l in 0..4 {
            for v in p.w[l].data.iter_mut() {
                *v = v.abs() * 0.5 + 0.01;
            }
            for v in p.b[l].iter_mut() {
                *v = 0.5;
            }
        }
        let (d, t) = random_batch(&mut rng, 6, 10, 3);
        let (_, g) = backward(&p, &d, &t);

        let h = 1e-3f32;
        // check a few weight entries in every layer
        for l in 0..4 {
            for &(r, c) in &[(0usize, 0usize), (1, 1)] {
                if r >= p.w[l].rows || c >= p.w[l].cols {
                    continue;
                }
                let orig = p.w[l].at(r, c);
                p.w[l].set(r, c, orig + h);
                let lp = mae_loss(&forward(&p, &d), &t);
                p.w[l].set(r, c, orig - h);
                let lm = mae_loss(&forward(&p, &d), &t);
                p.w[l].set(r, c, orig);
                let fd = ((lp - lm) / (2.0 * h as f64)) as f32;
                let an = g.w[l].at(r, c);
                assert!(
                    (fd - an).abs() < 1e-2 * (1.0 + an.abs()),
                    "layer {l} ({r},{c}): fd={fd} analytic={an}"
                );
            }
        }
        // and a bias entry
        let orig = p.b[1][2];
        p.b[1][2] = orig + h;
        let lp = mae_loss(&forward(&p, &d), &t);
        p.b[1][2] = orig - h;
        let lm = mae_loss(&forward(&p, &d), &t);
        p.b[1][2] = orig;
        let fd = ((lp - lm) / (2.0 * h as f64)) as f32;
        assert!((fd - g.b[1][2]).abs() < 1e-2 * (1.0 + g.b[1][2].abs()));
    }

    #[test]
    fn adam_training_converges_on_linear_map() {
        let mut rng = Rng::new(3);
        let sh = shape();
        let mut p = MlpParams::init(&sh, &mut rng);
        let (d, t) = random_batch(&mut rng, 64, 10, 3);
        let mut opt = Adam::new(&sh, 5e-3);
        let initial = mae_loss(&forward(&p, &d), &t);
        let mut last = initial;
        for _ in 0..300 {
            let (loss, g) = backward(&p, &d, &t);
            opt.step(&mut p, &g);
            last = loss;
        }
        assert!(
            last < 0.2 * initial,
            "no convergence: {initial} -> {last}"
        );
    }

    #[test]
    fn flatten_round_trips() {
        let mut rng = Rng::new(4);
        let p = MlpParams::init(&shape(), &mut rng);
        let flat = p.flatten();
        assert_eq!(flat.len(), 8);
        let q = MlpParams::from_flat(&shape(), &flat);
        for l in 0..4 {
            assert_eq!(p.w[l], q.w[l]);
            assert_eq!(p.b[l], q.b[l]);
        }
    }

    #[test]
    fn param_count_formula() {
        let sh = shape();
        assert_eq!(
            sh.param_count(),
            10 * 8 + 8 + 8 * 8 + 8 + 8 * 8 + 8 + 8 * 3 + 3
        );
    }

    #[test]
    fn loss_known_value() {
        let pred = Matrix::from_rows(&[vec![3.0, 4.0], vec![0.0, 0.0]]);
        let target = Matrix::zeros(2, 2);
        assert!((mae_loss(&pred, &target) - 2.5).abs() < 1e-5);
    }
}
