//! Compute runtime: the [`ComputeBackend`] trait and its implementations.
//!
//! - [`native`]: the default pure-Rust backend — evaluates the LSMDS /
//!   OSE-opt / MLP graphs directly, always available, no toolchain needed.
//! - [`pjrt`] (cargo feature `pjrt`): loads the AOT artifacts produced by
//!   `python/compile/aot.py` (HLO text + manifest) and executes them on a
//!   PJRT client, delegating to the native backend for any shape without
//!   an artifact. The serving path never touches Python either way.
//!
//! [`manifest`] (always compiled — it is plain data + hand-rolled JSON) is
//! the contract between the AOT compiler and the artifact runtime; the
//! `lmds-ose info` subcommand reads it without any PJRT dependency.
//!
//! [`simd`] is the explicit kernel tier underneath the native backend:
//! runtime-dispatched AVX2/NEON/scalar kernels for the hot per-row inner
//! loops (vector metrics, the blocked stress-gradient tile, the MLP
//! affine microkernel), bit-identical across tiers by construction and
//! pinned process-wide via [`simd::set_kernel_tier`] (`--kernel-tier`).

pub mod backend;
pub mod manifest;
pub mod native;
pub mod simd;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod handle;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{AdamState, Backend, ComputeBackend};
pub use manifest::{ArtifactSpec, Manifest};
pub use native::NativeBackend;
pub use simd::KernelTier;

#[cfg(feature = "pjrt")]
pub use client::{ArgValue, OutValue, Runtime};
#[cfg(feature = "pjrt")]
pub use handle::{OwnedArg, RuntimeHandle, RuntimeThread};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

/// Default artifact directory: `$LMDS_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("LMDS_ARTIFACTS") {
        return p.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
