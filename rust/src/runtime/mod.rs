//! PJRT runtime layer: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text + manifest) and executes them on the
//! CPU PJRT client. The serving path never touches Python.

pub mod client;
pub mod handle;
pub mod manifest;

pub use client::{ArgValue, OutValue, Runtime};
pub use handle::{OwnedArg, RuntimeHandle, RuntimeThread};
pub use manifest::{ArtifactSpec, Manifest};

/// Default artifact directory: `$LMDS_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("LMDS_ARTIFACTS") {
        return p.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
