//! Portable scalar tier: the canonical 8-lane tile kernels.
//!
//! These functions *define* the numerics of the kernel tier — the vector
//! tiers in `x86.rs`/`neon.rs` must match them bit-for-bit (asserted by
//! `tests/kernel_parity.rs`) — so they are written to be boring and
//! obviously correct: a `[_; 8]` lane array indexed by `j % 8`, combined
//! with the canonical stride-4 pairwise tree, multiply-then-add only.

use crate::mds::Matrix;

use super::{tree8_f32, tree8_f64};

/// Canonical squared Euclidean distance: f32 differences, squared and
/// accumulated per-lane in f64, tree-combined.
pub fn euclidean_sq(a: &[f32], b: &[f32]) -> f64 {
    let mut lanes = [0.0f64; 8];
    for j in 0..a.len() {
        let d = (a[j] - b[j]) as f64;
        lanes[j & 7] += d * d;
    }
    tree8_f64(&lanes)
}

/// Canonical Manhattan distance: f32 differences, absolute values
/// accumulated per-lane in f64, tree-combined.
pub fn manhattan(a: &[f32], b: &[f32]) -> f64 {
    let mut lanes = [0.0f64; 8];
    for j in 0..a.len() {
        lanes[j & 7] += ((a[j] - b[j]) as f64).abs();
    }
    tree8_f64(&lanes)
}

/// Canonical fused distance/stress/gradient tile (see
/// [`super::stress_row_tile`] for the contract). The f32 squared
/// distance uses the lane tile; per-row stress stays f64; the gradient
/// update is elementwise.
pub fn stress_row_tile(
    xi: &[f32],
    x: &Matrix,
    t0: usize,
    t1: usize,
    skip: usize,
    drow: &[f32],
    gr: &mut [f32],
    diff: &mut [f32],
) -> f64 {
    let k = xi.len();
    let mut s = 0.0f64;
    for j in t0..t1 {
        if j == skip {
            continue;
        }
        let xj = x.row(j);
        let mut lanes = [0.0f32; 8];
        for c in 0..k {
            let d = xi[c] - xj[c];
            diff[c] = d;
            lanes[c & 7] += d * d;
        }
        let d = tree8_f32(&lanes).sqrt();
        let resid = d - drow[j];
        s += (resid as f64) * (resid as f64);
        if d > 1e-12 {
            let coef = 2.0 * resid / d;
            for c in 0..k {
                gr[c] += coef * diff[c];
            }
        }
    }
    s
}

/// Canonical affine microkernel (see [`super::affine_into`] for the
/// contract): bias first, then `out += x[i] * w.row(i)` for ascending
/// `i` — exactly the pre-SIMD `nn::forward_block` inner loop.
pub fn affine_into(x: &[f32], w: &Matrix, b: &[f32], out: &mut [f32]) {
    out.copy_from_slice(b);
    for (i, &xv) in x.iter().enumerate() {
        let wr = w.row(i);
        for (o, &wv) in out.iter_mut().zip(wr.iter()) {
            *o += xv * wv;
        }
    }
}
