//! NEON tier (aarch64). Always compiled on aarch64; executed only after
//! NEON feature detection succeeded at dispatch time (NEON is baseline
//! on aarch64, so this is effectively always).
//!
//! Same bit-equality contract as the AVX2 tier (see `x86.rs`): no FMA
//! (multiply then add), the scalar tier's `j % 8` lane mapping — held
//! here as pairs of 4-wide registers — and the shared `tree8_*`
//! combine. NEON has no masked loads, so remainder elements are
//! processed scalar-wise *into the extracted lane array* (for lane-
//! mapped reductions) or elementwise (for the order-free axpy updates);
//! both append the tail contributions after the vector tiles, exactly
//! like the scalar tier does, so results stay bit-identical.

use std::arch::aarch64::*;

use crate::mds::Matrix;

use super::{tree8_f32, tree8_f64};

/// NEON [`super::euclidean_sq`]: two f32x4 loads per 8-tile, widened to
/// four f64x2 accumulators (lane pairs 0-1 / 2-3 / 4-5 / 6-7), scalar
/// tail into the extracted lane array, tree-combined.
///
/// # Safety
/// Caller must have verified NEON support; `a.len() == b.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn euclidean_sq(a: &[f32], b: &[f32]) -> f64 {
    // SAFETY: caller upholds the `# Safety` contract above. Vector
    // tiles read lanes j..j+8 with j + 8 <= n8 <= n, the scalar tail
    // reads single in-bounds elements n8..n, and the stores hit a
    // local [f64; 8] — nothing leaves the operand slices.
    unsafe {
        let n = a.len();
        let n8 = n - (n % 8);
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        let mut acc45 = vdupq_n_f64(0.0);
        let mut acc67 = vdupq_n_f64(0.0);
        let mut j = 0;
        while j < n8 {
            let da = vsubq_f32(vld1q_f32(ap.add(j)), vld1q_f32(bp.add(j)));
            let db = vsubq_f32(vld1q_f32(ap.add(j + 4)), vld1q_f32(bp.add(j + 4)));
            let d01 = vcvt_f64_f32(vget_low_f32(da));
            let d23 = vcvt_f64_f32(vget_high_f32(da));
            let d45 = vcvt_f64_f32(vget_low_f32(db));
            let d67 = vcvt_f64_f32(vget_high_f32(db));
            acc01 = vaddq_f64(acc01, vmulq_f64(d01, d01));
            acc23 = vaddq_f64(acc23, vmulq_f64(d23, d23));
            acc45 = vaddq_f64(acc45, vmulq_f64(d45, d45));
            acc67 = vaddq_f64(acc67, vmulq_f64(d67, d67));
            j += 8;
        }
        let mut lanes = [0.0f64; 8];
        vst1q_f64(lanes.as_mut_ptr(), acc01);
        vst1q_f64(lanes.as_mut_ptr().add(2), acc23);
        vst1q_f64(lanes.as_mut_ptr().add(4), acc45);
        vst1q_f64(lanes.as_mut_ptr().add(6), acc67);
        while j < n {
            let d = (*ap.add(j) - *bp.add(j)) as f64;
            lanes[j & 7] += d * d;
            j += 1;
        }
        tree8_f64(&lanes)
    }
}

/// NEON [`super::manhattan`]: as [`euclidean_sq`] with f64 `abs`
/// instead of the square.
///
/// # Safety
/// Caller must have verified NEON support; `a.len() == b.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn manhattan(a: &[f32], b: &[f32]) -> f64 {
    // SAFETY: same access pattern as `euclidean_sq` — vector tiles end
    // at n8 <= n, the scalar tail stays below n, stores hit a local
    // [f64; 8].
    unsafe {
        let n = a.len();
        let n8 = n - (n % 8);
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        let mut acc45 = vdupq_n_f64(0.0);
        let mut acc67 = vdupq_n_f64(0.0);
        let mut j = 0;
        while j < n8 {
            let da = vsubq_f32(vld1q_f32(ap.add(j)), vld1q_f32(bp.add(j)));
            let db = vsubq_f32(vld1q_f32(ap.add(j + 4)), vld1q_f32(bp.add(j + 4)));
            acc01 = vaddq_f64(acc01, vabsq_f64(vcvt_f64_f32(vget_low_f32(da))));
            acc23 = vaddq_f64(acc23, vabsq_f64(vcvt_f64_f32(vget_high_f32(da))));
            acc45 = vaddq_f64(acc45, vabsq_f64(vcvt_f64_f32(vget_low_f32(db))));
            acc67 = vaddq_f64(acc67, vabsq_f64(vcvt_f64_f32(vget_high_f32(db))));
            j += 8;
        }
        let mut lanes = [0.0f64; 8];
        vst1q_f64(lanes.as_mut_ptr(), acc01);
        vst1q_f64(lanes.as_mut_ptr().add(2), acc23);
        vst1q_f64(lanes.as_mut_ptr().add(4), acc45);
        vst1q_f64(lanes.as_mut_ptr().add(6), acc67);
        while j < n {
            lanes[j & 7] += ((*ap.add(j) - *bp.add(j)) as f64).abs();
            j += 1;
        }
        tree8_f64(&lanes)
    }
}

/// NEON [`super::stress_row_tile`]: 8-wide distance tiles into a pair
/// of f32x4 accumulators (lanes 0-3 / 4-7), scalar tail into the
/// extracted lane array, 4-wide gradient axpy with an elementwise tail.
///
/// # Safety
/// Caller must have verified NEON support and the slice-length contract
/// of [`super::stress_row_tile`].
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
pub unsafe fn stress_row_tile(
    xi: &[f32],
    x: &Matrix,
    t0: usize,
    t1: usize,
    skip: usize,
    drow: &[f32],
    gr: &mut [f32],
    diff: &mut [f32],
) -> f64 {
    // SAFETY: caller upholds the `# Safety` contract above, so `xi`,
    // each `x.row(j)` (j < t1 <= x.rows), `gr` and `diff` all have
    // length k = x.cols; vector tiles end at k8/k4 <= k and the scalar
    // tails stay below k.
    unsafe {
        let k = xi.len();
        let k8 = k - (k % 8);
        let k4 = k - (k % 4);
        let xip = xi.as_ptr();
        let dp = diff.as_mut_ptr();
        let gp = gr.as_mut_ptr();
        let mut s = 0.0f64;
        for j in t0..t1 {
            if j == skip {
                continue;
            }
            let xjp = x.row(j).as_ptr();
            let mut acc_a = vdupq_n_f32(0.0);
            let mut acc_b = vdupq_n_f32(0.0);
            let mut c = 0;
            while c < k8 {
                let da = vsubq_f32(vld1q_f32(xip.add(c)), vld1q_f32(xjp.add(c)));
                let db = vsubq_f32(vld1q_f32(xip.add(c + 4)), vld1q_f32(xjp.add(c + 4)));
                vst1q_f32(dp.add(c), da);
                vst1q_f32(dp.add(c + 4), db);
                acc_a = vaddq_f32(acc_a, vmulq_f32(da, da));
                acc_b = vaddq_f32(acc_b, vmulq_f32(db, db));
                c += 8;
            }
            let mut lanes = [0.0f32; 8];
            vst1q_f32(lanes.as_mut_ptr(), acc_a);
            vst1q_f32(lanes.as_mut_ptr().add(4), acc_b);
            while c < k {
                let d = *xip.add(c) - *xjp.add(c);
                *dp.add(c) = d;
                lanes[c & 7] += d * d;
                c += 1;
            }
            let d = tree8_f32(&lanes).sqrt();
            let resid = d - drow[j];
            s += (resid as f64) * (resid as f64);
            if d > 1e-12 {
                let coef = 2.0 * resid / d;
                let vcoef = vdupq_n_f32(coef);
                let mut c = 0;
                while c < k4 {
                    let g = vaddq_f32(
                        vld1q_f32(gp.add(c)),
                        vmulq_f32(vcoef, vld1q_f32(dp.add(c))),
                    );
                    vst1q_f32(gp.add(c), g);
                    c += 4;
                }
                while c < k {
                    *gp.add(c) += coef * *dp.add(c);
                    c += 1;
                }
            }
        }
        s
    }
}

/// NEON [`super::affine_into`]: broadcast `x[i]`, 4-wide axpy down the
/// weight row, elementwise tail (the update is order-free per element).
///
/// # Safety
/// Caller must have verified NEON support and the slice-length contract
/// of [`super::affine_into`].
#[target_feature(enable = "neon")]
pub unsafe fn affine_into(x: &[f32], w: &Matrix, b: &[f32], out: &mut [f32]) {
    // SAFETY: caller upholds the `# Safety` contract above, so `out`
    // and every `w.row(i)` (i < x.len() == w.rows) have length
    // k = w.cols; vector tiles end at k4 <= k and the elementwise tail
    // stays below k.
    unsafe {
        let k = out.len();
        let k4 = k - (k % 4);
        out.copy_from_slice(b);
        let op = out.as_mut_ptr();
        for (i, &xv) in x.iter().enumerate() {
            let wp = w.row(i).as_ptr();
            let vx = vdupq_n_f32(xv);
            let mut c = 0;
            while c < k4 {
                let o = vaddq_f32(vld1q_f32(op.add(c)), vmulq_f32(vx, vld1q_f32(wp.add(c))));
                vst1q_f32(op.add(c), o);
                c += 4;
            }
            while c < k {
                *op.add(c) += xv * *wp.add(c);
                c += 1;
            }
        }
    }
}
