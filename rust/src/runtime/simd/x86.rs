//! AVX2 tier (x86_64). Always compiled on x86_64; executed only after
//! `is_x86_feature_detected!("avx2")` succeeded at dispatch time.
//!
//! Bit-equality with the scalar tier is a hard contract, kept by three
//! rules:
//!
//! - **No FMA.** Every accumulation multiplies then adds (two
//!   roundings), exactly like the scalar tier. FMA's single rounding
//!   would change low bits, so the `fma` target feature is deliberately
//!   not enabled here even though every AVX2 CPU has it.
//! - **Same lane mapping.** An 8-wide accumulator register *is* the
//!   scalar tier's `[_; 8]` lane array: element `j` lands in lane
//!   `j % 8`, tiles advance in ascending order, and the final combine
//!   stores the register and applies the same `tree8_*` reduction.
//! - **Exact no-op tails.** Remainder lanes use AVX2 masked
//!   loads/stores: masked-off lanes read as `+0.0`, so they contribute
//!   `+0.0` to the accumulators — an exact no-op, because squared /
//!   absolute contributions keep every accumulator lane `>= +0.0` (or
//!   NaN, which propagates identically in all tiers) and
//!   `x + (+0.0) == x` bit-for-bit for such `x`.
//!
//! Safety: all functions are `unsafe fn` (MSRV 1.74 has no safe
//! `target_feature`); callers must have verified AVX2 support. Pointer
//! arithmetic never leaves the operand slices — masked ops take a
//! pointer to the first tail element and touch only the masked-on
//! lanes, all of which are in bounds.

use std::arch::x86_64::*;

use crate::mds::Matrix;

use super::{tree8_f32, tree8_f64};

/// Row `r` enables the first `r` of 8 lanes (i32 -1 = high bit set =
/// lane on) for `_mm256_maskload_ps` / `_mm256_maskstore_ps`.
#[rustfmt::skip]
const TAIL_MASKS: [[i32; 8]; 8] = [
    [ 0,  0,  0,  0,  0,  0,  0,  0],
    [-1,  0,  0,  0,  0,  0,  0,  0],
    [-1, -1,  0,  0,  0,  0,  0,  0],
    [-1, -1, -1,  0,  0,  0,  0,  0],
    [-1, -1, -1, -1,  0,  0,  0,  0],
    [-1, -1, -1, -1, -1,  0,  0,  0],
    [-1, -1, -1, -1, -1, -1,  0,  0],
    [-1, -1, -1, -1, -1, -1, -1,  0],
];

/// Load the lane mask enabling the first `r` lanes (`r < 8`).
///
/// # Safety
/// Requires AVX2 (caller-verified, as for every function here).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn tail_mask(r: usize) -> __m256i {
    // SAFETY: caller verified AVX2; the unaligned load reads exactly the
    // 32 bytes of `TAIL_MASKS[r]` (r < 8 is indexed safely above).
    unsafe { _mm256_loadu_si256(TAIL_MASKS[r].as_ptr() as *const __m256i) }
}

/// AVX2 [`super::euclidean_sq`]: f32x8 differences widened to two f64x4
/// accumulators (lanes 0-3 / 4-7), masked tail, tree-combined.
///
/// # Safety
/// Caller must have verified AVX2 support; `a.len() == b.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn euclidean_sq(a: &[f32], b: &[f32]) -> f64 {
    // SAFETY: caller upholds the `# Safety` contract above. Full tiles
    // read lanes j..j+8 with j + 8 <= n8 <= n, the masked tail reads
    // only the first n - n8 (< 8) lanes at offset n8, and the final
    // stores hit a local [f64; 8] — nothing leaves the operand slices.
    unsafe {
        let n = a.len();
        let n8 = n - (n % 8);
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let mut j = 0;
        while j < n8 {
            let d = _mm256_sub_ps(_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(bp.add(j)));
            let dlo = _mm256_cvtps_pd(_mm256_castps256_ps128(d));
            let dhi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(d));
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(dlo, dlo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(dhi, dhi));
            j += 8;
        }
        if n8 < n {
            let m = tail_mask(n - n8);
            let d = _mm256_sub_ps(
                _mm256_maskload_ps(ap.add(n8), m),
                _mm256_maskload_ps(bp.add(n8), m),
            );
            let dlo = _mm256_cvtps_pd(_mm256_castps256_ps128(d));
            let dhi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(d));
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(dlo, dlo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(dhi, dhi));
        }
        let mut lanes = [0.0f64; 8];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc_lo);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc_hi);
        tree8_f64(&lanes)
    }
}

/// AVX2 [`super::manhattan`]: as [`euclidean_sq`] with a sign-bit clear
/// (f64 `abs`) instead of the square.
///
/// # Safety
/// Caller must have verified AVX2 support; `a.len() == b.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn manhattan(a: &[f32], b: &[f32]) -> f64 {
    // SAFETY: same access pattern as `euclidean_sq` — full tiles end at
    // n8 <= n, the masked tail touches only in-bounds lanes, and the
    // final stores hit a local [f64; 8].
    unsafe {
        let n = a.len();
        let n8 = n - (n % 8);
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let sign = _mm256_set1_pd(-0.0);
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let mut j = 0;
        while j < n8 {
            let d = _mm256_sub_ps(_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(bp.add(j)));
            let dlo = _mm256_cvtps_pd(_mm256_castps256_ps128(d));
            let dhi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(d));
            acc_lo = _mm256_add_pd(acc_lo, _mm256_andnot_pd(sign, dlo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_andnot_pd(sign, dhi));
            j += 8;
        }
        if n8 < n {
            let m = tail_mask(n - n8);
            let d = _mm256_sub_ps(
                _mm256_maskload_ps(ap.add(n8), m),
                _mm256_maskload_ps(bp.add(n8), m),
            );
            let dlo = _mm256_cvtps_pd(_mm256_castps256_ps128(d));
            let dhi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(d));
            acc_lo = _mm256_add_pd(acc_lo, _mm256_andnot_pd(sign, dlo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_andnot_pd(sign, dhi));
        }
        let mut lanes = [0.0f64; 8];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc_lo);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc_hi);
        tree8_f64(&lanes)
    }
}

/// AVX2 [`super::stress_row_tile`]: the distance, the diff-scratch
/// store and the gradient axpy are all 8-wide with a shared tail mask
/// hoisted out of the `j` loop (K is loop-invariant).
///
/// # Safety
/// Caller must have verified AVX2 support and the slice-length contract
/// of [`super::stress_row_tile`] (`xi`/`gr`/`diff` of length `x.cols`,
/// `t1 <= x.rows`, `t1 <= drow.len()`).
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub unsafe fn stress_row_tile(
    xi: &[f32],
    x: &Matrix,
    t0: usize,
    t1: usize,
    skip: usize,
    drow: &[f32],
    gr: &mut [f32],
    diff: &mut [f32],
) -> f64 {
    // SAFETY: caller upholds the `# Safety` contract above, so `xi`,
    // each `x.row(j)` (j < t1 <= x.rows), `gr` and `diff` all have
    // length k = x.cols; full tiles end at k8 <= k and the shared mask
    // covers exactly the k - k8 (< 8) tail lanes of each slice.
    unsafe {
        let k = xi.len();
        let k8 = k - (k % 8);
        let tail = k - k8;
        let m = tail_mask(tail);
        let xip = xi.as_ptr();
        let dp = diff.as_mut_ptr();
        let gp = gr.as_mut_ptr();
        let mut s = 0.0f64;
        for j in t0..t1 {
            if j == skip {
                continue;
            }
            let xjp = x.row(j).as_ptr();
            let mut acc = _mm256_setzero_ps();
            let mut c = 0;
            while c < k8 {
                let d = _mm256_sub_ps(_mm256_loadu_ps(xip.add(c)), _mm256_loadu_ps(xjp.add(c)));
                _mm256_storeu_ps(dp.add(c), d);
                acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
                c += 8;
            }
            if tail > 0 {
                let d = _mm256_sub_ps(
                    _mm256_maskload_ps(xip.add(k8), m),
                    _mm256_maskload_ps(xjp.add(k8), m),
                );
                _mm256_maskstore_ps(dp.add(k8), m, d);
                acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
            }
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
            let d = tree8_f32(&lanes).sqrt();
            let resid = d - drow[j];
            s += (resid as f64) * (resid as f64);
            if d > 1e-12 {
                let coef = _mm256_set1_ps(2.0 * resid / d);
                let mut c = 0;
                while c < k8 {
                    let g = _mm256_add_ps(
                        _mm256_loadu_ps(gp.add(c)),
                        _mm256_mul_ps(coef, _mm256_loadu_ps(dp.add(c))),
                    );
                    _mm256_storeu_ps(gp.add(c), g);
                    c += 8;
                }
                if tail > 0 {
                    let g = _mm256_add_ps(
                        _mm256_maskload_ps(gp.add(k8), m),
                        _mm256_mul_ps(coef, _mm256_maskload_ps(dp.add(k8), m)),
                    );
                    _mm256_maskstore_ps(gp.add(k8), m, g);
                }
            }
        }
        s
    }
}

/// AVX2 [`super::affine_into`]: broadcast `x[i]`, 8-wide axpy down the
/// weight row, masked tail. Addition order per output element is
/// identical to the scalar tier (`out + x[i] * w`), so results are
/// bit-equal.
///
/// # Safety
/// Caller must have verified AVX2 support and the slice-length contract
/// of [`super::affine_into`] (`x.len() == w.rows`,
/// `b.len() == out.len() == w.cols`).
#[target_feature(enable = "avx2")]
pub unsafe fn affine_into(x: &[f32], w: &Matrix, b: &[f32], out: &mut [f32]) {
    // SAFETY: caller upholds the `# Safety` contract above, so `out`
    // and every `w.row(i)` (i < x.len() == w.rows) have length
    // k = w.cols; full tiles end at k8 <= k and the mask covers exactly
    // the k - k8 (< 8) tail lanes.
    unsafe {
        let k = out.len();
        let k8 = k - (k % 8);
        let tail = k - k8;
        let m = tail_mask(tail);
        out.copy_from_slice(b);
        let op = out.as_mut_ptr();
        for (i, &xv) in x.iter().enumerate() {
            let wp = w.row(i).as_ptr();
            let vx = _mm256_set1_ps(xv);
            let mut c = 0;
            while c < k8 {
                let o = _mm256_add_ps(
                    _mm256_loadu_ps(op.add(c)),
                    _mm256_mul_ps(vx, _mm256_loadu_ps(wp.add(c))),
                );
                _mm256_storeu_ps(op.add(c), o);
                c += 8;
            }
            if tail > 0 {
                let o = _mm256_add_ps(
                    _mm256_maskload_ps(op.add(k8), m),
                    _mm256_mul_ps(vx, _mm256_maskload_ps(wp.add(k8), m)),
                );
                _mm256_maskstore_ps(op.add(k8), m, o);
            }
        }
    }
}
