//! Explicit SIMD kernel tier: runtime-dispatched vector kernels for the
//! three per-row inner loops that dominate both pipeline stages and
//! serving p99 — the `strdist::metric` vector metrics, the blocked LSMDS
//! stress-gradient tile, and the MLP affine microkernel.
//!
//! # Tiers and dispatch
//!
//! Three tiers exist; one is pinned per process and every kernel call
//! dispatches through it:
//!
//! | kernel                | x86_64 tier     | aarch64 tier  | everywhere  |
//! |-----------------------|-----------------|---------------|-------------|
//! | [`euclidean_sq`]      | AVX2 f32x8→f64x4| NEON 2×f32x4  | scalar tile |
//! | [`manhattan`]         | AVX2 f32x8→f64x4| NEON 2×f32x4  | scalar tile |
//! | [`stress_row_tile`]   | AVX2 f32x8      | NEON 2×f32x4  | scalar tile |
//! | [`affine_into`]       | AVX2 f32x8      | NEON f32x4    | scalar tile |
//!
//! The tier resolves lazily on the first kernel call: `auto` consults the
//! `LMDS_KERNEL_TIER` environment variable (`auto|simd|scalar`) and then
//! CPU feature detection (`is_x86_feature_detected!("avx2")` on x86_64,
//! NEON detection on aarch64). [`set_kernel_tier`] — driven by the
//! `--kernel-tier` flag / `kernel_tier` config key — pins the tier for
//! the whole process and wins over the environment. Under Miri the
//! scalar tier is always selected, so the whole module is
//! Miri-checkable. AVX2 CPUs without FMA are not a practical concern
//! (every AVX2 part ships FMA), but FMA is deliberately *unused* — see
//! below — so detection gates on AVX2 alone.
//!
//! # Numerics: one canonical accumulation order, bit-equal tiers
//!
//! Every tier accumulates reductions in the same **8-lane tile order**:
//! element `j` contributes to lane `j % 8`, and the eight lane sums
//! combine with the fixed stride-4 pairwise tree
//! `((l0+l4) + (l2+l6)) + ((l1+l5) + (l3+l7))` ([`tree8_f32`] /
//! [`tree8_f64`]) — the natural register layout of an 8-wide vector
//! accumulator. No FMA contraction is used anywhere (multiply, then add:
//! two roundings), and remainder lanes contribute exact `+0.0` no-ops,
//! so the vector tiers are **bit-identical** to the scalar tier by
//! construction, not merely close: `--kernel-tier` is unobservable
//! except in speed, and `tests/kernel_parity.rs` asserts exact equality.
//! The historical strictly-serial summation orders differ from the
//! canonical order by ordinary rounding; parity suites hold them within
//! documented 1e-6 (metrics, MLP) and scale-aware 1e-3 (stress
//! gradient) bands.
//!
//! # Adding a kernel
//!
//! 1. Write the **scalar tile** version in `scalar.rs` first, using
//!    `lanes[j % 8]` accumulators and [`tree8_f32`]/[`tree8_f64`]; it is
//!    the semantics, so keep it boring.
//! 2. Mirror it in `x86.rs` (`#[target_feature(enable = "avx2")]`,
//!    masked loads for the tail, multiply-then-add only) and `neon.rs`
//!    (scalar tail into the extracted lane array).
//! 3. Add a dispatching wrapper here with a hard length assert, plus
//!    `_scalar`/`_vector` pinned twins for the differential tests.
//! 4. Pin vector-vs-scalar bit equality in `tests/kernel_parity.rs`
//!    over lengths covering every `len % 8` remainder, and a band vs
//!    any pre-existing serial oracle.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::mds::Matrix;

mod scalar;

#[cfg(target_arch = "x86_64")]
mod x86;

#[cfg(target_arch = "aarch64")]
mod neon;

#[cfg(target_arch = "x86_64")]
use x86 as arch;

#[cfg(target_arch = "aarch64")]
use neon as arch;

// ---------------------------------------------------------------------------
// Tier selection

/// Kernel-tier selection knob (`--kernel-tier`, `kernel_tier`,
/// `LMDS_KERNEL_TIER`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelTier {
    /// Resolve from the `LMDS_KERNEL_TIER` environment variable if set,
    /// else from CPU feature detection (the default).
    Auto,
    /// Force the vector kernels (falls back to scalar, loudly, when the
    /// CPU/build has no vector path).
    Simd,
    /// Force the portable scalar reference kernels.
    Scalar,
}

impl std::str::FromStr for KernelTier {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(KernelTier::Auto),
            "simd" => Ok(KernelTier::Simd),
            "scalar" => Ok(KernelTier::Scalar),
            other => Err(format!("unknown kernel tier {other:?} (auto|simd|scalar)")),
        }
    }
}

impl std::fmt::Display for KernelTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            KernelTier::Auto => "auto",
            KernelTier::Simd => "simd",
            KernelTier::Scalar => "scalar",
        })
    }
}

const TIER_UNSET: u8 = 0;
const TIER_SCALAR: u8 = 1;
const TIER_SIMD: u8 = 2;

/// Pinned tier: resolved lazily on first use, overridden by
/// [`set_kernel_tier`]. Relaxed ordering suffices — the resolved value
/// is a pure function of the environment, so racing initialisers agree.
static TIER: AtomicU8 = AtomicU8::new(TIER_UNSET);

#[cfg(target_arch = "x86_64")]
fn detect_simd() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(target_arch = "aarch64")]
fn detect_simd() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_simd() -> bool {
    false
}

/// Whether this CPU/build has a vector tier at all (AVX2 on x86_64, NEON
/// on aarch64). Always false under Miri, which cannot execute vendor
/// intrinsics — the scalar tier keeps the module Miri-checkable.
pub fn simd_supported() -> bool {
    !cfg!(miri) && detect_simd()
}

/// `LMDS_KERNEL_TIER` environment override; unset/invalid = Auto (an
/// invalid value warns rather than erroring so a stale environment can
/// never take the service down).
fn env_tier() -> KernelTier {
    match std::env::var("LMDS_KERNEL_TIER") {
        Ok(v) => v.parse().unwrap_or_else(|e: String| {
            log::warn!("ignoring LMDS_KERNEL_TIER: {e}");
            KernelTier::Auto
        }),
        Err(_) => KernelTier::Auto,
    }
}

fn resolve(requested: KernelTier) -> u8 {
    let effective = match requested {
        KernelTier::Auto => env_tier(),
        pinned => pinned,
    };
    match effective {
        KernelTier::Scalar => TIER_SCALAR,
        KernelTier::Simd if simd_supported() => TIER_SIMD,
        KernelTier::Simd => {
            log::warn!(
                "kernel tier \"simd\" requested but this CPU/build has no vector \
                 path; using the scalar tier"
            );
            TIER_SCALAR
        }
        KernelTier::Auto => {
            if simd_supported() {
                TIER_SIMD
            } else {
                TIER_SCALAR
            }
        }
    }
}

/// Pin the process-wide kernel tier (config/CLI override; wins over the
/// `LMDS_KERNEL_TIER` environment variable except under `Auto`, which
/// re-reads it). Safe to call at any time: all tiers are bit-identical,
/// so a mid-run switch changes speed only.
pub fn set_kernel_tier(tier: KernelTier) {
    TIER.store(resolve(tier), Ordering::Relaxed);
}

fn simd_active() -> bool {
    match TIER.load(Ordering::Relaxed) {
        TIER_SIMD => true,
        TIER_SCALAR => false,
        _ => {
            let resolved = resolve(KernelTier::Auto);
            TIER.store(resolved, Ordering::Relaxed);
            resolved == TIER_SIMD
        }
    }
}

/// Human-readable name of the tier kernels currently dispatch to
/// (resolving it first if needed): `"scalar"`, `"simd-avx2"` or
/// `"simd-neon"`.
pub fn active_tier_name() -> &'static str {
    if simd_active() {
        if cfg!(target_arch = "x86_64") {
            "simd-avx2"
        } else {
            "simd-neon"
        }
    } else {
        "scalar"
    }
}

// ---------------------------------------------------------------------------
// The canonical reduction tree

/// Combine eight f32 lane sums in the canonical stride-4 pairwise tree:
/// `((l0+l4) + (l2+l6)) + ((l1+l5) + (l3+l7))`. Every tier funnels its
/// reductions through this exact shape, which is what makes them
/// bit-comparable.
#[inline]
pub fn tree8_f32(l: &[f32; 8]) -> f32 {
    let a = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
    (a[0] + a[2]) + (a[1] + a[3])
}

/// f64 counterpart of [`tree8_f32`] (same tree shape).
#[inline]
pub fn tree8_f64(l: &[f64; 8]) -> f64 {
    let a = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
    (a[0] + a[2]) + (a[1] + a[3])
}

// ---------------------------------------------------------------------------
// Dispatching kernels

/// Squared Euclidean distance in f64, canonical 8-lane tile order
/// (differences are formed in f32, squared and accumulated in f64 —
/// the historical `strdist::metric` contract).
///
/// Panics if the operand lengths differ (the pre-SIMD kernels silently
/// truncated in release builds; an unsafe vector path must not).
pub fn euclidean_sq(a: &[f32], b: &[f32]) -> f64 {
    if simd_active() {
        euclidean_sq_vector(a, b)
    } else {
        euclidean_sq_scalar(a, b)
    }
}

/// Manhattan distance in f64, canonical 8-lane tile order. Panics on
/// length mismatch.
pub fn manhattan(a: &[f32], b: &[f32]) -> f64 {
    if simd_active() {
        manhattan_vector(a, b)
    } else {
        manhattan_scalar(a, b)
    }
}

/// Fused distance + stress + gradient kernel for one output row of the
/// blocked LSMDS gradient: sweeps `x` rows `t0..t1` (skipping `skip`,
/// the output row itself) against the row coordinates `xi`, writing the
/// coordinate differences into the `diff` scratch, accumulating the
/// gradient into `gr`, and returning the row's raw-stress contribution
/// `sum_j (d_ij - delta_ij)^2` in f64.
///
/// `drow` is the dissimilarity row `delta[i][..]` (indexed by absolute
/// `j`). `xi`, `gr` and `diff` must all have length `x.cols`. The f32
/// squared distance accumulates in the canonical 8-lane tile order; the
/// gradient update `gr[c] += coef * diff[c]` is elementwise and
/// order-free.
pub fn stress_row_tile(
    xi: &[f32],
    x: &Matrix,
    t0: usize,
    t1: usize,
    skip: usize,
    drow: &[f32],
    gr: &mut [f32],
    diff: &mut [f32],
) -> f64 {
    if simd_active() {
        stress_row_tile_vector(xi, x, t0, t1, skip, drow, gr, diff)
    } else {
        stress_row_tile_scalar(xi, x, t0, t1, skip, drow, gr, diff)
    }
}

/// Affine microkernel of the blocked MLP forward pass: `out = b`, then
/// `out += x[i] * w.row(i)` for ascending `i` (row-major axpy). The
/// accumulation order per output is bias first, then ascending input
/// index, multiply-then-add — identical to the serial `nn::forward`
/// oracle apart from its skip of exact-zero inputs.
pub fn affine_into(x: &[f32], w: &Matrix, b: &[f32], out: &mut [f32]) {
    if simd_active() {
        affine_into_vector(x, w, b, out)
    } else {
        affine_into_scalar(x, w, b, out)
    }
}

// ---------------------------------------------------------------------------
// Tier-pinned twins (differential tests and benches)

fn assert_metric_operands(a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "metric operands must have equal length");
}

fn assert_stress_operands(
    xi: &[f32],
    x: &Matrix,
    t1: usize,
    drow: &[f32],
    gr: &[f32],
    diff: &[f32],
) {
    let k = x.cols;
    assert_eq!(xi.len(), k, "xi length != K");
    assert_eq!(gr.len(), k, "gradient row length != K");
    assert_eq!(diff.len(), k, "diff scratch length != K");
    assert!(t1 <= x.rows, "tile end out of bounds");
    assert!(t1 <= drow.len(), "delta row shorter than tile end");
}

fn assert_affine_operands(x: &[f32], w: &Matrix, b: &[f32], out: &[f32]) {
    assert_eq!(x.len(), w.rows, "input length != weight rows");
    assert_eq!(b.len(), w.cols, "bias length != weight cols");
    assert_eq!(out.len(), w.cols, "output length != weight cols");
}

/// [`euclidean_sq`] pinned to the scalar tier.
pub fn euclidean_sq_scalar(a: &[f32], b: &[f32]) -> f64 {
    assert_metric_operands(a, b);
    scalar::euclidean_sq(a, b)
}

/// [`euclidean_sq`] pinned to the vector tier (falls back to the scalar
/// tier when the CPU/build has none, so it is always safe to call).
pub fn euclidean_sq_vector(a: &[f32], b: &[f32]) -> f64 {
    assert_metric_operands(a, b);
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if simd_supported() {
        // SAFETY: simd_supported() verified the target feature at runtime.
        return unsafe { arch::euclidean_sq(a, b) };
    }
    scalar::euclidean_sq(a, b)
}

/// [`manhattan`] pinned to the scalar tier.
pub fn manhattan_scalar(a: &[f32], b: &[f32]) -> f64 {
    assert_metric_operands(a, b);
    scalar::manhattan(a, b)
}

/// [`manhattan`] pinned to the vector tier (scalar fallback as
/// [`euclidean_sq_vector`]).
pub fn manhattan_vector(a: &[f32], b: &[f32]) -> f64 {
    assert_metric_operands(a, b);
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if simd_supported() {
        // SAFETY: simd_supported() verified the target feature at runtime.
        return unsafe { arch::manhattan(a, b) };
    }
    scalar::manhattan(a, b)
}

/// [`stress_row_tile`] pinned to the scalar tier.
pub fn stress_row_tile_scalar(
    xi: &[f32],
    x: &Matrix,
    t0: usize,
    t1: usize,
    skip: usize,
    drow: &[f32],
    gr: &mut [f32],
    diff: &mut [f32],
) -> f64 {
    assert_stress_operands(xi, x, t1, drow, gr, diff);
    scalar::stress_row_tile(xi, x, t0, t1, skip, drow, gr, diff)
}

/// [`stress_row_tile`] pinned to the vector tier (scalar fallback as
/// [`euclidean_sq_vector`]).
pub fn stress_row_tile_vector(
    xi: &[f32],
    x: &Matrix,
    t0: usize,
    t1: usize,
    skip: usize,
    drow: &[f32],
    gr: &mut [f32],
    diff: &mut [f32],
) -> f64 {
    assert_stress_operands(xi, x, t1, drow, gr, diff);
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if simd_supported() {
        // SAFETY: simd_supported() verified the target feature at runtime;
        // the asserts above pin every slice length the kernel reads.
        return unsafe { arch::stress_row_tile(xi, x, t0, t1, skip, drow, gr, diff) };
    }
    scalar::stress_row_tile(xi, x, t0, t1, skip, drow, gr, diff)
}

/// [`affine_into`] pinned to the scalar tier.
pub fn affine_into_scalar(x: &[f32], w: &Matrix, b: &[f32], out: &mut [f32]) {
    assert_affine_operands(x, w, b, out);
    scalar::affine_into(x, w, b, out);
}

/// [`affine_into`] pinned to the vector tier (scalar fallback as
/// [`euclidean_sq_vector`]).
pub fn affine_into_vector(x: &[f32], w: &Matrix, b: &[f32], out: &mut [f32]) {
    assert_affine_operands(x, w, b, out);
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if simd_supported() {
        // SAFETY: simd_supported() verified the target feature at runtime;
        // the asserts above pin every slice length the kernel reads.
        unsafe { arch::affine_into(x, w, b, out) };
        return;
    }
    scalar::affine_into(x, w, b, out);
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    // These unit tests are the Miri surface for the module (CI runs
    // `cargo miri test ... runtime::simd`): under Miri every dispatch
    // resolves to the scalar tier, so the canonical kernels get a full
    // UB check while the intrinsic tiers are covered by the ASan job and
    // `tests/kernel_parity.rs`.

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 4.0 - 2.0).collect()
    }

    #[test]
    fn tier_parses_and_prints() {
        for (s, t) in [
            ("auto", KernelTier::Auto),
            ("simd", KernelTier::Simd),
            ("scalar", KernelTier::Scalar),
            (" SIMD ", KernelTier::Simd),
        ] {
            assert_eq!(s.parse::<KernelTier>().unwrap(), t);
        }
        assert!("avx512".parse::<KernelTier>().is_err());
        assert_eq!(KernelTier::Simd.to_string(), "simd");
    }

    #[test]
    fn tier_pinning_round_trips() {
        set_kernel_tier(KernelTier::Scalar);
        assert_eq!(active_tier_name(), "scalar");
        set_kernel_tier(KernelTier::Simd);
        if simd_supported() {
            assert_ne!(active_tier_name(), "scalar");
        } else {
            // no vector path (e.g. under Miri): simd falls back, loudly
            assert_eq!(active_tier_name(), "scalar");
        }
        set_kernel_tier(KernelTier::Auto);
    }

    #[test]
    fn tree8_matches_plain_sum_on_exact_inputs() {
        // powers of two sum exactly in any order, so tree == serial
        let l = [1.0f32, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        assert_eq!(tree8_f32(&l), 255.0);
        let d = [1.0f64, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        assert_eq!(tree8_f64(&d), 255.0);
    }

    #[test]
    fn scalar_metric_matches_serial_oracle_band() {
        let mut rng = Rng::new(0x51);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 40] {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let serial_sq: f64 = a
                .iter()
                .zip(b.iter())
                .map(|(x, y)| {
                    let d = (*x - *y) as f64;
                    d * d
                })
                .sum();
            let got = euclidean_sq_scalar(&a, &b);
            assert!(
                (got - serial_sq).abs() <= 1e-6 * (1.0 + serial_sq),
                "n={n}: canonical {got} vs serial {serial_sq}"
            );
            let serial_l1: f64 =
                a.iter().zip(b.iter()).map(|(x, y)| ((*x - *y) as f64).abs()).sum();
            let got = manhattan_scalar(&a, &b);
            assert!((got - serial_l1).abs() <= 1e-6 * (1.0 + serial_l1));
        }
    }

    #[test]
    fn scalar_stress_tile_matches_inline_reference() {
        let mut rng = Rng::new(0x52);
        let n = 9;
        for k in [1usize, 2, 7, 8, 11] {
            let x = Matrix::from_vec(n, k, rand_vec(&mut rng, n * k));
            let delta = Matrix::from_vec(n, n, rand_vec(&mut rng, n * n));
            let i = 4;
            let mut gr = vec![0.0f32; k];
            let mut diff = vec![0.0f32; k];
            let s = stress_row_tile_scalar(
                x.row(i),
                &x,
                0,
                n,
                i,
                delta.row(i),
                &mut gr,
                &mut diff,
            );
            // reference: same tile order, written independently
            let mut s_ref = 0.0f64;
            let mut gr_ref = vec![0.0f32; k];
            for j in 0..n {
                if j == i {
                    continue;
                }
                let mut lanes = [0.0f32; 8];
                let mut dv = vec![0.0f32; k];
                for c in 0..k {
                    let d = x.at(i, c) - x.at(j, c);
                    dv[c] = d;
                    lanes[c & 7] += d * d;
                }
                let d = tree8_f32(&lanes).sqrt();
                let resid = d - delta.at(i, j);
                s_ref += (resid as f64) * (resid as f64);
                if d > 1e-12 {
                    let coef = 2.0 * resid / d;
                    for c in 0..k {
                        gr_ref[c] += coef * dv[c];
                    }
                }
            }
            assert_eq!(s.to_bits(), s_ref.to_bits(), "k={k}");
            assert_eq!(gr, gr_ref, "k={k}");
        }
    }

    #[test]
    fn scalar_affine_matches_inline_reference() {
        let mut rng = Rng::new(0x53);
        for (n_in, n_out) in [(1usize, 1usize), (3, 7), (8, 8), (5, 17)] {
            let w = Matrix::from_vec(n_in, n_out, rand_vec(&mut rng, n_in * n_out));
            let b = rand_vec(&mut rng, n_out);
            let x = rand_vec(&mut rng, n_in);
            let mut out = vec![0.0f32; n_out];
            affine_into_scalar(&x, &w, &b, &mut out);
            for c in 0..n_out {
                let mut acc = b[c];
                for i in 0..n_in {
                    acc += x[i] * w.at(i, c);
                }
                assert_eq!(out[c].to_bits(), acc.to_bits(), "({n_in},{n_out}) col {c}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "equal length")]
    fn metric_length_mismatch_panics() {
        euclidean_sq(&[1.0, 2.0], &[1.0]);
    }
}
