//! PJRT runtime: load HLO-text artifacts, compile them on the CPU client,
//! execute them with `Matrix`/scalar inputs. Compilation is lazy and cached
//! per artifact (one compiled executable per model variant).
//!
//! NOTE ON THREADING: the `xla` crate's `PjRtClient` is `Rc`-based and not
//! `Send`; a `Runtime` must stay on the thread that created it. The
//! coordinator runs one dedicated executor thread that owns the `Runtime`
//! (see `handle.rs`), which is also the natural serving architecture — a
//! single compute stream fed by the batcher.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::mds::Matrix;

use super::manifest::{ArtifactSpec, Manifest};

/// An input argument for an artifact execution.
pub enum ArgValue<'a> {
    /// Scalar f32.
    Scalar(f32),
    /// 2-D row-major matrix.
    Mat(&'a Matrix),
    /// 1-D vector.
    Vec1(&'a [f32]),
}

impl ArgValue<'_> {
    fn shape(&self) -> Vec<usize> {
        match self {
            ArgValue::Scalar(_) => vec![],
            ArgValue::Mat(m) => vec![m.rows, m.cols],
            ArgValue::Vec1(v) => vec![v.len()],
        }
    }
}

/// One output tensor: shape + row-major f32 data.
#[derive(Clone, Debug)]
pub struct OutValue {
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
    /// Flattened row-major values.
    pub data: Vec<f32>,
}

impl OutValue {
    /// The single value of a rank-0/len-1 output.
    pub fn scalar(&self) -> f32 {
        debug_assert_eq!(self.data.len(), 1);
        self.data[0]
    }

    /// Reinterpret as a matrix (rank <= 2 outputs only).
    pub fn into_matrix(self) -> Matrix {
        match self.shape.len() {
            2 => Matrix::from_vec(self.shape[0], self.shape[1], self.data),
            1 => Matrix::from_vec(self.shape[0], 1, self.data),
            0 => Matrix::from_vec(1, 1, self.data),
            _ => panic!("into_matrix on rank-{} output", self.shape.len()),
        }
    }
}

/// A PJRT client plus the compiled-executable and device-binding
/// caches for one artifact directory.
pub struct Runtime {
    /// The artifact manifest this runtime serves.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    compiled: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Device-resident argument sets (e.g. model weights) keyed by a
    /// caller-chosen binding key: uploaded once, reused every execution.
    bound: RefCell<HashMap<String, Vec<(usize, Rc<xla::PjRtBuffer>)>>>,
}

impl Runtime {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "runtime: platform={} artifacts={}",
            client.platform_name(),
            manifest.artifacts.len()
        );
        Ok(Runtime {
            manifest,
            client,
            compiled: RefCell::new(HashMap::new()),
            bound: RefCell::new(HashMap::new()),
        })
    }

    /// Look up an artifact by name.
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("unknown artifact {name:?}"))
    }

    /// Compile (or fetch the cached executable for) an artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.borrow().get(name) {
            return Ok(Rc::clone(exe));
        }
        let spec = self.spec(name)?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        log::debug!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let exe = Rc::new(exe);
        self.compiled
            .borrow_mut()
            .insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Number of executables compiled so far (for tests/metrics).
    pub fn compiled_count(&self) -> usize {
        self.compiled.borrow().len()
    }

    /// Execute an artifact with shape-checked inputs; returns all outputs.
    pub fn execute(&self, name: &str, args: &[ArgValue<'_>]) -> Result<Vec<OutValue>> {
        let spec = self.spec(name)?.clone();
        if args.len() != spec.args.len() {
            bail!(
                "{name}: expected {} args, got {}",
                spec.args.len(),
                args.len()
            );
        }
        for (i, (given, want)) in args.iter().zip(spec.args.iter()).enumerate() {
            if given.shape() != want.shape {
                bail!(
                    "{name}: arg {i} ({}) shape {:?} != expected {:?}",
                    want.name,
                    given.shape(),
                    want.shape
                );
            }
        }
        // All inputs go through explicitly Rust-owned PjRtBuffers +
        // execute_b: buffers are freed by Drop when this frame returns.
        // (The Literal-arg execute() path retains per-call allocations in
        // the C wrapper — observed as unbounded RSS growth over thousands
        // of training-step executions.)
        let buffers = args
            .iter()
            .map(|a| self.upload(a))
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::PjRtBuffer> = buffers.iter().collect();
        let exe = self.executable(name)?;
        let outputs = exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        let result = outputs[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: the single result is a tuple
        let parts = result.to_tuple().context("decomposing result tuple")?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                spec.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (part, os) in parts.into_iter().zip(spec.outputs.iter()) {
            let data = part.to_vec::<f32>().context("reading output as f32")?;
            let expect: usize = os.shape.iter().product();
            if data.len() != expect {
                bail!(
                    "{name}: output element count {} != manifest {}",
                    data.len(),
                    expect
                );
            }
            out.push(OutValue { shape: os.shape.clone(), data });
        }
        Ok(out)
    }

    /// Host -> device transfer of one argument (freed by Drop).
    fn upload(&self, v: &ArgValue<'_>) -> Result<xla::PjRtBuffer> {
        Ok(match v {
            ArgValue::Scalar(x) => {
                self.client.buffer_from_host_buffer::<f32>(&[*x], &[], None)?
            }
            ArgValue::Mat(m) => self.client.buffer_from_host_buffer::<f32>(
                &m.data,
                &[m.rows, m.cols],
                None,
            )?,
            ArgValue::Vec1(v) => {
                self.client.buffer_from_host_buffer::<f32>(v, &[v.len()], None)?
            }
        })
    }

    /// Upload an argument set to the device once, under `key`. Each entry
    /// is (argument position, value). Subsequent `execute_bound` calls
    /// reuse the device buffers — this removes the per-request host->device
    /// copy of model weights from the serving hot path.
    pub fn bind(&self, key: &str, args: &[(usize, ArgValue<'_>)]) -> Result<()> {
        let mut bufs = Vec::with_capacity(args.len());
        for (pos, v) in args {
            bufs.push((*pos, Rc::new(self.upload(v)?)));
        }
        self.bound.borrow_mut().insert(key.to_string(), bufs);
        Ok(())
    }

    /// Drop a device-resident argument binding.
    pub fn unbind(&self, key: &str) {
        self.bound.borrow_mut().remove(key);
    }

    /// True when `key` has a device-resident binding.
    pub fn has_binding(&self, key: &str) -> bool {
        self.bound.borrow().contains_key(key)
    }

    /// Execute with a mix of device-resident (bound) and fresh host
    /// arguments. `dynamic` supplies (position, value) for every argument
    /// position not covered by the binding.
    pub fn execute_bound(
        &self,
        name: &str,
        key: &str,
        dynamic: &[(usize, ArgValue<'_>)],
    ) -> Result<Vec<OutValue>> {
        let spec = self.spec(name)?.clone();
        let nargs = spec.args.len();
        let mut slots: Vec<Option<Rc<xla::PjRtBuffer>>> = vec![None; nargs];
        {
            let bound = self.bound.borrow();
            let set = bound
                .get(key)
                .with_context(|| format!("no binding {key:?}"))?;
            for (pos, buf) in set {
                anyhow::ensure!(*pos < nargs, "bound position {pos} out of range");
                slots[*pos] = Some(Rc::clone(buf));
            }
        }
        for (pos, v) in dynamic {
            anyhow::ensure!(*pos < nargs, "dynamic position {pos} out of range");
            if v.shape() != spec.args[*pos].shape {
                anyhow::bail!(
                    "{name}: arg {pos} shape {:?} != expected {:?}",
                    v.shape(),
                    spec.args[*pos].shape
                );
            }
            slots[*pos] = Some(Rc::new(self.upload(v)?));
        }
        let buffers: Vec<Rc<xla::PjRtBuffer>> = slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.with_context(|| format!("{name}: arg {i} unset")))
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::PjRtBuffer> = buffers.iter().map(|b| b.as_ref()).collect();
        let exe = self.executable(name)?;
        let outputs = exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        let result = outputs[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = result.to_tuple().context("decomposing result tuple")?;
        if parts.len() != spec.outputs.len() {
            bail!("{name}: expected {} outputs, got {}", spec.outputs.len(), parts.len());
        }
        let mut out = Vec::with_capacity(parts.len());
        for (part, os) in parts.into_iter().zip(spec.outputs.iter()) {
            let data = part.to_vec::<f32>().context("reading output as f32")?;
            out.push(OutValue { shape: os.shape.clone(), data });
        }
        Ok(out)
    }

    /// Convenience: find by graph + dims, then execute.
    pub fn execute_graph(
        &self,
        graph: &str,
        constraints: &[(&str, usize)],
        args: &[ArgValue<'_>],
    ) -> Result<Vec<OutValue>> {
        let name = self
            .manifest
            .find(graph, constraints)
            .with_context(|| format!("no artifact for {graph} {constraints:?}"))?
            .name
            .clone();
        self.execute(&name, args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_shapes() {
        let m = Matrix::zeros(3, 4);
        assert_eq!(ArgValue::Mat(&m).shape(), vec![3, 4]);
        assert_eq!(ArgValue::Scalar(1.0).shape(), Vec::<usize>::new());
        assert_eq!(ArgValue::Vec1(&[1.0, 2.0]).shape(), vec![2]);
    }

    #[test]
    fn out_value_conversions() {
        let o = OutValue { shape: vec![2, 2], data: vec![1.0, 2.0, 3.0, 4.0] };
        let m = o.into_matrix();
        assert_eq!(m.at(1, 0), 3.0);
        let s = OutValue { shape: vec![], data: vec![5.0] };
        assert_eq!(s.scalar(), 5.0);
    }
}
