//! The PJRT artifact backend (behind the `pjrt` cargo feature): implements
//! [`ComputeBackend`] by executing the AOT-lowered HLO artifacts through
//! the runtime executor thread.
//!
//! Artifact executables are shape-monomorphic, so each operation picks the
//! smallest batch variant that fits, zero-pads the request up to it, and
//! slices the padding off the result (padding rows never escape the
//! runtime boundary). Large device-resident operands — the landmark
//! configuration, MLP weights, the LSMDS dissimilarity matrix — are
//! uploaded once per distinct value (content-keyed bindings) and reused by
//! every subsequent execution.
//!
//! Any graph shape with no matching artifact delegates to the native
//! backend, so a partially-built artifact set degrades gracefully instead
//! of failing requests.

use std::collections::HashSet;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::mds::Matrix;
use crate::nn::{MlpParams, MlpShape};

use super::backend::{AdamState, ComputeBackend};
use super::handle::{OwnedArg, RuntimeHandle, RuntimeThread};
use super::native::NativeBackend;

/// Select the smallest available batch-size variant >= n (or the largest
/// one if n exceeds all variants — the caller then chunks).
pub fn pick_batch(available: &[usize], n: usize) -> Option<usize> {
    available
        .iter()
        .copied()
        .filter(|b| *b >= n)
        .min()
        .or_else(|| available.iter().copied().max())
}

/// Zero-pad a matrix to `rows` rows.
pub fn pad_rows(m: &Matrix, rows: usize) -> Matrix {
    if m.rows == rows {
        return m.clone();
    }
    let mut out = Matrix::zeros(rows, m.cols);
    out.data[..m.data.len()].copy_from_slice(&m.data);
    out
}

/// Copy rows `start..end` out of a matrix.
fn slice_rows(m: &Matrix, start: usize, end: usize) -> Matrix {
    Matrix::from_vec(
        end - start,
        m.cols,
        m.data[start * m.cols..end * m.cols].to_vec(),
    )
}

/// Content key for a device binding: FNV-1a over the operand lengths +
/// data, so identical operands across calls share one host->device upload.
///
/// The key is recomputed per call (the trait is stateless), so hashing is
/// bounded: operands up to 4096 elements hash in full; larger ones hash a
/// fixed stride sample plus their head and tail. Every producer of these
/// operands (LSMDS solves, Adam training, distance-matrix builds) updates
/// elements densely, so a changed operand always changes sampled
/// positions — while the serving hot path pays microseconds, not a full
/// pass over ~100k weight floats per request.
const HASH_FULL_LIMIT: usize = 4096;
const HASH_SAMPLES: usize = 1024;

fn content_key(prefix: &str, parts: &[&[f32]]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for part in parts {
        let n = part.len();
        eat(n as u64);
        if n <= HASH_FULL_LIMIT {
            for v in *part {
                eat(v.to_bits() as u64);
            }
        } else {
            let stride = n.div_ceil(HASH_SAMPLES);
            let mut i = 0;
            while i < n {
                eat(part[i].to_bits() as u64);
                i += stride;
            }
            // head and tail always participate
            for v in &part[..64] {
                eat(v.to_bits() as u64);
            }
            for v in &part[n - 64..] {
                eat(v.to_bits() as u64);
            }
        }
    }
    format!("{prefix}-{h:016x}")
}

/// Dim constraints identifying the MLP artifacts of a given shape.
fn mlp_constraints(shape: &MlpShape) -> Vec<(&'static str, usize)> {
    vec![
        ("L", shape.input),
        ("H1", shape.hidden[0]),
        ("H2", shape.hidden[1]),
        ("H3", shape.hidden[2]),
        ("K", shape.output),
    ]
}

/// Weight arguments (positions 1..=8 of `mlp_fwd`, shared across all B
/// variants) in artifact form.
fn weight_args(
    flat: &[Vec<f32>],
    arg_shapes: &[Vec<usize>],
    first_pos: usize,
) -> Vec<(usize, OwnedArg)> {
    flat.iter()
        .enumerate()
        .map(|(i, p)| {
            let sh = &arg_shapes[first_pos + i];
            let arg = if sh.len() == 2 {
                OwnedArg::Mat(Matrix::from_vec(sh[0], sh[1], p.clone()))
            } else {
                OwnedArg::Vec1(p.clone())
            };
            (first_pos + i, arg)
        })
        .collect()
}

/// [`ComputeBackend`] over AOT artifacts executed through PJRT,
/// delegating to the native backend for shapes with no artifact.
pub struct PjrtBackend {
    /// Executor-thread owner; a fresh [`RuntimeHandle`] is cloned out per
    /// operation (the mutex makes the backend `Sync` regardless of the
    /// standard library's `Sender` guarantees).
    rt: Mutex<RuntimeThread>,
    /// Delegation target for shapes with no artifact.
    native: NativeBackend,
    /// Content keys already uploaded to the device.
    bound: Mutex<HashSet<String>>,
}

impl PjrtBackend {
    /// Load the manifest and start the PJRT executor thread.
    pub fn load(artifact_dir: &Path) -> Result<PjrtBackend> {
        let rt = RuntimeThread::spawn(artifact_dir)?;
        Ok(PjrtBackend {
            rt: Mutex::new(rt),
            native: NativeBackend,
            bound: Mutex::new(HashSet::new()),
        })
    }

    fn handle(&self) -> RuntimeHandle {
        self.rt.lock().unwrap().handle()
    }

    /// Upload an argument set once per content key.
    fn ensure_bound(
        &self,
        h: &RuntimeHandle,
        key: &str,
        args: Vec<(usize, OwnedArg)>,
    ) -> Result<()> {
        {
            let bound = self.bound.lock().unwrap();
            if bound.contains(key) {
                return Ok(());
            }
        }
        h.bind(key, args)?;
        self.bound.lock().unwrap().insert(key.to_string());
        Ok(())
    }
}

impl ComputeBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn lsmds_steps(
        &self,
        x: &Matrix,
        delta: &Matrix,
        lr: f32,
        steps: usize,
    ) -> Result<(Matrix, f64)> {
        let h = self.handle();
        let n = delta.rows;
        let Some(spec) = h.manifest().find("lsmds_steps", &[("N", n)]).cloned() else {
            log::debug!("no lsmds_steps artifact for N={n}; native fallback");
            return self.native.lsmds_steps(x, delta, lr, steps);
        };
        let t = spec.dim("T").unwrap_or(1).max(1);
        let execs = steps.div_ceil(t).max(1);
        // the N x N dissimilarity matrix crosses host->device ONCE; only
        // the N x K configuration moves per call
        let key = content_key("lsmds-delta", &[&delta.data]);
        self.ensure_bound(&h, &key, vec![(1, OwnedArg::Mat(delta.clone()))])?;
        let mut xc = x.clone();
        let mut sigma = f64::NAN;
        for _ in 0..execs {
            let out = h.execute_bound(
                &spec.name,
                &key,
                vec![(0, OwnedArg::Mat(xc)), (2, OwnedArg::Scalar(lr))],
            )?;
            let mut it = out.into_iter();
            xc = it.next().context("missing X output")?.into_matrix();
            sigma = it.next().context("missing sigma output")?.scalar() as f64;
        }
        Ok((xc, sigma))
    }

    fn lsmds_step_chunk(&self, n: usize) -> usize {
        self.handle()
            .manifest()
            .find("lsmds_steps", &[("N", n)])
            .and_then(|s| s.dim("T"))
            .unwrap_or(1)
            .max(1)
    }

    fn ose_opt_steps(
        &self,
        landmarks: &Matrix,
        deltas: &Matrix,
        y0: &Matrix,
        lr: f32,
        steps: usize,
    ) -> Result<(Matrix, Vec<f32>)> {
        let l = landmarks.rows;
        let k = landmarks.cols;
        anyhow::ensure!(deltas.cols == l, "deltas width != L");
        anyhow::ensure!(
            y0.rows == deltas.rows && y0.cols == k,
            "y0 shape ({}, {}) != ({}, {k})",
            y0.rows,
            y0.cols,
            deltas.rows
        );
        let h = self.handle();
        let avail = h.manifest().available_dims("ose_opt", "B", &[("L", l)]);
        if avail.is_empty() {
            log::debug!("no ose_opt artifact for L={l}; native fallback");
            return self.native.ose_opt_steps(landmarks, deltas, y0, lr, steps);
        }
        // landmarks live on-device across all calls (position 0)
        let key = content_key("ose-landmarks", &[&landmarks.data]);
        self.ensure_bound(&h, &key, vec![(0, OwnedArg::Mat(landmarks.clone()))])?;

        let max_b = avail.iter().copied().max().unwrap_or(1).max(1);
        let mut y = Matrix::zeros(deltas.rows, k);
        let mut obj = vec![0.0f32; deltas.rows];
        let mut start = 0;
        while start < deltas.rows {
            let end = (start + max_b).min(deltas.rows);
            let rows = end - start;
            let b = pick_batch(&avail, rows).context("no ose_opt variant")?;
            let spec = h
                .manifest()
                .find("ose_opt", &[("L", l), ("B", b)])
                .context("ose_opt artifact vanished")?
                .clone();
            let t = spec.dim("T").unwrap_or(60).max(1);
            let execs = steps.div_ceil(t).max(1);
            let padded_d = pad_rows(&slice_rows(deltas, start, end), b);
            let mut yp = pad_rows(&slice_rows(y0, start, end), b);
            let mut last_obj = vec![0.0f32; b];
            for _ in 0..execs {
                let out = h.execute_bound(
                    &spec.name,
                    &key,
                    vec![
                        (1, OwnedArg::Mat(padded_d.clone())),
                        (2, OwnedArg::Mat(yp)),
                        (3, OwnedArg::Scalar(lr)),
                    ],
                )?;
                let mut it = out.into_iter();
                yp = it.next().context("missing Y output")?.into_matrix();
                if let Some(o) = it.next() {
                    last_obj = o.data;
                }
            }
            for r in 0..rows {
                y.row_mut(start + r).copy_from_slice(yp.row(r));
                obj[start + r] = last_obj[r];
            }
            start = end;
        }
        Ok((y, obj))
    }

    fn ose_opt_step_chunk(&self, l: usize) -> usize {
        let h = self.handle();
        let avail = h.manifest().available_dims("ose_opt", "B", &[("L", l)]);
        avail
            .first()
            .and_then(|b| h.manifest().find("ose_opt", &[("L", l), ("B", *b)]))
            .and_then(|s| s.dim("T"))
            .unwrap_or(usize::MAX)
            .max(1)
    }

    fn mlp_fwd(&self, params: &MlpParams, d: &Matrix) -> Result<Matrix> {
        anyhow::ensure!(d.cols == params.shape.input, "input width != L");
        let h = self.handle();
        let constraints = mlp_constraints(&params.shape);
        let avail = h.manifest().available_dims("mlp_fwd", "B", &constraints);
        if avail.is_empty() {
            log::debug!(
                "no mlp_fwd artifact for L={}; native fallback",
                params.shape.input
            );
            return self.native.mlp_fwd(params, d);
        }
        let flat = params.flatten();
        let flat_refs: Vec<&[f32]> = flat.iter().map(|p| p.as_slice()).collect();
        let key = content_key("mlp-weights", &flat_refs);
        let k = params.shape.output;
        let max_b = avail.iter().copied().max().unwrap_or(1).max(1);
        let mut out = Matrix::zeros(d.rows, k);
        let mut start = 0;
        while start < d.rows {
            let end = (start + max_b).min(d.rows);
            let rows = end - start;
            let b = pick_batch(&avail, rows).context("no mlp_fwd variant")?;
            let spec = h
                .manifest()
                .find("mlp_fwd", &{
                    let mut c = constraints.clone();
                    c.push(("B", b));
                    c
                })
                .context("mlp_fwd artifact vanished")?
                .clone();
            let arg_shapes: Vec<Vec<usize>> =
                spec.args.iter().map(|a| a.shape.clone()).collect();
            self.ensure_bound(&h, &key, weight_args(&flat, &arg_shapes, 1))?;
            let padded = pad_rows(&slice_rows(d, start, end), b);
            // hot path: only the input tile crosses host->device
            let y = h
                .execute_bound(&spec.name, &key, vec![(0, OwnedArg::Mat(padded))])?
                .swap_remove(0)
                .into_matrix();
            for r in 0..rows {
                out.row_mut(start + r).copy_from_slice(y.row(r));
            }
            start = end;
        }
        Ok(out)
    }

    fn mlp_loss(&self, params: &MlpParams, d: &Matrix, x: &Matrix) -> Result<f64> {
        let h = self.handle();
        let mut constraints = mlp_constraints(&params.shape);
        constraints.push(("B", d.rows));
        let Some(spec) = h.manifest().find("mlp_loss", &constraints).cloned() else {
            return self.native.mlp_loss(params, d, x);
        };
        let arg_shapes: Vec<Vec<usize>> =
            spec.args.iter().map(|a| a.shape.clone()).collect();
        let mut args: Vec<OwnedArg> = Vec::with_capacity(10);
        for (i, p) in params.flatten().into_iter().enumerate() {
            let sh = &arg_shapes[i];
            args.push(if sh.len() == 2 {
                OwnedArg::Mat(Matrix::from_vec(sh[0], sh[1], p))
            } else {
                OwnedArg::Vec1(p)
            });
        }
        args.push(OwnedArg::Mat(d.clone()));
        args.push(OwnedArg::Mat(x.clone()));
        let out = h.execute(&spec.name, args)?;
        Ok(out[0].scalar() as f64)
    }

    fn mlp_train_step(
        &self,
        state: &mut AdamState,
        d: &Matrix,
        x: &Matrix,
        lr: f32,
    ) -> Result<f32> {
        let h = self.handle();
        let constraints = mlp_constraints(&state.shape);
        let spec = match h.manifest().find("mlp_train_step", &constraints) {
            Some(s) if s.dim("B") == Some(d.rows) => s.clone(),
            _ => {
                log::debug!(
                    "no mlp_train_step artifact for L={} B={}; native fallback",
                    state.shape.input,
                    d.rows
                );
                return self.native.mlp_train_step(state, d, x, lr);
            }
        };
        let arg_shapes: Vec<Vec<usize>> =
            spec.args.iter().map(|a| a.shape.clone()).collect();
        let to_arg = |data: Vec<f32>, shape: &[usize]| -> OwnedArg {
            if shape.len() == 2 {
                OwnedArg::Mat(Matrix::from_vec(shape[0], shape[1], data))
            } else {
                OwnedArg::Vec1(data)
            }
        };
        let mut args: Vec<OwnedArg> = Vec::with_capacity(28);
        for (i, p) in state.params.iter().enumerate() {
            args.push(to_arg(p.clone(), &arg_shapes[i]));
        }
        for (i, p) in state.m.iter().enumerate() {
            args.push(to_arg(p.clone(), &arg_shapes[8 + i]));
        }
        for (i, p) in state.v.iter().enumerate() {
            args.push(to_arg(p.clone(), &arg_shapes[16 + i]));
        }
        args.push(OwnedArg::Scalar(state.t));
        args.push(OwnedArg::Mat(d.clone()));
        args.push(OwnedArg::Mat(x.clone()));
        args.push(OwnedArg::Scalar(lr));

        let out = h.execute(&spec.name, args)?;
        anyhow::ensure!(out.len() >= 26, "mlp_train_step: short output");
        // outputs: 8 params, 8 m, 8 v, t, loss
        for (i, o) in out.iter().take(8).enumerate() {
            state.params[i] = o.data.clone();
        }
        for (i, o) in out.iter().skip(8).take(8).enumerate() {
            state.m[i] = o.data.clone();
        }
        for (i, o) in out.iter().skip(16).take(8).enumerate() {
            state.v[i] = o.data.clone();
        }
        state.t = out[24].scalar();
        Ok(out[25].scalar())
    }

    fn mlp_train_batch(&self, shape: &MlpShape) -> Option<usize> {
        self.handle()
            .manifest()
            .find("mlp_train_step", &mlp_constraints(shape))
            .and_then(|s| s.dim("B"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_batch_prefers_smallest_fit() {
        assert_eq!(pick_batch(&[1, 64, 256], 1), Some(1));
        assert_eq!(pick_batch(&[1, 64, 256], 2), Some(64));
        assert_eq!(pick_batch(&[1, 64, 256], 64), Some(64));
        assert_eq!(pick_batch(&[1, 64, 256], 65), Some(256));
        assert_eq!(pick_batch(&[1, 64, 256], 1000), Some(256)); // chunked
        assert_eq!(pick_batch(&[], 4), None);
    }

    #[test]
    fn pad_rows_zero_fills() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0]]);
        let p = pad_rows(&m, 3);
        assert_eq!(p.rows, 3);
        assert_eq!(p.row(0), &[1.0, 2.0]);
        assert_eq!(p.row(2), &[0.0, 0.0]);
    }

    #[test]
    fn content_key_is_stable_and_content_sensitive() {
        let a = [1.0f32, 2.0];
        let b = [1.0f32, 2.0];
        let c = [1.0f32, 3.0];
        assert_eq!(
            content_key("k", &[a.as_slice()]),
            content_key("k", &[b.as_slice()])
        );
        assert_ne!(
            content_key("k", &[a.as_slice()]),
            content_key("k", &[c.as_slice()])
        );
        assert_ne!(
            content_key("k", &[a.as_slice()]),
            content_key("other", &[a.as_slice()])
        );
    }

    #[test]
    fn content_key_sampled_path_sees_head_and_tail() {
        // operands above HASH_FULL_LIMIT take the strided-sample path;
        // head/tail elements always participate
        let big = vec![1.0f32; HASH_FULL_LIMIT * 2];
        let mut tail_changed = big.clone();
        *tail_changed.last_mut().unwrap() = 2.0;
        let mut head_changed = big.clone();
        head_changed[0] = 2.0;
        let base = content_key("k", &[big.as_slice()]);
        assert_eq!(base, content_key("k", &[big.clone().as_slice()]));
        assert_ne!(base, content_key("k", &[tail_changed.as_slice()]));
        assert_ne!(base, content_key("k", &[head_changed.as_slice()]));
    }
}
