//! The compute-backend seam: every numeric graph the system executes —
//! LSMDS stress descent, the batched OSE optimiser (Eq. 2), and the MLP
//! forward/loss/Adam-train-step graphs (Sec. 4.2) — goes through
//! [`ComputeBackend`]. Two implementations exist:
//!
//! - [`NativeBackend`](super::native::NativeBackend): pure Rust, always
//!   available, row-parallel; the default.
//! - `PjrtBackend` (behind the `pjrt` cargo feature): executes the
//!   AOT-lowered HLO artifacts produced by `python/compile/aot.py` through
//!   a PJRT client, transparently delegating to the native backend for any
//!   shape it has no artifact for.
//!
//! Consumers (the pipeline, trainer, serving methods, figure harnesses)
//! hold a clonable [`Backend`] and never know which implementation runs —
//! this is the seam that later multi-backend/sharding work plugs into.

use std::sync::Arc;

use anyhow::Result;

use crate::mds::Matrix;
use crate::nn::{MlpParams, MlpShape};

/// Host-side Adam optimiser state threaded through
/// [`ComputeBackend::mlp_train_step`], in the artifact's flat argument
/// order (w1, b1, w2, b2, w3, b3, w4, b4). Both backends consume and
/// update the same representation, so training can switch backends
/// mid-run without conversion.
pub struct AdamState {
    /// Layer shape the flat buffers below belong to.
    pub shape: MlpShape,
    /// Flattened parameters.
    pub params: Vec<Vec<f32>>,
    /// First-moment accumulators.
    pub m: Vec<Vec<f32>>,
    /// Second-moment accumulators.
    pub v: Vec<Vec<f32>>,
    /// Step counter (f32 to match the artifact's scalar slot).
    pub t: f32,
}

impl AdamState {
    /// Fresh state (zero moments, step 0) around initial parameters.
    pub fn new(params: &MlpParams) -> AdamState {
        let flat = params.flatten();
        let zeros: Vec<Vec<f32>> = flat.iter().map(|p| vec![0.0; p.len()]).collect();
        AdamState {
            shape: params.shape.clone(),
            params: flat,
            m: zeros.clone(),
            v: zeros,
            t: 0.0,
        }
    }

    /// Current parameters in structured form.
    pub fn to_params(&self) -> MlpParams {
        MlpParams::from_flat(&self.shape, &self.params)
    }
}

/// A strategy for executing the system's compute graphs. Implementations
/// must be thread-safe: the serving path calls them from the batcher
/// thread while the pipeline may train on another.
pub trait ComputeBackend: Send + Sync {
    /// Short identifier ("native", "pjrt") for logs and method names.
    fn name(&self) -> &'static str;

    /// Run `steps` gradient-descent iterations on the raw stress (Eq. 1)
    /// of configuration `x` (N x K) against dissimilarities `delta`
    /// (N x N). Returns the updated configuration and the stress sigma
    /// evaluated at the configuration the final step departed from (the
    /// convergence signal the caller checks between calls).
    fn lsmds_steps(
        &self,
        x: &Matrix,
        delta: &Matrix,
        lr: f32,
        steps: usize,
    ) -> Result<(Matrix, f64)>;

    /// Natural step granularity for [`Self::lsmds_steps`] at size N: the
    /// caller loops in chunks of this many steps, checking convergence in
    /// between. PJRT returns the artifact's unrolled T; native defaults to
    /// per-iteration checking.
    fn lsmds_step_chunk(&self, _n: usize) -> usize {
        1
    }

    /// Run `steps` majorization iterations of the batched OSE optimisation
    /// (Eq. 2): embed `deltas.rows` new points (each row = distances to the
    /// L landmarks) into the fixed `landmarks` (L x K) configuration,
    /// starting from `y0` (B x K). Returns the final coordinates and the
    /// Eq.-2 objective of every row at the final iterate.
    fn ose_opt_steps(
        &self,
        landmarks: &Matrix,
        deltas: &Matrix,
        y0: &Matrix,
        lr: f32,
        steps: usize,
    ) -> Result<(Matrix, Vec<f32>)>;

    /// Natural step granularity for [`Self::ose_opt_steps`] at L landmarks.
    /// PJRT returns the artifact's unrolled inner T; `usize::MAX` means
    /// "no preference — any step count is equally cheap" (the native
    /// default), letting callers pick a granularity that suits their
    /// convergence checks.
    fn ose_opt_step_chunk(&self, _l: usize) -> usize {
        usize::MAX
    }

    /// MLP forward pass: `d` (B x L) -> predictions (B x K).
    fn mlp_fwd(&self, params: &MlpParams, d: &Matrix) -> Result<Matrix>;

    /// Eq.-3 loss of the forward pass against targets `x` (B x K).
    fn mlp_loss(&self, params: &MlpParams, d: &Matrix, x: &Matrix) -> Result<f64>;

    /// One fused forward/backward/Adam step on `state` for minibatch
    /// (`d`, `x`); returns the batch loss (Eq. 3).
    fn mlp_train_step(
        &self,
        state: &mut AdamState,
        d: &Matrix,
        x: &Matrix,
        lr: f32,
    ) -> Result<f32>;

    /// Preferred minibatch size for [`Self::mlp_train_step`] at this shape
    /// (PJRT: the fixed artifact batch; native: `None` = caller's choice).
    fn mlp_train_batch(&self, _shape: &MlpShape) -> Option<usize> {
        None
    }
}

/// Clonable handle to a [`ComputeBackend`] — the type every consumer
/// passes around.
#[derive(Clone)]
pub struct Backend(Arc<dyn ComputeBackend>);

impl Backend {
    /// Wrap any backend implementation.
    pub fn new(backend: Arc<dyn ComputeBackend>) -> Backend {
        Backend(backend)
    }

    /// The pure-Rust native backend (always available).
    pub fn native() -> Backend {
        log::debug!(
            "native backend kernel tier: {}",
            super::simd::active_tier_name()
        );
        Backend(Arc::new(super::native::NativeBackend::default()))
    }

    /// The PJRT artifact backend over `artifact_dir`. Fails when the
    /// manifest is missing or the PJRT client cannot start (e.g. this
    /// build links the in-tree `xla` stub).
    #[cfg(feature = "pjrt")]
    pub fn pjrt(artifact_dir: &std::path::Path) -> anyhow::Result<Backend> {
        Ok(Backend(Arc::new(super::pjrt::PjrtBackend::load(artifact_dir)?)))
    }

    /// Best available backend: PJRT when the feature is compiled in and
    /// its artifacts load, the native backend otherwise.
    pub fn auto() -> Backend {
        #[cfg(feature = "pjrt")]
        {
            match Backend::pjrt(&super::default_artifact_dir()) {
                Ok(b) => return b,
                Err(e) => {
                    log::debug!("pjrt backend unavailable ({e:#}); using native")
                }
            }
        }
        Backend::native()
    }
}

impl std::ops::Deref for Backend {
    type Target = dyn ComputeBackend;

    fn deref(&self) -> &Self::Target {
        self.0.as_ref()
    }
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Backend({})", self.0.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn adam_state_round_trips_params() {
        let mut rng = Rng::new(1);
        let shape = MlpShape { input: 6, hidden: [5, 4, 3], output: 2 };
        let params = MlpParams::init(&shape, &mut rng);
        let state = AdamState::new(&params);
        assert_eq!(state.params.len(), 8);
        assert_eq!(state.t, 0.0);
        assert!(state.m.iter().all(|v| v.iter().all(|x| *x == 0.0)));
        let back = state.to_params();
        for l in 0..4 {
            assert_eq!(back.w[l], params.w[l]);
            assert_eq!(back.b[l], params.b[l]);
        }
    }

    #[test]
    fn backend_handle_clones_share_the_implementation() {
        let a = Backend::native();
        let b = a.clone();
        assert_eq!(a.name(), "native");
        assert_eq!(b.name(), "native");
        assert!(format!("{a:?}").contains("native"));
    }
}
