//! Cross-thread access to the (non-`Send`) PJRT runtime: a dedicated
//! executor thread owns the [`Runtime`]; clonable [`RuntimeHandle`]s submit
//! jobs over a channel and block on a reply. This single compute stream is
//! the stage the dynamic batcher feeds.

use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::mds::Matrix;

use super::client::{ArgValue, OutValue, Runtime};
use super::manifest::Manifest;

/// Owned argument (must cross the channel).
#[derive(Clone, Debug)]
pub enum OwnedArg {
    /// Scalar f32.
    Scalar(f32),
    /// 2-D row-major matrix.
    Mat(Matrix),
    /// 1-D vector.
    Vec1(Vec<f32>),
}

impl OwnedArg {
    fn as_ref(&self) -> ArgValue<'_> {
        match self {
            OwnedArg::Scalar(x) => ArgValue::Scalar(*x),
            OwnedArg::Mat(m) => ArgValue::Mat(m),
            OwnedArg::Vec1(v) => ArgValue::Vec1(v),
        }
    }
}

enum Job {
    Execute {
        name: String,
        args: Vec<OwnedArg>,
        reply: Sender<Result<Vec<OutValue>>>,
    },
    /// Upload an argument set to the device once under a binding key.
    Bind {
        key: String,
        args: Vec<(usize, OwnedArg)>,
        reply: Sender<Result<()>>,
    },
    /// Execute with a device-resident binding + fresh dynamic args.
    ExecuteBound {
        name: String,
        key: String,
        dynamic: Vec<(usize, OwnedArg)>,
        reply: Sender<Result<Vec<OutValue>>>,
    },
    /// Pre-compile an artifact (warmup).
    Compile { name: String, reply: Sender<Result<()>> },
    Shutdown,
}

/// Handle to the executor thread. Cloning is cheap; all clones feed the
/// same single compute stream.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Job>,
    manifest: std::sync::Arc<Manifest>,
}

// Sender<Job> is Send; Manifest is plain data.
/// Owner of the dedicated PJRT executor thread: spawns it, hands out
/// [`RuntimeHandle`]s, and joins it on drop.
pub struct RuntimeThread {
    handle: Option<JoinHandle<()>>,
    tx: Sender<Job>,
    manifest: std::sync::Arc<Manifest>,
}

impl RuntimeThread {
    /// Spawn the executor thread and wait until the PJRT client is up.
    pub fn spawn(artifact_dir: &Path) -> Result<RuntimeThread> {
        let dir: PathBuf = artifact_dir.to_path_buf();
        // parse the manifest on the caller thread too (cheap, Send) so
        // handles can answer shape questions without a round-trip
        let manifest = std::sync::Arc::new(Manifest::load(&dir)?);
        let (tx, rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let rt = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Execute { name, args, reply } => {
                            let refs: Vec<ArgValue<'_>> =
                                args.iter().map(|a| a.as_ref()).collect();
                            let _ = reply.send(rt.execute(&name, &refs));
                        }
                        Job::Bind { key, args, reply } => {
                            let refs: Vec<(usize, ArgValue<'_>)> = args
                                .iter()
                                .map(|(p, a)| (*p, a.as_ref()))
                                .collect();
                            let _ = reply.send(rt.bind(&key, &refs));
                        }
                        Job::ExecuteBound { name, key, dynamic, reply } => {
                            let refs: Vec<(usize, ArgValue<'_>)> = dynamic
                                .iter()
                                .map(|(p, a)| (*p, a.as_ref()))
                                .collect();
                            let _ = reply.send(rt.execute_bound(&name, &key, &refs));
                        }
                        Job::Compile { name, reply } => {
                            let _ = reply.send(rt.executable(&name).map(|_| ()));
                        }
                        Job::Shutdown => break,
                    }
                }
            })
            .context("spawning pjrt-executor")?;
        ready_rx
            .recv()
            .context("executor thread died during startup")??;
        Ok(RuntimeThread { handle: Some(handle), tx, manifest })
    }

    /// A new clonable handle onto the executor thread.
    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle {
            tx: self.tx.clone(),
            manifest: std::sync::Arc::clone(&self.manifest),
        }
    }
}

impl Drop for RuntimeThread {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl RuntimeHandle {
    /// The artifact manifest the executor serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact by name (blocking).
    pub fn execute(&self, name: &str, args: Vec<OwnedArg>) -> Result<Vec<OutValue>> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::Execute { name: name.to_string(), args, reply })
            .context("executor thread gone")?;
        rx.recv().context("executor thread dropped the reply")?
    }

    /// Execute by graph family + dim constraints (blocking).
    pub fn execute_graph(
        &self,
        graph: &str,
        constraints: &[(&str, usize)],
        args: Vec<OwnedArg>,
    ) -> Result<Vec<OutValue>> {
        let name = self
            .manifest
            .find(graph, constraints)
            .with_context(|| format!("no artifact for {graph} {constraints:?}"))?
            .name
            .clone();
        self.execute(&name, args)
    }

    /// Upload an argument set to the device once (e.g. model weights).
    pub fn bind(&self, key: &str, args: Vec<(usize, OwnedArg)>) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::Bind { key: key.to_string(), args, reply })
            .context("executor thread gone")?;
        rx.recv().context("executor thread dropped the reply")?
    }

    /// Execute with a previously bound argument set + dynamic args.
    pub fn execute_bound(
        &self,
        name: &str,
        key: &str,
        dynamic: Vec<(usize, OwnedArg)>,
    ) -> Result<Vec<OutValue>> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::ExecuteBound {
                name: name.to_string(),
                key: key.to_string(),
                dynamic,
                reply,
            })
            .context("executor thread gone")?;
        rx.recv().context("executor thread dropped the reply")?
    }

    /// Pre-compile (warm) an artifact so the first request doesn't pay
    /// compilation latency.
    pub fn warm(&self, name: &str) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::Compile { name: name.to_string(), reply })
            .context("executor thread gone")?;
        rx.recv().context("executor thread dropped the reply")?
    }
}
