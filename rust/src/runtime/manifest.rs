//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Rust discovers executables exclusively through
//! `artifacts/manifest.json` — file names are never guessed.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
/// One artifact input argument.
pub struct ArgSpec {
    /// Argument name (as lowered).
    pub name: String,
    /// Expected tensor shape.
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug, PartialEq)]
/// One artifact output tensor.
pub struct OutSpec {
    /// Produced tensor shape.
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
/// One AOT-lowered executable: its graph, shape variant and file.
pub struct ArtifactSpec {
    /// Unique artifact name (`graph@scale` convention).
    pub name: String,
    /// Graph family: lsmds_steps | ose_opt | mlp_fwd | mlp_train_step | mlp_loss.
    pub graph: String,
    /// Shape-variant tag (e.g. the unrolled batch/step sizes).
    pub scale: String,
    /// HLO file, relative to the manifest directory at parse time.
    pub file: PathBuf,
    /// Named dimension bindings (L, K, B, T, ...).
    pub dims: BTreeMap<String, usize>,
    /// Input argument specs, in call order.
    pub args: Vec<ArgSpec>,
    /// Output tensor specs, in result order.
    pub outputs: Vec<OutSpec>,
}

impl ArtifactSpec {
    /// Named dimension value, if bound.
    pub fn dim(&self, key: &str) -> Option<usize> {
        self.dims.get(key).copied()
    }
}

#[derive(Clone, Debug)]
/// The contract between the AOT compiler (`python/compile/aot.py`)
/// and the artifact runtime: every lowered executable plus the model
/// shape they were lowered for.
pub struct Manifest {
    /// Embedding dimension K the artifacts were lowered for.
    pub k_dim: usize,
    /// Hidden-layer sizes of the lowered MLP graphs.
    pub hidden: Vec<usize>,
    /// Every lowered executable.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Read and parse `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text (`dir` anchors relative artifact paths).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let root = Json::parse(text).context("parsing manifest.json")?;
        let version = root
            .get("version")
            .and_then(Json::as_usize)
            .context("manifest: missing version")?;
        if version != 1 {
            bail!("manifest version {version} unsupported (expected 1)");
        }
        let k_dim = root
            .get("k_dim")
            .and_then(Json::as_usize)
            .context("manifest: missing k_dim")?;
        let hidden = root
            .get("hidden")
            .and_then(Json::as_arr)
            .context("manifest: missing hidden")?
            .iter()
            .map(|h| h.as_usize().context("bad hidden entry"))
            .collect::<Result<Vec<_>>>()?;

        let mut artifacts = Vec::new();
        for entry in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest: missing artifacts")?
        {
            artifacts.push(parse_entry(entry, dir)?);
        }
        Ok(Manifest { k_dim, hidden, artifacts })
    }

    /// Find the artifact of a graph family whose dims contain all the given
    /// (key, value) constraints.
    pub fn find(&self, graph: &str, constraints: &[(&str, usize)]) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| {
            a.graph == graph
                && constraints.iter().all(|(k, v)| a.dim(k) == Some(*v))
        })
    }

    /// All values of one dim across a graph family (e.g. available batch
    /// sizes of `mlp_fwd` at a given L) — sorted ascending.
    pub fn available_dims(
        &self,
        graph: &str,
        key: &str,
        constraints: &[(&str, usize)],
    ) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| {
                a.graph == graph
                    && constraints.iter().all(|(k, v)| a.dim(k) == Some(*v))
            })
            .filter_map(|a| a.dim(key))
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }
}

fn parse_entry(entry: &Json, dir: &Path) -> Result<ArtifactSpec> {
    let name = entry
        .get("name")
        .and_then(Json::as_str)
        .context("artifact: missing name")?
        .to_string();
    let graph = entry
        .get("graph")
        .and_then(Json::as_str)
        .context("artifact: missing graph")?
        .to_string();
    let scale = entry
        .get("scale")
        .and_then(Json::as_str)
        .unwrap_or("unknown")
        .to_string();
    let file = dir.join(
        entry
            .get("file")
            .and_then(Json::as_str)
            .context("artifact: missing file")?,
    );

    let mut dims = BTreeMap::new();
    if let Some(Json::Obj(m)) = entry.get("dims") {
        for (k, v) in m {
            dims.insert(
                k.clone(),
                v.as_usize().with_context(|| format!("bad dim {k}"))?,
            );
        }
    }

    let parse_shape = |j: &Json| -> Result<Vec<usize>> {
        j.get("shape")
            .and_then(Json::as_arr)
            .context("missing shape")?
            .iter()
            .map(|x| x.as_usize().context("bad shape entry"))
            .collect()
    };

    let mut args = Vec::new();
    for a in entry
        .get("args")
        .and_then(Json::as_arr)
        .context("artifact: missing args")?
    {
        args.push(ArgSpec {
            name: a
                .get("name")
                .and_then(Json::as_str)
                .context("arg: missing name")?
                .to_string(),
            shape: parse_shape(a)?,
        });
    }

    let mut outputs = Vec::new();
    for o in entry
        .get("outputs")
        .and_then(Json::as_arr)
        .context("artifact: missing outputs")?
    {
        outputs.push(OutSpec { shape: parse_shape(o)? });
    }

    Ok(ArtifactSpec { name, graph, scale, file, dims, args, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "k_dim": 7, "hidden": [256, 128, 64],
      "artifacts": [
        {"name": "ose_opt__B8_K7_L32_T5", "graph": "ose_opt",
         "scale": "smoke", "file": "ose_opt__B8_K7_L32_T5.hlo.txt",
         "dims": {"B": 8, "K": 7, "L": 32, "T": 5},
         "args": [{"name": "xl", "shape": [32, 7], "dtype": "f32"},
                  {"name": "d", "shape": [8, 32], "dtype": "f32"},
                  {"name": "y0", "shape": [8, 7], "dtype": "f32"},
                  {"name": "lr", "shape": [], "dtype": "f32"}],
         "outputs": [{"shape": [8, 7], "dtype": "f32"},
                     {"shape": [8], "dtype": "f32"}]},
        {"name": "ose_opt__B64_K7_L32_T5", "graph": "ose_opt",
         "scale": "small", "file": "b.hlo.txt",
         "dims": {"B": 64, "K": 7, "L": 32, "T": 5},
         "args": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.k_dim, 7);
        assert_eq!(m.hidden, vec![256, 128, 64]);
        assert_eq!(m.artifacts.len(), 2);
        let a = &m.artifacts[0];
        assert_eq!(a.dim("L"), Some(32));
        assert_eq!(a.args[0].shape, vec![32, 7]);
        assert_eq!(a.args[3].shape, Vec::<usize>::new());
        assert_eq!(a.outputs[1].shape, vec![8]);
        assert!(a.file.starts_with("/tmp/a"));
    }

    #[test]
    fn find_respects_constraints() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        let a = m.find("ose_opt", &[("L", 32), ("B", 8)]).unwrap();
        assert_eq!(a.dim("B"), Some(8));
        assert!(m.find("ose_opt", &[("L", 999)]).is_none());
        assert!(m.find("nope", &[]).is_none());
    }

    #[test]
    fn available_dims_sorted() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert_eq!(m.available_dims("ose_opt", "B", &[("L", 32)]), vec![8, 64]);
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replacen("\"version\": 1", "\"version\": 9", 1);
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find("ose_opt", &[("L", 32)]).is_some());
            assert_eq!(m.k_dim, 7);
        }
    }
}
