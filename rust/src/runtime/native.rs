//! The pure-Rust native compute backend: evaluates the same graphs the
//! PJRT artifacts encode (LSMDS stress descent, batched OSE majorization,
//! fused MLP forward / loss / Adam train step) directly on the CPU,
//! row-parallel where the shape allows it.
//!
//! Numerics mirror the serial oracles in `ose::optimise` and `nn::mlp`:
//! the OSE majorization and train-step paths match operation-for-operation
//! (same accumulation order, same eps), while the LSMDS and MLP-forward
//! paths run the cache-blocked flat-`f32` kernels
//! (`mds::lsmds::stress_gradient_blocked`, `nn::forward_block`) that the
//! dedicated cross-check tests in `tests/backend_parity.rs` hold against
//! those oracles — this backend is both the default production path and
//! the reference the PJRT artifacts are validated against.
//!
//! The blocked kernels themselves dispatch through the explicit SIMD
//! kernel tier ([`crate::runtime::simd`], `--kernel-tier`), so every
//! caller of this backend — monolithic and divide base solves, in-RAM
//! and out-of-core pipelines, unsharded and sharded serving — inherits
//! the vector kernels with no wiring of its own, and all tiers produce
//! bit-identical results.

use anyhow::Result;

use crate::mds::lsmds::stress_gradient_blocked;
use crate::mds::Matrix;
use crate::nn::{self, MlpParams};
use crate::ose::optimise::objective_and_grad;
use crate::util::threadpool::{default_parallelism, parallel_for_chunks, SyncSlice};

use super::backend::{AdamState, ComputeBackend};

/// Rows of the input batch forwarded per thread-pool work item in
/// [`ComputeBackend::mlp_fwd`]: large enough that each worker amortises
/// its activation scratch buffers, small enough to balance ragged loads.
const FWD_BLOCK_ROWS: usize = 32;

/// Pure-Rust backend. Stateless; cheap to construct.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn lsmds_steps(
        &self,
        x: &Matrix,
        delta: &Matrix,
        lr: f32,
        steps: usize,
    ) -> Result<(Matrix, f64)> {
        anyhow::ensure!(delta.rows == delta.cols, "delta must be square");
        anyhow::ensure!(x.rows == delta.rows, "x/delta row mismatch");
        let lr = lr as f64;
        let mut x = x.clone();
        let mut sigma = f64::NAN;
        for _ in 0..steps {
            let (grad, s) = stress_gradient_blocked(&x, delta);
            sigma = s;
            for (xi, gi) in x.data.iter_mut().zip(grad.data.iter()) {
                *xi -= (lr * *gi as f64) as f32;
            }
        }
        Ok((x, sigma))
    }

    fn ose_opt_steps(
        &self,
        landmarks: &Matrix,
        deltas: &Matrix,
        y0: &Matrix,
        lr: f32,
        steps: usize,
    ) -> Result<(Matrix, Vec<f32>)> {
        let l = landmarks.rows;
        let k = landmarks.cols;
        anyhow::ensure!(deltas.cols == l, "deltas width {} != L {l}", deltas.cols);
        anyhow::ensure!(
            y0.rows == deltas.rows && y0.cols == k,
            "y0 shape ({}, {}) != ({}, {k})",
            y0.rows,
            y0.cols,
            deltas.rows
        );
        let b = deltas.rows;
        let lrf = lr as f64;
        let mut y = Matrix::zeros(b, k);
        let mut obj = vec![0.0f32; b];
        {
            let yslots = SyncSlice::new(&mut y.data);
            let oslots = SyncSlice::new(&mut obj);
            parallel_for_chunks(b, 4, default_parallelism(), |start, end| {
                for r in start..end {
                    let mut yr: Vec<f32> = y0.row(r).to_vec();
                    for _ in 0..steps {
                        let (_, grad) =
                            objective_and_grad(landmarks, deltas.row(r), &yr);
                        for c in 0..k {
                            yr[c] -= (lrf * grad[c]) as f32;
                        }
                    }
                    let (o, _) = objective_and_grad(landmarks, deltas.row(r), &yr);
                    // SAFETY: row r is owned by this chunk; obj[r] and the
                    // output row are each written exactly once.
                    unsafe {
                        oslots.write(r, o as f32);
                        for c in 0..k {
                            yslots.write(r * k + c, yr[c]);
                        }
                    }
                }
            });
        }
        Ok((y, obj))
    }

    fn mlp_fwd(&self, params: &MlpParams, d: &Matrix) -> Result<Matrix> {
        anyhow::ensure!(
            d.cols == params.shape.input,
            "input width {} != L {}",
            d.cols,
            params.shape.input
        );
        let k = params.shape.output;
        let l = params.shape.input;
        let mut out = Matrix::zeros(d.rows, k);
        {
            let slots = SyncSlice::new(&mut out.data);
            parallel_for_chunks(
                d.rows,
                FWD_BLOCK_ROWS,
                default_parallelism(),
                |start, end| {
                    let rows = end - start;
                    let mut block = vec![0.0f32; rows * k];
                    nn::forward_block(
                        params,
                        &d.data[start * l..end * l],
                        rows,
                        &mut block,
                    );
                    // SAFETY: rows start..end belong to this chunk alone, so
                    // the output cells are each written exactly once.
                    unsafe {
                        for (i, v) in block.iter().enumerate() {
                            slots.write(start * k + i, *v);
                        }
                    }
                },
            );
        }
        Ok(out)
    }

    fn mlp_loss(&self, params: &MlpParams, d: &Matrix, x: &Matrix) -> Result<f64> {
        let pred = self.mlp_fwd(params, d)?;
        anyhow::ensure!(
            (pred.rows, pred.cols) == (x.rows, x.cols),
            "target shape mismatch"
        );
        Ok(nn::mae_loss(&pred, x))
    }

    fn mlp_train_step(
        &self,
        state: &mut AdamState,
        d: &Matrix,
        x: &Matrix,
        lr: f32,
    ) -> Result<f32> {
        anyhow::ensure!(d.cols == state.shape.input, "input width != L");
        anyhow::ensure!(x.cols == state.shape.output, "label width != K");
        anyhow::ensure!(d.rows == x.rows, "batch mismatch");
        let params = state.to_params();
        let (loss, grads) = nn::backward(&params, d, x);
        state.t += 1.0;
        let bc1 = 1.0 - nn::mlp::BETA1.powf(state.t);
        let bc2 = 1.0 - nn::mlp::BETA2.powf(state.t);
        for layer in 0..4 {
            let (wi, bi) = (2 * layer, 2 * layer + 1);
            nn::adam_update(
                &mut state.params[wi],
                &grads.w[layer].data,
                &mut state.m[wi],
                &mut state.v[wi],
                lr,
                bc1,
                bc2,
            );
            nn::adam_update(
                &mut state.params[bi],
                &grads.b[layer],
                &mut state.m[bi],
                &mut state.v[bi],
                lr,
                bc1,
                bc2,
            );
        }
        Ok(loss as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::MlpShape;
    use crate::util::prng::Rng;

    #[test]
    fn ose_opt_zero_steps_returns_initial_guess() {
        let mut rng = Rng::new(1);
        let lm = Matrix::random_normal(&mut rng, 10, 3, 1.0);
        let deltas = Matrix::from_vec(
            2,
            10,
            (0..20).map(|_| rng.next_f32() + 0.5).collect(),
        );
        let y0 = Matrix::random_normal(&mut rng, 2, 3, 1.0);
        let (y, obj) = NativeBackend
            .ose_opt_steps(&lm, &deltas, &y0, 0.05, 0)
            .unwrap();
        assert_eq!(y.data, y0.data);
        assert_eq!(obj.len(), 2);
        assert!(obj.iter().all(|o| o.is_finite() && *o >= 0.0));
    }

    #[test]
    fn mlp_fwd_rejects_wrong_width() {
        let mut rng = Rng::new(2);
        let params = MlpParams::init(
            &MlpShape { input: 8, hidden: [4, 4, 4], output: 2 },
            &mut rng,
        );
        assert!(NativeBackend.mlp_fwd(&params, &Matrix::zeros(3, 7)).is_err());
    }

    #[test]
    fn lsmds_steps_reduce_stress() {
        let mut rng = Rng::new(3);
        let hidden = Matrix::random_normal(&mut rng, 20, 2, 1.0);
        let mut delta = Matrix::zeros(20, 20);
        for i in 0..20 {
            for j in 0..20 {
                let d = crate::strdist::euclidean(hidden.row(i), hidden.row(j));
                delta.set(i, j, d as f32);
            }
        }
        let mut x0 = Matrix::random_normal(&mut rng, 20, 2, 1.0);
        x0.center_columns();
        let before = crate::mds::stress::raw_stress(&x0, &delta);
        let (x, sigma) = NativeBackend
            .lsmds_steps(&x0, &delta, 1.0 / 40.0, 50)
            .unwrap();
        let after = crate::mds::stress::raw_stress(&x, &delta);
        assert!(after < before, "{before} -> {after}");
        assert!(sigma.is_finite());
    }
}
