//! Minimal JSON value model, parser and writer.
//!
//! The image vendors no `serde`, so artifact manifests, run configs and
//! result files go through this hand-rolled implementation. It supports the
//! full JSON grammar (RFC 8259) minus exotic number edge cases beyond f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
/// A JSON value (hand-rolled: the offline image vendors no serde).
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys, so serialisation is deterministic).
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
/// Where and why parsing failed.
pub struct ParseError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// What was expected.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // -- constructors ------------------------------------------------------

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    /// Build an array of strings.
    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    // -- accessors ---------------------------------------------------------

    /// Object field lookup (`None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integral value, if representable as `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted-path lookup.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- parse -------------------------------------------------------------

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- write -------------------------------------------------------------

    /// Compact single-line serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Indented serialisation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            out.push_str(&format!("{}", x as i64));
        } else {
            out.push_str(&format!("{x}"));
        }
    } else {
        // JSON has no inf/nan; null is the least-bad round-trip.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            self.pos -= 1; // compensate the += 1 below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.b[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}, null], "d": true}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path("a").unwrap().as_arr().unwrap()[1].path("b").unwrap(),
            &Json::Str("c".into())
        );
        assert_eq!(v.path("d").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"\\q\"", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn string_escapes_round_trip() {
        let s = "a\"b\\c\nd\te\u{1F600}\u{8}";
        let j = Json::Str(s.to_string());
        let round = Json::parse(&j.to_string()).unwrap();
        assert_eq!(round, j);
    }

    #[test]
    fn unicode_escape_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v, Json::Str("\u{1F600}".into()));
    }

    #[test]
    fn numbers_round_trip() {
        for x in [0.0, -1.0, 3.25, 1e-9, 123456789.0, -2.5e17] {
            let j = Json::Num(x);
            let round = Json::parse(&j.to_string()).unwrap();
            assert_eq!(round.as_f64().unwrap(), x);
        }
    }

    #[test]
    fn integers_format_without_fraction() {
        assert_eq!(Json::Num(7.0).to_string(), "7");
        assert_eq!(Json::Num(7.5).to_string(), "7.5");
    }

    #[test]
    fn pretty_output_reparses() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.0])),
            ("name", Json::Str("test".into())),
            ("nested", Json::obj(vec![("k", Json::Null)])),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(5.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"version":1,"artifacts":[
            {"name":"ose_opt__B8","file":"a.hlo.txt",
             "dims":{"B":8,"L":32},
             "args":[{"name":"xl","shape":[32,7],"dtype":"f32"}]}]}"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].path("dims.L").unwrap().as_usize(), Some(32));
        let shape = arts[0].get("args").unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize(), Some(32));
    }
}
