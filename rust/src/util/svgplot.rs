//! Minimal SVG chart writer — renders the figure JSON under `results/`
//! into actual figure files (line charts for Figs 1/4, scatter for Fig 2),
//! since the image has no plotting stack.

use std::fmt::Write as _;

#[derive(Clone, Debug)]
/// One plotted series.
pub struct Series {
    /// Legend label.
    pub label: String,
    /// (x, y) samples in plot order.
    pub points: Vec<(f64, f64)>,
    /// SVG stroke/fill colour.
    pub color: &'static str,
}

#[derive(Clone, Debug)]
/// A minimal line/scatter chart rendered to standalone SVG.
pub struct Chart {
    /// Chart title.
    pub title: String,
    /// X-axis label.
    pub x_label: String,
    /// Y-axis label.
    pub y_label: String,
    /// Log-scale the y axis.
    pub log_y: bool,
    /// The plotted series.
    pub series: Vec<Series>,
    /// scatter (markers only) vs line chart
    pub scatter: bool,
}

const W: f64 = 640.0;
const H: f64 = 420.0;
const ML: f64 = 70.0; // margins
const MR: f64 = 20.0;
const MT: f64 = 40.0;
const MB: f64 = 55.0;

impl Chart {
    /// Empty line chart with the given labels.
    pub fn line(title: &str, x_label: &str, y_label: &str) -> Chart {
        Chart {
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            log_y: false,
            series: vec![],
            scatter: false,
        }
    }

    /// Append a series.
    pub fn add(&mut self, label: &str, color: &'static str, points: Vec<(f64, f64)>) {
        self.series.push(Series { label: label.into(), points, color });
    }

    fn bounds(&self) -> (f64, f64, f64, f64) {
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for s in &self.series {
            for &(x, y) in &s.points {
                let y = if self.log_y { y.max(1e-12).log10() } else { y };
                x0 = x0.min(x);
                x1 = x1.max(x);
                y0 = y0.min(y);
                y1 = y1.max(y);
            }
        }
        if !x0.is_finite() {
            return (0.0, 1.0, 0.0, 1.0);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let pad = (y1 - y0) * 0.08;
        (x0, x1, y0 - pad, y1 + pad)
    }

    /// Render to a standalone SVG document.
    pub fn render(&self) -> String {
        let (x0, x1, y0, y1) = self.bounds();
        let sx = |x: f64| ML + (x - x0) / (x1 - x0) * (W - ML - MR);
        let sy = |y: f64| {
            let y = if self.log_y { y.max(1e-12).log10() } else { y };
            H - MB - (y - y0) / (y1 - y0) * (H - MT - MB)
        };
        let mut s = String::new();
        let _ = write!(
            s,
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{W}" height="{H}" viewBox="0 0 {W} {H}">"#
        );
        let _ = write!(
            s,
            r#"<rect width="{W}" height="{H}" fill="white"/><text x="{:.0}" y="24" font-size="15" text-anchor="middle" font-family="sans-serif">{}</text>"#,
            W / 2.0,
            esc(&self.title)
        );
        // axes
        let _ = write!(
            s,
            r#"<line x1="{ML}" y1="{MT}" x2="{ML}" y2="{:.1}" stroke="black"/><line x1="{ML}" y1="{:.1}" x2="{:.1}" y2="{:.1}" stroke="black"/>"#,
            H - MB,
            H - MB,
            W - MR,
            H - MB
        );
        // ticks (5 per axis)
        for i in 0..=4 {
            let fx = x0 + (x1 - x0) * i as f64 / 4.0;
            let px = sx(fx);
            let _ = write!(
                s,
                r#"<line x1="{px:.1}" y1="{:.1}" x2="{px:.1}" y2="{:.1}" stroke="black"/><text x="{px:.1}" y="{:.1}" font-size="11" text-anchor="middle" font-family="sans-serif">{}</text>"#,
                H - MB,
                H - MB + 5.0,
                H - MB + 18.0,
                fmt_tick(fx)
            );
            let fy = y0 + (y1 - y0) * i as f64 / 4.0;
            let py = H - MB - (fy - y0) / (y1 - y0) * (H - MT - MB);
            let label = if self.log_y { 10f64.powf(fy) } else { fy };
            let _ = write!(
                s,
                r#"<line x1="{:.1}" y1="{py:.1}" x2="{ML}" y2="{py:.1}" stroke="black"/><text x="{:.1}" y="{:.1}" font-size="11" text-anchor="end" font-family="sans-serif">{}</text>"#,
                ML - 5.0,
                ML - 8.0,
                py + 4.0,
                fmt_tick(label)
            );
        }
        // axis labels
        let _ = write!(
            s,
            r#"<text x="{:.0}" y="{:.0}" font-size="13" text-anchor="middle" font-family="sans-serif">{}</text>"#,
            (ML + W - MR) / 2.0,
            H - 12.0,
            esc(&self.x_label)
        );
        let _ = write!(
            s,
            r#"<text x="16" y="{:.0}" font-size="13" text-anchor="middle" font-family="sans-serif" transform="rotate(-90 16 {:.0})">{}</text>"#,
            (MT + H - MB) / 2.0,
            (MT + H - MB) / 2.0,
            esc(&self.y_label)
        );
        // series
        for (si, ser) in self.series.iter().enumerate() {
            if !self.scatter && ser.points.len() > 1 {
                let mut path = String::new();
                for (i, &(x, y)) in ser.points.iter().enumerate() {
                    let _ = write!(
                        path,
                        "{}{:.1},{:.1} ",
                        if i == 0 { "M" } else { "L" },
                        sx(x),
                        sy(y)
                    );
                }
                let _ = write!(
                    s,
                    r#"<path d="{path}" fill="none" stroke="{}" stroke-width="2"/>"#,
                    ser.color
                );
            }
            for &(x, y) in &ser.points {
                let _ = write!(
                    s,
                    r#"<circle cx="{:.1}" cy="{:.1}" r="3" fill="{}"/>"#,
                    sx(x),
                    sy(y),
                    ser.color
                );
            }
            // legend
            let ly = MT + 8.0 + si as f64 * 18.0;
            let _ = write!(
                s,
                r#"<rect x="{:.1}" y="{:.1}" width="12" height="12" fill="{}"/><text x="{:.1}" y="{:.1}" font-size="12" font-family="sans-serif">{}</text>"#,
                W - MR - 170.0,
                ly - 10.0,
                ser.color,
                W - MR - 152.0,
                ly,
                esc(&ser.label)
            );
        }
        s.push_str("</svg>");
        s
    }
}

fn esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

fn fmt_tick(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1e5 || v.abs() < 1e-3 {
        format!("{v:.1e}")
    } else if v.fract().abs() < 1e-9 {
        format!("{}", v as i64)
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chart() -> Chart {
        let mut c = Chart::line("Test", "L", "Err");
        c.add("opt", "#d62728", vec![(100.0, 90.0), (500.0, 75.0), (1000.0, 72.0)]);
        c.add("nn", "#1f77b4", vec![(100.0, 88.0), (500.0, 89.0), (1000.0, 88.0)]);
        c
    }

    #[test]
    fn renders_valid_svg_skeleton() {
        let svg = chart().render();
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert!(svg.contains("Test"));
        assert!(svg.contains("opt"));
        assert!(svg.matches("<path").count() == 2);
        assert!(svg.matches("<circle").count() == 6);
    }

    #[test]
    fn log_scale_monotone_mapping() {
        let mut c = chart();
        c.log_y = true;
        let svg = c.render();
        assert!(svg.contains("<svg"));
    }

    #[test]
    fn escapes_labels() {
        let mut c = Chart::line("a<b & c", "x", "y");
        c.add("s", "#000", vec![(0.0, 1.0)]);
        let svg = c.render();
        assert!(svg.contains("a&lt;b &amp; c"));
        assert!(!svg.contains("a<b"));
    }

    #[test]
    fn empty_chart_does_not_panic() {
        let c = Chart::line("empty", "x", "y");
        let svg = c.render();
        assert!(svg.contains("</svg>"));
    }

    #[test]
    fn scatter_mode_omits_paths() {
        let mut c = chart();
        c.scatter = true;
        let svg = c.render();
        assert_eq!(svg.matches("<path").count(), 0);
        assert!(svg.matches("<circle").count() >= 6);
    }
}
