//! Stderr logger on the `log` facade, filtered by `LMDS_LOG`
//! (error|warn|info|debug|trace; default info).

use std::io::Write;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::OnceCell;

static START: OnceCell<Instant> = OnceCell::new();

struct StderrLogger {
    level: LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:9.3}s {lvl} {}] {}",
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Level from `LMDS_LOG` env var.
pub fn init() {
    let _ = START.set(Instant::now());
    let level = match std::env::var("LMDS_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let logger = Box::leak(Box::new(StderrLogger { level }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
