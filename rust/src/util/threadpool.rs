//! Scoped data-parallelism without rayon/tokio: a chunked parallel-for built
//! on `std::thread::scope`, plus a small persistent worker pool for the
//! coordinator's request handlers.
//!
//! The dissimilarity-matrix build (O(L·M) Levenshtein calls) and the batched
//! OSE evaluation dominate CPU time outside PJRT; both are embarrassingly
//! parallel over rows, which is exactly the shape `parallel_for_chunks`
//! provides.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// Number of worker threads to use: all cores, clamped to `1..=32`. The
/// lower bound keeps degenerate `available_parallelism` results usable;
/// the upper cap exists because the PJRT CPU client spins up its own pool
/// and beyond ~32 threads the row-parallel kernels here are memory-bound
/// anyway — extra workers only add scheduling thrash. Callers that know
/// better can pass their own thread count to [`parallel_for_chunks`].
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 32)
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on `threads` threads.
/// Work is distributed dynamically (atomic cursor) so ragged per-item costs
/// (e.g. Levenshtein on variable-length strings) balance automatically.
///
/// Degenerate inputs are safe: `n == 0` runs nothing, `chunk == 0` is
/// treated as 1 (a zero chunk would otherwise never advance the cursor),
/// and `threads` is clamped to the number of chunks so no worker spawns
/// with nothing to do.
pub fn parallel_for_chunks<F>(n: usize, chunk: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let chunk = chunk.max(1);
    let threads = threads.max(1).min(n.div_ceil(chunk));
    if threads == 1 {
        let mut start = 0;
        while start < n {
            f(start, (start + chunk).min(n));
            start += chunk;
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                f(start, (start + chunk).min(n));
            });
        }
    });
}

/// Map `0..n` in parallel into a pre-allocated output vector.
/// `f(i)` must be pure w.r.t. index i.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SyncSlice::new(&mut out);
        parallel_for_chunks(n, 64, threads, |start, end| {
            for i in start..end {
                // SAFETY: each index is written by exactly one chunk owner.
                unsafe { slots.write(i, f(i)) };
            }
        });
    }
    out
}

/// Shared mutable slice with caller-guaranteed disjoint index ownership.
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: `write` is the only mutation and its contract (each index
// written by at most one thread, no concurrent reads) makes the shared
// reference race-free; T: Send lets the written values cross threads.
unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}
// SAFETY: the wrapper is only a raw pointer + length view of a `&mut
// [T]` with T: Send; moving the view to another thread moves nothing
// that the origin thread still aliases mutably.
unsafe impl<T: Send> Send for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    /// Wrap a slice whose indices the caller partitions among threads.
    pub fn new(slice: &'a mut [T]) -> Self {
        Self {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Slice length.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True for an empty slice.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// # Safety
    /// Each index must be written by at most one thread, and not read while
    /// the parallel section is live.
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        // SAFETY: the caller upholds the `# Safety` contract (exclusive
        // index ownership), and i < len keeps the write in bounds.
        unsafe { self.ptr.add(i).write(value) };
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A small persistent worker pool (FIFO) for the serving path, where
/// per-request `thread::scope` spawning would dominate the sub-millisecond
/// latency budget.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Pool of `threads` workers (min 1).
    pub fn new(threads: usize) -> Self {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let handles = (0..threads.max(1))
            .map(|_| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            queued.fetch_sub(1, Ordering::Relaxed);
                        }
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), handles, queued }
    }

    /// Queue depth (jobs submitted but not yet finished).
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    /// Queue one job (FIFO).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker pool hung up");
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_every_index_once() {
        let n = 10_001;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 37, 8, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_handles_empty_and_tiny() {
        parallel_for_chunks(0, 16, 4, |_, _| panic!("should not run"));
        let count = AtomicUsize::new(0);
        parallel_for_chunks(1, 16, 4, |s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_for_zero_chunk_is_treated_as_one() {
        // chunk = 0 used to divide by zero / never advance the cursor
        let n = 17;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 0, 4, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_more_threads_than_items() {
        // threads > n and n < chunk: single chunk, no idle-worker panics
        for (n, chunk, threads) in [(3usize, 16usize, 64usize), (1, 1, 8), (5, 100, 3)] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            parallel_for_chunks(n, chunk, threads, |s, e| {
                for i in s..e {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n={n} chunk={chunk} threads={threads}"
            );
        }
    }

    #[test]
    fn parallel_for_chunk_of_one_covers_all() {
        // chunk = 1: every index is its own work item (max contention case)
        let n = 257;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 1, 8, |s, e| {
            assert_eq!(e, s + 1);
            hits[s].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn default_parallelism_honours_clamp() {
        let p = default_parallelism();
        assert!((1..=32).contains(&p));
    }

    #[test]
    fn parallel_map_matches_serial() {
        let got = parallel_map(1000, 8, |i| (i * i) as u64);
        let want: Vec<u64> = (0..1000u64).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn worker_pool_runs_all_jobs() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..500u64 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(i, Ordering::Relaxed);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::Relaxed), (0..500).sum::<u64>());
    }
}
