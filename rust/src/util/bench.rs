//! Micro/macro benchmark harness (no `criterion` in the offline image).
//!
//! Provides warmup, timed iterations with per-iteration samples, robust
//! statistics (median + MAD rather than mean, so GC-less but
//! scheduler-noisy CPU runs don't skew), and a uniform one-line report
//! format that `cargo bench` targets print.

use std::time::{Duration, Instant};

use super::stats;

#[derive(Clone, Debug)]
/// Warmup/measurement budget of one bench subject.
pub struct BenchConfig {
    /// Minimum wall time to spend in warmup.
    pub warmup: Duration,
    /// Minimum wall time to spend measuring.
    pub measure: Duration,
    /// Hard cap on measured iterations (for very slow subjects).
    pub max_iters: usize,
    /// Minimum measured iterations (for very fast subjects).
    pub min_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

impl BenchConfig {
    /// Quick preset for heavy end-to-end subjects (one warmup pass,
    /// a handful of samples).
    pub fn heavy() -> Self {
        Self {
            warmup: Duration::from_millis(0),
            measure: Duration::from_secs(2),
            max_iters: 20,
            min_iters: 3,
        }
    }
}

#[derive(Clone, Debug)]
/// Robust timing summary of one bench subject.
pub struct BenchResult {
    /// Subject name.
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Per-iteration wall times (seconds).
    pub samples_s: Vec<f64>,
    /// Median iteration time (seconds).
    pub median_s: f64,
    /// Median absolute deviation (seconds).
    pub mad_s: f64,
    /// Mean iteration time (seconds).
    pub mean_s: f64,
    /// Fastest iteration (seconds).
    pub min_s: f64,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "{:<48} {:>12}/iter  (median; mad {}, min {}, n={})",
            self.name,
            fmt_duration(self.median_s),
            fmt_duration(self.mad_s),
            fmt_duration(self.min_s),
            self.iters,
        )
    }

    /// Throughput helper: items per second at the median sample.
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / self.median_s
    }
}

/// Human-scale duration formatting (ns/µs/ms/s).
pub fn fmt_duration(s: f64) -> String {
    if !s.is_finite() {
        "n/a".into()
    } else if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark `f`, which performs ONE logical iteration per call.
/// The closure's return value is black-boxed to stop dead-code elimination.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < cfg.warmup {
        std::hint::black_box(f());
    }
    // Measure.
    let mut samples = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < cfg.measure || samples.len() < cfg.min_iters)
        && samples.len() < cfg.max_iters
    {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }

    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = stats::quantile(&sorted, 0.5);
    let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = stats::quantile(&devs, 0.5);

    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median_s: median,
        mad_s: mad,
        mean_s: stats::mean(&samples),
        min_s: sorted[0],
        samples_s: samples,
    }
}

/// Simple scope timer for ad-hoc profiling of pipeline phases.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    /// Start a labelled wall-clock timer.
    pub fn start(label: &str) -> Self {
        Self { label: label.to_string(), start: Instant::now() }
    }

    /// Seconds elapsed so far.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Stop, log the elapsed time, and return it in seconds.
    pub fn stop(self) -> f64 {
        let dt = self.elapsed_s();
        log::debug!("{}: {}", self.label, fmt_duration(dt));
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(30),
            max_iters: 1000,
            min_iters: 5,
        };
        let r = bench("spin", &cfg, || {
            let mut acc = 0u64;
            for i in 0..2_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters >= 5);
        assert!(r.median_s > 0.0);
        assert!(r.min_s <= r.median_s);
        assert!(r.median_s <= r.samples_s.iter().cloned().fold(0.0, f64::max));
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" µs"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }

    #[test]
    fn throughput_inverts_median() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            samples_s: vec![0.5],
            median_s: 0.5,
            mad_s: 0.0,
            mean_s: 0.5,
            min_s: 0.5,
        };
        assert!((r.throughput(100) - 200.0).abs() < 1e-9);
    }
}
