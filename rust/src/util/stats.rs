//! Summary statistics, quantiles and fixed-bucket histograms — the numeric
//! backbone of the metrics module, the bench harness and the figure
//! reproductions (Fig 3 needs PErr distributions, Fig 4 mean RTs).

/// Streaming mean/variance (Welford) with min/max tracking.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one sample in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples seen.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Smallest sample seen.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Quantile of a sample via linear interpolation (type-7, the R default —
/// matches what the paper's R analysis would have produced).
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty sample");
    assert!((0.0..=1.0).contains(&q), "q={q} out of range");
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let h = (n - 1) as f64 * q;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    let frac = h - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Sorts a copy and returns (p50, p95, p99).
pub fn percentiles(xs: &[f64]) -> (f64, f64, f64) {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (quantile(&v, 0.5), quantile(&v, 0.95), quantile(&v, 0.99))
}

/// Mean of a slice (NaN when empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Median of a slice (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile(&v, 0.5)
}

/// Fixed-width histogram over [lo, hi); out-of-range values clamp to the
/// edge buckets so nothing is silently dropped.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Inclusive lower edge.
    pub lo: f64,
    /// Exclusive upper edge.
    pub hi: f64,
    /// Per-bucket counts.
    pub buckets: Vec<u64>,
}

impl Histogram {
    /// Histogram over `[lo, hi)` with `nbuckets` equal buckets.
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Self { lo, hi, buckets: vec![0; nbuckets] }
    }

    /// Count one sample (out-of-range clamps to the edge buckets).
    pub fn push(&mut self, x: f64) {
        let nb = self.buckets.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * nb as f64).floor() as i64).clamp(0, nb as i64 - 1);
        self.buckets[idx as usize] += 1;
    }

    /// Total samples counted.
    pub fn total(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Midpoint value of bucket `i`.
    pub fn bucket_mid(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.buckets.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }

    /// Terminal sparkline for quick visual checks in example binaries.
    pub fn render(&self, width: usize) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        let step = (self.buckets.len() as f64 / width.max(1) as f64).max(1.0);
        let mut out = String::new();
        let mut i = 0.0;
        while (i as usize) < self.buckets.len() && out.chars().count() < width {
            let b = self.buckets[i as usize];
            let level = ((b as f64 / max as f64) * 7.0).round() as usize;
            out.push(BARS[level.min(7)]);
            i += step;
        }
        out
    }
}

/// Log-bucketed histogram over `(0, +inf)` with a fixed bucket count set at
/// construction — the bounded-memory backbone of the serving metrics. Values
/// below `lo` clamp into the first bucket, values at or above the top edge
/// into the last, so nothing is dropped and the footprint never grows.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    lo: f64,
    per_decade: usize,
    buckets: Vec<u64>,
    count: u64,
}

impl LogHistogram {
    /// Buckets span `[lo, hi)` with `per_decade` geometric buckets per
    /// factor of 10 (relative resolution `10^(1/per_decade)`).
    pub fn new(lo: f64, hi: f64, per_decade: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && per_decade > 0);
        let decades = (hi / lo).log10();
        let n = (decades * per_decade as f64).ceil() as usize;
        Self { lo, per_decade, buckets: vec![0; n.max(1)], count: 0 }
    }

    /// Count one sample (NaN/sub-`lo` clamp into bucket 0).
    pub fn push(&mut self, x: f64) {
        // NaN, non-positive and sub-lo values all clamp into bucket 0
        let idx = if x.is_nan() || x <= self.lo {
            0
        } else {
            let raw = ((x / self.lo).log10() * self.per_decade as f64).floor();
            (raw as i64).clamp(0, self.buckets.len() as i64 - 1) as usize
        };
        self.buckets[idx] += 1;
        self.count += 1;
    }

    /// Total samples counted.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Fixed at construction; the histogram never reallocates.
    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    /// Geometric midpoint of bucket `i`.
    pub fn bucket_mid(&self, i: usize) -> f64 {
        self.lo * 10f64.powf((i as f64 + 0.5) / self.per_decade as f64)
    }

    /// Quantile estimate: the geometric midpoint of the bucket holding the
    /// rank-`q` sample. Monotone in `q`; NaN when empty. Relative error is
    /// bounded by half a bucket width (`10^(1/(2*per_decade))`).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = (q.clamp(0.0, 1.0) * (self.count - 1) as f64).floor() as u64;
        let mut seen = 0u64;
        let mut last = 0usize;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c > rank {
                return self.bucket_mid(i);
            }
            seen += c;
            last = i;
        }
        self.bucket_mid(last)
    }
}

/// Bounded uniform sample of a stream (Vitter's Algorithm R) with its own
/// deterministic xorshift64* state — no allocation beyond `cap` slots.
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    state: u64,
}

impl Reservoir {
    /// Reservoir of `cap` slots with a deterministic seed.
    pub fn new(cap: usize, seed: u64) -> Self {
        Self {
            cap: cap.max(1),
            seen: 0,
            samples: Vec::with_capacity(cap.max(1)),
            state: seed | 1,
        }
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Offer one sample (kept with probability cap/seen).
    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            let j = self.next_u64() % self.seen;
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
    }

    /// The current sample set (unordered).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Slot capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Samples offered so far.
    pub fn seen(&self) -> u64 {
        self.seen
    }
}

/// A bounded-memory sample distribution: Welford moments + log-bucketed
/// histogram + uniform reservoir. Percentiles are exact while every sample
/// still fits in the reservoir (`n <= cap`) and histogram-approximate
/// (bounded relative error) beyond that — memory is fixed either way.
#[derive(Clone, Debug)]
pub struct BoundedDist {
    run: Running,
    hist: LogHistogram,
    res: Reservoir,
}

impl BoundedDist {
    /// Distribution with the given histogram range/resolution and
    /// reservoir capacity.
    pub fn new(lo: f64, hi: f64, per_decade: usize, reservoir_cap: usize, seed: u64) -> Self {
        Self {
            run: Running::new(),
            hist: LogHistogram::new(lo, hi, per_decade),
            res: Reservoir::new(reservoir_cap, seed),
        }
    }

    /// Latency-shaped default: 1µs .. 1000s at ~12% relative resolution.
    pub fn for_latency(seed: u64) -> Self {
        Self::new(1e-6, 1e3, 20, 512, seed)
    }

    /// Fold one sample into all three summaries.
    pub fn push(&mut self, x: f64) {
        self.run.push(x);
        self.hist.push(x);
        self.res.push(x);
    }

    /// Samples seen.
    pub fn count(&self) -> u64 {
        self.run.count()
    }

    /// Sample mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        self.run.mean()
    }

    /// (p50, p95, p99); NaN when empty.
    pub fn percentiles(&self) -> (f64, f64, f64) {
        if self.run.count() == 0 {
            (f64::NAN, f64::NAN, f64::NAN)
        } else if self.run.count() <= self.res.capacity() as u64 {
            percentiles(self.res.samples())
        } else {
            (
                self.hist.quantile(0.50),
                self.hist.quantile(0.95),
                self.hist.quantile(0.99),
            )
        }
    }

    /// Retained sample slots — fixed at construction, never grows.
    pub fn footprint(&self) -> usize {
        self.hist.bucket_count() + self.res.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for x in xs {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 5.0f64).powi(2)).sum::<f64>() / 7.0;
        assert!((r.var() - var).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn quantile_type7_matches_r() {
        // R: quantile(c(1,2,3,4), c(.25,.5,.9)) -> 1.75 2.50 3.70
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&v, 0.25) - 1.75).abs() < 1e-12);
        assert!((quantile(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile(&v, 0.9) - 3.7).abs() < 1e-9);
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 1.0), 4.0);
    }

    #[test]
    fn percentiles_ordering() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let (p50, p95, p99) = percentiles(&xs);
        assert!(p50 < p95 && p95 < p99);
        assert!((p50 - 499.5).abs() < 1.0);
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for i in 0..100 {
            h.push(i as f64 / 100.0);
        }
        h.push(-5.0); // clamps into bucket 0
        h.push(5.0); // clamps into bucket 9
        assert_eq!(h.total(), 102);
        assert_eq!(h.buckets[0], 11);
        assert_eq!(h.buckets[9], 11);
        assert!((h.bucket_mid(0) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn render_has_requested_width() {
        let mut h = Histogram::new(0.0, 1.0, 40);
        for i in 0..1000 {
            h.push((i % 40) as f64 / 40.0);
        }
        assert_eq!(h.render(20).chars().count(), 20);
    }

    #[test]
    fn log_histogram_quantiles_bounded_error() {
        let mut h = LogHistogram::new(1e-6, 1e3, 20);
        let n_buckets = h.bucket_count();
        // 10k samples uniform on [1ms, 100ms) in log space
        for i in 0..10_000 {
            let t = i as f64 / 10_000.0;
            h.push(1e-3 * 10f64.powf(2.0 * t));
        }
        assert_eq!(h.count(), 10_000);
        assert_eq!(h.bucket_count(), n_buckets, "bucket count must not grow");
        let (p50, p95, p99) = (h.quantile(0.5), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // true p50 of the stream is 1e-3 * 10^1 = 10ms; one bucket is ~12%
        assert!((p50 / 1e-2).ln().abs() < 0.2, "p50 {p50}");
        assert!((p99 / 1e-3 / 10f64.powf(1.98)).ln().abs() < 0.2, "p99 {p99}");
    }

    #[test]
    fn log_histogram_clamps_extremes_without_panic() {
        let mut h = LogHistogram::new(1e-6, 1e3, 10);
        for x in [0.0, -1.0, f64::NAN, 1e-12, 1e12, f64::INFINITY] {
            h.push(x);
        }
        assert_eq!(h.count(), 6);
        assert!(h.quantile(0.5).is_finite());
        assert!(LogHistogram::new(1e-6, 1e3, 10).quantile(0.5).is_nan());
    }

    #[test]
    fn reservoir_is_bounded_and_representative() {
        let mut r = Reservoir::new(256, 42);
        for i in 0..100_000 {
            r.push(i as f64);
        }
        assert_eq!(r.samples().len(), 256);
        assert_eq!(r.seen(), 100_000);
        // a uniform sample of 0..100k has mean ~50k; 3-sigma band for
        // n=256 is ~±5.4k
        let m = mean(r.samples());
        assert!((m - 50_000.0).abs() < 8_000.0, "reservoir mean {m}");
    }

    #[test]
    fn bounded_dist_exact_small_then_approx_large() {
        let mut d = BoundedDist::new(1e-6, 1e3, 20, 100, 7);
        for i in 0..100 {
            d.push(1e-3 * (i + 1) as f64); // 1ms..100ms
        }
        // all samples retained: percentiles are exact (type-7)
        let (p50, _, p99) = d.percentiles();
        assert!((p50 - 0.0505).abs() < 1e-9, "exact p50 {p50}");
        assert!((p99 - 0.09901).abs() < 1e-4, "exact p99 {p99}");
        let fp = d.footprint();
        for i in 0..100_000 {
            d.push(1e-3 * ((i % 100) + 1) as f64);
        }
        assert_eq!(d.footprint(), fp, "footprint grew under load");
        let (p50, p95, p99) = d.percentiles();
        assert!(p50 <= p95 && p95 <= p99);
        assert!((p50 / 0.05).ln().abs() < 0.3, "approx p50 {p50}");
    }
}
