//! Summary statistics, quantiles and fixed-bucket histograms — the numeric
//! backbone of the metrics module, the bench harness and the figure
//! reproductions (Fig 3 needs PErr distributions, Fig 4 mean RTs).

/// Streaming mean/variance (Welford) with min/max tracking.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Quantile of a sample via linear interpolation (type-7, the R default —
/// matches what the paper's R analysis would have produced).
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty sample");
    assert!((0.0..=1.0).contains(&q), "q={q} out of range");
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let h = (n - 1) as f64 * q;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    let frac = h - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Sorts a copy and returns (p50, p95, p99).
pub fn percentiles(xs: &[f64]) -> (f64, f64, f64) {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (quantile(&v, 0.5), quantile(&v, 0.95), quantile(&v, 0.99))
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile(&v, 0.5)
}

/// Fixed-width histogram over [lo, hi); out-of-range values clamp to the
/// edge buckets so nothing is silently dropped.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub buckets: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Self { lo, hi, buckets: vec![0; nbuckets] }
    }

    pub fn push(&mut self, x: f64) {
        let nb = self.buckets.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * nb as f64).floor() as i64).clamp(0, nb as i64 - 1);
        self.buckets[idx as usize] += 1;
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn bucket_mid(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.buckets.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }

    /// Terminal sparkline for quick visual checks in example binaries.
    pub fn render(&self, width: usize) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        let step = (self.buckets.len() as f64 / width.max(1) as f64).max(1.0);
        let mut out = String::new();
        let mut i = 0.0;
        while (i as usize) < self.buckets.len() && out.chars().count() < width {
            let b = self.buckets[i as usize];
            let level = ((b as f64 / max as f64) * 7.0).round() as usize;
            out.push(BARS[level.min(7)]);
            i += step;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for x in xs {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 5.0f64).powi(2)).sum::<f64>() / 7.0;
        assert!((r.var() - var).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn quantile_type7_matches_r() {
        // R: quantile(c(1,2,3,4), c(.25,.5,.9)) -> 1.75 2.50 3.70
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&v, 0.25) - 1.75).abs() < 1e-12);
        assert!((quantile(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile(&v, 0.9) - 3.7).abs() < 1e-9);
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 1.0), 4.0);
    }

    #[test]
    fn percentiles_ordering() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let (p50, p95, p99) = percentiles(&xs);
        assert!(p50 < p95 && p95 < p99);
        assert!((p50 - 499.5).abs() < 1.0);
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for i in 0..100 {
            h.push(i as f64 / 100.0);
        }
        h.push(-5.0); // clamps into bucket 0
        h.push(5.0); // clamps into bucket 9
        assert_eq!(h.total(), 102);
        assert_eq!(h.buckets[0], 11);
        assert_eq!(h.buckets[9], 11);
        assert!((h.bucket_mid(0) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn render_has_requested_width() {
        let mut h = Histogram::new(0.0, 1.0, 40);
        for i in 0..1000 {
            h.push((i % 40) as f64 / 40.0);
        }
        assert_eq!(h.render(20).chars().count(), 20);
    }
}
