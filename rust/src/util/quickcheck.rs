//! Property-based testing mini-framework (no `proptest` in the image).
//!
//! Usage:
//! ```ignore
//! property("dist symmetry", 200, |g| {
//!     let a = g.string(0..12);
//!     let b = g.string(0..12);
//!     prop_assert(levenshtein(&a, &b) == levenshtein(&b, &a), "symmetry")
//! });
//! ```
//!
//! On failure the framework re-runs the property on progressively simpler
//! inputs by *re-generating with smaller size bounds* (size-based shrinking:
//! cruder than structural shrinking, but effective because all our
//! generators honour the `size` knob) and reports the smallest failing seed
//! so the case can be replayed deterministically.

use super::prng::Rng;

/// Generator handle passed to properties.
pub struct Gen {
    rng: Rng,
    /// Current size bound (shrunk on failure re-runs).
    pub size: usize,
}

impl Gen {
    /// Generator with the given seed and size bound.
    pub fn new(seed: u64, size: usize) -> Self {
        Self { rng: Rng::new(seed), size }
    }

    /// Uniform `u64`.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform integer in `[lo, hi]`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.index(hi - lo + 1)
    }

    /// Length in [lo, min(hi, lo + size)] — honours the shrink knob.
    pub fn len_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size);
        self.usize_in(lo, hi)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Lowercase ASCII string with length in `lo..=hi` (size-bounded).
    pub fn string(&mut self, lo: usize, hi: usize) -> String {
        let n = self.len_in(lo, hi);
        (0..n)
            .map(|_| (b'a' + self.rng.index(26) as u8) as char)
            .collect()
    }

    /// Unicode-ish string mixing ASCII, accents and a few multibyte chars.
    pub fn unicode_string(&mut self, lo: usize, hi: usize) -> String {
        const POOL: &[char] = &[
            'a', 'b', 'z', 'é', 'ü', 'ß', 'ñ', '中', '🙂', ' ', '-', '\'',
        ];
        let n = self.len_in(lo, hi);
        (0..n).map(|_| POOL[self.rng.index(POOL.len())]).collect()
    }

    /// Vector of `[lo, hi]`-length with entries in `[-scale, scale)`.
    pub fn vec_f32(&mut self, lo: usize, hi: usize, scale: f32) -> Vec<f32> {
        let n = self.len_in(lo, hi);
        (0..n)
            .map(|_| (self.rng.next_normal() as f32) * scale)
            .collect()
    }

    /// Uniform pick from a non-empty slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.index(items.len())]
    }
}

/// Outcome of one property evaluation.
// LINT-ALLOW(style): the String is a human-readable counterexample message.
pub type PropResult = Result<(), String>;

/// Pass/fail check inside a property body.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Approximate-equality check inside a property body.
pub fn prop_assert_close(a: f64, b: f64, tol: f64, msg: &str) -> PropResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{msg}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `prop` for `cases` random cases. Panics with a replayable report on
/// the first failure, after size-shrinking to the simplest failing size.
pub fn property(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    // Deterministic base seed per property name so failures replay.
    let base = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
        });
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let size = 4 + (case % 64); // grow sizes over cases
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // shrink: retry same seed with smaller sizes, keep smallest fail
            let mut smallest = (size, msg);
            let mut s = size / 2;
            loop {
                let mut g = Gen::new(seed, s);
                if let Err(m) = prop(&mut g) {
                    smallest = (s, m);
                    if s == 0 {
                        break;
                    }
                    s /= 2;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 shrunk size {}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        property("add commutes", 50, |g| {
            counter.set(counter.get() + 1);
            let a = g.u64() >> 2;
            let b = g.u64() >> 2;
            prop_assert(a + b == b + a, "commutativity")
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_context() {
        property("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_reports_small_size() {
        let result = std::panic::catch_unwind(|| {
            property("fails on len>=3", 100, |g| {
                let s = g.string(0, 50);
                prop_assert(s.len() < 3, "long string")
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // the shrink loop must have reduced the size bound below the start
        assert!(msg.contains("shrunk size"), "{msg}");
    }

    #[test]
    fn generators_respect_bounds() {
        let mut g = Gen::new(1, 16);
        for _ in 0..200 {
            let n = g.usize_in(3, 9);
            assert!((3..=9).contains(&n));
            let s = g.string(2, 6);
            assert!((2..=6).contains(&s.len()));
            let x = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&x));
        }
    }
}
