//! Deterministic pseudo-random number generation.
//!
//! The offline image vendors no `rand` crate, so this module provides the
//! generators the rest of the library needs: SplitMix64 for seeding and
//! xoshiro256++ (Blackman & Vigna) as the workhorse. Every experiment in
//! this repo is seeded, so results are exactly reproducible run-to-run.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Generator starting from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 256-bit state general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (never produces the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (for per-thread/per-subsystem use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Standard normal via Box-Muller (f64 precision).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > f64::EPSILON {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill with iid N(0, sigma^2) f32 values.
    pub fn normal_vec_f32(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| self.next_normal() as f32 * sigma).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Weighted index sample (weights need not be normalised).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: non-positive total weight");
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn next_below_unbiased_small_n() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.next_below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(10);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut seen = std::collections::HashSet::new();
        for i in &idx {
            assert!(*i < 100);
            assert!(seen.insert(*i), "duplicate index {i}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(12);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(13);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }
}
