//! Support substrates built from scratch for the offline image (no tokio /
//! clap / serde / rand / criterion / proptest in the vendored crate set).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prng;
pub mod quickcheck;
pub mod stats;
pub mod svgplot;
pub mod threadpool;
