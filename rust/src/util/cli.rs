//! Command-line parsing (no `clap` in the offline image).
//!
//! Supports the subset this launcher needs: subcommands, `--flag`,
//! `--key value` / `--key=value`, typed accessors with defaults, positional
//! arguments, and auto-generated usage text from registered options.

use std::collections::BTreeMap;

#[derive(Debug)]
/// Argument-parsing failure.
pub enum CliError {
    /// Flag not declared in the spec list.
    Unknown(String),
    /// Value-taking flag given without a value.
    MissingValue(String),
    /// Value failed to parse: (flag, value, expected type).
    BadValue(String, String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => {
                write!(f, "option --{name} expects a value")
            }
            CliError::BadValue(name, raw, why) => {
                write!(f, "invalid value for --{name}: {raw:?} ({why})")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Declarative option spec (used for usage text + unknown-option checking).
#[derive(Clone, Debug)]
pub struct OptSpec {
    /// Flag name (without the leading `--`).
    pub name: &'static str,
    /// Help text shown by `--help`.
    pub help: &'static str,
    /// True when the flag consumes a value.
    pub takes_value: bool,
    /// Default value when the flag is absent.
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Non-flag arguments, in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv` against `specs`. Flags are options with
    /// `takes_value == false`.
    pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    out.values.insert(name, v);
                } else {
                    out.flags.push(name);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        // install defaults
        for s in specs {
            if let Some(d) = s.default {
                out.values.entry(s.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(out)
    }

    /// True when the boolean flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of a flag (or its default), if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Value of a flag that is guaranteed present (has a default).
    pub fn str(&self, name: &str) -> String {
        self.get(name).unwrap_or_default().to_string()
    }

    /// Parse a flag value as `usize`.
    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.typed(name, |v| v.parse::<usize>().ok())
    }

    /// Parse a flag value as `u64`.
    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.typed(name, |v| v.parse::<u64>().ok())
    }

    /// Parse a flag value as `f64`.
    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.typed(name, |v| v.parse::<f64>().ok())
    }

    /// Comma-separated list of usize, e.g. `--landmarks 100,300,1000`.
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        self.typed(name, |v| {
            v.split(',')
                .map(|p| p.trim().parse::<usize>().ok())
                .collect::<Option<Vec<_>>>()
        })
    }

    fn typed<T>(&self, name: &str, f: impl Fn(&str) -> Option<T>) -> Result<T, CliError> {
        let raw = self.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        f(raw).ok_or_else(|| {
            CliError::BadValue(name.into(), raw.into(), "parse failed".into())
        })
    }
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nUSAGE:\n  lmds-ose {cmd} [OPTIONS]\n\nOPTIONS:\n");
    for o in specs {
        let val = if o.takes_value { " <value>" } else { "" };
        let def = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{val}\n      {}{def}\n", o.name, o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "n", help: "count", takes_value: true, default: Some("10") },
            OptSpec { name: "name", help: "label", takes_value: true, default: None },
            OptSpec { name: "verbose", help: "talk", takes_value: false, default: None },
            OptSpec { name: "ls", help: "list", takes_value: true, default: None },
        ]
    }

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positionals() {
        let a = Args::parse(
            &argv(&["--n", "42", "--verbose", "pos1", "--name=x y", "pos2"]),
            &specs(),
        )
        .unwrap();
        assert_eq!(a.usize("n").unwrap(), 42);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.str("name"), "x y");
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn defaults_apply_when_absent() {
        let a = Args::parse(&argv(&[]), &specs()).unwrap();
        assert_eq!(a.usize("n").unwrap(), 10);
        assert_eq!(a.get("name"), None);
    }

    #[test]
    fn unknown_and_missing_are_errors() {
        assert!(matches!(
            Args::parse(&argv(&["--bogus"]), &specs()),
            Err(CliError::Unknown(_))
        ));
        assert!(matches!(
            Args::parse(&argv(&["--name"]), &specs()),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn typed_accessors_validate() {
        let a = Args::parse(&argv(&["--n", "abc"]), &specs()).unwrap();
        assert!(matches!(a.usize("n"), Err(CliError::BadValue(..))));
        let a = Args::parse(&argv(&["--ls", "1, 2,3"]), &specs()).unwrap();
        assert_eq!(a.usize_list("ls").unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn usage_mentions_every_option() {
        let u = usage("demo", "Demo command", &specs());
        for o in specs() {
            assert!(u.contains(o.name));
        }
    }
}
