//! Landmark selection (paper Sec. 4): random sampling (cheap, recommended
//! for large-scale data) and farthest point sampling (FPS — controllable /
//! reproducible, at the cost of O(L·N) distance evaluations), plus a
//! hybrid "maxmin over a random candidate pool" that bounds FPS cost.

use crate::strdist::Dissimilarity;
use crate::util::prng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Landmark-selection strategy (paper Sec. 4).
pub enum LandmarkMethod {
    /// Uniform random distinct indices — O(L), the large-scale default.
    Random,
    /// Farthest point sampling — O(L·N) metric calls, spread-maximising.
    Fps,
    /// FPS over a random candidate subsample of the given size factor
    /// (candidates = factor * L), trading exactness for speed.
    MaxMinPool,
}

impl LandmarkMethod {
    /// Parse a method name (random|fps|maxmin).
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "random" => Some(Self::Random),
            "fps" => Some(Self::Fps),
            "maxmin" | "pool" => Some(Self::MaxMinPool),
            _ => None,
        }
    }
}

/// Random selection of `l` distinct indices out of `n`.
pub fn random_landmarks(rng: &mut Rng, n: usize, l: usize) -> Vec<usize> {
    let mut idx = rng.sample_indices(n, l);
    idx.sort_unstable();
    idx
}

/// Farthest point sampling: start from a random point, then repeatedly add
/// the point whose minimum distance to the selected set is largest.
/// O(L·N) metric evaluations, O(N) memory.
///
/// Always returns exactly `l` distinct indices (duplicate objects that
/// collapse the FPS picks are topped up from the unselected indices);
/// `l > n` is a caller error and panics via the assert below.
pub fn fps_landmarks<T: Sync + ?Sized>(
    rng: &mut Rng,
    objects: &[&T],
    l: usize,
    metric: &dyn Dissimilarity<T>,
) -> Vec<usize> {
    let n = objects.len();
    assert!(l <= n, "l={l} > n={n}");
    if l == 0 {
        return vec![];
    }
    let mut selected = Vec::with_capacity(l);
    let first = rng.index(n);
    selected.push(first);
    // min distance from each point to the selected set
    let mut min_dist: Vec<f64> = (0..n)
        .map(|i| metric.dist(objects[i], objects[first]))
        .collect();
    while selected.len() < l {
        // argmax of min_dist
        let (mut best, mut best_d) = (0usize, f64::NEG_INFINITY);
        for (i, &d) in min_dist.iter().enumerate() {
            if d > best_d {
                best = i;
                best_d = d;
            }
        }
        selected.push(best);
        for i in 0..n {
            let d = metric.dist(objects[i], objects[best]);
            if d < min_dist[i] {
                min_dist[i] = d;
            }
        }
    }
    selected.sort_unstable();
    selected.dedup();
    // Ties on duplicate objects can collapse FPS picks. Top up with a
    // deterministic scan of the unselected indices starting at a random
    // offset: since l <= n is asserted above there are always enough
    // distinct indices, so this returns EXACTLY l landmarks (the old
    // random-retry top-up could bail after 10n misses and silently return
    // fewer, starving the OSE method of its expected input width).
    if selected.len() < l {
        let mut chosen = vec![false; n];
        for &i in &selected {
            chosen[i] = true;
        }
        let offset = rng.index(n);
        for step in 0..n {
            if selected.len() == l {
                break;
            }
            let cand = (offset + step) % n;
            if !chosen[cand] {
                chosen[cand] = true;
                selected.push(cand);
            }
        }
    }
    debug_assert_eq!(selected.len(), l);
    selected.sort_unstable();
    selected
}

/// FPS restricted to a random candidate pool of `pool_factor * l` points —
/// the standard trick for very large N where exact FPS's O(L·N) scans are
/// the bottleneck.
pub fn maxmin_pool_landmarks<T: Sync + ?Sized>(
    rng: &mut Rng,
    objects: &[&T],
    l: usize,
    pool_factor: usize,
    metric: &dyn Dissimilarity<T>,
) -> Vec<usize> {
    let n = objects.len();
    let pool_size = (l * pool_factor.max(2)).min(n);
    let pool = rng.sample_indices(n, pool_size);
    let pool_objs: Vec<&T> = pool.iter().map(|&i| objects[i]).collect();
    let inner = fps_landmarks(rng, &pool_objs, l, metric);
    let mut out: Vec<usize> = inner.into_iter().map(|i| pool[i]).collect();
    out.sort_unstable();
    out
}

/// Dispatch helper.
pub fn select_landmarks<T: Sync + ?Sized>(
    method: LandmarkMethod,
    rng: &mut Rng,
    objects: &[&T],
    l: usize,
    metric: &dyn Dissimilarity<T>,
) -> Vec<usize> {
    match method {
        LandmarkMethod::Random => random_landmarks(rng, objects.len(), l),
        LandmarkMethod::Fps => fps_landmarks(rng, objects, l, metric),
        LandmarkMethod::MaxMinPool => {
            maxmin_pool_landmarks(rng, objects, l, 4, metric)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strdist::{Euclidean, Levenshtein};

    #[test]
    fn random_landmarks_distinct_sorted() {
        let mut rng = Rng::new(1);
        let idx = random_landmarks(&mut rng, 100, 30);
        assert_eq!(idx.len(), 30);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn fps_spreads_points() {
        // 1-D points on [0, 100]: whatever the random start, L = 5 FPS
        // picks must be well separated (min pairwise gap >= 15) and must
        // cover the line (no point farther than 25 from a landmark).
        let coords: Vec<Vec<f32>> = (0..101).map(|i| vec![i as f32]).collect();
        let objs: Vec<&[f32]> = coords.iter().map(|c| c.as_slice()).collect();
        for seed in 0..8 {
            let mut rng = Rng::new(seed);
            let idx = fps_landmarks(&mut rng, &objs, 5, &Euclidean);
            let mut min_gap = f64::INFINITY;
            for (a, &i) in idx.iter().enumerate() {
                for &j in &idx[a + 1..] {
                    min_gap = min_gap.min((i as f64 - j as f64).abs());
                }
            }
            assert!(min_gap >= 15.0, "seed {seed}: {idx:?} (gap {min_gap})");
            let covering = (0..101)
                .map(|p| {
                    idx.iter()
                        .map(|&i| (p as f64 - i as f64).abs())
                        .fold(f64::INFINITY, f64::min)
                })
                .fold(0.0, f64::max);
            assert!(covering <= 25.0, "seed {seed}: {idx:?} (cover {covering})");
        }
    }

    #[test]
    fn fps_min_separation_beats_random() {
        // FPS's defining property: its selected set has a larger minimum
        // pairwise distance than a random selection (on generic data).
        let mut rng = Rng::new(3);
        let coords: Vec<Vec<f32>> = (0..200)
            .map(|_| vec![rng.next_f32() * 10.0, rng.next_f32() * 10.0])
            .collect();
        let objs: Vec<&[f32]> = coords.iter().map(|c| c.as_slice()).collect();
        let min_sep = |idx: &[usize]| -> f64 {
            let mut best = f64::INFINITY;
            for (a, &i) in idx.iter().enumerate() {
                for &j in &idx[a + 1..] {
                    best = best.min(crate::strdist::euclidean(&coords[i], &coords[j]));
                }
            }
            best
        };
        let fps = fps_landmarks(&mut rng, &objs, 20, &Euclidean);
        let rnd = random_landmarks(&mut rng, 200, 20);
        assert!(min_sep(&fps) > min_sep(&rnd), "{} vs {}", min_sep(&fps), min_sep(&rnd));
    }

    #[test]
    fn fps_works_on_strings() {
        let names = ["anna", "annie", "anne", "bob", "bobby", "robert",
                     "christopher", "chris"];
        let objs: Vec<&str> = names.to_vec();
        let mut rng = Rng::new(4);
        let idx = fps_landmarks(&mut rng, &objs, 4, &Levenshtein);
        assert_eq!(idx.len(), 4);
        // "christopher" is the most isolated name; FPS should pick it
        assert!(idx.contains(&6), "{idx:?}");
    }

    #[test]
    fn fps_handles_duplicates_by_topping_up() {
        let names = ["same", "same", "same", "same", "other"];
        let objs: Vec<&str> = names.to_vec();
        let mut rng = Rng::new(5);
        let idx = fps_landmarks(&mut rng, &objs, 3, &Levenshtein);
        assert_eq!(idx.len(), 3);
    }

    #[test]
    fn fps_returns_exactly_l_even_when_all_objects_identical() {
        // worst case for the top-up: every FPS pick collapses onto one
        // index, so l-1 landmarks must come from the deterministic scan
        for l in [1usize, 7, 16] {
            let names = vec!["same"; 16];
            let objs: Vec<&str> = names.clone();
            for seed in 0..20 {
                let mut rng = Rng::new(seed);
                let idx = fps_landmarks(&mut rng, &objs, l, &Levenshtein);
                assert_eq!(idx.len(), l, "l={l} seed={seed}: {idx:?}");
                assert!(idx.windows(2).all(|w| w[0] < w[1]), "distinct+sorted");
                assert!(idx.iter().all(|&i| i < 16));
            }
        }
    }

    #[test]
    fn pool_variant_returns_l_valid_indices() {
        let coords: Vec<Vec<f32>> = (0..500)
            .map(|i| vec![(i % 37) as f32, (i / 37) as f32])
            .collect();
        let objs: Vec<&[f32]> = coords.iter().map(|c| c.as_slice()).collect();
        let mut rng = Rng::new(6);
        let idx = maxmin_pool_landmarks(&mut rng, &objs, 25, 4, &Euclidean);
        assert_eq!(idx.len(), 25);
        assert!(idx.iter().all(|&i| i < 500));
        let mut d = idx.clone();
        d.dedup();
        assert_eq!(d.len(), 25);
    }

    #[test]
    fn method_from_name() {
        assert_eq!(LandmarkMethod::from_name("fps"), Some(LandmarkMethod::Fps));
        assert_eq!(LandmarkMethod::from_name("random"), Some(LandmarkMethod::Random));
        assert_eq!(LandmarkMethod::from_name("nope"), None);
    }
}
