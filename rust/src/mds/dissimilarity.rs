//! Parallel dissimilarity-matrix construction — the O(L^2)/O(L·M) input
//! stage of the two-phase pipeline. For string data this is millions of
//! Levenshtein calls; rows are independent, so it parallelises perfectly
//! over the thread pool.

use crate::strdist::Dissimilarity;
use crate::util::threadpool::{default_parallelism, parallel_for_chunks, SyncSlice};

use super::matrix::Matrix;

/// Full symmetric N x N matrix over one object set (zero diagonal).
/// Computes only the upper triangle and mirrors it.
pub fn full_matrix<T: Sync + ?Sized>(
    objects: &[&T],
    metric: &dyn Dissimilarity<T>,
) -> Matrix {
    let n = objects.len();
    let mut out = Matrix::zeros(n, n);
    {
        let slots = SyncSlice::new(&mut out.data);
        parallel_for_chunks(n, 8, default_parallelism(), |start, end| {
            for i in start..end {
                for j in (i + 1)..n {
                    let d = metric.dist(objects[i], objects[j]) as f32;
                    // SAFETY: (i, j) and (j, i) cells are owned by the chunk
                    // that owns row i (j > i: the mirrored write targets row
                    // j's column i, only ever written by row i's owner).
                    unsafe {
                        slots.write(i * n + j, d);
                        slots.write(j * n + i, d);
                    }
                }
            }
        });
    }
    out
}

/// Rectangular matrix of distances from each of `rows` to each of `cols`
/// (e.g. out-of-sample objects x landmarks). Row-parallel.
pub fn cross_matrix<T: Sync + ?Sized>(
    rows: &[&T],
    cols: &[&T],
    metric: &dyn Dissimilarity<T>,
) -> Matrix {
    let (nr, nc) = (rows.len(), cols.len());
    let mut out = Matrix::zeros(nr, nc);
    {
        let slots = SyncSlice::new(&mut out.data);
        parallel_for_chunks(nr, 8, default_parallelism(), |start, end| {
            for i in start..end {
                for j in 0..nc {
                    let d = metric.dist(rows[i], cols[j]) as f32;
                    // SAFETY: row i is owned by this chunk; cell (i, j) is
                    // written exactly once.
                    unsafe { slots.write(i * nc + j, d) };
                }
            }
        });
    }
    out
}

/// Distance vector from one object to a set (the serving-path primitive:
/// a query against the landmarks).
pub fn dist_vector<T: ?Sized>(
    query: &T,
    cols: &[&T],
    metric: &dyn Dissimilarity<T>,
) -> Vec<f32> {
    cols.iter().map(|c| metric.dist(query, c) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strdist::{Euclidean, Levenshtein};

    #[test]
    fn full_matrix_symmetric_zero_diagonal() {
        let names = ["anna", "bob", "carol", "dan", "erin"];
        let objs: Vec<&str> = names.to_vec();
        let m = full_matrix(&objs, &Levenshtein);
        assert_eq!(m.rows, 5);
        for i in 0..5 {
            assert_eq!(m.at(i, i), 0.0);
            for j in 0..5 {
                assert_eq!(m.at(i, j), m.at(j, i));
            }
        }
        assert_eq!(m.at(0, 1), 4.0); // anna -> bob
    }

    #[test]
    fn full_matrix_matches_serial_large() {
        // exercise the parallel path with enough rows for several chunks
        let names: Vec<String> = (0..120).map(|i| format!("name{i:03}")).collect();
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let m = full_matrix(&objs, &Levenshtein);
        for i in (0..120).step_by(17) {
            for j in (0..120).step_by(13) {
                let want = crate::strdist::levenshtein(&names[i], &names[j]) as f32;
                assert_eq!(m.at(i, j), want);
            }
        }
    }

    #[test]
    fn cross_matrix_values() {
        let rows = ["abc", "abd"];
        let cols = ["abc", "xyz", "ab"];
        let m = cross_matrix(&rows, &cols, &Levenshtein);
        assert_eq!(m.rows, 2);
        assert_eq!(m.cols, 3);
        assert_eq!(m.at(0, 0), 0.0);
        assert_eq!(m.at(0, 1), 3.0);
        assert_eq!(m.at(0, 2), 1.0);
        assert_eq!(m.at(1, 0), 1.0);
    }

    #[test]
    fn dist_vector_matches_cross_row() {
        let cols = ["alpha", "beta", "gamma"];
        let v = dist_vector("alda", &cols, &Levenshtein);
        let m = cross_matrix(&["alda"], &cols, &Levenshtein);
        assert_eq!(v, m.row(0));
    }

    #[test]
    fn works_on_vectors_too() {
        let a = vec![0.0f32, 0.0];
        let b = vec![3.0f32, 4.0];
        let objs: Vec<&[f32]> = vec![&a, &b];
        let m = full_matrix(&objs, &Euclidean);
        assert_eq!(m.at(0, 1), 5.0);
    }
}
