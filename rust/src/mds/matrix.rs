//! Dense row-major f32 matrix — the shared numeric container between the
//! dissimilarity engine, the pure-Rust MDS/NN baselines and the PJRT
//! runtime (whose literals are row-major f32 too, so hand-off is a memcpy).

use crate::util::prng::Rng;

#[derive(Clone, Debug, PartialEq)]
/// Dense row-major f32 matrix.
pub struct Matrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major backing storage (`rows * cols` values).
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap a row-major buffer (must have `rows * cols` entries).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build from row vectors (all must share one length).
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// iid N(0, sigma^2) entries.
    pub fn random_normal(rng: &mut Rng, rows: usize, cols: usize, sigma: f32) -> Self {
        Self { rows, cols, data: rng.normal_vec_f32(rows * cols, sigma) }
    }

    #[inline]
    /// Value at (r, c).
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    /// Set the value at (r, c).
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    /// Row `r` as a slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    /// Row `r` as a mutable slice.
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Select a subset of rows (e.g. the landmark coordinates).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Vertically stack two matrices with equal column counts.
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Subtract the column means (centre the configuration). Returns the
    /// means that were removed.
    pub fn center_columns(&mut self) -> Vec<f32> {
        let mut means = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (c, m) in means.iter_mut().enumerate() {
                *m += self.at(r, c);
            }
        }
        for m in means.iter_mut() {
            *m /= self.rows.max(1) as f32;
        }
        for r in 0..self.rows {
            for c in 0..self.cols {
                let v = self.at(r, c) - means[c];
                self.set(r, c, v);
            }
        }
        means
    }

    /// Largest element-wise absolute difference to `other` (same shape).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Frobenius norm (sqrt of the sum of squared entries), in f64.
    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_validates_length() {
        Matrix::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn select_rows_and_vstack() {
        let m = Matrix::from_rows(&[
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![5.0, 6.0],
        ]);
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.row(0), &[5.0, 6.0]);
        assert_eq!(s.row(1), &[1.0, 2.0]);
        let v = s.vstack(&m);
        assert_eq!(v.rows, 5);
        assert_eq!(v.row(4), &[5.0, 6.0]);
    }

    #[test]
    fn center_columns_zeroes_means() {
        let mut m = Matrix::from_rows(&[vec![1.0, 10.0], vec![3.0, 20.0]]);
        let means = m.center_columns();
        assert_eq!(means, vec![2.0, 15.0]);
        assert_eq!(m.row(0), &[-1.0, -5.0]);
        assert_eq!(m.row(1), &[1.0, 5.0]);
    }

    #[test]
    fn norms_and_diffs() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        let b = Matrix::from_vec(1, 2, vec![3.0, 5.0]);
        assert_eq!(a.frobenius_norm(), 5.0);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }

    #[test]
    fn random_normal_is_seeded() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = Matrix::random_normal(&mut r1, 4, 3, 1.0);
        let b = Matrix::random_normal(&mut r2, 4, 3, 1.0);
        assert_eq!(a, b);
    }
}
