//! Multidimensional scaling core: dissimilarity-matrix engine, the LSMDS
//! gradient-descent solver (paper Sec. 2.1), the SMACOF and classical-MDS
//! baselines, landmark selection (Sec. 4), the paper's error metrics
//! (Eqs. 1, 4, 5), the divide-and-conquer base solver (partitioned
//! parallel block solves + orthogonal-Procrustes stitching), and the
//! layered small-world landmark graph behind sub-O(L) OSE queries and
//! graph-assisted landmark selection.

pub mod classical;
pub mod dissimilarity;
pub mod divide;
pub mod graph;
pub mod landmarks;
pub mod lsmds;
pub mod matrix;
pub mod procrustes;
pub mod smacof;
pub mod stress;

pub use divide::{DeltaSource, DivideConfig, DivideResult, PointsDelta, SubsetDelta};
pub use graph::{graph_landmarks, GraphConfig, LandmarkGraph, SmallWorld};
pub use landmarks::LandmarkMethod;
pub use lsmds::{lsmds, lsmds_from, LsmdsConfig, LsmdsResult};
pub use matrix::Matrix;
pub use procrustes::Procrustes;
pub use smacof::{smacof, SmacofConfig};
