//! Multidimensional scaling core: dissimilarity-matrix engine, the LSMDS
//! gradient-descent solver (paper Sec. 2.1), the SMACOF and classical-MDS
//! baselines, landmark selection (Sec. 4), and the paper's error metrics
//! (Eqs. 1, 4, 5).

pub mod classical;
pub mod dissimilarity;
pub mod landmarks;
pub mod lsmds;
pub mod matrix;
pub mod smacof;
pub mod stress;

pub use landmarks::LandmarkMethod;
pub use lsmds::{lsmds, lsmds_from, LsmdsConfig, LsmdsResult};
pub use matrix::Matrix;
pub use smacof::{smacof, SmacofConfig};
