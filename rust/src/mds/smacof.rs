//! SMACOF (De Leeuw & Mair) — stress majorization via the Guttman
//! transform. The paper contrasts its gradient-descent LSMDS with the
//! SMACOF implementation used by much of the literature (Sec. 2.1, [6]);
//! we ship both and *prove* (in tests) the identity the whole artifact
//! design relies on: for unit weights and a centred configuration,
//!
//! ```text
//! Guttman(X) == X - grad sigma_raw(X) / (2N)
//! ```
//!
//! i.e. SMACOF is plain GD with lr = 1/(2N).

use super::matrix::Matrix;
use super::stress::{normalized_stress, raw_stress};
use crate::util::prng::Rng;

/// One Guttman transform: X' = (1/n) B(X) X with
/// B_ij = -delta_ij / d_ij (i != j), B_ii = sum_{j != i} delta_ij / d_ij.
///
/// Coincident points (d_ij ~ 0) with a positive target distance get the
/// limit contribution delta_ij * u along a deterministic unit direction u
/// instead of the textbook subgradient 0: with the zero convention two
/// points seeded at the same coordinates exert no force on each other and
/// never separate, silently pinning the configuration (the pair's stress
/// term delta_ij^2 is frozen in). The direction is a pure function of the
/// index pair and antisymmetric (u_ij = -u_ji), so transforms stay
/// deterministic and the pair moves apart, not in lockstep. Coincident
/// pairs with delta_ij = 0 (true duplicates) still contribute nothing —
/// they belong together.
pub fn guttman_transform(x: &Matrix, delta: &Matrix) -> Matrix {
    let n = x.rows;
    let k = x.cols;
    let mut out = Matrix::zeros(n, k);
    for i in 0..n {
        let xi = x.row(i);
        let mut acc = vec![0.0f64; k];
        let mut diag = 0.0f64;
        for j in 0..n {
            if j == i {
                continue;
            }
            let xj = x.row(j);
            let d = crate::strdist::euclidean(xi, xj);
            let delta_ij = delta.at(i, j) as f64;
            if d > 1e-12 {
                let ratio = delta_ij / d;
                diag += ratio;
                for c in 0..k {
                    acc[c] -= ratio * xj[c] as f64;
                }
            } else if delta_ij > 0.0 {
                // limit of ratio * (x_i - x_j) as the pair separates along
                // u: contributes delta_ij * u to this row's update only
                let u = coincident_direction(i, j, k);
                for c in 0..k {
                    acc[c] += delta_ij * u[c];
                }
            }
        }
        for c in 0..k {
            out.set(i, c, ((diag * xi[c] as f64 + acc[c]) / n as f64) as f32);
        }
    }
    out
}

/// Deterministic unit direction for a coincident pair: a pure function of
/// the unordered index pair, negated for the higher index so the two
/// points of the pair receive equal-and-opposite pushes.
fn coincident_direction(i: usize, j: usize, k: usize) -> Vec<f64> {
    let (lo, hi) = (i.min(j) as u64, i.max(j) as u64);
    let mut rng = Rng::new((lo << 32) ^ hi ^ 0xC01C_1DE5);
    let mut u: Vec<f64> = (0..k).map(|_| rng.next_normal()).collect();
    let norm = u.iter().map(|v| v * v).sum::<f64>().sqrt();
    if norm > 1e-12 {
        for v in u.iter_mut() {
            *v /= norm;
        }
    } else if k > 0 {
        u[0] = 1.0;
    }
    if (i as u64) == hi {
        for v in u.iter_mut() {
            *v = -*v;
        }
    }
    u
}

#[derive(Clone, Debug)]
/// SMACOF solver settings.
pub struct SmacofConfig {
    /// Embedding dimension K.
    pub dim: usize,
    /// Maximum Guttman-transform iterations.
    pub max_iters: usize,
    /// Stop when relative stress improvement drops below this.
    pub rel_tol: f64,
    /// Seed of the random initial configuration.
    pub seed: u64,
}

impl Default for SmacofConfig {
    fn default() -> Self {
        Self { dim: 7, max_iters: 500, rel_tol: 1e-6, seed: 7 }
    }
}

#[derive(Clone, Debug)]
/// What one SMACOF run produced.
pub struct SmacofResult {
    /// N x K solution configuration.
    pub config: Matrix,
    /// Raw stress (Eq. 1) of the solution.
    pub raw_stress: f64,
    /// Normalised stress of the solution.
    pub normalized_stress: f64,
    /// Guttman iterations actually run.
    pub iters: usize,
}

/// Full SMACOF run from a random centred start.
pub fn smacof(delta: &Matrix, cfg: &SmacofConfig) -> SmacofResult {
    let n = delta.rows;
    let mut rng = Rng::new(cfg.seed);
    let mut x = Matrix::random_normal(&mut rng, n, cfg.dim, 1.0);
    x.center_columns();
    let mut prev = f64::INFINITY;
    let mut iters = 0;
    for it in 0..cfg.max_iters {
        x = guttman_transform(&x, delta);
        iters = it + 1;
        if it % 10 == 9 {
            let sigma = raw_stress(&x, delta);
            if prev.is_finite() && (prev - sigma) / prev.max(1e-30) < cfg.rel_tol {
                break;
            }
            prev = sigma;
        }
    }
    let sigma = raw_stress(&x, delta);
    SmacofResult {
        normalized_stress: normalized_stress(&x, delta),
        raw_stress: sigma,
        config: x,
        iters,
    }
}

#[cfg(test)]
mod tests {
    use super::super::lsmds::stress_gradient;
    use super::*;
    use crate::strdist::euclidean;

    fn realizable(seed: u64, n: usize, k: usize) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::random_normal(&mut rng, n, k, 1.0);
        let mut d = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                d.set(i, j, euclidean(x.row(i), x.row(j)) as f32);
            }
        }
        (x, d)
    }

    #[test]
    fn guttman_equals_gd_with_half_inverse_n_lr() {
        // The identity every artifact relies on (see model.py docstring).
        let (_, delta) = realizable(1, 22, 4);
        let mut rng = Rng::new(2);
        let mut x = Matrix::random_normal(&mut rng, 22, 4, 1.0);
        x.center_columns();

        let via_guttman = guttman_transform(&x, &delta);
        let (grad, _) = stress_gradient(&x, &delta);
        let lr = 1.0 / (2.0 * 22.0);
        let mut via_gd = x.clone();
        for (v, g) in via_gd.data.iter_mut().zip(grad.data.iter()) {
            *v -= (lr * *g as f64) as f32;
        }
        assert!(
            via_guttman.max_abs_diff(&via_gd) < 1e-5,
            "identity violated: {}",
            via_guttman.max_abs_diff(&via_gd)
        );
    }

    #[test]
    fn stress_never_increases() {
        let (_, delta) = realizable(3, 35, 3);
        let mut rng = Rng::new(4);
        let mut x = Matrix::random_normal(&mut rng, 35, 3, 1.5);
        x.center_columns();
        let mut prev = raw_stress(&x, &delta);
        for _ in 0..50 {
            x = guttman_transform(&x, &delta);
            let cur = raw_stress(&x, &delta);
            assert!(cur <= prev + 1e-9, "{prev} -> {cur}");
            prev = cur;
        }
    }

    #[test]
    fn smacof_recovers_realizable_config() {
        let (_, delta) = realizable(5, 40, 2);
        let r = smacof(&delta, &SmacofConfig {
            dim: 2,
            max_iters: 2000,
            rel_tol: 1e-10,
            seed: 6,
        });
        assert!(r.normalized_stress < 0.05, "sigma = {}", r.normalized_stress);
    }

    #[test]
    fn coincident_points_separate_to_target_distance() {
        // regression: with the old `ratio = 0` convention two points
        // seeded at identical coordinates exerted no force on each other
        // and never separated. For exactly two coincident points with
        // target distance t, one transform must move them to distance t
        // (the limit contribution is t * u with u antisymmetric).
        let x = Matrix::from_rows(&[vec![0.5, -0.25, 1.0], vec![0.5, -0.25, 1.0]]);
        let mut delta = Matrix::zeros(2, 2);
        delta.set(0, 1, 3.0);
        delta.set(1, 0, 3.0);
        let out = guttman_transform(&x, &delta);
        let d = euclidean(out.row(0), out.row(1));
        assert!((d - 3.0).abs() < 1e-5, "separated to {d}, want 3");
        // determinism: same input, same output
        let again = guttman_transform(&x, &delta);
        assert_eq!(out.data, again.data);
    }

    #[test]
    fn duplicate_rows_in_larger_config_escape_and_converge() {
        // seed two identical rows inside a realizable 12-point problem:
        // iterating the transform must split them and still reach a low
        // stress (the old convention froze the pair's stress term in)
        let (x0, delta) = realizable(11, 12, 3);
        let mut x = x0.clone();
        let dup = x.row(4).to_vec();
        x.row_mut(7).copy_from_slice(&dup); // rows 4 and 7 now coincide
        assert!(euclidean(x.row(4), x.row(7)) < 1e-12);
        assert!(delta.at(4, 7) > 0.1, "target distance must be positive");
        for _ in 0..400 {
            x = guttman_transform(&x, &delta);
        }
        let d = euclidean(x.row(4), x.row(7));
        assert!(d > 1e-3, "duplicates never separated (d = {d})");
        let sigma = normalized_stress(&x, &delta);
        assert!(sigma < 0.05, "stuck at stress {sigma}");
        assert!(x.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn true_duplicates_with_zero_delta_stay_together() {
        // delta(0,1) = 0 and identical coordinates: the pair belongs
        // together and must NOT be pushed apart by the coincident fix
        let x = Matrix::from_rows(&[
            vec![1.0, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 2.0],
        ]);
        let mut delta = Matrix::zeros(3, 3);
        let d02 = euclidean(x.row(0), x.row(2)) as f32;
        delta.set(0, 2, d02);
        delta.set(2, 0, d02);
        delta.set(1, 2, d02);
        delta.set(2, 1, d02);
        let out = guttman_transform(&x, &delta);
        assert!(euclidean(out.row(0), out.row(1)) < 1e-9, "zero-delta pair split");
    }

    #[test]
    fn smacof_and_lsmds_agree_on_stress_level() {
        use super::super::lsmds::{lsmds, LsmdsConfig};
        let (_, delta) = realizable(7, 30, 3);
        let a = smacof(&delta, &SmacofConfig { dim: 3, max_iters: 800, rel_tol: 1e-9, seed: 8 });
        let b = lsmds(&delta, &LsmdsConfig {
            dim: 3,
            max_iters: 800,
            rel_tol: 1e-9,
            seed: 9,
            ..Default::default()
        });
        // different inits, same optimisation problem: final stress similar
        assert!((a.normalized_stress - b.normalized_stress).abs() < 0.05);
    }
}
