//! SMACOF (De Leeuw & Mair) — stress majorization via the Guttman
//! transform. The paper contrasts its gradient-descent LSMDS with the
//! SMACOF implementation used by much of the literature (Sec. 2.1, [6]);
//! we ship both and *prove* (in tests) the identity the whole artifact
//! design relies on: for unit weights and a centred configuration,
//!
//! ```text
//! Guttman(X) == X - grad sigma_raw(X) / (2N)
//! ```
//!
//! i.e. SMACOF is plain GD with lr = 1/(2N).

use super::matrix::Matrix;
use super::stress::{normalized_stress, raw_stress};
use crate::util::prng::Rng;

/// One Guttman transform: X' = (1/n) B(X) X with
/// B_ij = -delta_ij / d_ij (i != j), B_ii = sum_{j != i} delta_ij / d_ij.
pub fn guttman_transform(x: &Matrix, delta: &Matrix) -> Matrix {
    let n = x.rows;
    let k = x.cols;
    let mut out = Matrix::zeros(n, k);
    for i in 0..n {
        let xi = x.row(i);
        let mut acc = vec![0.0f64; k];
        let mut diag = 0.0f64;
        for j in 0..n {
            if j == i {
                continue;
            }
            let xj = x.row(j);
            let d = crate::strdist::euclidean(xi, xj);
            let ratio = if d > 1e-12 { delta.at(i, j) as f64 / d } else { 0.0 };
            diag += ratio;
            for c in 0..k {
                acc[c] -= ratio * xj[c] as f64;
            }
        }
        for c in 0..k {
            out.set(i, c, ((diag * xi[c] as f64 + acc[c]) / n as f64) as f32);
        }
    }
    out
}

#[derive(Clone, Debug)]
pub struct SmacofConfig {
    pub dim: usize,
    pub max_iters: usize,
    pub rel_tol: f64,
    pub seed: u64,
}

impl Default for SmacofConfig {
    fn default() -> Self {
        Self { dim: 7, max_iters: 500, rel_tol: 1e-6, seed: 7 }
    }
}

#[derive(Clone, Debug)]
pub struct SmacofResult {
    pub config: Matrix,
    pub raw_stress: f64,
    pub normalized_stress: f64,
    pub iters: usize,
}

/// Full SMACOF run from a random centred start.
pub fn smacof(delta: &Matrix, cfg: &SmacofConfig) -> SmacofResult {
    let n = delta.rows;
    let mut rng = Rng::new(cfg.seed);
    let mut x = Matrix::random_normal(&mut rng, n, cfg.dim, 1.0);
    x.center_columns();
    let mut prev = f64::INFINITY;
    let mut iters = 0;
    for it in 0..cfg.max_iters {
        x = guttman_transform(&x, delta);
        iters = it + 1;
        if it % 10 == 9 {
            let sigma = raw_stress(&x, delta);
            if prev.is_finite() && (prev - sigma) / prev.max(1e-30) < cfg.rel_tol {
                break;
            }
            prev = sigma;
        }
    }
    let sigma = raw_stress(&x, delta);
    SmacofResult {
        normalized_stress: normalized_stress(&x, delta),
        raw_stress: sigma,
        config: x,
        iters,
    }
}

#[cfg(test)]
mod tests {
    use super::super::lsmds::stress_gradient;
    use super::*;
    use crate::strdist::euclidean;

    fn realizable(seed: u64, n: usize, k: usize) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::random_normal(&mut rng, n, k, 1.0);
        let mut d = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                d.set(i, j, euclidean(x.row(i), x.row(j)) as f32);
            }
        }
        (x, d)
    }

    #[test]
    fn guttman_equals_gd_with_half_inverse_n_lr() {
        // The identity every artifact relies on (see model.py docstring).
        let (_, delta) = realizable(1, 22, 4);
        let mut rng = Rng::new(2);
        let mut x = Matrix::random_normal(&mut rng, 22, 4, 1.0);
        x.center_columns();

        let via_guttman = guttman_transform(&x, &delta);
        let (grad, _) = stress_gradient(&x, &delta);
        let lr = 1.0 / (2.0 * 22.0);
        let mut via_gd = x.clone();
        for (v, g) in via_gd.data.iter_mut().zip(grad.data.iter()) {
            *v -= (lr * *g as f64) as f32;
        }
        assert!(
            via_guttman.max_abs_diff(&via_gd) < 1e-5,
            "identity violated: {}",
            via_guttman.max_abs_diff(&via_gd)
        );
    }

    #[test]
    fn stress_never_increases() {
        let (_, delta) = realizable(3, 35, 3);
        let mut rng = Rng::new(4);
        let mut x = Matrix::random_normal(&mut rng, 35, 3, 1.5);
        x.center_columns();
        let mut prev = raw_stress(&x, &delta);
        for _ in 0..50 {
            x = guttman_transform(&x, &delta);
            let cur = raw_stress(&x, &delta);
            assert!(cur <= prev + 1e-9, "{prev} -> {cur}");
            prev = cur;
        }
    }

    #[test]
    fn smacof_recovers_realizable_config() {
        let (_, delta) = realizable(5, 40, 2);
        let r = smacof(&delta, &SmacofConfig {
            dim: 2,
            max_iters: 2000,
            rel_tol: 1e-10,
            seed: 6,
        });
        assert!(r.normalized_stress < 0.05, "sigma = {}", r.normalized_stress);
    }

    #[test]
    fn smacof_and_lsmds_agree_on_stress_level() {
        use super::super::lsmds::{lsmds, LsmdsConfig};
        let (_, delta) = realizable(7, 30, 3);
        let a = smacof(&delta, &SmacofConfig { dim: 3, max_iters: 800, rel_tol: 1e-9, seed: 8 });
        let b = lsmds(&delta, &LsmdsConfig {
            dim: 3,
            max_iters: 800,
            rel_tol: 1e-9,
            seed: 9,
            ..Default::default()
        });
        // different inits, same optimisation problem: final stress similar
        assert!((a.normalized_stress - b.normalized_stress).abs() < 0.05);
    }
}
