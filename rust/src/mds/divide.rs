//! Divide-and-conquer base MDS (the partition-and-align family of
//! "Multidimensional Scaling for Big Data"): partition the sample into B
//! overlapping blocks that all share a common anchor subset, solve each
//! block's MDS independently (fanned out across the thread pool), then
//! stitch the blocks into one configuration by fitting an orthogonal
//! Procrustes transform ([`super::procrustes`]) from every block's anchor
//! coordinates onto the reference block's.
//!
//! Why it scales: a monolithic solve touches all L^2 dissimilarities every
//! iteration. With B blocks over a sample of L points and A anchors, each
//! block holds L/B + A points, so one sweep costs B·(L/B + A)^2 ≈ L^2/B
//! pair visits — and the blocks are independent, so they run concurrently.
//! Peak per-block working memory is O((L/B + A)^2) instead of O(L^2).
//!
//! The input is a [`DeltaSource`] rather than a materialised matrix, so the
//! full L x L dissimilarity matrix never needs to exist: a source can
//! compute entries on demand (e.g. [`PointsDelta`] for coordinate data, or
//! a string metric over an object table), which is what lets the L = 50k
//! bench run on hardware where the 10 GB monolithic matrix cannot.
//!
//! Accuracy model: every block sees the *exact* dissimilarities among its
//! own points, so for realizable inputs each block recovers its geometry
//! and the anchors pin the blocks together rigidly — the stitched stress
//! stays within a small band of the monolithic solve (enforced by the
//! partition-invariance suite in `tests/divide.rs`). For non-realizable
//! data the blocks optimise restrictions of the true objective, so the
//! stitched configuration is an approximation; anchor count controls the
//! trade (more anchors = tighter stitching, more per-block cost).

use anyhow::Result;

use crate::strdist::euclidean;
use crate::util::prng::Rng;
use crate::util::threadpool::{default_parallelism, parallel_for_chunks, SyncSlice};

use super::lsmds::{lsmds, LsmdsConfig};
use super::matrix::Matrix;
use super::procrustes::Procrustes;

/// Anything that can serve dissimilarities by index pair. Implementations
/// must be cheap to query concurrently (block solves read disjoint
/// sub-matrices from worker threads).
///
/// Implementations range from a fully materialised [`Matrix`] to the
/// matrix-free [`PointsDelta`] and the disk-backed
/// [`crate::data::source::TableDelta`], whose rows never enter RAM
/// wholesale. Implementing it for a custom store takes two methods:
///
/// ```
/// use lmds_ose::mds::divide::DeltaSource;
///
/// /// Distances derived from a rule instead of stored data.
/// struct Ring(usize);
///
/// impl DeltaSource for Ring {
///     fn len(&self) -> usize {
///         self.0
///     }
///     fn dist(&self, i: usize, j: usize) -> f32 {
///         let d = i.abs_diff(j);
///         d.min(self.0 - d) as f32 // hop count around the ring
///     }
/// }
///
/// let ring = Ring(6);
/// assert_eq!(ring.dist(0, 5), 1.0);
/// let sub = ring.sub_matrix(&[0, 2, 5]);
/// assert_eq!(sub.at(0, 1), 2.0);
/// assert_eq!(sub.at(1, 2), sub.at(2, 1), "sub-matrix is symmetric");
/// ```
pub trait DeltaSource: Sync {
    /// Number of objects.
    fn len(&self) -> usize;

    /// True when the source holds no objects.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dissimilarity between objects `i` and `j`.
    fn dist(&self, i: usize, j: usize) -> f32;

    /// Materialise the symmetric sub-matrix over `idx` (the per-block
    /// input). The default computes the upper triangle and mirrors it.
    fn sub_matrix(&self, idx: &[usize]) -> Matrix {
        let m = idx.len();
        let mut out = Matrix::zeros(m, m);
        for (r, &i) in idx.iter().enumerate() {
            for (c, &j) in idx.iter().enumerate().skip(r + 1) {
                let d = self.dist(i, j);
                out.set(r, c, d);
                out.set(c, r, d);
            }
        }
        out
    }
}

/// A fully materialised dissimilarity matrix (the pipeline's `delta_LL`).
impl DeltaSource for Matrix {
    fn len(&self) -> usize {
        self.rows
    }

    fn dist(&self, i: usize, j: usize) -> f32 {
        self.at(i, j)
    }

    fn sub_matrix(&self, idx: &[usize]) -> Matrix {
        let m = idx.len();
        let mut out = Matrix::zeros(m, m);
        for (r, &i) in idx.iter().enumerate() {
            let row = self.row(i);
            let dst = out.row_mut(r);
            for (c, &j) in idx.iter().enumerate() {
                dst[c] = row[j];
            }
        }
        out
    }
}

/// Euclidean dissimilarities over an N x K coordinate table, computed on
/// demand — O(N·K) memory for any N, the matrix-free source the large-L
/// benches use.
pub struct PointsDelta<'a> {
    /// N x K coordinate table (one object per row).
    pub points: &'a Matrix,
}

impl DeltaSource for PointsDelta<'_> {
    fn len(&self) -> usize {
        self.points.rows
    }

    fn dist(&self, i: usize, j: usize) -> f32 {
        euclidean(self.points.row(i), self.points.row(j)) as f32
    }
}

/// A view of `source` restricted to `idx`: position `p` of the subset is
/// object `idx[p]` of the underlying source. This is how the base solve
/// runs over a landmark sample of an out-of-core table without copying
/// anything — `SubsetDelta` over a
/// [`TableDelta`](crate::data::source::TableDelta) serves exactly the
/// L x L sub-problem, still evaluated at the storage layer.
pub struct SubsetDelta<'a, S: DeltaSource + ?Sized> {
    source: &'a S,
    idx: &'a [usize],
}

impl<'a, S: DeltaSource + ?Sized> SubsetDelta<'a, S> {
    /// Restrict `source` to the objects in `idx` (indices must be in
    /// range; duplicates are allowed and behave as coincident objects).
    pub fn new(source: &'a S, idx: &'a [usize]) -> Self {
        let n = source.len();
        assert!(
            idx.iter().all(|&i| i < n),
            "subset index out of range (source has {n} objects)"
        );
        SubsetDelta { source, idx }
    }

    /// The subset indices, in subset-position order.
    pub fn indices(&self) -> &[usize] {
        self.idx
    }
}

impl<S: DeltaSource + ?Sized> DeltaSource for SubsetDelta<'_, S> {
    fn len(&self) -> usize {
        self.idx.len()
    }

    fn dist(&self, i: usize, j: usize) -> f32 {
        self.source.dist(self.idx[i], self.idx[j])
    }

    fn sub_matrix(&self, idx: &[usize]) -> Matrix {
        // Delegate through the source so a specialised sub_matrix (e.g.
        // Matrix's row-copy fast path) still kicks in.
        let mapped: Vec<usize> = idx.iter().map(|&p| self.idx[p]).collect();
        self.source.sub_matrix(&mapped)
    }
}

/// Divide-and-conquer shape: how many blocks, how many shared anchors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DivideConfig {
    /// Number of blocks B (0 is treated as 1).
    pub blocks: usize,
    /// Shared anchor count A; 0 picks [`auto_anchors`]. Values below the
    /// rigidity floor `dim + 1` are raised to it — fewer anchors cannot
    /// pin rotation + translation between blocks.
    pub anchors: usize,
}

impl Default for DivideConfig {
    fn default() -> Self {
        Self { blocks: 8, anchors: 0 }
    }
}

/// Default anchor count for a sample of `l` points embedded into `dim`
/// dimensions: sqrt(L), clamped to [2(dim+1), 512]. sqrt keeps the anchor
/// overhead (A extra rows in every block) sublinear while growing the
/// stitching constraint set with the sample; the floor guarantees a
/// well-posed Procrustes fit with slack, the cap bounds per-block cost.
pub fn auto_anchors(l: usize, dim: usize) -> usize {
    let floor = 2 * (dim + 1);
    let cap = 512usize.max(floor);
    (((l as f64).sqrt()) as usize).clamp(floor, cap).min(l)
}

/// What one divide-and-conquer solve did, beyond the configuration itself.
#[derive(Clone, Debug)]
pub struct DivideResult {
    /// L x K stitched configuration (centred).
    pub config: Matrix,
    /// Indices of the shared anchor points (ascending).
    pub anchor_idx: Vec<usize>,
    /// Total points per block (anchors + own chunk), per block.
    pub block_sizes: Vec<usize>,
    /// Per-block anchor-fit RMSD from the Procrustes stitch (block 0 is
    /// the reference and reports 0); the stitch-quality diagnostic.
    pub align_rmsd: Vec<f64>,
}

/// Solve with the pure-Rust [`lsmds`] block solver. The backend-aware
/// path (blocked kernels, PJRT artifacts) lives in
/// `coordinator::embedder::solve_base`, which routes each block through
/// [`divide_solve_with`] and a `ComputeBackend`.
pub fn divide_solve<S>(
    source: &S,
    lcfg: &LsmdsConfig,
    dcfg: &DivideConfig,
) -> Result<DivideResult>
where
    S: DeltaSource + ?Sized,
{
    divide_solve_with(source, lcfg.dim, dcfg, lcfg.seed, |b, sub| {
        let mut c = lcfg.clone();
        c.seed = block_seed(lcfg.seed, b as u64);
        Ok(lsmds(sub, &c).config)
    })
}

/// Derive a per-block seed: blocks must not share their random init (a
/// deterministic function of the base seed keeps runs reproducible).
pub fn block_seed(seed: u64, block: u64) -> u64 {
    seed ^ (block + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xD1F1DE
}

/// The anchor/block split a divide-and-conquer solve runs over — also the
/// shard plan the serving layer partitions its landmarks with (each shard
/// owns one block of the divide solve).
#[derive(Clone, Debug)]
pub struct Partition {
    /// Indices of the shared anchor points (ascending).
    pub anchor_idx: Vec<usize>,
    /// Per-block index lists: block `b` is `anchor_idx ++ chunk_b`, so
    /// positions `0..anchor_idx.len()` of every block are the anchors.
    pub block_idx: Vec<Vec<usize>>,
}

impl Partition {
    /// Number of shared anchors (the prefix length of every block).
    pub fn anchors(&self) -> usize {
        self.anchor_idx.len()
    }

    /// Number of blocks actually formed (`<= DivideConfig::blocks`).
    pub fn blocks(&self) -> usize {
        self.block_idx.len()
    }
}

/// Split `source` into the anchor set and B overlapping blocks: FPS
/// anchors shared by every block, non-anchor points in B contiguous
/// chunks. Deterministic in `seed`. This is step 1+2 of
/// [`divide_solve_with`], exposed so the serving layer can shard its
/// landmark set with the exact same plan.
pub fn partition_blocks<S: DeltaSource + ?Sized>(
    source: &S,
    dim: usize,
    dcfg: &DivideConfig,
    seed: u64,
) -> Partition {
    let l = source.len();
    if l == 0 {
        return Partition { anchor_idx: vec![], block_idx: vec![] };
    }

    // 1. Anchor selection: farthest-point sampling on the source metric,
    //    so the shared frame spans the configuration instead of sampling
    //    one corner of it. Clamped to the rigidity floor dim + 1.
    let anchors = match dcfg.anchors {
        0 => auto_anchors(l, dim),
        a => a.max(dim + 1),
    }
    .min(l);
    let anchor_idx = fps_anchors(source, anchors, seed);
    let mut is_anchor = vec![false; l];
    for &i in &anchor_idx {
        is_anchor[i] = true;
    }
    let rest: Vec<usize> = (0..l).filter(|&i| !is_anchor[i]).collect();

    // 2. Partition the non-anchor points into B contiguous chunks.
    let blocks = dcfg.blocks.max(1).min(rest.len().max(1));
    let per = rest.len().div_ceil(blocks);
    let chunks: Vec<&[usize]> = if rest.is_empty() {
        vec![&[][..]]
    } else {
        rest.chunks(per).collect()
    };
    let block_idx: Vec<Vec<usize>> = chunks
        .iter()
        .map(|chunk| {
            let mut idx = anchor_idx.clone();
            idx.extend_from_slice(chunk);
            idx
        })
        .collect();
    Partition { anchor_idx, block_idx }
}

/// Core divide-and-conquer driver, generic over the per-block solver.
///
/// `solve_block(b, sub_delta)` receives the block index and the block's
/// dissimilarity sub-matrix (anchors occupy rows `0..A`, the block's own
/// points follow) and must return a configuration with one row per input
/// row in `dim` columns. Blocks are fanned out across the thread pool; the
/// block solver itself may parallelise internally (the dynamic chunk
/// cursor balances either way).
///
/// ```
/// use lmds_ose::mds::divide::{divide_solve_with, DivideConfig, PointsDelta};
/// use lmds_ose::mds::lsmds::{lsmds, LsmdsConfig};
/// use lmds_ose::mds::Matrix;
/// use lmds_ose::util::prng::Rng;
///
/// // 60 points in R^2, served matrix-free: no 60 x 60 matrix exists.
/// let points = Matrix::random_normal(&mut Rng::new(7), 60, 2, 1.0);
/// let source = PointsDelta { points: &points };
///
/// let lcfg = LsmdsConfig { dim: 2, max_iters: 50, ..Default::default() };
/// let r = divide_solve_with(
///     &source,
///     2,
///     &DivideConfig { blocks: 3, anchors: 8 },
///     42,
///     |_, sub| Ok(lsmds(sub, &lcfg).config), // any per-block solver
/// )
/// .unwrap();
/// assert_eq!((r.config.rows, r.config.cols), (60, 2));
/// assert_eq!(r.block_sizes.len(), 3);
/// assert_eq!(r.align_rmsd[0], 0.0, "block 0 is the reference frame");
/// ```
pub fn divide_solve_with<S, F>(
    source: &S,
    dim: usize,
    dcfg: &DivideConfig,
    seed: u64,
    solve_block: F,
) -> Result<DivideResult>
where
    S: DeltaSource + ?Sized,
    F: Fn(usize, &Matrix) -> Result<Matrix> + Sync,
{
    let l = source.len();
    if l == 0 {
        return Ok(DivideResult {
            config: Matrix::zeros(0, dim),
            anchor_idx: vec![],
            block_sizes: vec![],
            align_rmsd: vec![],
        });
    }

    // 1+2. Anchor selection and block partition (shared with the serving
    //      layer's shard planner; see `partition_blocks`).
    let part = partition_blocks(source, dim, dcfg, seed);
    let Partition { anchor_idx, block_idx } = part;
    let anchors = anchor_idx.len();
    let b_eff = block_idx.len();

    // 3. Solve every block concurrently: block b = anchors ++ chunk_b.
    let mut solved: Vec<Option<Result<Matrix>>> = (0..b_eff).map(|_| None).collect();
    {
        let slots = SyncSlice::new(&mut solved);
        let threads = default_parallelism().min(b_eff);
        parallel_for_chunks(b_eff, 1, threads, |start, end| {
            for b in start..end {
                let sub = source.sub_matrix(&block_idx[b]);
                let r = solve_block(b, &sub);
                // SAFETY: each block index is written exactly once.
                unsafe { slots.write(b, Some(r)) };
            }
        });
    }

    // 4. Stitch: block 0 is the reference frame; every other block is
    //    mapped onto it by the rigid Procrustes fit over the shared
    //    anchors. Anchor coordinates are averaged across all aligned
    //    copies (they are the best-constrained points in the solve).
    let mut aligned: Vec<Matrix> = Vec::with_capacity(b_eff);
    let mut align_rmsd = Vec::with_capacity(b_eff);
    let mut block_sizes = Vec::with_capacity(b_eff);
    let mut reference: Option<Matrix> = None;
    for (b, slot) in solved.into_iter().enumerate() {
        let x = slot.expect("block not solved")?;
        anyhow::ensure!(
            x.rows == block_idx[b].len() && x.cols == dim,
            "block {b}: solver returned {}x{}, expected {}x{dim}",
            x.rows,
            x.cols,
            block_idx[b].len()
        );
        block_sizes.push(x.rows);
        let anchor_rows: Vec<usize> = (0..anchors).collect();
        if let Some(ref_anchors) = &reference {
            let own = x.select_rows(&anchor_rows);
            let fit = Procrustes::fit(&own, ref_anchors);
            align_rmsd.push(fit.rmsd);
            aligned.push(fit.apply(&x));
        } else {
            align_rmsd.push(0.0);
            reference = Some(x.select_rows(&anchor_rows));
            aligned.push(x);
        }
    }

    // 5. Assemble the global configuration.
    let mut config = Matrix::zeros(l, dim);
    let inv_b = 1.0f64 / b_eff as f64;
    for (b, x) in aligned.iter().enumerate() {
        for (r, &i) in block_idx[b].iter().enumerate() {
            if r < anchors {
                // averaged across blocks
                let dst = config.row_mut(i);
                for c in 0..dim {
                    dst[c] += (x.at(r, c) as f64 * inv_b) as f32;
                }
            } else {
                config.row_mut(i).copy_from_slice(x.row(r));
            }
        }
    }
    config.center_columns();
    Ok(DivideResult { config, anchor_idx, block_sizes, align_rmsd })
}

/// Farthest-point sampling of `a` anchor indices directly on a
/// [`DeltaSource`] (the object-level FPS in [`super::landmarks`] needs the
/// objects + metric; here only index-pair distances exist). O(A·L) `dist`
/// calls, O(L) memory. Returns ascending indices.
pub fn fps_anchors<S: DeltaSource + ?Sized>(source: &S, a: usize, seed: u64) -> Vec<usize> {
    let l = source.len();
    let a = a.min(l);
    if a == 0 {
        return vec![];
    }
    let mut rng = Rng::new(seed ^ 0xA2C4_0125);
    let first = rng.index(l);
    let mut selected = vec![first];
    let mut min_dist: Vec<f32> = (0..l).map(|i| source.dist(i, first)).collect();
    while selected.len() < a {
        let (mut best, mut best_d) = (0usize, f32::NEG_INFINITY);
        for (i, &d) in min_dist.iter().enumerate() {
            if d > best_d {
                best = i;
                best_d = d;
            }
        }
        // duplicate objects can exhaust distinct maxima; fall back to the
        // first unselected index so exactly `a` anchors come back
        if min_dist[best] <= 0.0 && selected.contains(&best) {
            if let Some(i) = (0..l).find(|i| !selected.contains(i)) {
                best = i;
            } else {
                break;
            }
        }
        selected.push(best);
        for i in 0..l {
            let d = source.dist(i, best);
            if d < min_dist[i] {
                min_dist[i] = d;
            }
        }
    }
    selected.sort_unstable();
    selected.dedup();
    // top up (duplicates collapsed): deterministic ascending scan
    let mut cursor = 0usize;
    while selected.len() < a && cursor < l {
        if selected.binary_search(&cursor).is_err() {
            selected.push(cursor);
            selected.sort_unstable();
        }
        cursor += 1;
    }
    selected
}

/// Normalised stress estimated over `pairs` sampled index pairs — the
/// O(pairs) stand-in for the O(L^2) exact metric at scales where the full
/// sum is itself a cost. Deterministic in `seed`.
pub fn sampled_normalized_stress<S: DeltaSource + ?Sized>(
    source: &S,
    x: &Matrix,
    pairs: usize,
    seed: u64,
) -> f64 {
    let l = source.len();
    assert_eq!(l, x.rows);
    if l < 2 {
        return 0.0;
    }
    let mut rng = Rng::new(seed ^ 0x57E5_5);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for _ in 0..pairs {
        let i = rng.index(l);
        let mut j = rng.index(l - 1);
        if j >= i {
            j += 1;
        }
        let delta = source.dist(i, j) as f64;
        let d = euclidean(x.row(i), x.row(j));
        num += (d - delta) * (d - delta);
        den += delta * delta;
    }
    if den <= 0.0 {
        return 0.0;
    }
    (num / den).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mds::stress::normalized_stress;

    fn realizable(seed: u64, n: usize, k: usize) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::random_normal(&mut rng, n, k, 1.0);
        let mut d = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                d.set(i, j, euclidean(x.row(i), x.row(j)) as f32);
            }
        }
        (x, d)
    }

    #[test]
    fn points_delta_matches_materialised_matrix() {
        let (x, d) = realizable(1, 20, 3);
        let src = PointsDelta { points: &x };
        assert_eq!(src.len(), 20);
        for i in 0..20 {
            for j in 0..20 {
                assert!((src.dist(i, j) - d.at(i, j)).abs() < 1e-6);
            }
        }
        let idx = [3usize, 7, 11, 19];
        let sub_p = src.sub_matrix(&idx);
        let sub_m = DeltaSource::sub_matrix(&d, &idx);
        assert!(sub_p.max_abs_diff(&sub_m) < 1e-6);
    }

    #[test]
    fn sub_matrix_picks_the_right_entries() {
        let (_, d) = realizable(2, 12, 2);
        let idx = [0usize, 5, 9];
        let sub = DeltaSource::sub_matrix(&d, &idx);
        assert_eq!((sub.rows, sub.cols), (3, 3));
        for (r, &i) in idx.iter().enumerate() {
            for (c, &j) in idx.iter().enumerate() {
                assert_eq!(sub.at(r, c), d.at(i, j));
            }
        }
    }

    #[test]
    fn fps_anchors_spread_and_exact_count() {
        let (_, d) = realizable(3, 40, 2);
        for a in [3usize, 7, 15, 40] {
            let idx = fps_anchors(&d, a, 9);
            assert_eq!(idx.len(), a);
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "sorted distinct");
            assert!(idx.iter().all(|&i| i < 40));
        }
    }

    #[test]
    fn fps_anchors_handle_duplicate_objects() {
        // all-zero distances: every FPS pick collapses; top-up must still
        // return exactly `a` distinct indices
        let d = Matrix::zeros(10, 10);
        let idx = fps_anchors(&d, 6, 4);
        assert_eq!(idx.len(), 6);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn auto_anchors_respects_bounds() {
        assert_eq!(auto_anchors(100, 3), 10.max(2 * 4));
        assert!(auto_anchors(1_000_000, 3) <= 512);
        assert!(auto_anchors(4, 7) <= 4, "never more anchors than points");
        assert!(auto_anchors(10_000, 3) == 100);
    }

    #[test]
    fn divide_recovers_realizable_configuration() {
        let (_, delta) = realizable(5, 120, 3);
        let lcfg = LsmdsConfig { dim: 3, max_iters: 2000, rel_tol: 1e-9, ..Default::default() };
        let r = divide_solve(&delta, &lcfg, &DivideConfig { blocks: 4, anchors: 16 }).unwrap();
        assert_eq!((r.config.rows, r.config.cols), (120, 3));
        assert_eq!(r.anchor_idx.len(), 16);
        assert_eq!(r.block_sizes.len(), 4);
        let stress = normalized_stress(&r.config, &delta);
        assert!(stress < 0.08, "stitched stress {stress}");
        // stitch quality: anchors agreed across blocks
        assert!(r.align_rmsd.iter().all(|&e| e < 0.2), "{:?}", r.align_rmsd);
    }

    #[test]
    fn divide_handles_degenerate_shapes() {
        let (_, delta) = realizable(6, 30, 2);
        let lcfg = LsmdsConfig { dim: 2, max_iters: 300, ..Default::default() };
        // B larger than the number of non-anchor points
        let r = divide_solve(&delta, &lcfg, &DivideConfig { blocks: 64, anchors: 10 }).unwrap();
        assert_eq!(r.config.rows, 30);
        assert!(r.config.data.iter().all(|v| v.is_finite()));
        // anchors = 0 -> auto; blocks = 0 -> 1
        let r = divide_solve(&delta, &lcfg, &DivideConfig { blocks: 0, anchors: 0 }).unwrap();
        assert_eq!(r.config.rows, 30);
        assert_eq!(r.block_sizes.len(), 1);
        // anchors >= L: single all-anchor block
        let r = divide_solve(&delta, &lcfg, &DivideConfig { blocks: 3, anchors: 64 }).unwrap();
        assert_eq!(r.config.rows, 30);
        assert_eq!(r.anchor_idx.len(), 30);
    }

    #[test]
    fn divide_empty_input() {
        let d = Matrix::zeros(0, 0);
        let r = divide_solve(
            &d,
            &LsmdsConfig { dim: 3, ..Default::default() },
            &DivideConfig::default(),
        )
        .unwrap();
        assert_eq!((r.config.rows, r.config.cols), (0, 3));
    }

    #[test]
    fn block_solver_errors_propagate() {
        let (_, delta) = realizable(7, 24, 2);
        let r = divide_solve_with(
            &delta,
            2,
            &DivideConfig { blocks: 3, anchors: 6 },
            1,
            |b, _sub| {
                if b == 1 {
                    anyhow::bail!("injected failure");
                }
                Ok(Matrix::zeros(0, 0)) // wrong shape for the others
            },
        );
        assert!(r.is_err());
    }

    #[test]
    fn sampled_stress_tracks_exact_stress() {
        let (x, delta) = realizable(8, 60, 3);
        // perturb so stress is non-zero
        let mut y = x.clone();
        for v in y.data.iter_mut() {
            *v *= 1.3;
        }
        let exact = normalized_stress(&y, &delta);
        let approx = sampled_normalized_stress(&delta, &y, 20_000, 1);
        assert!(
            (exact - approx).abs() < 0.05 * (1.0 + exact),
            "exact {exact} vs sampled {approx}"
        );
    }

    #[test]
    fn partition_blocks_covers_every_index_once() {
        let (_, delta) = realizable(11, 50, 2);
        let dcfg = DivideConfig { blocks: 4, anchors: 8 };
        let p = partition_blocks(&delta, 2, &dcfg, 33);
        assert_eq!(p.anchors(), 8);
        assert_eq!(p.blocks(), 4);
        // every block starts with the shared anchors
        for b in &p.block_idx {
            assert_eq!(&b[..p.anchors()], &p.anchor_idx[..]);
        }
        // non-anchor indices land in exactly one block
        let mut seen = vec![0usize; 50];
        for b in &p.block_idx {
            for &i in &b[p.anchors()..] {
                seen[i] += 1;
            }
        }
        for &i in &p.anchor_idx {
            assert_eq!(seen[i], 0, "anchor {i} duplicated in a chunk");
            seen[i] = 1;
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
        // deterministic in the seed
        let q = partition_blocks(&delta, 2, &dcfg, 33);
        assert_eq!(p.anchor_idx, q.anchor_idx);
        assert_eq!(p.block_idx, q.block_idx);
        // empty source degenerates cleanly
        let p = partition_blocks(&Matrix::zeros(0, 0), 2, &dcfg, 33);
        assert_eq!(p.blocks(), 0);
    }

    #[test]
    fn divide_is_deterministic() {
        let (_, delta) = realizable(9, 80, 2);
        let lcfg = LsmdsConfig { dim: 2, max_iters: 200, ..Default::default() };
        let dcfg = DivideConfig { blocks: 3, anchors: 8 };
        let a = divide_solve(&delta, &lcfg, &dcfg).unwrap();
        let b = divide_solve(&delta, &lcfg, &dcfg).unwrap();
        assert_eq!(a.config.data, b.config.data);
        assert_eq!(a.anchor_idx, b.anchor_idx);
    }
}
