//! Orthogonal Procrustes alignment — the glue of the divide-and-conquer
//! base solver ([`super::divide`]). An MDS configuration is only defined up
//! to rotation, reflection and translation, so two independently solved
//! blocks that share anchor points agree on the anchors' *distances* but
//! not their coordinates. This module fits the rigid transform (orthogonal
//! map + translation, optionally an isotropic scale) that best maps one
//! block's anchor coordinates onto another's, in the least-squares sense:
//!
//! ```text
//!   min_{R orthogonal, t}  sum_i || s * (x_i - mean_x) R + mean_y - y_i ||^2
//! ```
//!
//! The classical solution is R = U V^T from the SVD of the k x k
//! cross-covariance M = Xc^T Yc (Schönemann 1966). Like
//! [`super::classical::symmetric_top_eigs`], the dense linear algebra is
//! from scratch (no LAPACK in the image), but where classical MDS power-
//! iterates an N x N Gram matrix, the matrices here are k x k (k = the
//! embedding dimension, single digits), so a full cyclic Jacobi
//! eigendecomposition in f64 is both simpler and numerically tighter than
//! seeded power iteration: V comes from the eigenvectors of M^T M, U from
//! M V / sigma, with Gram-Schmidt completion for rank-deficient fits. The
//! whole fit is O(n k^2 + k^4) — negligible next to any block solve.

use super::matrix::Matrix;

/// Relative singular-value floor: directions with sigma below this times
/// the largest sigma are treated as rank-deficient and completed by
/// Gram-Schmidt instead of divided by ~0.
const RANK_TOL: f64 = 1e-9;

/// A fitted rigid (optionally scaled) alignment `y ≈ s·x·R + t`, stored in
/// folded affine form so applying it is one pass over the rows.
#[derive(Clone, Debug)]
pub struct Procrustes {
    /// k x k linear part (scale folded in), row-major f64.
    linear: Vec<f64>,
    /// k-vector offset (translation folded with the centroids).
    offset: Vec<f64>,
    /// Embedding dimension k.
    pub dim: usize,
    /// The fitted isotropic scale (1.0 for rigid fits).
    pub scale: f64,
    /// Root-mean-square residual of the fit points under the transform —
    /// the stitch-quality diagnostic the divide solver reports per block.
    pub rmsd: f64,
}

impl Procrustes {
    /// Identity transform in `k` dimensions.
    pub fn identity(k: usize) -> Procrustes {
        let mut linear = vec![0.0f64; k * k];
        for c in 0..k {
            linear[c * k + c] = 1.0;
        }
        Procrustes { linear, offset: vec![0.0; k], dim: k, scale: 1.0, rmsd: 0.0 }
    }

    /// Fit the rigid transform (rotation/reflection + translation) mapping
    /// `source` onto `target`. Both are n x k with equal shapes; n >= 1.
    pub fn fit(source: &Matrix, target: &Matrix) -> Procrustes {
        Procrustes::fit_impl(source, target, false)
    }

    /// Like [`Procrustes::fit`], additionally estimating an isotropic
    /// scale (the similarity-transform variant). Not used by the divide
    /// solver — blocks fit the same dissimilarities, so rescaling anchors
    /// would distort every non-anchor distance — but exposed for callers
    /// aligning configurations of different provenance.
    pub fn fit_with_scale(source: &Matrix, target: &Matrix) -> Procrustes {
        Procrustes::fit_impl(source, target, true)
    }

    fn fit_impl(source: &Matrix, target: &Matrix, with_scale: bool) -> Procrustes {
        assert_eq!(
            (source.rows, source.cols),
            (target.rows, target.cols),
            "procrustes: shape mismatch"
        );
        let (n, k) = (source.rows, source.cols);
        if n == 0 || k == 0 {
            return Procrustes::identity(k);
        }

        // Centroids in f64.
        let mut ms = vec![0.0f64; k];
        let mut mt = vec![0.0f64; k];
        for i in 0..n {
            for c in 0..k {
                ms[c] += source.at(i, c) as f64;
                mt[c] += target.at(i, c) as f64;
            }
        }
        for c in 0..k {
            ms[c] /= n as f64;
            mt[c] /= n as f64;
        }

        // Cross-covariance M = Xc^T Yc (k x k) and the source spread.
        let mut m = vec![0.0f64; k * k];
        let mut src_sq = 0.0f64;
        for i in 0..n {
            for a in 0..k {
                let xa = source.at(i, a) as f64 - ms[a];
                src_sq += xa * xa;
                for b in 0..k {
                    let yb = target.at(i, b) as f64 - mt[b];
                    m[a * k + b] += xa * yb;
                }
            }
        }

        // Eigendecomposition of A = M^T M gives V and sigma^2.
        let mut a = vec![0.0f64; k * k];
        for i in 0..k {
            for j in 0..k {
                let mut acc = 0.0;
                for r in 0..k {
                    acc += m[r * k + i] * m[r * k + j];
                }
                a[i * k + j] = acc;
            }
        }
        let (evals, v) = jacobi_eigs(&a, k);
        let sigma: Vec<f64> = evals.iter().map(|l| l.max(0.0).sqrt()).collect();
        let sigma_max = sigma.first().copied().unwrap_or(0.0);

        // U columns: M v_i / sigma_i, Gram-Schmidt completed where sigma
        // vanishes (rank-deficient or degenerate anchor sets).
        let mut u = vec![0.0f64; k * k];
        for (col, s) in sigma.iter().enumerate() {
            if *s > RANK_TOL * sigma_max.max(1e-300) {
                for r in 0..k {
                    let mut acc = 0.0;
                    for c in 0..k {
                        acc += m[r * k + c] * v[c * k + col];
                    }
                    u[r * k + col] = acc / s;
                }
            } else {
                complete_column(&mut u, k, col);
            }
        }

        // R = U V^T; scale = tr(Sigma) / ||Xc||^2 when requested.
        let mut rot = vec![0.0f64; k * k];
        for i in 0..k {
            for j in 0..k {
                let mut acc = 0.0;
                for c in 0..k {
                    acc += u[i * k + c] * v[j * k + c];
                }
                rot[i * k + j] = acc;
            }
        }
        let scale = if with_scale && src_sq > 0.0 {
            sigma.iter().sum::<f64>() / src_sq
        } else {
            1.0
        };

        // Fold: y = s * (x - ms) R + mt  =  x (sR) + (mt - s * ms R).
        let mut linear = vec![0.0f64; k * k];
        for i in 0..k {
            for j in 0..k {
                linear[i * k + j] = scale * rot[i * k + j];
            }
        }
        let mut offset = mt.clone();
        for j in 0..k {
            let mut acc = 0.0;
            for i in 0..k {
                acc += ms[i] * linear[i * k + j];
            }
            offset[j] -= acc;
        }

        let mut t = Procrustes { linear, offset, dim: k, scale, rmsd: 0.0 };
        // Fit residual on the fit points themselves.
        let mut sq = 0.0f64;
        let mut row = vec![0.0f64; k];
        for i in 0..n {
            t.apply_row_f64(source.row(i), &mut row);
            for c in 0..k {
                let r = row[c] - target.at(i, c) as f64;
                sq += r * r;
            }
        }
        t.rmsd = (sq / n as f64).sqrt();
        t
    }

    /// Apply to every row of `x`, returning the transformed matrix.
    pub fn apply(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.dim, "procrustes: dim mismatch");
        let mut out = Matrix::zeros(x.rows, x.cols);
        let mut row = vec![0.0f64; self.dim];
        for i in 0..x.rows {
            self.apply_row_f64(x.row(i), &mut row);
            for (c, v) in row.iter().enumerate() {
                out.set(i, c, *v as f32);
            }
        }
        out
    }

    /// Apply to one coordinate row, accumulating in f64 into `out`.
    fn apply_row_f64(&self, x: &[f32], out: &mut [f64]) {
        let k = self.dim;
        for (j, o) in out.iter_mut().enumerate() {
            let mut acc = self.offset[j];
            for (i, xv) in x.iter().enumerate() {
                acc += (*xv as f64) * self.linear[i * k + j];
            }
            *o = acc;
        }
    }

    /// Sign of the orthogonal part's determinant: -1.0 means the fit uses
    /// a reflection (legitimate for MDS configurations, which are only
    /// defined up to the full orthogonal group).
    pub fn det_sign(&self) -> f64 {
        let k = self.dim;
        let mut lu: Vec<f64> = self.linear.clone();
        let mut sign = 1.0f64;
        for col in 0..k {
            // partial pivot
            let mut p = col;
            for r in (col + 1)..k {
                if lu[r * k + col].abs() > lu[p * k + col].abs() {
                    p = r;
                }
            }
            if lu[p * k + col] == 0.0 {
                return 0.0;
            }
            if p != col {
                for c in 0..k {
                    lu.swap(col * k + c, p * k + c);
                }
                sign = -sign;
            }
            if lu[col * k + col] < 0.0 {
                sign = -sign;
            }
            for r in (col + 1)..k {
                let f = lu[r * k + col] / lu[col * k + col];
                for c in col..k {
                    lu[r * k + c] -= f * lu[col * k + c];
                }
            }
        }
        sign
    }
}

/// Replace column `col` of `u` with a unit vector orthogonal to columns
/// `0..col` (Gram-Schmidt over the standard basis candidates).
fn complete_column(u: &mut [f64], k: usize, col: usize) {
    for cand in 0..k {
        let mut w = vec![0.0f64; k];
        w[cand] = 1.0;
        for prev in 0..col {
            let mut dot = 0.0;
            for r in 0..k {
                dot += w[r] * u[r * k + prev];
            }
            for r in 0..k {
                w[r] -= dot * u[r * k + prev];
            }
        }
        let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > 1e-6 {
            for r in 0..k {
                u[r * k + col] = w[r] / norm;
            }
            return;
        }
    }
    // Unreachable for col < k, but keep the column well-defined.
    u[col * k + col] = 1.0;
}

/// Full eigendecomposition of a symmetric k x k matrix (row-major f64) by
/// cyclic Jacobi rotations. Returns eigenvalues in descending order with
/// the matching eigenvectors as *columns* of the returned k x k buffer.
/// Deterministic, no seeds; k is the embedding dimension, so cost is moot.
pub fn jacobi_eigs(a: &[f64], k: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), k * k);
    let mut m = a.to_vec();
    let mut v = vec![0.0f64; k * k];
    for i in 0..k {
        v[i * k + i] = 1.0;
    }
    let frob: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let tol = 1e-14 * frob.max(1e-300);
    for _sweep in 0..64 {
        let mut off = 0.0f64;
        for p in 0..k {
            for q in (p + 1)..k {
                off += m[p * k + q] * m[p * k + q];
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..k {
            for q in (p + 1)..k {
                let apq = m[p * k + q];
                if apq.abs() <= tol / (k * k) as f64 {
                    continue;
                }
                let app = m[p * k + p];
                let aqq = m[q * k + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/columns p and q of m
                for r in 0..k {
                    let mrp = m[r * k + p];
                    let mrq = m[r * k + q];
                    m[r * k + p] = c * mrp - s * mrq;
                    m[r * k + q] = s * mrp + c * mrq;
                }
                for col in 0..k {
                    let mpc = m[p * k + col];
                    let mqc = m[q * k + col];
                    m[p * k + col] = c * mpc - s * mqc;
                    m[q * k + col] = s * mpc + c * mqc;
                }
                // accumulate the rotation into v (columns p, q)
                for r in 0..k {
                    let vrp = v[r * k + p];
                    let vrq = v[r * k + q];
                    v[r * k + p] = c * vrp - s * vrq;
                    v[r * k + q] = s * vrp + c * vrq;
                }
            }
        }
    }
    let mut evals: Vec<f64> = (0..k).map(|i| m[i * k + i]).collect();
    // sort eigenpairs by descending eigenvalue (total_cmp: NaNs from a
    // divergent caller must not turn into a sort panic here)
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&i, &j| evals[j].total_cmp(&evals[i]));
    let sorted_vals: Vec<f64> = order.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = vec![0.0f64; k * k];
    for (new_col, &old_col) in order.iter().enumerate() {
        for r in 0..k {
            sorted_vecs[r * k + new_col] = v[r * k + old_col];
        }
    }
    evals = sorted_vals;
    (evals, sorted_vecs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strdist::euclidean;
    use crate::util::prng::Rng;

    /// Random k x k orthogonal matrix (f64, via Gram-Schmidt on a random
    /// Gaussian matrix); `reflect` flips one column so det = -1.
    fn random_orthogonal(rng: &mut Rng, k: usize, reflect: bool) -> Vec<f64> {
        let mut q = vec![0.0f64; k * k];
        for col in 0..k {
            let mut w: Vec<f64> = (0..k).map(|_| rng.next_normal()).collect();
            loop {
                for prev in 0..col {
                    let mut dot = 0.0;
                    for r in 0..k {
                        dot += w[r] * q[r * k + prev];
                    }
                    for r in 0..k {
                        w[r] -= dot * q[r * k + prev];
                    }
                }
                let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
                if norm > 1e-6 {
                    for r in 0..k {
                        q[r * k + col] = w[r] / norm;
                    }
                    break;
                }
                w = (0..k).map(|_| rng.next_normal()).collect();
            }
        }
        if reflect {
            for r in 0..k {
                q[r * k] = -q[r * k];
            }
        }
        q
    }

    fn transform_rows(x: &Matrix, q: &[f64], scale: f64, t: &[f64]) -> Matrix {
        let k = x.cols;
        let mut out = Matrix::zeros(x.rows, k);
        for i in 0..x.rows {
            for j in 0..k {
                let mut acc = t[j];
                for c in 0..k {
                    acc += scale * x.at(i, c) as f64 * q[c * k + j];
                }
                out.set(i, j, acc as f32);
            }
        }
        out
    }

    #[test]
    fn jacobi_diagonalises_known_matrix() {
        // symmetric 3x3 with known spectrum {6, 3, 1} (constructed as
        // Q diag Q^T for a fixed rotation)
        let mut rng = Rng::new(11);
        let k = 3;
        let q = random_orthogonal(&mut rng, k, false);
        let d = [6.0f64, 3.0, 1.0];
        let mut a = vec![0.0f64; k * k];
        for i in 0..k {
            for j in 0..k {
                let mut acc = 0.0;
                for c in 0..k {
                    acc += q[i * k + c] * d[c] * q[j * k + c];
                }
                a[i * k + j] = acc;
            }
        }
        let (vals, vecs) = jacobi_eigs(&a, k);
        for (got, want) in vals.iter().zip(d.iter()) {
            assert!((got - want).abs() < 1e-10, "{vals:?}");
        }
        // eigenvector property: A v = lambda v
        for col in 0..k {
            for r in 0..k {
                let mut av = 0.0;
                for c in 0..k {
                    av += a[r * k + c] * vecs[c * k + col];
                }
                assert!(
                    (av - vals[col] * vecs[r * k + col]).abs() < 1e-9,
                    "col {col}"
                );
            }
        }
    }

    #[test]
    fn recovers_rotation_translation() {
        for (seed, k) in [(1u64, 2usize), (2, 3), (3, 7)] {
            let mut rng = Rng::new(seed);
            let x = Matrix::random_normal(&mut rng, 30, k, 1.0);
            let q = random_orthogonal(&mut rng, k, false);
            let t: Vec<f64> = (0..k).map(|_| rng.next_normal() * 3.0).collect();
            let y = transform_rows(&x, &q, 1.0, &t);
            let fit = Procrustes::fit(&x, &y);
            let got = fit.apply(&x);
            assert!(
                got.max_abs_diff(&y) < 1e-5,
                "k={k}: diff {} rmsd {}",
                got.max_abs_diff(&y),
                fit.rmsd
            );
            assert!(fit.rmsd < 1e-5);
            assert!((fit.scale - 1.0).abs() < 1e-12);
            assert!((fit.det_sign() - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn recovers_reflection() {
        let mut rng = Rng::new(5);
        let k = 3;
        let x = Matrix::random_normal(&mut rng, 25, k, 1.0);
        let q = random_orthogonal(&mut rng, k, true);
        let t = vec![1.5f64, -2.0, 0.25];
        let y = transform_rows(&x, &q, 1.0, &t);
        let fit = Procrustes::fit(&x, &y);
        assert!(fit.apply(&x).max_abs_diff(&y) < 1e-5);
        assert!((fit.det_sign() + 1.0).abs() < 1e-6, "reflection must be allowed");
    }

    #[test]
    fn recovers_scale_when_asked() {
        let mut rng = Rng::new(6);
        let k = 4;
        let x = Matrix::random_normal(&mut rng, 40, k, 1.0);
        let q = random_orthogonal(&mut rng, k, false);
        let t = vec![0.0f64; k];
        let y = transform_rows(&x, &q, 2.5, &t);
        let rigid = Procrustes::fit(&x, &y);
        assert!((rigid.scale - 1.0).abs() < 1e-12, "rigid fit never rescales");
        let sim = Procrustes::fit_with_scale(&x, &y);
        assert!((sim.scale - 2.5).abs() < 1e-4, "scale {}", sim.scale);
        assert!(sim.apply(&x).max_abs_diff(&y) < 1e-4);
    }

    #[test]
    fn preserves_distances_of_non_fit_points() {
        // A rigid transform fitted on anchors must preserve ALL pairwise
        // distances when applied to a larger configuration.
        let mut rng = Rng::new(7);
        let k = 3;
        let x = Matrix::random_normal(&mut rng, 50, k, 1.0);
        let q = random_orthogonal(&mut rng, k, true);
        let t = vec![4.0f64, -1.0, 2.0];
        let anchors = x.select_rows(&[0, 1, 2, 3, 4, 5, 6]);
        let anchors_y = transform_rows(&anchors, &q, 1.0, &t);
        let fit = Procrustes::fit(&anchors, &anchors_y);
        let moved = fit.apply(&x);
        for i in 0..x.rows {
            for j in (i + 1)..x.rows {
                let before = euclidean(x.row(i), x.row(j));
                let after = euclidean(moved.row(i), moved.row(j));
                assert!((before - after).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn degenerate_fits_stay_finite() {
        // fewer anchors than dimensions, and all-identical anchors: the
        // transform is under-determined but must stay orthogonal + finite
        let mut rng = Rng::new(8);
        let k = 5;
        let x = Matrix::random_normal(&mut rng, 2, k, 1.0);
        let y = Matrix::random_normal(&mut rng, 2, k, 1.0);
        let fit = Procrustes::fit(&x, &y);
        let out = fit.apply(&x);
        assert!(out.data.iter().all(|v| v.is_finite()));
        assert!(fit.det_sign().abs() > 0.5, "orthogonal part stays full rank");

        let same = Matrix::from_rows(&[vec![1.0f32; 3], vec![1.0f32; 3]]);
        let tgt = Matrix::from_rows(&[vec![2.0f32; 3], vec![2.0f32; 3]]);
        let fit = Procrustes::fit(&same, &tgt);
        let out = fit.apply(&same);
        assert!(out.data.iter().all(|v| v.is_finite()));
        // centroids must still map onto each other
        assert!((out.at(0, 0) - 2.0).abs() < 1e-5);
    }

    #[test]
    fn identity_is_identity() {
        let id = Procrustes::identity(3);
        let x = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![-1.0, 0.5, 4.0]]);
        assert_eq!(id.apply(&x).data, x.data);
        assert_eq!(id.scale, 1.0);
        assert_eq!(id.rmsd, 0.0);
    }
}
