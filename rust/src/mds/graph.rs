//! Layered small-world (HNSW-style) graph over landmark embeddings —
//! the index behind sub-O(L) OSE queries and graph-assisted landmark
//! selection (docs/QUERY_PATH.md walks one query through it).
//!
//! The graph is dependency-free and deterministic: node levels come from
//! a seeded geometric lottery ([`util::prng::Rng`](crate::util::prng::Rng)),
//! nodes are inserted in index order, and every tie is broken by node id,
//! so the same input and [`GraphConfig`] always produce a byte-identical
//! structure ([`LandmarkGraph::to_bytes`]). Search is the classic two-act
//! descent: greedy hops through the sparse upper layers to land near the
//! query, then a best-first beam of width `ef` on the dense bottom layer —
//! O(log L) hops instead of an O(L) scan.
//!
//! Two consumers in this crate:
//!
//! * **Sparse OSE queries** — `BackendOpt` with `query_k > 0` asks
//!   [`LandmarkGraph::knn_delta`] for each query's k nearest landmarks and
//!   majorizes against only those rows (`docs/QUERY_PATH.md`).
//! * **Landmark selection** — [`graph_landmarks`] replaces the O(N·L)
//!   farthest-point scan for out-of-core corpora with a graph-pruned
//!   maxmin sweep over a bounded candidate pool, seeded from the upper
//!   layers of the hierarchy (the free subsample the level lottery gives
//!   us — the annembed idiom).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet, VecDeque};

use anyhow::{bail, Result};

use crate::mds::divide::DeltaSource;
use crate::mds::matrix::Matrix;
use crate::strdist::euclidean;
use crate::util::prng::Rng;

/// Hard ceiling on the level lottery (2^16 nodes per expected top-level
/// occupant is far beyond any L this crate targets).
const MAX_LEVEL: usize = 16;

/// Candidate-pool multiple used by [`graph_landmarks`]: the maxmin sweep
/// runs over `POOL_FACTOR * l` corpus objects instead of all N.
pub const GRAPH_POOL_FACTOR: usize = 4;

/// Construction / search parameters for the landmark graph.
///
/// `m` is the neighbour budget per node per layer (the bottom layer keeps
/// up to `2m`); `ef_construction` and `ef_search` are the beam widths used
/// while building and querying. All randomness flows from `seed`, so equal
/// configs over equal inputs build byte-identical graphs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GraphConfig {
    /// Neighbours per node per layer (bottom layer caps at `2m`).
    pub m: usize,
    /// Beam width while inserting nodes (recall of the build itself).
    pub ef_construction: usize,
    /// Default beam width at query time (raised to `k` when smaller).
    pub ef_search: usize,
    /// Seed for the level lottery; equal seeds give equal graphs.
    pub seed: u64,
}

impl Default for GraphConfig {
    fn default() -> Self {
        GraphConfig { m: 12, ef_construction: 64, ef_search: 48, seed: 0x9A27 }
    }
}

/// Search candidate ordered by distance, ties broken by node id so heap
/// order (and therefore every result) is deterministic.
#[derive(Clone, Copy, Debug)]
struct Cand {
    d: f32,
    id: u32,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.d.total_cmp(&other.d).then(self.id.cmp(&other.id))
    }
}

/// The layered topology alone, built over any symmetric distance oracle —
/// no coordinates stored. [`LandmarkGraph`] pairs it with an owned
/// coordinate table; [`graph_landmarks`] runs it directly over a
/// [`DeltaSource`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SmallWorld {
    m: usize,
    levels: Vec<u8>,
    /// `layers[layer][node]` → neighbour ids; empty for nodes whose level
    /// is below `layer`. `layers[0]` covers every node.
    layers: Vec<Vec<Vec<u32>>>,
    entry: usize,
}

impl SmallWorld {
    /// Build over `n` objects using the symmetric oracle `dist(i, j)`.
    /// Deterministic for a given `(n, cfg)`: levels come from the seeded
    /// lottery, insertion follows index order, ties break by id.
    pub fn build_with<F>(n: usize, cfg: &GraphConfig, dist: F) -> SmallWorld
    where
        F: Fn(usize, usize) -> f32,
    {
        let m = cfg.m.max(2);
        let ef_c = cfg.ef_construction.max(m);
        let mut rng = Rng::new(cfg.seed);
        let inv_ln_m = 1.0 / (m as f64).ln();
        let levels: Vec<u8> = (0..n)
            .map(|_| {
                let u = 1.0 - rng.next_f64(); // (0, 1]
                ((-u.ln() * inv_ln_m) as usize).min(MAX_LEVEL) as u8
            })
            .collect();
        let top = levels.iter().copied().max().unwrap_or(0) as usize;
        let mut layers: Vec<Vec<Vec<u32>>> =
            (0..=top).map(|_| vec![Vec::new(); n]).collect();
        if n == 0 {
            return SmallWorld { m, levels, layers, entry: 0 };
        }

        let mut entry = 0usize;
        let mut cur_top = levels[0] as usize;
        for i in 1..n {
            let li = levels[i] as usize;
            let dist_to = |j: usize| dist(i, j);
            let mut cur = entry;
            let mut layer = cur_top;
            while layer > li {
                cur = greedy_descent(&layers[layer], cur, &dist_to);
                layer -= 1;
            }
            let mut eps = vec![cur];
            for layer in (0..=li.min(cur_top)).rev() {
                let cands = search_layer(&layers[layer], &eps, ef_c, &dist_to);
                let cap = if layer == 0 { 2 * m } else { m };
                for c in cands.iter().take(m) {
                    let j = c.id as usize;
                    layers[layer][i].push(c.id);
                    layers[layer][j].push(i as u32);
                    if layers[layer][j].len() > cap {
                        prune_neighbours(&mut layers[layer][j], cap, &|v| {
                            dist(j, v)
                        });
                    }
                }
                eps = cands.iter().map(|c| c.id as usize).collect();
            }
            if li > cur_top {
                cur_top = li;
                entry = i;
            }
        }
        SmallWorld { m, levels, layers, entry }
    }

    /// Number of indexed objects.
    pub fn len(&self) -> usize {
        self.levels.len()
    }

    /// True when the graph indexes nothing.
    pub fn is_empty(&self) -> bool {
        self.levels.is_empty()
    }

    /// Highest layer present (0 for a flat or empty graph).
    pub fn max_level(&self) -> usize {
        self.layers.len().saturating_sub(1)
    }

    /// The global entry node (top of the level hierarchy).
    pub fn entry(&self) -> usize {
        self.entry
    }

    /// Nodes whose level is at least `layer`, ascending by id.
    pub fn layer_nodes(&self, layer: usize) -> Vec<usize> {
        (0..self.levels.len())
            .filter(|&i| self.levels[i] as usize >= layer)
            .collect()
    }

    /// The upper-layer nodes (level ≥ 1): a free, geometry-independent
    /// ~1/m subsample the level lottery already paid for. The annembed
    /// trick — [`graph_landmarks`] seeds its maxmin sweep from these
    /// instead of drawing a fresh sample.
    pub fn subsample(&self) -> Vec<usize> {
        self.layer_nodes(1)
    }

    /// k-nearest search with the oracle `dist_to(node)`: greedy descent
    /// through the upper layers, then an `ef`-wide beam on layer 0.
    /// Returns up to `k` `(node, distance)` pairs, nearest first.
    pub fn search<F>(&self, k: usize, ef: usize, dist_to: F) -> Vec<(usize, f32)>
    where
        F: Fn(usize) -> f32,
    {
        if self.is_empty() || k == 0 {
            return Vec::new();
        }
        let mut cur = self.entry;
        for layer in (1..self.layers.len()).rev() {
            cur = greedy_descent(&self.layers[layer], cur, &dist_to);
        }
        let mut cands =
            search_layer(&self.layers[0], &[cur], ef.max(k), &dist_to);
        cands.truncate(k);
        cands.into_iter().map(|c| (c.id as usize, c.d)).collect()
    }
}

/// Move to the neighbour closest to the query until no neighbour improves.
fn greedy_descent(
    adj: &[Vec<u32>],
    start: usize,
    dist_to: &dyn Fn(usize) -> f32,
) -> usize {
    let mut cur = start;
    let mut best = dist_to(cur);
    loop {
        let before = cur;
        for &nb in &adj[before] {
            let d = dist_to(nb as usize);
            if d < best {
                best = d;
                cur = nb as usize;
            }
        }
        if cur == before {
            return cur;
        }
    }
}

/// Best-first beam search on one layer: expand the nearest unexpanded
/// candidate until the beam's worst member beats everything left. Returns
/// up to `ef` candidates, nearest first.
fn search_layer(
    adj: &[Vec<u32>],
    eps: &[usize],
    ef: usize,
    dist_to: &dyn Fn(usize) -> f32,
) -> Vec<Cand> {
    let mut visited: HashSet<u32> = HashSet::new();
    let mut frontier: BinaryHeap<Reverse<Cand>> = BinaryHeap::new();
    let mut beam: BinaryHeap<Cand> = BinaryHeap::new();
    for &e in eps {
        let id = e as u32;
        if visited.insert(id) {
            let c = Cand { d: dist_to(e), id };
            frontier.push(Reverse(c));
            beam.push(c);
        }
    }
    while beam.len() > ef {
        beam.pop();
    }
    while let Some(Reverse(c)) = frontier.pop() {
        if beam.len() >= ef {
            let worst = beam.peek().map(|b| b.d).unwrap_or(f32::INFINITY);
            if c.d > worst {
                break;
            }
        }
        for &nb in &adj[c.id as usize] {
            if !visited.insert(nb) {
                continue;
            }
            let d = dist_to(nb as usize);
            let admit = beam.len() < ef
                || d < beam.peek().map(|b| b.d).unwrap_or(f32::INFINITY);
            if admit {
                let nc = Cand { d, id: nb };
                frontier.push(Reverse(nc));
                beam.push(nc);
                if beam.len() > ef {
                    beam.pop();
                }
            }
        }
    }
    beam.into_sorted_vec()
}

/// Keep the `cap` neighbours nearest to the owning node, dropping the rest.
fn prune_neighbours(list: &mut Vec<u32>, cap: usize, dist_to: &dyn Fn(usize) -> f32) {
    let mut scored: Vec<Cand> =
        list.iter().map(|&v| Cand { d: dist_to(v as usize), id: v }).collect();
    scored.sort_unstable();
    scored.truncate(cap);
    list.clear();
    list.extend(scored.into_iter().map(|c| c.id));
}

/// Indices of the `k` smallest entries of `values` (ties broken by index),
/// returned in ascending index order — the exact O(L) fallback used when no
/// landmark graph is attached to a sparse query path.
pub fn nearest_k(values: &[f32], k: usize) -> Vec<usize> {
    let l = values.len();
    if k == 0 {
        return Vec::new();
    }
    if k >= l {
        return (0..l).collect();
    }
    let mut idx: Vec<usize> = (0..l).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        values[a].total_cmp(&values[b]).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// A small-world graph paired with the L x K landmark configuration it
/// indexes — the artifact serialised alongside the base solve so serving
/// replicas can answer k-nearest-landmark queries without rebuilding.
#[derive(Clone, Debug, PartialEq)]
pub struct LandmarkGraph {
    cfg: GraphConfig,
    points: Matrix,
    core: SmallWorld,
}

impl LandmarkGraph {
    /// Build the graph over an L x K landmark configuration (one landmark
    /// per row, Euclidean metric). Deterministic: the same `points` and
    /// `cfg` always produce a byte-identical graph.
    ///
    /// ```
    /// use lmds_ose::mds::graph::{GraphConfig, LandmarkGraph};
    /// use lmds_ose::mds::Matrix;
    /// use lmds_ose::util::prng::Rng;
    ///
    /// let mut rng = Rng::new(7);
    /// let landmarks = Matrix::random_normal(&mut rng, 500, 4, 1.0);
    /// let graph = LandmarkGraph::build(&landmarks, &GraphConfig::default());
    /// assert_eq!(graph.len(), 500);
    /// // Same seed, same input => byte-identical index.
    /// let again = LandmarkGraph::build(&landmarks, &GraphConfig::default());
    /// assert_eq!(graph.to_bytes(), again.to_bytes());
    /// ```
    pub fn build(points: &Matrix, cfg: &GraphConfig) -> LandmarkGraph {
        let core = SmallWorld::build_with(points.rows, cfg, |i, j| {
            euclidean(points.row(i), points.row(j)) as f32
        });
        LandmarkGraph { cfg: cfg.clone(), points: points.clone(), core }
    }

    /// Number of indexed landmarks.
    pub fn len(&self) -> usize {
        self.points.rows
    }

    /// True when the graph indexes no landmarks.
    pub fn is_empty(&self) -> bool {
        self.points.rows == 0
    }

    /// Embedding dimension of the indexed landmarks.
    pub fn dim(&self) -> usize {
        self.points.cols
    }

    /// The indexed landmark configuration (L x K).
    pub fn points(&self) -> &Matrix {
        &self.points
    }

    /// The layered topology (for layer inspection / the free subsample).
    pub fn core(&self) -> &SmallWorld {
        &self.core
    }

    /// k nearest landmarks to a query coordinate, nearest first, as
    /// `(landmark index, distance)` pairs.
    ///
    /// ```
    /// use lmds_ose::mds::graph::{GraphConfig, LandmarkGraph};
    /// use lmds_ose::mds::Matrix;
    /// use lmds_ose::util::prng::Rng;
    ///
    /// let mut rng = Rng::new(11);
    /// let landmarks = Matrix::random_normal(&mut rng, 800, 3, 1.0);
    /// let graph = LandmarkGraph::build(&landmarks, &GraphConfig::default());
    /// let hits = graph.knn(landmarks.row(42), 5);
    /// assert_eq!(hits.len(), 5);
    /// assert_eq!(hits[0].0, 42); // a landmark's own row is its nearest hit
    /// assert!(hits.windows(2).all(|w| w[0].1 <= w[1].1));
    /// ```
    pub fn knn(&self, query: &[f32], k: usize) -> Vec<(usize, f32)> {
        assert_eq!(query.len(), self.points.cols, "query dimension mismatch");
        self.knn_ef(query, k, self.cfg.ef_search)
    }

    /// [`knn`](Self::knn) with an explicit beam width (recall knob).
    pub fn knn_ef(&self, query: &[f32], k: usize, ef: usize) -> Vec<(usize, f32)> {
        self.core.search(k, ef, |i| euclidean(query, self.points.row(i)) as f32)
    }

    /// k nearest landmarks for an OSE query given its dissimilarity row
    /// (`delta[i]` = distance from the query object to landmark `i`),
    /// ascending by landmark index. The row itself is the distance oracle,
    /// so the search reads only the O(k log L) entries it visits; if the
    /// graph walk comes back short (disconnected fringe), the exact
    /// [`nearest_k`] scan takes over so the result always has
    /// `min(k, L)` indices.
    pub fn knn_delta(&self, delta: &[f32], k: usize) -> Vec<usize> {
        assert_eq!(delta.len(), self.len(), "delta row length mismatch");
        let k = k.min(self.len());
        let hits =
            self.core.search(k, self.cfg.ef_search.max(k), |i| delta[i]);
        if hits.len() < k {
            return nearest_k(delta, k);
        }
        let mut idx: Vec<usize> = hits.into_iter().map(|(i, _)| i).collect();
        idx.sort_unstable();
        idx
    }

    /// Serialise to a versioned little-endian byte blob (stored alongside
    /// the base solve). Byte-stable across runs for equal inputs.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"LMG1");
        push_u32(&mut out, self.points.rows as u32);
        push_u32(&mut out, self.points.cols as u32);
        for v in &self.points.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        push_u32(&mut out, self.cfg.m as u32);
        push_u32(&mut out, self.cfg.ef_construction as u32);
        push_u32(&mut out, self.cfg.ef_search as u32);
        out.extend_from_slice(&self.cfg.seed.to_le_bytes());
        push_u32(&mut out, self.core.entry as u32);
        push_u32(&mut out, self.core.layers.len() as u32);
        out.extend_from_slice(&self.core.levels);
        for layer in &self.core.layers {
            for list in layer {
                push_u32(&mut out, list.len() as u32);
                for &v in list {
                    push_u32(&mut out, v);
                }
            }
        }
        out
    }

    /// Deserialise a blob written by [`to_bytes`](Self::to_bytes).
    pub fn from_bytes(bytes: &[u8]) -> Result<LandmarkGraph> {
        let mut cur = Cursor { bytes, pos: 0 };
        let magic = cur.take(4)?;
        if magic != b"LMG1" {
            bail!("landmark graph blob: bad magic {magic:?}");
        }
        let rows = cur.u32()? as usize;
        let cols = cur.u32()? as usize;
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(f32::from_le_bytes(cur.take(4)?.try_into().unwrap()));
        }
        let points = Matrix::from_vec(rows, cols, data);
        let m = cur.u32()? as usize;
        let ef_construction = cur.u32()? as usize;
        let ef_search = cur.u32()? as usize;
        let seed = u64::from_le_bytes(cur.take(8)?.try_into().unwrap());
        let entry = cur.u32()? as usize;
        let n_layers = cur.u32()? as usize;
        if rows > 0 && entry >= rows {
            bail!("landmark graph blob: entry {entry} out of range (L={rows})");
        }
        if n_layers == 0 || n_layers > MAX_LEVEL + 1 {
            bail!("landmark graph blob: implausible layer count {n_layers}");
        }
        let levels = cur.take(rows)?.to_vec();
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let mut layer = Vec::with_capacity(rows);
            for _ in 0..rows {
                let deg = cur.u32()? as usize;
                let mut list = Vec::with_capacity(deg);
                for _ in 0..deg {
                    let v = cur.u32()?;
                    if v as usize >= rows {
                        bail!("landmark graph blob: neighbour {v} out of range");
                    }
                    list.push(v);
                }
                layer.push(list);
            }
            layers.push(layer);
        }
        if cur.pos != bytes.len() {
            bail!(
                "landmark graph blob: {} trailing bytes",
                bytes.len() - cur.pos
            );
        }
        Ok(LandmarkGraph {
            cfg: GraphConfig { m, ef_construction, ef_search, seed },
            points,
            core: SmallWorld { m: m.max(2), levels, layers, entry },
        })
    }
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("landmark graph blob: truncated at byte {}", self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

/// Graph-assisted landmark selection for out-of-core corpora: an
/// approximate farthest-point (maxmin) sweep whose per-pick update walks
/// the small-world graph instead of rescanning every object — the
/// replacement for the O(N·L) [`fps_anchors`](crate::mds::divide::fps_anchors)
/// scan when the corpus never fits in memory.
///
/// The sweep runs over a bounded candidate pool ([`GRAPH_POOL_FACTOR`]` * l`
/// objects, deterministically sampled), builds a [`SmallWorld`] over it with
/// `source.dist` as the oracle, seeds the selection from the hierarchy's
/// entry node plus the upper-layer free subsample ([`SmallWorld::subsample`],
/// capped at `l/4`), then picks the remaining landmarks maxmin-style: each
/// new pick relaxes `min_dist` only inside its own graph neighbourhood
/// (a pruned flood stopping where distances stop improving), so selection
/// cost is O(pool · m) distance calls instead of O(N · L).
///
/// Returns exactly `min(l, source.len())` distinct indices, ascending.
/// Deterministic for a given `(source, l, cfg, seed)`.
///
/// ```
/// use lmds_ose::mds::graph::{graph_landmarks, GraphConfig};
/// use lmds_ose::mds::{Matrix, PointsDelta};
/// use lmds_ose::util::prng::Rng;
///
/// let mut rng = Rng::new(3);
/// let corpus = Matrix::random_normal(&mut rng, 2000, 3, 1.0);
/// let source = PointsDelta { points: &corpus };
/// let idx = graph_landmarks(&source, 50, &GraphConfig::default(), 99);
/// assert_eq!(idx.len(), 50);
/// assert!(idx.windows(2).all(|w| w[0] < w[1])); // sorted, distinct
/// ```
pub fn graph_landmarks<S: DeltaSource + ?Sized>(
    source: &S,
    l: usize,
    cfg: &GraphConfig,
    seed: u64,
) -> Vec<usize> {
    let n = source.len();
    let l = l.min(n);
    if l == 0 {
        return Vec::new();
    }
    if l == n {
        return (0..n).collect();
    }

    // Bounded candidate pool, deterministically sampled.
    let mut rng = Rng::new(seed ^ 0x6_1A9D);
    let pool_n = (GRAPH_POOL_FACTOR * l).max(l + 1).min(n);
    let pool: Vec<usize> = if pool_n == n {
        (0..n).collect()
    } else {
        let mut p = rng.sample_indices(n, pool_n);
        p.sort_unstable();
        p
    };

    let gcfg = GraphConfig { seed: cfg.seed ^ seed, ..cfg.clone() };
    let core =
        SmallWorld::build_with(pool_n, &gcfg, |a, b| source.dist(pool[a], pool[b]));

    let mut chosen = vec![false; pool_n];
    let mut min_d = vec![f32::INFINITY; pool_n];
    let mut heap: BinaryHeap<Cand> = BinaryHeap::new();
    let mut selected: Vec<usize> = Vec::with_capacity(l);

    // Seeds: the hierarchy entry plus the upper-layer free subsample.
    let mut seeds = vec![core.entry()];
    for v in core.subsample() {
        if seeds.len() >= (l / 4).max(1) {
            break;
        }
        if v != core.entry() {
            seeds.push(v);
        }
    }
    // One dense pass from the first seed pins min_d everywhere …
    chosen[seeds[0]] = true;
    min_d[seeds[0]] = 0.0;
    selected.push(seeds[0]);
    for v in 0..pool_n {
        if !chosen[v] {
            min_d[v] = source.dist(pool[v], pool[seeds[0]]);
        }
    }
    // … then every further seed and pick relaxes only its neighbourhood.
    for s in 1..seeds.len() {
        let v = seeds[s];
        if chosen[v] || selected.len() >= l {
            continue;
        }
        chosen[v] = true;
        min_d[v] = 0.0;
        selected.push(v);
        relax_from(source, &pool, &core, v, &chosen, &mut min_d, &mut heap);
    }
    for v in 0..pool_n {
        if !chosen[v] {
            heap.push(Cand { d: min_d[v], id: v as u32 });
        }
    }

    while selected.len() < l {
        let v = match heap.pop() {
            Some(c) => {
                let v = c.id as usize;
                // Lazy invalidation: stale entries (relaxed since pushed,
                // or already selected) are skipped.
                if chosen[v] || c.d != min_d[v] {
                    continue;
                }
                v
            }
            // Disconnected fringe: fall back to a direct argmax scan.
            None => match argmax_min_dist(&chosen, &min_d) {
                Some(v) => v,
                None => break,
            },
        };
        chosen[v] = true;
        min_d[v] = 0.0;
        selected.push(v);
        relax_from(source, &pool, &core, v, &chosen, &mut min_d, &mut heap);
    }
    // Top up (duplicate-heavy metrics can exhaust distinct candidates).
    for v in 0..pool_n {
        if selected.len() >= l {
            break;
        }
        if !chosen[v] {
            chosen[v] = true;
            selected.push(v);
        }
    }

    let mut out: Vec<usize> = selected.into_iter().map(|v| pool[v]).collect();
    out.sort_unstable();
    out
}

/// Pruned flood from a newly selected pool node: follow layer-0 edges
/// while `min_d` keeps improving, pushing each improvement for the maxmin
/// heap. Distances are measured to the new pick only, so the walk stays
/// inside the pick's neighbourhood.
fn relax_from<S: DeltaSource + ?Sized>(
    source: &S,
    pool: &[usize],
    core: &SmallWorld,
    from: usize,
    chosen: &[bool],
    min_d: &mut [f32],
    heap: &mut BinaryHeap<Cand>,
) {
    let mut visited: HashSet<u32> = HashSet::new();
    let mut queue: VecDeque<usize> = VecDeque::new();
    visited.insert(from as u32);
    queue.push_back(from);
    while let Some(u) = queue.pop_front() {
        for &nb in &core.layers[0][u] {
            if !visited.insert(nb) {
                continue;
            }
            let w = nb as usize;
            if chosen[w] {
                continue;
            }
            let d = source.dist(pool[w], pool[from]);
            if d < min_d[w] {
                min_d[w] = d;
                heap.push(Cand { d, id: nb });
                queue.push_back(w);
            }
        }
    }
}

/// Unchosen pool node with the largest `min_d` (ties → lowest index).
fn argmax_min_dist(chosen: &[bool], min_d: &[f32]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for v in 0..chosen.len() {
        if chosen[v] {
            continue;
        }
        match best {
            None => best = Some(v),
            Some(b) if min_d[v] > min_d[b] => best = Some(v),
            _ => {}
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mds::divide::PointsDelta;

    fn gaussians(seed: u64, n: usize, k: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::random_normal(&mut rng, n, k, 1.0)
    }

    fn brute_knn(points: &Matrix, query: &[f32], k: usize) -> Vec<usize> {
        let d: Vec<f32> = (0..points.rows)
            .map(|i| euclidean(query, points.row(i)) as f32)
            .collect();
        nearest_k(&d, k)
    }

    #[test]
    fn empty_and_singleton_graphs() {
        let empty = Matrix::zeros(0, 3);
        let g = LandmarkGraph::build(&empty, &GraphConfig::default());
        assert!(g.is_empty());
        assert!(g.knn(&[0.0, 0.0, 0.0], 4).is_empty());

        let one = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let g = LandmarkGraph::build(&one, &GraphConfig::default());
        let hits = g.knn(&[1.0, 2.0], 3);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0, 0);
    }

    #[test]
    fn upper_layer_subsample_fraction_tracks_level_lottery() {
        let pts = gaussians(5, 4000, 3);
        let g = LandmarkGraph::build(&pts, &GraphConfig::default());
        let upper = g.core().subsample().len() as f64 / 4000.0;
        // Expected fraction is 1/m = 1/12 ≈ 0.083.
        assert!((0.03..0.20).contains(&upper), "upper fraction {upper}");
    }

    #[test]
    fn knn_matches_brute_force_on_a_line() {
        // Points on a line: the graph search has an unambiguous answer.
        let n = 200;
        let pts = Matrix::from_vec(n, 1, (0..n).map(|i| i as f32).collect());
        let g = LandmarkGraph::build(&pts, &GraphConfig::default());
        for q in [0.2f32, 57.6, 103.4, 198.9] {
            let got: Vec<usize> =
                g.knn(&[q], 3).into_iter().map(|(i, _)| i).collect();
            let mut got = got;
            got.sort_unstable();
            assert_eq!(got, brute_knn(&pts, &[q], 3), "query {q}");
        }
    }

    #[test]
    fn recall_is_high_on_gaussian_clouds() {
        let pts = gaussians(9, 600, 4);
        let g = LandmarkGraph::build(&pts, &GraphConfig::default());
        let queries = gaussians(10, 50, 4);
        let k = 5;
        let mut hit = 0usize;
        for q in 0..queries.rows {
            let exact = brute_knn(&pts, queries.row(q), k);
            let approx: HashSet<usize> = g
                .knn(queries.row(q), k)
                .into_iter()
                .map(|(i, _)| i)
                .collect();
            hit += exact.iter().filter(|i| approx.contains(i)).count();
        }
        let recall = hit as f64 / (50 * k) as f64;
        assert!(recall >= 0.9, "recall {recall}");
    }

    #[test]
    fn construction_is_deterministic_and_seed_sensitive() {
        let pts = gaussians(21, 400, 3);
        let a = LandmarkGraph::build(&pts, &GraphConfig::default());
        let b = LandmarkGraph::build(&pts, &GraphConfig::default());
        assert_eq!(a.to_bytes(), b.to_bytes());
        let other =
            GraphConfig { seed: 0xDEAD, ..GraphConfig::default() };
        let c = LandmarkGraph::build(&pts, &other);
        assert_ne!(a.to_bytes(), c.to_bytes());
    }

    #[test]
    fn serialisation_round_trips() {
        let pts = gaussians(33, 300, 5);
        let g = LandmarkGraph::build(&pts, &GraphConfig::default());
        let blob = g.to_bytes();
        let back = LandmarkGraph::from_bytes(&blob).unwrap();
        assert_eq!(back, g);
        assert_eq!(back.to_bytes(), blob);
    }

    #[test]
    fn serialisation_rejects_corrupt_blobs() {
        let pts = gaussians(34, 50, 2);
        let g = LandmarkGraph::build(&pts, &GraphConfig::default());
        let blob = g.to_bytes();
        assert!(LandmarkGraph::from_bytes(&blob[..blob.len() - 3]).is_err());
        let mut bad_magic = blob.clone();
        bad_magic[0] = b'X';
        assert!(LandmarkGraph::from_bytes(&bad_magic).is_err());
        let mut trailing = blob;
        trailing.push(0);
        assert!(LandmarkGraph::from_bytes(&trailing).is_err());
    }

    #[test]
    fn knn_delta_agrees_with_coordinate_knn() {
        let pts = gaussians(40, 500, 3);
        let g = LandmarkGraph::build(&pts, &GraphConfig::default());
        let queries = gaussians(41, 20, 3);
        for q in 0..queries.rows {
            let row = queries.row(q);
            let delta: Vec<f32> = (0..pts.rows)
                .map(|i| euclidean(row, pts.row(i)) as f32)
                .collect();
            let mut via_coords: Vec<usize> =
                g.knn(row, 8).into_iter().map(|(i, _)| i).collect();
            via_coords.sort_unstable();
            assert_eq!(g.knn_delta(&delta, 8), via_coords, "query {q}");
        }
    }

    #[test]
    fn nearest_k_selects_smallest_with_index_ties() {
        let v = [3.0f32, 1.0, 2.0, 1.0, 5.0];
        assert_eq!(nearest_k(&v, 2), vec![1, 3]);
        assert_eq!(nearest_k(&v, 3), vec![1, 2, 3]);
        assert_eq!(nearest_k(&v, 0), Vec::<usize>::new());
        assert_eq!(nearest_k(&v, 9), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn graph_landmarks_degenerate_sizes() {
        let pts = gaussians(50, 30, 2);
        let src = PointsDelta { points: &pts };
        assert!(graph_landmarks(&src, 0, &GraphConfig::default(), 1).is_empty());
        assert_eq!(
            graph_landmarks(&src, 30, &GraphConfig::default(), 1),
            (0..30).collect::<Vec<_>>()
        );
        assert_eq!(
            graph_landmarks(&src, 99, &GraphConfig::default(), 1),
            (0..30).collect::<Vec<_>>()
        );
        let idx = graph_landmarks(&src, 7, &GraphConfig::default(), 1);
        assert_eq!(idx.len(), 7);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn graph_landmarks_is_deterministic() {
        let pts = gaussians(51, 900, 3);
        let src = PointsDelta { points: &pts };
        let a = graph_landmarks(&src, 40, &GraphConfig::default(), 7);
        let b = graph_landmarks(&src, 40, &GraphConfig::default(), 7);
        assert_eq!(a, b);
    }

    /// Max over all objects of the distance to its closest selected
    /// landmark — the coverage radius of a selection.
    fn fill_distance(pts: &Matrix, idx: &[usize]) -> f32 {
        let mut worst = 0.0f32;
        for i in 0..pts.rows {
            let best = idx
                .iter()
                .map(|&j| euclidean(pts.row(i), pts.row(j)) as f32)
                .fold(f32::INFINITY, f32::min);
            worst = worst.max(best);
        }
        worst
    }

    #[test]
    fn graph_landmarks_cover_clusters_like_fps() {
        // Four well-separated clusters: a maxmin-style selector must put
        // landmarks in all of them, and its coverage radius must stay
        // within a small factor of the exact farthest-point sweep.
        let per = 200;
        let centers = [(-50.0f32, -50.0), (-50.0, 50.0), (50.0, -50.0), (50.0, 50.0)];
        let mut rng = Rng::new(61);
        let mut data = Vec::new();
        for &(cx, cy) in &centers {
            for _ in 0..per {
                data.push(cx + rng.next_normal() as f32);
                data.push(cy + rng.next_normal() as f32);
            }
        }
        let pts = Matrix::from_vec(4 * per, 2, data);
        let src = PointsDelta { points: &pts };
        let idx = graph_landmarks(&src, 8, &GraphConfig::default(), 3);
        assert_eq!(idx.len(), 8);
        for c in 0..4 {
            let lo = c * per;
            let hi = lo + per;
            assert!(
                idx.iter().any(|&i| i >= lo && i < hi),
                "cluster {c} got no landmark: {idx:?}"
            );
        }
        let exact = crate::mds::divide::fps_anchors(&src, 8, 3);
        let ratio = fill_distance(&pts, &idx) / fill_distance(&pts, &exact);
        assert!(ratio <= 3.0, "coverage ratio vs exact FPS: {ratio}");
    }
}
