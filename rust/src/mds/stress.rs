//! Stress and error metrics — Eq. 1 (raw/normalised stress), Eq. 4
//! (point error PErr), Eq. 5 (total error Err(m)). These are the quantities
//! every figure in the paper plots, so their definitions live in one place
//! and are unit-tested against hand-computed values.

use crate::strdist::euclidean;
use crate::util::threadpool::{default_parallelism, parallel_for_chunks, SyncSlice};

use super::matrix::Matrix;

/// Row-parallel reduction shared by the exact stress metrics: worker
/// threads each accumulate whole rows `i` (inner `j > i` loop in
/// ascending order, exactly the serial association), write the row sum
/// into its slot, and the final reduction adds the per-row partials in
/// ascending row order — so the result is bit-identical across thread
/// counts and runs, just not to the historical fully-serial association.
/// The triangular row costs are ragged; the dynamic chunk cursor in
/// [`parallel_for_chunks`] balances them.
fn row_parallel_sum(n: usize, per_row: impl Fn(usize) -> f64 + Sync) -> f64 {
    let mut partials = vec![0.0f64; n];
    {
        let slots = SyncSlice::new(&mut partials);
        parallel_for_chunks(n, 8, default_parallelism(), |start, end| {
            for i in start..end {
                // SAFETY: each row index is written exactly once.
                unsafe { slots.write(i, per_row(i)) };
            }
        });
    }
    partials.iter().sum()
}

/// Raw stress (Eq. 1): sum over unordered pairs of (d_ij - delta_ij)^2.
///
/// Row-parallel over the thread pool (the O(L^2) pair sweep costs as
/// much as a divide-and-conquer base solve at L = 10k when run serially)
/// with a deterministic per-row accumulation order — repeated calls are
/// bit-identical regardless of thread count.
pub fn raw_stress(x: &Matrix, delta: &Matrix) -> f64 {
    assert_eq!(x.rows, delta.rows);
    assert_eq!(delta.rows, delta.cols);
    let n = x.rows;
    row_parallel_sum(n, |i| {
        let xi = x.row(i);
        let mut acc = 0.0f64;
        for j in (i + 1)..n {
            let d = euclidean(xi, x.row(j));
            let r = d - delta.at(i, j) as f64;
            acc += r * r;
        }
        acc
    })
}

/// Normalised stress: sqrt(sigma_raw / sum_{i<j} delta_ij^2) (Sec. 2.1).
/// Row-parallel, deterministic (see [`raw_stress`]).
pub fn normalized_stress(x: &Matrix, delta: &Matrix) -> f64 {
    let num = raw_stress(x, delta);
    let n = delta.rows;
    let den = row_parallel_sum(n, |i| {
        let row = delta.row(i);
        let mut acc = 0.0f64;
        for &v in &row[(i + 1)..] {
            let d = v as f64;
            acc += d * d;
        }
        acc
    });
    if den <= 0.0 {
        return 0.0;
    }
    (num / den).sqrt()
}

/// Point error (Eq. 4) for ONE embedded point `y_hat` against all N
/// pre-mapped points: sum_i (delta_iy - ||x_i - y_hat||)^2.
///
/// `delta_to_all[i]` is the original-space dissimilarity from y to point i.
pub fn point_error(config: &Matrix, delta_to_all: &[f32], y_hat: &[f32]) -> f64 {
    assert_eq!(config.rows, delta_to_all.len());
    let mut acc = 0.0f64;
    for i in 0..config.rows {
        let d = euclidean(config.row(i), y_hat);
        let r = delta_to_all[i] as f64 - d;
        acc += r * r;
    }
    acc
}

/// Normalised point error, as plotted in Figs. 2-3: PErr(y) divided by the
/// sum of the dissimilarities from y to the existing points.
pub fn point_error_normalized(
    config: &Matrix,
    delta_to_all: &[f32],
    y_hat: &[f32],
) -> f64 {
    let denom: f64 = delta_to_all.iter().map(|d| *d as f64).sum();
    if denom <= 0.0 {
        return 0.0;
    }
    point_error(config, delta_to_all, y_hat) / denom
}

/// Total error Err(m) (Eq. 5) of embedding m new points:
/// sum_{i,j} (delta_{i y_j} - ||x_i - y_hat_j||)^2 / delta_{i y_j}.
///
/// `delta_new[j][i]`: original dissimilarity from new point j to existing
/// point i (an m x N matrix); `y_hat`: m x K embedded coordinates.
/// Terms with delta == 0 contribute their squared residual un-normalised
/// (the limit of the paper's expression as delta -> 0 is undefined; treating
/// the weight as 1 keeps the metric finite and is how the R code behaves
/// with its data, which has no zero dissimilarities across samples).
pub fn total_error(config: &Matrix, delta_new: &Matrix, y_hat: &Matrix) -> f64 {
    assert_eq!(delta_new.rows, y_hat.rows);
    assert_eq!(delta_new.cols, config.rows);
    let m = y_hat.rows;
    let mut partials = vec![0.0f64; m];
    {
        let slots = SyncSlice::new(&mut partials);
        parallel_for_chunks(m, 4, default_parallelism(), |start, end| {
            for j in start..end {
                let mut acc = 0.0f64;
                for i in 0..config.rows {
                    let d = euclidean(config.row(i), y_hat.row(j));
                    let delta = delta_new.at(j, i) as f64;
                    let r = delta - d;
                    acc += if delta > 0.0 { r * r / delta } else { r * r };
                }
                // SAFETY: column j is written exactly once, by the one
                // chunk owner that covers it.
                unsafe { slots.write(j, acc) };
            }
        });
    }
    partials.iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn square_config() -> Matrix {
        // unit square in R^2
        Matrix::from_rows(&[
            vec![0.0, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 1.0],
        ])
    }

    fn square_delta() -> Matrix {
        let x = square_config();
        let n = x.rows;
        let mut d = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                d.set(i, j, euclidean(x.row(i), x.row(j)) as f32);
            }
        }
        d
    }

    #[test]
    fn perfect_embedding_has_zero_stress() {
        let x = square_config();
        let delta = square_delta();
        assert!(raw_stress(&x, &delta) < 1e-12);
        // delta stores f32 distances: the normalised ratio keeps sqrt of
        // f32 quantisation noise, so ~1e-7 is the practical floor
        assert!(normalized_stress(&x, &delta) < 1e-6);
    }

    #[test]
    fn raw_stress_hand_value() {
        // two points at distance 1, target distance 3 -> (1-3)^2 = 4
        let x = Matrix::from_rows(&[vec![0.0], vec![1.0]]);
        let mut delta = Matrix::zeros(2, 2);
        delta.set(0, 1, 3.0);
        delta.set(1, 0, 3.0);
        assert!((raw_stress(&x, &delta) - 4.0).abs() < 1e-12);
        // normalised: sqrt(4 / 9)
        assert!((normalized_stress(&x, &delta) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn point_error_hand_value() {
        let config = square_config();
        let y_hat = [0.5f32, 0.5];
        // all 4 distances are sqrt(0.5); pretend original deltas were 1.0
        let deltas = [1.0f32; 4];
        let want = 4.0 * (1.0 - 0.5f64.sqrt()).powi(2);
        assert!((point_error(&config, &deltas, &y_hat) - want).abs() < 1e-9);
        let norm = point_error_normalized(&config, &deltas, &y_hat);
        assert!((norm - want / 4.0).abs() < 1e-9);
    }

    #[test]
    fn total_error_reduces_to_weighted_point_errors() {
        let config = square_config();
        let y_hat = Matrix::from_rows(&[vec![0.5, 0.5], vec![2.0, 2.0]]);
        let delta_new = Matrix::from_rows(&[vec![1.0; 4], vec![2.0; 4]]);
        let got = total_error(&config, &delta_new, &y_hat);
        // manual: term = (delta - d)^2 / delta
        let mut want = 0.0f64;
        for j in 0..2 {
            for i in 0..4 {
                let d = euclidean(config.row(i), y_hat.row(j));
                let delta = delta_new.at(j, i) as f64;
                want += (delta - d).powi(2) / delta;
            }
        }
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn total_error_zero_delta_terms_stay_finite() {
        let config = square_config();
        let y_hat = Matrix::from_rows(&[vec![0.0, 0.0]]);
        let delta_new = Matrix::from_rows(&[vec![0.0, 1.0, 1.0, 2.0f32.sqrt()]]);
        let e = total_error(&config, &delta_new, &y_hat);
        assert!(e.is_finite());
        assert!(e < 1e-9); // the embedding is exact here
    }

    #[test]
    fn parallel_stress_matches_serial_oracle_and_is_deterministic() {
        // large enough for several parallel chunks
        let n = 300;
        let mut rng = crate::util::prng::Rng::new(0x57e5);
        let x = Matrix::random_normal(&mut rng, n, 3, 1.0);
        let mut delta = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let d = euclidean(x.row(i), x.row(j)) as f32 * 1.1 + 0.01;
                delta.set(i, j, if i == j { 0.0 } else { d });
            }
        }
        // serial oracle with the same per-row association
        let mut want_raw = 0.0f64;
        let mut want_den = 0.0f64;
        for i in 0..n {
            let mut row_raw = 0.0f64;
            let mut row_den = 0.0f64;
            for j in (i + 1)..n {
                let d = euclidean(x.row(i), x.row(j));
                let r = d - delta.at(i, j) as f64;
                row_raw += r * r;
                let dd = delta.at(i, j) as f64;
                row_den += dd * dd;
            }
            want_raw += row_raw;
            want_den += row_den;
        }
        let got_raw = raw_stress(&x, &delta);
        assert_eq!(got_raw, want_raw, "bit-identical to the row-ordered oracle");
        let got_norm = normalized_stress(&x, &delta);
        assert_eq!(got_norm, (want_raw / want_den).sqrt());
        // repeated runs are bit-identical (thread count must not leak in)
        assert_eq!(raw_stress(&x, &delta), got_raw);
        assert_eq!(normalized_stress(&x, &delta), got_norm);
    }

    #[test]
    fn stress_scales_quadratically_with_residual() {
        let x = Matrix::from_rows(&[vec![0.0], vec![1.0]]);
        let mut d2 = Matrix::zeros(2, 2);
        d2.set(0, 1, 2.0);
        d2.set(1, 0, 2.0);
        let mut d3 = Matrix::zeros(2, 2);
        d3.set(0, 1, 3.0);
        d3.set(1, 0, 3.0);
        let s2 = raw_stress(&x, &d2); // (1-2)^2 = 1
        let s3 = raw_stress(&x, &d3); // (1-3)^2 = 4
        assert!((s3 / s2 - 4.0).abs() < 1e-12);
    }
}
