//! Classical (Torgerson) MDS via double centering + power iteration.
//!
//! Two roles: (a) the baseline family that most prior OSE work targets
//! (Trosset & Priebe, Bengio et al. — Sec. 3 of the paper), against which
//! the LSMDS OSE is contrasted; (b) a cheap, deterministic initialiser for
//! the iterative LSMDS/SMACOF solvers (starting near the classical solution
//! cuts iteration counts substantially — used by the perf pass).
//!
//! Eigendecomposition is a from-scratch power iteration with deflation on
//! the centred Gram matrix B = -1/2 J D^2 J (no LAPACK in the image).

use crate::util::prng::Rng;

use super::matrix::Matrix;

/// Top-k eigenpairs of a symmetric matrix via power iteration + deflation.
/// Returns (eigenvalues, eigenvectors as columns of an n x k matrix).
pub fn symmetric_top_eigs(
    a: &Matrix,
    k: usize,
    iters: usize,
    seed: u64,
) -> (Vec<f64>, Matrix) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let k = k.min(n);
    let mut rng = Rng::new(seed);
    let mut vals = Vec::with_capacity(k);
    let mut vecs = Matrix::zeros(n, k);
    // working copy we deflate in f64
    let mut m: Vec<f64> = a.data.iter().map(|x| *x as f64).collect();

    for kk in 0..k {
        let mut v: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        normalize(&mut v);
        let mut lambda = 0.0f64;
        for _ in 0..iters {
            let mut w = vec![0.0f64; n];
            for i in 0..n {
                let row = &m[i * n..(i + 1) * n];
                let mut acc = 0.0;
                for (j, r) in row.iter().enumerate() {
                    acc += r * v[j];
                }
                w[i] = acc;
            }
            lambda = dot(&w, &v);
            let norm = normalize(&mut w);
            if norm < 1e-15 {
                break;
            }
            v = w;
        }
        vals.push(lambda);
        for i in 0..n {
            vecs.set(i, kk, v[i] as f32);
        }
        // deflate: m -= lambda v v^T
        for i in 0..n {
            for j in 0..n {
                m[i * n + j] -= lambda * v[i] * v[j];
            }
        }
    }
    (vals, vecs)
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn normalize(v: &mut [f64]) -> f64 {
    let n = dot(v, v).sqrt();
    if n > 0.0 {
        v.iter_mut().for_each(|x| *x /= n);
    }
    n
}

/// Classical MDS: embed a dissimilarity matrix into k dimensions.
/// Negative eigenvalues (non-Euclidean input) are clamped to zero, per
/// Torgerson's original prescription.
pub fn classical_mds(delta: &Matrix, k: usize) -> Matrix {
    assert_eq!(delta.rows, delta.cols);
    let n = delta.rows;
    // B = -1/2 J D^2 J, J = I - 11^T/n
    let mut d2 = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let d = delta.at(i, j) as f64;
            d2[i * n + j] = d * d;
        }
    }
    let row_means: Vec<f64> = (0..n)
        .map(|i| d2[i * n..(i + 1) * n].iter().sum::<f64>() / n as f64)
        .collect();
    let grand = row_means.iter().sum::<f64>() / n as f64;
    let mut b = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = -0.5 * (d2[i * n + j] - row_means[i] - row_means[j] + grand);
            b.set(i, j, v as f32);
        }
    }
    let (vals, vecs) = symmetric_top_eigs(&b, k, 200, 0xC1A5);
    let mut out = Matrix::zeros(n, k);
    for (c, lambda) in vals.iter().enumerate() {
        let scale = lambda.max(0.0).sqrt();
        for r in 0..n {
            out.set(r, c, (vecs.at(r, c) as f64 * scale) as f32);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strdist::euclidean;

    #[test]
    fn power_iteration_finds_dominant_eig() {
        // diag(5, 2, 1) with known eigenvectors
        let a = Matrix::from_rows(&[
            vec![5.0, 0.0, 0.0],
            vec![0.0, 2.0, 0.0],
            vec![0.0, 0.0, 1.0],
        ]);
        let (vals, vecs) = symmetric_top_eigs(&a, 2, 300, 1);
        assert!((vals[0] - 5.0).abs() < 1e-6, "{vals:?}");
        assert!((vals[1] - 2.0).abs() < 1e-5, "{vals:?}");
        assert!(vecs.at(0, 0).abs() > 0.999);
        assert!(vecs.at(1, 1).abs() > 0.999);
    }

    #[test]
    fn classical_mds_recovers_euclidean_distances() {
        let mut rng = Rng::new(2);
        let x = Matrix::random_normal(&mut rng, 20, 3, 1.0);
        let n = x.rows;
        let mut delta = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                delta.set(i, j, euclidean(x.row(i), x.row(j)) as f32);
            }
        }
        let y = classical_mds(&delta, 3);
        // distances must be reproduced (configuration is only unique up to
        // rotation/reflection, so compare distance matrices)
        for i in 0..n {
            for j in 0..n {
                let got = euclidean(y.row(i), y.row(j));
                assert!(
                    (got - delta.at(i, j) as f64).abs() < 1e-2,
                    "({i},{j}): {got} vs {}",
                    delta.at(i, j)
                );
            }
        }
    }

    #[test]
    fn classical_mds_handles_non_euclidean_input() {
        // Levenshtein distances are non-Euclidean; classical MDS must not
        // produce NaNs (negative eigenvalues clamp to 0).
        use crate::mds::dissimilarity::full_matrix;
        use crate::strdist::Levenshtein;
        let names = ["anna", "annie", "bob", "robert", "roberta", "bobby"];
        let objs: Vec<&str> = names.to_vec();
        let delta = full_matrix(&objs, &Levenshtein);
        let y = classical_mds(&delta, 3);
        assert!(y.data.iter().all(|v| v.is_finite()));
        // similar names should embed nearer than dissimilar ones
        let close = euclidean(y.row(0), y.row(1)); // anna/annie
        let far = euclidean(y.row(0), y.row(3)); // anna/robert
        assert!(close < far);
    }
}
